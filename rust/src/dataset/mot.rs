//! MOT challenge ground-truth / detection file formats.
//!
//! Ground truth (`gt.txt`) rows are
//! `frame, id, bb_left, bb_top, bb_width, bb_height, conf, class, visibility`
//! and detection files replace `id` with `-1` and carry the detector score
//! in the `conf` column. The paper writes its TOD inferences in this format
//! and pre-processes ground truth by zeroing the consideration flag for
//! classes that are neither pedestrian (1) nor static person (7).
//!
//! Implemented verbatim so a real MOT17Det download drops into the same
//! pipeline as our synthetic sequences.

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use crate::detection::Detection;
use crate::geometry::BBox;

/// MOT17 class labels (subset relevant to MOT17Det).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MotClass {
    Pedestrian,
    PersonOnVehicle,
    Car,
    Bicycle,
    Motorbike,
    NonMotorVehicle,
    StaticPerson,
    Distractor,
    Occluder,
    OccluderOnGround,
    OccluderFull,
    Reflection,
    Other(u32),
}

impl MotClass {
    pub fn from_id(id: u32) -> MotClass {
        match id {
            1 => MotClass::Pedestrian,
            2 => MotClass::PersonOnVehicle,
            3 => MotClass::Car,
            4 => MotClass::Bicycle,
            5 => MotClass::Motorbike,
            6 => MotClass::NonMotorVehicle,
            7 => MotClass::StaticPerson,
            8 => MotClass::Distractor,
            9 => MotClass::Occluder,
            10 => MotClass::OccluderOnGround,
            11 => MotClass::OccluderFull,
            12 => MotClass::Reflection,
            other => MotClass::Other(other),
        }
    }

    pub fn id(self) -> u32 {
        match self {
            MotClass::Pedestrian => 1,
            MotClass::PersonOnVehicle => 2,
            MotClass::Car => 3,
            MotClass::Bicycle => 4,
            MotClass::Motorbike => 5,
            MotClass::NonMotorVehicle => 6,
            MotClass::StaticPerson => 7,
            MotClass::Distractor => 8,
            MotClass::Occluder => 9,
            MotClass::OccluderOnGround => 10,
            MotClass::OccluderFull => 11,
            MotClass::Reflection => 12,
            MotClass::Other(id) => id,
        }
    }

    /// The paper's accuracy evaluation considers pedestrians and static
    /// persons as positive ground truth; everything else is ignored.
    pub fn is_person(self) -> bool {
        matches!(self, MotClass::Pedestrian | MotClass::StaticPerson)
    }
}

/// One ground-truth (or detection) row.
#[derive(Debug, Clone, PartialEq)]
pub struct GtEntry {
    pub frame: u64,
    /// Track id; -1 for detections.
    pub id: i64,
    pub bbox: BBox,
    /// GT: consideration flag (0/1). Detections: confidence score.
    pub conf: f64,
    pub class: MotClass,
    /// Visibility ratio in [0, 1]; -1 when meaningless (detections).
    pub visibility: f64,
}

impl GtEntry {
    /// Parse one CSV row. Accepts both 9-column gt rows and shorter
    /// 7-column det rows (class/visibility defaulting).
    pub fn parse(line: &str) -> Result<GtEntry, String> {
        let fields: Vec<&str> = line.trim().split(',').collect();
        if fields.len() < 7 {
            return Err(format!("mot row needs >= 7 fields: {line:?}"));
        }
        let num = |i: usize| -> Result<f64, String> {
            fields[i]
                .trim()
                .parse::<f64>()
                .map_err(|e| format!("field {i} ({:?}): {e}", fields[i]))
        };
        let frame = num(0)? as u64;
        let id = num(1)? as i64;
        let bbox = BBox::new(num(2)?, num(3)?, num(4)?, num(5)?);
        let conf = num(6)?;
        let class = if fields.len() > 7 {
            let cid = num(7)?;
            if cid < 0.0 {
                MotClass::Pedestrian
            } else {
                MotClass::from_id(cid as u32)
            }
        } else {
            MotClass::Pedestrian
        };
        let visibility = if fields.len() > 8 { num(8)? } else { -1.0 };
        Ok(GtEntry { frame, id, bbox, conf, class, visibility })
    }

    /// Serialize in MOT CSV form.
    pub fn to_line(&self) -> String {
        format!(
            "{},{},{:.2},{:.2},{:.2},{:.2},{},{},{}",
            self.frame,
            self.id,
            self.bbox.x,
            self.bbox.y,
            self.bbox.w,
            self.bbox.h,
            trim_f64(self.conf),
            self.class.id(),
            trim_f64(self.visibility),
        )
    }

    /// The paper's MOT17Det gt preprocessing: zero the consideration flag
    /// when the class is neither pedestrian nor static person.
    pub fn preprocess_for_eval(mut self) -> GtEntry {
        if !self.class.is_person() {
            self.conf = 0.0;
        }
        self
    }

    /// Whether this gt row counts as a positive for AP evaluation.
    pub fn is_considered(&self) -> bool {
        self.conf > 0.0 && self.class.is_person()
    }
}

fn trim_f64(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e12 {
        format!("{}", v as i64)
    } else {
        format!("{v:.6}")
            .trim_end_matches('0')
            .trim_end_matches('.')
            .to_string()
    }
}

/// Parse a whole gt/det file (one row per line, blank lines skipped).
pub fn parse_file_text(text: &str) -> Result<Vec<GtEntry>, String> {
    text.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty())
        .map(GtEntry::parse)
        .collect()
}

/// Read a gt/det file from disk.
pub fn read_file(path: &Path) -> Result<Vec<GtEntry>, String> {
    let f = std::fs::File::open(path)
        .map_err(|e| format!("open {path:?}: {e}"))?;
    let mut out = Vec::new();
    for line in BufReader::new(f).lines() {
        let line = line.map_err(|e| format!("read {path:?}: {e}"))?;
        let t = line.trim();
        if !t.is_empty() {
            out.push(GtEntry::parse(t)?);
        }
    }
    Ok(out)
}

/// Write entries to a gt/det file.
pub fn write_file(path: &Path, entries: &[GtEntry]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    for e in entries {
        writeln!(f, "{}", e.to_line())?;
    }
    Ok(())
}

/// Convert per-frame detections into MOT det rows the way the paper does:
/// id = -1 (detection), score in the conf column, visibility = -1.
pub fn detections_to_entries(
    frame: u64,
    dets: &[Detection],
) -> Vec<GtEntry> {
    dets.iter()
        .map(|d| GtEntry {
            frame,
            id: -1,
            bbox: d.bbox,
            conf: d.score as f64,
            class: MotClass::Pedestrian,
            visibility: -1.0,
        })
        .collect()
}

/// Group entries by frame id into a dense per-frame vector
/// (frames are 1-based; missing frames yield empty vectors).
pub fn group_by_frame(entries: &[GtEntry], n_frames: u64) -> Vec<Vec<GtEntry>> {
    let mut frames: Vec<Vec<GtEntry>> = vec![Vec::new(); n_frames as usize];
    for e in entries {
        if e.frame >= 1 && e.frame <= n_frames {
            frames[(e.frame - 1) as usize].push(e.clone());
        }
    }
    frames
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_paper_example_row() {
        // the paper quotes: 1, -1, 794.2, 47.5, 71.2, 174.8, 1, classID, 0.8
        let e = GtEntry::parse("1,-1,794.2,47.5,71.2,174.8,1,1,0.8").unwrap();
        assert_eq!(e.frame, 1);
        assert_eq!(e.id, -1);
        assert!((e.bbox.x - 794.2).abs() < 1e-9);
        assert!((e.bbox.h - 174.8).abs() < 1e-9);
        assert_eq!(e.conf, 1.0);
        assert_eq!(e.class, MotClass::Pedestrian);
        assert!((e.visibility - 0.8).abs() < 1e-9);
    }

    #[test]
    fn roundtrip_line() {
        let e = GtEntry::parse("17,3,100.5,50.25,30,60,1,7,0.25").unwrap();
        let line = e.to_line();
        let back = GtEntry::parse(&line).unwrap();
        assert_eq!(back, e);
    }

    #[test]
    fn parse_rejects_bad_rows() {
        assert!(GtEntry::parse("1,2,3").is_err());
        assert!(GtEntry::parse("a,b,c,d,e,f,g").is_err());
        assert!(GtEntry::parse("").is_err());
    }

    #[test]
    fn short_det_row_defaults() {
        let e = GtEntry::parse("3,-1,10,20,30,40,0.9").unwrap();
        assert_eq!(e.class, MotClass::Pedestrian);
        assert_eq!(e.visibility, -1.0);
        assert!((e.conf - 0.9).abs() < 1e-12);
    }

    #[test]
    fn preprocess_zeroes_non_person() {
        let car = GtEntry::parse("1,1,0,0,10,10,1,3,1").unwrap();
        let ped = GtEntry::parse("1,2,0,0,10,10,1,1,1").unwrap();
        let stat = GtEntry::parse("1,3,0,0,10,10,1,7,1").unwrap();
        assert!(!car.clone().preprocess_for_eval().is_considered());
        assert!(ped.clone().preprocess_for_eval().is_considered());
        assert!(stat.clone().preprocess_for_eval().is_considered());
        // flag already 0 stays unconsidered even for pedestrians
        let off = GtEntry::parse("1,4,0,0,10,10,0,1,1").unwrap();
        assert!(!off.preprocess_for_eval().is_considered());
    }

    #[test]
    fn class_table_roundtrip() {
        for id in 1..=12 {
            assert_eq!(MotClass::from_id(id).id(), id);
        }
        assert_eq!(MotClass::from_id(99), MotClass::Other(99));
        assert!(MotClass::Pedestrian.is_person());
        assert!(MotClass::StaticPerson.is_person());
        assert!(!MotClass::Car.is_person());
    }

    #[test]
    fn group_by_frame_dense() {
        let entries = vec![
            GtEntry::parse("2,1,0,0,10,10,1,1,1").unwrap(),
            GtEntry::parse("2,2,0,0,10,10,1,1,1").unwrap(),
            GtEntry::parse("4,3,0,0,10,10,1,1,1").unwrap(),
            GtEntry::parse("9,9,0,0,10,10,1,1,1").unwrap(), // out of range
        ];
        let frames = group_by_frame(&entries, 5);
        assert_eq!(frames.len(), 5);
        assert_eq!(frames[0].len(), 0);
        assert_eq!(frames[1].len(), 2);
        assert_eq!(frames[3].len(), 1);
    }

    #[test]
    fn detections_to_entries_matches_paper_format() {
        let dets = vec![Detection::new(
            BBox::new(794.2, 47.5, 71.2, 174.8),
            0.8,
            crate::detection::PERSON_CLASS,
        )];
        let rows = detections_to_entries(1, &dets);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].id, -1);
        assert_eq!(rows[0].visibility, -1.0);
        assert!(rows[0].to_line().starts_with("1,-1,794.2"));
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("tod_mot_test");
        let path = dir.join("gt.txt");
        let entries = vec![
            GtEntry::parse("1,1,10,20,30,40,1,1,0.9").unwrap(),
            GtEntry::parse("2,1,12,22,30,40,1,1,0.8").unwrap(),
        ];
        write_file(&path, &entries).unwrap();
        let back = read_file(&path).unwrap();
        assert_eq!(back, entries);
        std::fs::remove_dir_all(&dir).ok();
    }
}
