//! Property-testing mini-harness (proptest stand-in; DESIGN.md §3).

pub mod prop;

pub use prop::{Gen, PropConfig};
