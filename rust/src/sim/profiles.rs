//! Capacity/latency/telemetry profiles for the four DNN operating points.
//!
//! Calibration sources (all from the paper's §IV on a Jetson Nano in MAX
//! power mode, TensorRT FP16):
//! * latency: Fig. 5 — only YOLOv4-tiny-288 meets the 33 ms 30-FPS budget;
//! * accuracy vs object size: Fig. 4 ordering (Y-416 best everywhere,
//!   tiny-288 worst) plus the speed/accuracy findings of Huang et al. [6]
//!   that lightweight detectors match heavyweight ones on *large* objects;
//! * power: Fig. 14 — 3.8 / 4.8 / 7.2 / 7.5 W;
//! * GPU utilisation: §IV.D — 84% (Y-288) and 91% (Y-416) while running;
//! * memory: Fig. 11 — 2.21 / 2.21 / 2.22 / 2.56 GB single-model,
//!   2.85 GB with all four loaded, 1.5 GB baseline.

use crate::DnnKind;

/// Behavioural profile of one DNN variant on the simulated Jetson Nano.
#[derive(Debug, Clone)]
pub struct DnnProfile {
    pub kind: DnnKind,
    /// Mean inference latency, seconds (Fig. 5 calibration).
    pub latency_mean_s: f64,
    /// Latency jitter (lognormal-ish std as a fraction of the mean).
    pub latency_jitter: f64,
    /// Object area fraction at which detection probability is 50%.
    /// Smaller = better small-object detection.
    pub s50_area_frac: f64,
    /// Logistic width of the detectability curve (in log10 area units).
    pub det_width: f64,
    /// Detection probability ceiling for large, fully visible objects.
    pub p_max: f64,
    /// Localisation noise: box center/size std as a fraction of box size.
    pub loc_noise: f64,
    /// Expected false positives per frame.
    pub fp_rate: f64,
    /// Mean confidence score for true detections (capacity-dependent).
    pub score_mean: f64,
    /// Board power while this DNN is executing, watts (Fig. 14).
    pub power_active_w: f64,
    /// GPU utilisation while executing, percent (§IV.D).
    pub gpu_util_pct: f64,
    /// Resident weight/engine memory, GB (Fig. 11 decomposition).
    pub mem_weights_gb: f64,
    /// Peak activation workspace while executing, GB.
    pub mem_workspace_gb: f64,
}

/// Idle board power (screen/SoC baseline between inferences), watts.
pub const POWER_IDLE_W: f64 = 2.6;

/// GPU utilisation when no inference is in flight, percent.
pub const GPU_IDLE_PCT: f64 = 4.0;

/// Memory allocated before any DNN is loaded (paper: "1.5 GB initially").
pub const MEM_BASE_GB: f64 = 1.5;

impl DnnProfile {
    /// The calibrated profile for a variant.
    pub fn of(kind: DnnKind) -> DnnProfile {
        match kind {
            DnnKind::TinyY288 => DnnProfile {
                kind,
                latency_mean_s: 0.0270,
                latency_jitter: 0.04,
                s50_area_frac: 0.0035,
                det_width: 0.35,
                p_max: 0.95,
                loc_noise: 0.060,
                fp_rate: 0.9,
                score_mean: 0.62,
                power_active_w: 3.8,
                gpu_util_pct: 38.0,
                mem_weights_gb: 0.05,
                mem_workspace_gb: 0.66,
            },
            DnnKind::TinyY416 => DnnProfile {
                kind,
                latency_mean_s: 0.0510,
                latency_jitter: 0.04,
                s50_area_frac: 0.0015,
                det_width: 0.35,
                p_max: 0.96,
                loc_noise: 0.050,
                fp_rate: 0.7,
                score_mean: 0.66,
                power_active_w: 4.8,
                gpu_util_pct: 55.0,
                mem_weights_gb: 0.07,
                mem_workspace_gb: 0.64,
            },
            DnnKind::Y288 => DnnProfile {
                kind,
                latency_mean_s: 0.0920,
                latency_jitter: 0.05,
                s50_area_frac: 0.0009,
                det_width: 0.40,
                p_max: 0.97,
                loc_noise: 0.038,
                fp_rate: 0.5,
                score_mean: 0.70,
                power_active_w: 7.2,
                gpu_util_pct: 84.0,
                mem_weights_gb: 0.12,
                mem_workspace_gb: 0.60,
            },
            DnnKind::Y416 => DnnProfile {
                kind,
                latency_mean_s: 0.1530,
                latency_jitter: 0.05,
                s50_area_frac: 0.0004,
                det_width: 0.40,
                p_max: 0.98,
                loc_noise: 0.030,
                fp_rate: 0.4,
                score_mean: 0.72,
                power_active_w: 7.5,
                gpu_util_pct: 91.0,
                mem_weights_gb: 0.21,
                mem_workspace_gb: 0.85,
            },
        }
    }

    /// All four profiles, lightest first.
    pub fn all() -> Vec<DnnProfile> {
        DnnKind::ALL.iter().map(|&k| DnnProfile::of(k)).collect()
    }

    /// Probability of detecting a fully visible object whose box covers
    /// `area_frac` of the frame: a logistic in log10(area) centred on
    /// `s50_area_frac`. Large objects saturate at `p_max` for every
    /// variant — the Huang et al. [6] observation TOD exploits.
    pub fn detect_prob(&self, area_frac: f64) -> f64 {
        if area_frac <= 0.0 {
            return 0.0;
        }
        let z = (area_frac.log10() - self.s50_area_frac.log10())
            / self.det_width;
        self.p_max / (1.0 + (-z).exp())
    }

    /// Single-model resident memory, GB (paper Fig. 11).
    pub fn mem_single_gb(&self) -> f64 {
        MEM_BASE_GB + self.mem_weights_gb + self.mem_workspace_gb
    }
}

/// Memory with a set of DNNs preloaded: weights are resident per model,
/// the activation workspace is shared (sized by the largest) — this is
/// what makes TOD's "load all four" only ~11% more than Y-416 alone.
pub fn mem_loaded_gb(kinds: &[DnnKind]) -> f64 {
    let mut weights = 0.0;
    let mut ws: f64 = 0.0;
    for &k in kinds {
        let p = DnnProfile::of(k);
        weights += p.mem_weights_gb;
        ws = ws.max(p.mem_workspace_gb);
    }
    MEM_BASE_GB + weights + ws
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_ordering_matches_fig5() {
        let p: Vec<f64> = DnnProfile::all()
            .iter()
            .map(|p| p.latency_mean_s)
            .collect();
        assert!(p.windows(2).all(|w| w[0] < w[1]), "latency must increase");
        // only tiny-288 meets the 30-FPS budget (Fig. 5 finding)
        assert!(p[0] < 1.0 / 30.0);
        for v in &p[1..] {
            assert!(*v > 1.0 / 30.0);
        }
        // tiny-288 and tiny-416 both meet MOT17-05's 14 FPS budget
        assert!(p[1] < 1.0 / 14.0);
        assert!(p[2] > 1.0 / 14.0);
    }

    #[test]
    fn detectability_ordering_heavier_is_better_on_small() {
        let small = 0.001;
        let probs: Vec<f64> = DnnProfile::all()
            .iter()
            .map(|p| p.detect_prob(small))
            .collect();
        assert!(
            probs.windows(2).all(|w| w[0] < w[1]),
            "heavier nets must see small objects better: {probs:?}"
        );
    }

    #[test]
    fn large_objects_equalise_capacity() {
        // Huang et al. [6]: on large objects light ≈ heavy
        let large = 0.08;
        let probs: Vec<f64> = DnnProfile::all()
            .iter()
            .map(|p| p.detect_prob(large))
            .collect();
        let spread = probs.iter().cloned().fold(0.0f64, f64::max)
            - probs.iter().cloned().fold(1.0f64, f64::min);
        assert!(spread < 0.12, "large-object spread {spread}: {probs:?}");
        for p in probs {
            assert!(p > 0.85);
        }
        // contrast: the small-object gap is far larger than this spread
        let small_gap = DnnProfile::of(DnnKind::Y416).detect_prob(0.001)
            - DnnProfile::of(DnnKind::TinyY288).detect_prob(0.001);
        assert!(small_gap > 2.0 * spread);
    }

    #[test]
    fn detect_prob_is_monotone_in_size() {
        for p in DnnProfile::all() {
            let mut prev = 0.0;
            for e in -40..-4 {
                let a = 10f64.powf(e as f64 / 10.0);
                let v = p.detect_prob(a);
                assert!(v >= prev);
                prev = v;
            }
            assert_eq!(p.detect_prob(0.0), 0.0);
            assert_eq!(p.detect_prob(-1.0), 0.0);
        }
    }

    #[test]
    fn s50_is_the_halfway_point() {
        for p in DnnProfile::all() {
            let v = p.detect_prob(p.s50_area_frac);
            assert!((v - p.p_max / 2.0).abs() < 1e-9);
        }
    }

    #[test]
    fn power_matches_fig14() {
        let p = DnnProfile::all();
        assert_eq!(p[0].power_active_w, 3.8);
        assert_eq!(p[1].power_active_w, 4.8);
        assert_eq!(p[2].power_active_w, 7.2);
        assert_eq!(p[3].power_active_w, 7.5);
        assert!(POWER_IDLE_W < p[0].power_active_w);
    }

    #[test]
    fn memory_matches_fig11() {
        // singles: 2.21, 2.21, 2.22, 2.56 GB (±0.03); all four ≈ 2.85 GB
        let singles: Vec<f64> = DnnProfile::all()
            .iter()
            .map(|p| p.mem_single_gb())
            .collect();
        let expect = [2.21, 2.21, 2.22, 2.56];
        for (got, want) in singles.iter().zip(expect) {
            assert!((got - want).abs() < 0.03, "{got} vs {want}");
        }
        let all = mem_loaded_gb(&DnnKind::ALL);
        assert!((all - 2.85).abs() < 0.08, "all-loaded {all}");
        // paper: TOD needs ~11% more than single Y-416
        let ratio = all / singles[3];
        assert!(ratio > 1.05 && ratio < 1.20, "ratio {ratio}");
    }

    #[test]
    fn gpu_util_matches_paper() {
        assert_eq!(DnnProfile::of(DnnKind::Y288).gpu_util_pct, 84.0);
        assert_eq!(DnnProfile::of(DnnKind::Y416).gpu_util_pct, 91.0);
    }
}
