//! Frame sources: iteration over a sequence's frames with arrival
//! timestamps, decoupling schedulers from where frames come from
//! (synthetic world, MOT files on disk, or a live rasterized stream).

use crate::dataset::mot::GtEntry;
use crate::dataset::synth::Sequence;
use crate::video::clock::FrameClock;

/// One frame presented to a scheduler.
#[derive(Debug, Clone)]
pub struct Frame<'a> {
    /// 1-based frame id.
    pub id: u64,
    /// Arrival timestamp under the evaluation FPS.
    pub t_arrival: f64,
    /// Ground truth rows (empty when streaming without gt).
    pub gt: &'a [GtEntry],
}

/// A pull-based source of frames at a fixed evaluation FPS.
pub struct FrameSource<'a> {
    seq: &'a Sequence,
    clock: FrameClock,
    next: u64,
}

impl<'a> FrameSource<'a> {
    /// Stream a sequence at the given evaluation FPS (which may differ
    /// from the capture FPS — the paper evaluates MOT17-05 at its native
    /// 14 FPS and everything else at 30).
    pub fn new(seq: &'a Sequence, eval_fps: f64) -> Self {
        FrameSource { seq, clock: FrameClock::new(eval_fps), next: 1 }
    }

    pub fn clock(&self) -> FrameClock {
        self.clock
    }

    pub fn n_frames(&self) -> u64 {
        self.seq.n_frames()
    }

    pub fn frame_size(&self) -> (f64, f64) {
        (self.seq.spec.width as f64, self.seq.spec.height as f64)
    }
}

impl<'a> Iterator for FrameSource<'a> {
    type Item = Frame<'a>;

    fn next(&mut self) -> Option<Frame<'a>> {
        if self.next > self.seq.n_frames() {
            return None;
        }
        let id = self.next;
        self.next += 1;
        Some(Frame {
            id,
            t_arrival: self.clock.arrival(id),
            gt: self.seq.gt(id),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth::{CameraMotion, SequenceSpec};

    fn tiny_seq() -> Sequence {
        Sequence::generate(SequenceSpec {
            name: "T".into(),
            width: 320,
            height: 240,
            fps: 30.0,
            frames: 10,
            density: 3,
            ref_height: 80.0,
            depth_range: (1.0, 2.0),
            walk_speed: 1.0,
            camera: CameraMotion::Static,
            seed: 1,
        })
    }

    #[test]
    fn yields_all_frames_in_order() {
        let seq = tiny_seq();
        let src = FrameSource::new(&seq, 30.0);
        let ids: Vec<u64> = src.map(|f| f.id).collect();
        assert_eq!(ids, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn arrival_times_use_eval_fps() {
        let seq = tiny_seq();
        let src = FrameSource::new(&seq, 14.0);
        let frames: Vec<_> = src.collect();
        assert!((frames[0].t_arrival - 1.0 / 14.0).abs() < 1e-12);
        assert!((frames[9].t_arrival - 10.0 / 14.0).abs() < 1e-12);
    }

    #[test]
    fn gt_is_attached() {
        let seq = tiny_seq();
        let src = FrameSource::new(&seq, 30.0);
        for f in src {
            assert_eq!(seq.gt(f.id).len(), f.gt.len());
        }
    }
}
