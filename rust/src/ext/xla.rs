//! Stub of the `xla` crate's PJRT surface (the subset
//! `runtime/{engine,pool}.rs` uses).
//!
//! Containers without `xla_extension` still build the full serving
//! path; every entry point that would touch the PJRT C API returns
//! [`Error`] instead. [`PjRtClient::cpu`] is the single choke point —
//! it fails first, so the downstream methods on [`Literal`],
//! [`PjRtBuffer`] and [`PjRtLoadedExecutable`] are unreachable at
//! runtime but keep the real crate's shapes so swapping the genuine
//! bindings back in is purely a dependency change.

use std::fmt;

pub const UNAVAILABLE: &str = "PJRT backend not built: this binary was compiled with the in-crate \
     `xla` stub (src/ext/xla.rs). Link the real `xla` crate / \
     xla_extension to serve compiled detector variants";

/// Error type standing in for `xla::Error`.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error(UNAVAILABLE.to_string())
}

pub type Result<T> = std::result::Result<T, Error>;

/// Stand-in for `xla::PjRtClient`.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    /// The real constructor dlopens the PJRT CPU plugin; the stub fails
    /// here so nothing downstream can be reached with a live client.
    pub fn cpu() -> Result<Self> {
        Err(unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Stand-in for `xla::HloModuleProto`.
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(unavailable())
    }
}

/// Stand-in for `xla::XlaComputation`.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _priv: () }
    }
}

/// Stand-in for `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// Stand-in for `xla::PjRtBuffer`.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Stand-in for `xla::Literal` (host tensor).
pub struct Literal {
    _priv: (),
}

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal { _priv: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

/// Stand-in for `xla::ArrayShape`.
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_constructor_fails_with_explanation() {
        let err = match PjRtClient::cpu() {
            Err(e) => e,
            Ok(_) => panic!("stub client must not construct"),
        };
        assert!(err.to_string().contains("stub"));
    }

    #[test]
    fn stub_error_converts_into_anyhow() {
        fn load() -> crate::ext::anyhow::Result<PjRtClient> {
            Ok(PjRtClient::cpu()?)
        }
        let err = load().unwrap_err();
        assert!(err.to_string().contains("PJRT backend not built"));
    }
}
