//! The scenario matrix, end to end: replay every curated edge workload
//! under adaptive and fixed selection and print the differential the
//! conformance suite pins — adaptive never loses to the best fixed DNN,
//! on any scenario.
//!
//! Uses the free ladder-shaped calibration table so the example runs in
//! seconds; `tod figures --id scenario` (and the goldens under
//! `rust/tests/goldens/`) use the fully calibrated table instead.
//!
//! ```bash
//! cargo run --release --example scenario_matrix
//! ```

use tod::coordinator::policy::Thresholds;
use tod::predictor::CalibrationTable;
use tod::scenario::{
    run_scenario, scenario_spec, HarnessConfig, RunRecord, ScenarioId,
};
use tod::DnnKind;

fn main() {
    let table =
        CalibrationTable::from_ladder(&Thresholds::h_opt(), &DnnKind::ALL);

    println!(
        "{:<16} {:>5} {:>8} {:>9} {:>9} {:>8} {:>8}",
        "scenario", "strm", "frames", "tod AP", "best fix", "margin", "drop%"
    );
    for id in ScenarioId::ALL {
        let spec = scenario_spec(id);
        let streams = spec.compile().expect("matrix scenarios compile");

        // adaptive: the ladder projected through a calibration surface
        let adaptive = run_scenario(
            &spec.name,
            &streams,
            &HarnessConfig::projected(table.clone()),
        )
        .expect("replay");
        let record = RunRecord::from_run(&adaptive, spec.seed);

        // the four fixed baselines
        let mut best_fixed = f64::NEG_INFINITY;
        let mut best_label = DnnKind::TinyY288;
        for k in DnnKind::ALL {
            let run = run_scenario(
                &spec.name,
                &streams,
                &HarnessConfig::fixed(k),
            )
            .expect("replay");
            if run.mean_ap() > best_fixed {
                best_fixed = run.mean_ap();
                best_label = k;
            }
        }

        let a = &record.aggregate;
        println!(
            "{:<16} {:>5} {:>8} {:>9.3} {:>9.3} {:>+8.3} {:>7.1}%  (best: {})",
            record.scenario,
            record.streams.len(),
            a.frames,
            a.mean_ap,
            best_fixed,
            a.mean_ap - best_fixed,
            if a.frames == 0 {
                0.0
            } else {
                a.dropped as f64 / a.frames as f64 * 100.0
            },
            best_label.short_label(),
        );

        // phase story for the first stream: where the selection moved
        let s = &record.streams[0];
        let phase_story: Vec<String> = s
            .phases
            .iter()
            .map(|p| {
                let top = DnnKind::ALL
                    .iter()
                    .max_by_key(|d| p.deploy[d.index()])
                    .expect("four variants");
                format!("{}->{}", p.label, top.short_label())
            })
            .collect();
        println!("{:<16} {}", "", phase_story.join("  "));
    }
    println!(
        "\n(each scenario shifts regime mid-run; the margin column is \
         what `tod scenario check` pins per scenario in the goldens)"
    );
}
