//! Tiny command-line argument parser (clap stand-in; DESIGN.md §3).

pub mod args;

pub use args::Args;
