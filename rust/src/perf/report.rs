//! Versioned bench reports (`BENCH_<n>.json`) and the regression gate.
//!
//! A report is a flat list of named cases, each with timing percentiles
//! (from [`crate::bench::Bench`]) and an allocs/op figure (from
//! [`crate::perf::alloc`]). Reports serialise through [`crate::util::json`]
//! so the on-disk form is deterministic (sorted keys, stable float
//! formatting) and diffs cleanly between PRs.
//!
//! ## Bootstrap semantics
//!
//! A committed baseline may carry `null` metrics for some or all cases.
//! Such entries are *record-only*: they pin the suite's shape (every
//! baseline case must still exist in the current run) without gating its
//! numbers — the state a baseline is in when it was authored on a machine
//! without a toolchain, or when a new case has not had numbers pinned
//! yet. Once a case has real numbers committed, [`diff`](BenchReport::diff)
//! gates it: `min_ns` may not regress by more than the tolerance
//! (default [`DEFAULT_TOLERANCE`] = 15%), and `allocs_per_op` may not
//! increase at all (allocation counts are deterministic, so any increase
//! is a real regression, not noise). `min_ns` is the gated statistic
//! because the minimum over hundreds of iterations is far more stable
//! than the mean on shared CI runners.

use std::io;
use std::path::Path;

use crate::util::json::Json;

/// Schema identifier written into every report.
pub const BENCH_SCHEMA: &str = "tod-bench";

/// Schema version (bump when the case format changes shape).
pub const BENCH_SCHEMA_VERSION: u32 = 1;

/// Default regression tolerance on `min_ns` (fractional: 0.15 = 15%).
pub const DEFAULT_TOLERANCE: f64 = 0.15;

/// One measured bench case. `None` metrics mean "not pinned" (see the
/// module docs on bootstrap semantics).
#[derive(Debug, Clone, PartialEq)]
pub struct CaseReport {
    pub name: String,
    pub iters: u64,
    pub mean_ns: Option<f64>,
    pub p50_ns: Option<f64>,
    pub min_ns: Option<f64>,
    pub allocs_per_op: Option<f64>,
    /// Operations per second derived from `mean_ns`.
    pub ops_per_s: Option<f64>,
}

impl CaseReport {
    /// A record-only placeholder (all metrics unpinned).
    pub fn unpinned(name: &str) -> Self {
        CaseReport {
            name: name.to_string(),
            iters: 0,
            mean_ns: None,
            p50_ns: None,
            min_ns: None,
            allocs_per_op: None,
            ops_per_s: None,
        }
    }
}

/// A full suite run: schema header plus one entry per case.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchReport {
    /// Report generation in the repo's `BENCH_<n>.json` trajectory.
    pub generation: u32,
    /// `"quick"` or `"full"` (target time per case).
    pub mode: String,
    /// Free-form provenance of the run (reference machine, toolchain,
    /// pinning protocol). Never compared by [`diff`](Self::diff) — it
    /// exists so a committed baseline says where its numbers came from.
    pub comment: Option<String>,
    pub cases: Vec<CaseReport>,
}

fn opt_num(v: Option<f64>) -> Json {
    match v {
        Some(x) if x.is_finite() => Json::num(x),
        _ => Json::Null,
    }
}

fn read_opt_num(j: &Json, key: &str) -> Option<f64> {
    j.get(key).and_then(Json::as_f64)
}

impl BenchReport {
    pub fn to_json(&self) -> Json {
        let cases = self.cases.iter().map(|c| {
            Json::obj(vec![
                ("name", Json::str(&c.name)),
                ("iters", Json::num(c.iters as f64)),
                ("mean_ns", opt_num(c.mean_ns)),
                ("p50_ns", opt_num(c.p50_ns)),
                ("min_ns", opt_num(c.min_ns)),
                ("allocs_per_op", opt_num(c.allocs_per_op)),
                ("ops_per_s", opt_num(c.ops_per_s)),
            ])
        });
        let mut fields = vec![
            ("schema", Json::str(BENCH_SCHEMA)),
            ("schema_version", Json::num(BENCH_SCHEMA_VERSION as f64)),
            ("generation", Json::num(self.generation as f64)),
            ("mode", Json::str(&self.mode)),
            ("cases", Json::arr(cases)),
        ];
        if let Some(c) = &self.comment {
            fields.push(("comment", Json::str(c)));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        let schema = j
            .get("schema")
            .and_then(Json::as_str)
            .ok_or("missing \"schema\"")?;
        if schema != BENCH_SCHEMA {
            return Err(format!("unknown schema {schema:?}"));
        }
        let version = j
            .get("schema_version")
            .and_then(Json::as_usize)
            .ok_or("missing \"schema_version\"")?;
        if version as u32 > BENCH_SCHEMA_VERSION {
            return Err(format!(
                "schema version {version} is newer than this binary \
                 ({BENCH_SCHEMA_VERSION})"
            ));
        }
        let generation = j
            .get("generation")
            .and_then(Json::as_usize)
            .ok_or("missing \"generation\"")? as u32;
        let mode = j
            .get("mode")
            .and_then(Json::as_str)
            .unwrap_or("full")
            .to_string();
        let comment = j
            .get("comment")
            .and_then(Json::as_str)
            .map(str::to_string);
        let raw = j
            .get("cases")
            .and_then(Json::as_arr)
            .ok_or("missing \"cases\" array")?;
        let mut cases = Vec::with_capacity(raw.len());
        for c in raw {
            let name = c
                .get("name")
                .and_then(Json::as_str)
                .ok_or("case missing \"name\"")?
                .to_string();
            cases.push(CaseReport {
                name,
                iters: c
                    .get("iters")
                    .and_then(Json::as_usize)
                    .unwrap_or(0) as u64,
                mean_ns: read_opt_num(c, "mean_ns"),
                p50_ns: read_opt_num(c, "p50_ns"),
                min_ns: read_opt_num(c, "min_ns"),
                allocs_per_op: read_opt_num(c, "allocs_per_op"),
                ops_per_s: read_opt_num(c, "ops_per_s"),
            });
        }
        Ok(BenchReport { generation, mode, comment, cases })
    }

    pub fn save(&self, path: &Path) -> io::Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_json().to_pretty())
    }

    pub fn load(path: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| format!("parse {}: {e}", path.display()))?;
        Self::from_json(&j)
    }

    fn case(&self, name: &str) -> Option<&CaseReport> {
        self.cases.iter().find(|c| c.name == name)
    }

    /// Compare `self` (the current run) against a committed baseline.
    pub fn diff(&self, baseline: &BenchReport, tolerance: f64) -> BenchDiff {
        let mut d = BenchDiff::default();
        for base in &baseline.cases {
            let Some(cur) = self.case(&base.name) else {
                d.missing.push(base.name.clone());
                continue;
            };
            let pinned_time = base.min_ns.is_some();
            let pinned_allocs = base.allocs_per_op.is_some();
            if !pinned_time && !pinned_allocs {
                d.unpinned.push(base.name.clone());
                continue;
            }
            if let (Some(b), Some(c)) = (base.min_ns, cur.min_ns) {
                d.lines.push(DiffLine {
                    name: base.name.clone(),
                    metric: "min_ns",
                    base: b,
                    cur: c,
                    regressed: c > b * (1.0 + tolerance),
                });
            } else if pinned_time {
                // pinned in the baseline but absent from the run
                d.missing.push(format!("{} (min_ns)", base.name));
            }
            if let (Some(b), Some(c)) = (base.allocs_per_op, cur.allocs_per_op)
            {
                d.lines.push(DiffLine {
                    name: base.name.clone(),
                    metric: "allocs_per_op",
                    base: b,
                    cur: c,
                    // allocation counts are deterministic: no tolerance
                    regressed: c > b,
                });
            } else if pinned_allocs {
                d.missing.push(format!("{} (allocs_per_op)", base.name));
            }
        }
        for cur in &self.cases {
            if baseline.case(&cur.name).is_none() {
                d.new_cases.push(cur.name.clone());
            }
        }
        d
    }
}

/// One gated metric comparison.
#[derive(Debug, Clone)]
pub struct DiffLine {
    pub name: String,
    pub metric: &'static str,
    pub base: f64,
    pub cur: f64,
    pub regressed: bool,
}

/// Outcome of a baseline diff; `is_regression()` drives the CI exit code.
#[derive(Debug, Clone, Default)]
pub struct BenchDiff {
    pub lines: Vec<DiffLine>,
    /// Baseline cases with no pinned metrics (record-only).
    pub unpinned: Vec<String>,
    /// Baseline cases (or pinned metrics) absent from the current run.
    pub missing: Vec<String>,
    /// Current cases the baseline does not know about.
    pub new_cases: Vec<String>,
}

impl BenchDiff {
    /// True when any pinned metric regressed or a baseline case vanished.
    pub fn is_regression(&self) -> bool {
        !self.missing.is_empty() || self.lines.iter().any(|l| l.regressed)
    }

    /// Human-readable summary (one line per comparison).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for l in &self.lines {
            let delta = if l.base > 0.0 {
                (l.cur / l.base - 1.0) * 100.0
            } else {
                f64::INFINITY
            };
            out.push_str(&format!(
                "{} {:<34} {:>13}: {:>12.1} -> {:>12.1}  ({:+.1}%)\n",
                if l.regressed { "FAIL" } else { " ok " },
                l.name,
                l.metric,
                l.base,
                l.cur,
                delta,
            ));
        }
        for n in &self.unpinned {
            out.push_str(&format!("note {n:<34} baseline unpinned (record-only)\n"));
        }
        for n in &self.missing {
            out.push_str(&format!("FAIL {n:<34} missing from current run\n"));
        }
        for n in &self.new_cases {
            out.push_str(&format!("note {n:<34} new case (not in baseline)\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn case(name: &str, min_ns: f64, allocs: f64) -> CaseReport {
        CaseReport {
            name: name.to_string(),
            iters: 100,
            mean_ns: Some(min_ns * 1.1),
            p50_ns: Some(min_ns * 1.05),
            min_ns: Some(min_ns),
            allocs_per_op: Some(allocs),
            ops_per_s: Some(1e9 / (min_ns * 1.1)),
        }
    }

    fn report(cases: Vec<CaseReport>) -> BenchReport {
        BenchReport {
            generation: 6,
            mode: "full".to_string(),
            comment: None,
            cases,
        }
    }

    #[test]
    fn roundtrips_through_json() {
        let r = report(vec![case("nms/dense", 1234.5, 0.0)]);
        let j = r.to_json();
        let back = BenchReport::from_json(&j).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn comment_roundtrips_and_is_optional() {
        // a baseline without the key (every pre-provenance BENCH_<n>)
        // parses as None; a comment survives the round trip verbatim
        let bare = report(vec![]);
        let parsed = BenchReport::from_json(&bare.to_json()).unwrap();
        assert_eq!(parsed.comment, None);
        assert!(!bare.to_json().to_pretty().contains("comment"));

        let mut with = report(vec![]);
        with.comment = Some("ref machine: jetson-nano, rustc 1.79".into());
        let back = BenchReport::from_json(&with.to_json()).unwrap();
        assert_eq!(back.comment, with.comment);
    }

    #[test]
    fn null_metrics_roundtrip_as_unpinned() {
        let r = report(vec![CaseReport::unpinned("step/session")]);
        let text = r.to_json().to_pretty();
        assert!(text.contains("\"min_ns\": null"));
        let back =
            BenchReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.cases[0].min_ns, None);
    }

    #[test]
    fn within_tolerance_passes() {
        let base = report(vec![case("a", 1000.0, 2.0)]);
        let cur = report(vec![case("a", 1100.0, 2.0)]);
        let d = cur.diff(&base, 0.15);
        assert!(!d.is_regression(), "{}", d.render());
    }

    #[test]
    fn slow_regression_fails() {
        let base = report(vec![case("a", 1000.0, 2.0)]);
        let cur = report(vec![case("a", 1200.0, 2.0)]);
        let d = cur.diff(&base, 0.15);
        assert!(d.is_regression());
        assert!(d.render().contains("FAIL"));
    }

    #[test]
    fn alloc_increase_fails_without_tolerance() {
        let base = report(vec![case("a", 1000.0, 0.0)]);
        let mut faster = case("a", 500.0, 1.0);
        faster.allocs_per_op = Some(1.0);
        let cur = report(vec![faster]);
        let d = cur.diff(&base, 0.15);
        assert!(d.is_regression(), "one new alloc/op must gate");
    }

    #[test]
    fn unpinned_baseline_records_only() {
        let base = report(vec![
            CaseReport::unpinned("a"),
            CaseReport::unpinned("b"),
        ]);
        let cur = report(vec![case("a", 999.0, 3.0), case("b", 1.0, 0.0)]);
        let d = cur.diff(&base, 0.15);
        assert!(!d.is_regression());
        assert_eq!(d.unpinned.len(), 2);
    }

    #[test]
    fn missing_case_fails_even_when_unpinned_elsewhere() {
        let base = report(vec![case("a", 1000.0, 0.0)]);
        let cur = report(vec![case("other", 10.0, 0.0)]);
        let d = cur.diff(&base, 0.15);
        assert!(d.is_regression());
        assert_eq!(d.missing, vec!["a".to_string()]);
        assert_eq!(d.new_cases, vec!["other".to_string()]);
    }

    #[test]
    fn newer_schema_is_rejected() {
        let mut j = report(vec![]).to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("schema_version".to_string(), Json::num(999.0));
        }
        assert!(BenchReport::from_json(&j).is_err());
    }
}
