//! Steady-state allocation discipline of the per-frame hot path.
//!
//! `StreamSession::step` carries reusable buffers (detection scratch,
//! carried detections, greedy-matching scratch, feature-extractor
//! scratch) plus run-long accumulators pre-sized in `StreamSession::new`.
//! Once every elastic buffer has grown to fit the largest frame it has
//! seen, a step must not touch the allocator at all — that is the
//! "steady-state allocs/frame == 0" acceptance bound, measured here
//! through the crate's counting global allocator.
//!
//! A step is classified *steady* from the sequence itself: its frame
//! presents no more work (ground-truth partition sizes, worst-case
//! detection count over every DNN the policy could pick) than the
//! maximum already absorbed by an earlier step, and the previous step
//! did not raise any of those maxima (scratch sized from the carried
//! set lags the step that grew it by one). Steps that raise a maximum
//! are legitimate growth, not a regression, and are exempt.
//!
//! The bound is checked twice: for a bare session, and for a session
//! with a `NullRecorder` attached — the full emit path (events *and*
//! the span arena of ISSUE 8) runs and must stay alloc-free too.

use tod::coordinator::{
    MbbsPolicy, OracleBackend, SessionEvent, StreamSession,
};
use tod::dataset::catalog::{generate, SequenceId};
use tod::dataset::Sequence;
use tod::detection::passes_score_filter;
use tod::obs::{shared, NullRecorder};
use tod::perf::count_allocs;
use tod::sim::latency::LatencyModel;
use tod::sim::oracle::OracleDetector;
use tod::DnnKind;

/// Drive `sess` over `seq`, asserting zero allocations on every step
/// classified steady; returns how many steps qualified.
fn steady_alloc_audit(
    seq: &Sequence,
    oracle: &OracleDetector,
    mut sess: StreamSession<'_>,
    label: &str,
) -> usize {
    let n = seq.n_frames() as usize;

    // Worst-case per-frame demand over every DNN (the oracle is a pure
    // function of (seed, frame, dnn), so this is exact, not sampled).
    let raw_demand = |f: u64| -> usize {
        DnnKind::ALL
            .iter()
            .map(|&d| oracle.detect(f, seq.gt(f), d).len())
            .max()
            .unwrap_or(0)
    };
    let filt_demand = |f: u64| -> usize {
        DnnKind::ALL
            .iter()
            .map(|&d| {
                oracle
                    .detect(f, seq.gt(f), d)
                    .iter()
                    .filter(|d| passes_score_filter(d))
                    .count()
            })
            .max()
            .unwrap_or(0)
    };
    let gt_parts = |f: u64| -> (usize, usize) {
        let c = seq.gt(f).iter().filter(|g| g.is_considered()).count();
        (c, seq.gt(f).len() - c)
    };

    let mut det = OracleBackend(oracle.clone());
    let mut lat = LatencyModel::deterministic();

    // Absorbed maxima: raw/filtered counts realised on inferred frames
    // (for the chosen DNN), gt partition sizes on every frame.
    let (mut cap_raw, mut cap_filt) = (0usize, 0usize);
    let (mut cap_cons, mut cap_ign) = (0usize, 0usize);
    let mut prev_raised = true;
    let mut steady_steps = 0usize;

    for i in 0..n {
        let f = (i + 1) as u64;
        let (cons, ign) = gt_parts(f);
        let steady = i >= n / 4
            && !prev_raised
            && raw_demand(f) <= cap_raw
            && filt_demand(f) <= cap_filt
            && cons <= cap_cons
            && ign <= cap_ign;

        let (delta, ev) = count_allocs(|| sess.step(&mut det, &mut lat));
        assert!(
            !matches!(ev, SessionEvent::Finished),
            "{label}: sequence exhausted early at step {i}"
        );

        if steady {
            assert_eq!(
                delta.allocs, 0,
                "{label}: steady-state step {i} (frame {f}) made {} \
                 allocations ({} bytes)",
                delta.allocs, delta.bytes
            );
            steady_steps += 1;
        }

        // update absorbed maxima from what the step actually did
        prev_raised = false;
        if let SessionEvent::Inferred { dnn, .. }
        | SessionEvent::InferenceFailed { dnn, .. } = ev
        {
            let dets = oracle.detect(f, seq.gt(f), dnn);
            let raw = dets.len();
            let filt =
                dets.iter().filter(|d| passes_score_filter(d)).count();
            if raw > cap_raw {
                cap_raw = raw;
                prev_raised = true;
            }
            if filt > cap_filt {
                cap_filt = filt;
                prev_raised = true;
            }
        }
        if cons > cap_cons {
            cap_cons = cons;
            prev_raised = true;
        }
        if ign > cap_ign {
            cap_ign = ign;
            prev_raised = true;
        }
    }

    // The guard must not be vacuous: on MOT17-02 (600 frames, stable
    // density) the bulk of the back three-quarters is steady.
    assert!(
        steady_steps >= n / 10,
        "{label}: only {steady_steps}/{n} steps classified steady — \
         demand guard too strict to certify the zero-alloc bound"
    );
    steady_steps
}

fn fixture() -> (Sequence, OracleDetector) {
    let seq = generate(SequenceId::Mot02);
    let oracle = OracleDetector::new(
        seq.spec.seed,
        seq.spec.width as f64,
        seq.spec.height as f64,
    );
    (seq, oracle)
}

#[test]
fn session_step_is_alloc_free_in_steady_state() {
    let (seq, oracle) = fixture();
    let sess = StreamSession::new(&seq, MbbsPolicy::tod_default(), 30.0);
    steady_alloc_audit(&seq, &oracle, sess, "bare session");
}

#[test]
fn recorded_session_step_is_alloc_free_in_steady_state() {
    // the NullRecorder runs the whole emit path — event construction,
    // span arena open/close, recorder dispatch — and must add zero
    // allocations to a steady step
    let (seq, oracle) = fixture();
    let sess = StreamSession::new(&seq, MbbsPolicy::tod_default(), 30.0)
        .with_recorder(shared(NullRecorder), 0, 0.0);
    steady_alloc_audit(&seq, &oracle, sess, "null-recorded session");
}
