//! # TOD: Transprecise Object Detection
//!
//! A reproduction of *"TOD: Transprecise Object Detection to Maximise
//! Real-Time Accuracy on the Edge"* (Lee, Varghese, Woods, Vandierendonck —
//! IEEE ICFEC 2021) as a three-layer Rust + JAX + Pallas system.
//!
//! The paper's contribution — a per-frame, proactive DNN selector driven by
//! the Median of Bounding-Box Sizes (MBBS) under a real-time FPS budget —
//! lives in [`coordinator`]. Everything it depends on is built here too:
//! the MOT dataset substrate ([`dataset`]), the AP evaluator ([`eval`]),
//! the Jetson-Nano behavioural models ([`sim`], [`telemetry`]), the fixed-
//! FPS frame clock ([`video`]), and a PJRT-backed inference runtime
//! ([`runtime`]) that serves the four AOT-compiled YOLO-style detector
//! variants produced by `python/compile/aot.py`.
//!
//! ## Feature-driven selection
//!
//! The paper's Algorithm 1 reads one number (MBBS) against hand-tuned
//! thresholds. This crate generalises the decision input to a per-frame
//! stream-feature vector ([`features::FrameFeatures`]: size, count,
//! density, and EWMA-smoothed apparent speed from greedy IoU/centroid
//! matching of consecutive detection sets) and adds a calibrated
//! projected-accuracy selector
//! ([`coordinator::projected::ProjectedAccuracyPolicy`] over a
//! [`predictor::CalibrationTable`] fitted by `tod calibrate`), which
//! picks the network maximising projected AP under a per-frame latency
//! budget. MBBS-threshold policies consume the size channel only and
//! stay bit-identical.
//!
//! ## Single stream vs many
//!
//! The paper's loop serves one camera per accelerator. This crate splits
//! that loop into a resumable per-stream state machine —
//! [`coordinator::session::StreamSession`], advanced one frame at a time
//! via `step()` — so the classic single-stream drivers
//! ([`coordinator::scheduler::run_realtime`]) and the production-shaped
//! multi-stream scheduler
//! ([`coordinator::multistream::MultiStreamScheduler`]) share one
//! implementation of Algorithm 1 + 2. The multi-stream scheduler
//! interleaves N sessions over a single virtual accelerator in round-robin
//! or earliest-deadline-first order, inflates inference latency under
//! contention ([`sim::latency::ContentionModel`]), and reports aggregate
//! utilisation through [`telemetry::utilisation::UtilisationSummary`].
//! A 1-stream schedule reproduces the paper's single-stream results bit
//! for bit.
//!
//! ## Energy and utilisation governance
//!
//! The paper's resource headline — TOD matches YOLOv4-416 accuracy on
//! MOT17-05 at 45.1% of the GPU and 62.7% of board power — is owned by
//! the [`power`] module: an online [`power::EnergyMeter`] folds each
//! busy interval into joules / average watts / GPU-busy fraction as the
//! session steps (not post-hoc), a [`power::PowerBudget`] governor
//! enforces watts and/or GPU-% caps over a sliding window by masking
//! the feasible DNN set (with an optional DVFS-style
//! [`power::RateCap`]), and [`power::BudgetedPolicy`] composes the mask
//! with any [`coordinator::policy::SelectionPolicy`] — demoting a
//! threshold ladder's choice, or running an energy-aware argmax over a
//! calibrated table (highest projected AP in budget, ties to the
//! lowest energy per frame). With no caps configured every policy is
//! bit-identical to its unwrapped self.
//!
//! ## Serving at scale: the micro-batching server
//!
//! Production traffic means many cameras per box and a request path
//! that must never die. [`runtime::server::InferenceServer`] puts a
//! multi-producer micro-batching front in front of the engine pool:
//! concurrent streams submit [`runtime::server::InferRequest`]s, the
//! server collects them into per-DNN batches (flush at
//! `max_batch` or `max_wait` — [`runtime::batch::BatchConfig`]),
//! dispatches each batch on the crate's [`exec::pool::ThreadPool`],
//! and resolves every request through its own
//! [`runtime::server::ResultHandle`]. Admission is bounded
//! (block-or-shed, [`runtime::batch::AdmissionPolicy`]) and the whole
//! path is **panic-free by construction**: engine errors fail their
//! own request ([`runtime::server::ServeError`]), a panicking backend
//! is caught per item, and a batch that never runs resolves its
//! requests with a shutdown error instead of stranding waiters. The
//! same discipline runs down the stack: the
//! [`coordinator::scheduler::Detector`] trait is fallible, a failed
//! inference carries the previous detections forward
//! ([`coordinator::session::SessionEvent::InferenceFailed`]), and the
//! evaluators order NaN scores deterministically instead of panicking.
//!
//! The batching *win* is quantified deterministically in virtual time:
//! [`sim::latency::BatchLatencyModel`] prices a batch as setup +
//! per-item marginal cost (a batch of one costs exactly the unbatched
//! mean), and [`coordinator::multistream::BatchingSim`] lets the
//! multi-stream scheduler amortise setup across back-to-back same-DNN
//! dispatches — `tod multistream --batch` and `benches/batching.rs`
//! print the frames/s side by side.
//!
//! ## Scenario diversity, pinned byte for byte
//!
//! The paper's claim is adaptation to *changing* streams, yet its
//! evaluation replays seven static sequences. The [`scenario`]
//! subsystem makes workload diversity first-class: composable phased
//! scenario descriptions ([`scenario::ScenarioSpec`] — density,
//! object-size geometry, camera motion, FPS sag/burst, day/night
//! noise, stream churn; versioned JSON via [`scenario::store`]),
//! compiled deterministically onto [`dataset::synth`] worlds and
//! replayed end to end by [`scenario::harness`] over the production
//! [`coordinator::session::StreamSession`] state machine under any
//! policy × dispatch × watts-budget × batching configuration. Every
//! run flattens into a byte-stable [`scenario::RunRecord`]; the eight
//! curated scenarios of [`scenario::matrix`] are pinned by golden
//! reports under `rust/tests/goldens/` (`tod scenario
//! {list,run,record,check}`), including the differential claim that
//! projected and watts-budgeted selection never lose to the best
//! (budget-feasible) fixed DNN on any scenario.
//!
//! ## Performance model and bench trajectory
//!
//! Selection must stay in the paper's "negligible overhead" envelope,
//! and that is now *measured*, not asserted: the [`perf`] layer owns a
//! counting `#[global_allocator]` ([`perf::alloc`], allocs/op as a
//! deterministic metric), the canonical hot-path bench suite
//! ([`perf::suite`], run by `tod bench`), and the versioned
//! `BENCH_<n>.json` report + regression gate ([`perf::report`]; CI
//! fails on >15% `min_ns` regression or any allocs/op increase against
//! the committed baseline). The hot paths themselves — NMS, greedy
//! matching, AP pooling, feature extraction, table lookup, the
//! per-frame [`coordinator::session::StreamSession::step`] and the
//! multi-stream dispatch queue — run allocation-free in steady state on
//! reusable scratch, each pinned bit-identical to its straightforward
//! reference implementation by property tests (DESIGN.md §13).
//!
//! ## Observability
//!
//! Every scheduling decision the system makes — frame presented /
//! inferred / dropped / failed, DNN selected, budget clamp engaged,
//! batch formed / flushed / shed, stream join / leave — is emitted as a
//! structured, versioned [`obs::Event`] through the [`obs::Recorder`]
//! trait: no recorder attached costs one branch on the hot path (the
//! zero-alloc steady-state bound is unchanged), the bounded
//! [`obs::FlightRecorder`] ring retains the last N events without
//! allocating (dumped by the scenario harness on conformance failures),
//! and the [`obs::JsonlSink`] captures full traces that are
//! byte-identical under the same seed (`tod run --trace`,
//! `tod trace summarize/grep/explain-drop`). [`obs::MetricsRegistry`]
//! aggregates the same events plus the siloed summaries into monotone
//! counters and fixed-bucket histograms with Prometheus-style
//! exposition (`tod metrics`). See DESIGN.md §14.
//!
//! On top of the spine sits the profiling/health tier (DESIGN.md §15):
//! [`coordinator::session::StreamSession`] emits hierarchical,
//! deterministic **spans** (stream ▸ frame ▸ pipeline stages, virtual
//! time, allocation-free via [`obs::SpanArena`]);
//! [`obs::profile`] attributes self- vs child-time per stage offline
//! (`tod trace profile`, stage histograms in the registry, the
//! invariant that stage self-times sum to each frame span);
//! [`obs::export`] renders byte-deterministic Chrome traces and
//! collapsed-stack flamegraphs (`tod trace export --chrome`,
//! `tod trace flame`); and [`obs::slo`] evaluates rolling-window SLOs
//! (p99 latency, drop rate, freshness-decay AP proxy, watts cap) over
//! any trace, emitting latched [`obs::Event::SloBreach`] /
//! [`obs::Event::SloRecovered`] transitions — `tod slo check` turns a
//! scenario run into a CI health gate.
//!
//! ## Static analysis: the invariants, enforced at the source
//!
//! The three invariant families above — byte-stable serialisation,
//! a panic-free serving path, alloc-free hot loops — are each pinned
//! dynamically (golden traces, property tests, the counting
//! allocator). The [`analysis`] subsystem enforces the same three as
//! **rule zones** at the source level: `tod lint` scans the crate's
//! own sources with a dependency-free token scanner, maps files and
//! functions onto zones via the versioned `rust/lint-policy.json`
//! (schema `tod-lint-policy` v1), and reports every violation as
//! `file:line` + rule id + zone in a versioned `tod-lint` JSON
//! report. Exemptions are inline `// tod-lint: allow(<rule>)
//! reason="..."` waivers — honoured, but enumerated in the report so
//! they stay visible — and `tod lint --check` gates CI on zero
//! unwaived findings. See DESIGN.md §16.
//!
//! See `DESIGN.md` for the system inventory, the per-experiment index,
//! the multi-stream architecture (§8), the power subsystem (§10),
//! the batching server (§11), the scenario matrix + conformance
//! harness (§12), the performance model (§13), the observability
//! layers (§14–§15) and the static-analysis zones (§16), and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod analysis;
pub mod app;
pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod dataset;
pub mod detection;
pub mod eval;
pub mod exec;
pub mod ext;
pub mod experiments;
pub mod features;
pub mod geometry;
pub mod obs;
pub mod perf;
pub mod power;
pub mod predictor;
pub mod runtime;
pub mod scenario;
pub mod sim;
pub mod telemetry;
pub mod testing;
pub mod util;
pub mod video;

/// Every heap allocation in the process routes through the counting
/// allocator so `tod bench` can report allocs/op and the zero-alloc
/// steady-state tests can gate scratch reuse (see [`perf::alloc`]).
#[global_allocator]
static GLOBAL_ALLOC: perf::alloc::CountingAllocator =
    perf::alloc::CountingAllocator;

/// The four DNN operating points the paper serves, ordered from the
/// lightest to the heaviest weight (the order Algorithm 1 indexes them).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DnnKind {
    /// YOLOv4-tiny at 288x288 input (lightest; `DNN_1` in Algorithm 1).
    TinyY288,
    /// YOLOv4-tiny at 416x416 input (`DNN_2`).
    TinyY416,
    /// Full YOLOv4 at 288x288 input (`DNN_3`).
    Y288,
    /// Full YOLOv4 at 416x416 input (heaviest; `DNN_4`, the default).
    Y416,
}

impl DnnKind {
    /// Number of DNN operating points (the length of [`DnnKind::ALL`]).
    /// Use this instead of a literal `4` when sizing per-DNN arrays so
    /// ladder changes surface as type errors, not silent truncation.
    pub const COUNT: usize = 4;

    /// All four variants, lightest first.
    pub const ALL: [DnnKind; Self::COUNT] = [
        DnnKind::TinyY288,
        DnnKind::TinyY416,
        DnnKind::Y288,
        DnnKind::Y416,
    ];

    /// The artifact/manifest name used by `python/compile/aot.py`.
    pub fn artifact_name(self) -> &'static str {
        match self {
            DnnKind::TinyY288 => "yolov4-tiny-288",
            DnnKind::TinyY416 => "yolov4-tiny-416",
            DnnKind::Y288 => "yolov4-288",
            DnnKind::Y416 => "yolov4-416",
        }
    }

    /// Short label used in the paper's Fig. 12 ("YT-288", ..., "Y-416").
    pub fn short_label(self) -> &'static str {
        match self {
            DnnKind::TinyY288 => "YT-288",
            DnnKind::TinyY416 => "YT-416",
            DnnKind::Y288 => "Y-288",
            DnnKind::Y416 => "Y-416",
        }
    }

    /// Index in Algorithm 1's `DNN_1..DNN_4` numbering (0-based).
    pub fn index(self) -> usize {
        match self {
            DnnKind::TinyY288 => 0,
            DnnKind::TinyY416 => 1,
            DnnKind::Y288 => 2,
            DnnKind::Y416 => 3,
        }
    }

    /// Inverse of [`DnnKind::index`].
    pub fn from_index(i: usize) -> Option<DnnKind> {
        DnnKind::ALL.get(i).copied()
    }

    /// Square input resolution of the variant.
    pub fn input_size(self) -> usize {
        match self {
            DnnKind::TinyY288 | DnnKind::Y288 => 288,
            DnnKind::TinyY416 | DnnKind::Y416 => 416,
        }
    }

    /// Whether this is a tiny-topology variant.
    pub fn is_tiny(self) -> bool {
        matches!(self, DnnKind::TinyY288 | DnnKind::TinyY416)
    }
}

impl std::fmt::Display for DnnKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.artifact_name())
    }
}

impl std::str::FromStr for DnnKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "yolov4-tiny-288" | "tiny-288" | "YT-288" => Ok(DnnKind::TinyY288),
            "yolov4-tiny-416" | "tiny-416" | "YT-416" => Ok(DnnKind::TinyY416),
            "yolov4-288" | "288" | "Y-288" => Ok(DnnKind::Y288),
            "yolov4-416" | "416" | "Y-416" => Ok(DnnKind::Y416),
            other => Err(format!("unknown DNN variant: {other}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dnn_order_is_lightest_first() {
        assert_eq!(DnnKind::ALL[0], DnnKind::TinyY288);
        assert_eq!(DnnKind::ALL[3], DnnKind::Y416);
        for (i, d) in DnnKind::ALL.iter().enumerate() {
            assert_eq!(d.index(), i);
            assert_eq!(DnnKind::from_index(i), Some(*d));
        }
        assert_eq!(DnnKind::from_index(4), None);
    }

    #[test]
    fn dnn_roundtrip_names() {
        for d in DnnKind::ALL {
            let parsed: DnnKind = d.artifact_name().parse().unwrap();
            assert_eq!(parsed, d);
            let parsed: DnnKind = d.short_label().parse().unwrap();
            assert_eq!(parsed, d);
        }
        assert!("yolo9000".parse::<DnnKind>().is_err());
    }

    #[test]
    fn dnn_properties() {
        assert!(DnnKind::TinyY288.is_tiny());
        assert!(!DnnKind::Y416.is_tiny());
        assert_eq!(DnnKind::Y416.input_size(), 416);
        assert_eq!(DnnKind::TinyY288.input_size(), 288);
    }
}
