//! Budget-aware selection: any [`SelectionPolicy`] composed with a
//! [`PowerBudget`] governor.
//!
//! Two composition modes:
//!
//! * **Mask** — wrap an existing policy (threshold ladder, projected,
//!   fixed...). The inner policy picks as usual; if its choice is
//!   budget-infeasible it is demoted to the heaviest *feasible* lighter
//!   DNN (degrading accuracy is recoverable, breaching the power cap is
//!   not). With no caps configured the wrapper is bit-identical to the
//!   inner policy — pinned by the golden test in `rust/tests/power.rs`.
//! * **Argmax** — energy-aware selection over a calibrated
//!   [`CalibrationTable`]: pick the budget-feasible DNN with the
//!   highest projected AP, breaking ties toward the lowest energy per
//!   frame. With an unbounded governor this coincides with
//!   [`crate::coordinator::projected::ProjectedAccuracyPolicy`].
//!
//! When *no* DNN is feasible (the window is saturated), both modes fall
//! back to the lightest DNN: it drains the window fastest and is the
//! cheapest way to keep the stream's detections fresh while the
//! governor recovers headroom.

use crate::coordinator::policy::SelectionPolicy;
use crate::features::FrameFeatures;
use crate::obs::{mask_to_bits, Event as ObsEvent, SharedRecorder};
use crate::predictor::CalibrationTable;
use crate::DnnKind;

use super::budget::{DnnMask, PowerBudget, SharedBudget};

enum Mode {
    Mask(Box<dyn SelectionPolicy>),
    Argmax { table: CalibrationTable },
}

/// A [`SelectionPolicy`] whose choices respect a [`PowerBudget`].
///
/// The governor learns stream time through the policy notification
/// hooks ([`SelectionPolicy::on_frame`] /
/// [`SelectionPolicy::on_inferred`]), which
/// [`crate::coordinator::session::StreamSession`] drives every step —
/// so budget enforcement works unchanged under both the single-stream
/// driver and the multi-stream scheduler. Hand the same
/// [`SharedBudget`] to several streams' policies to enforce one
/// board-level budget across all of them.
pub struct BudgetedPolicy {
    mode: Mode,
    budget: SharedBudget,
    /// Capture start of the frame being decided (set by `on_frame`).
    now: f64,
    /// Observability sink for [`ObsEvent::BudgetClamp`] emissions.
    recorder: Option<SharedRecorder>,
    /// Stream id stamped on emitted clamps.
    obs_stream: u32,
}

impl BudgetedPolicy {
    /// Mask mode over a privately owned governor.
    pub fn masking(
        inner: Box<dyn SelectionPolicy>,
        budget: PowerBudget,
    ) -> Self {
        Self::masking_shared(inner, budget.shared())
    }

    /// Mask mode over a shared (board-level) governor.
    pub fn masking_shared(
        inner: Box<dyn SelectionPolicy>,
        budget: SharedBudget,
    ) -> Self {
        BudgetedPolicy {
            mode: Mode::Mask(inner),
            budget,
            now: 0.0,
            recorder: None,
            obs_stream: 0,
        }
    }

    /// Energy-aware argmax mode over a privately owned governor.
    pub fn argmax(table: CalibrationTable, budget: PowerBudget) -> Self {
        Self::argmax_shared(table, budget.shared())
    }

    /// Energy-aware argmax mode over a shared governor.
    pub fn argmax_shared(
        table: CalibrationTable,
        budget: SharedBudget,
    ) -> Self {
        BudgetedPolicy {
            mode: Mode::Argmax { table },
            budget,
            now: 0.0,
            recorder: None,
            obs_stream: 0,
        }
    }

    /// Attach an observability recorder: every demotion the governor
    /// forces is emitted as [`ObsEvent::BudgetClamp`] stamped with
    /// `stream`, at the deciding frame's capture time (the same `t` as
    /// the session's matching `DnnSelected`, which is what lets
    /// `tod trace explain-drop` join the two).
    pub fn with_recorder(
        mut self,
        recorder: SharedRecorder,
        stream: u32,
    ) -> Self {
        self.recorder = Some(recorder);
        self.obs_stream = stream;
        self
    }

    /// Emit a clamp if a recorder is attached. `now` is stream time;
    /// epoch-shifting adapters move `on_frame` to board time before it
    /// reaches this policy, so the timestamp is already board-global.
    fn emit_clamp(&self, requested: DnnKind, granted: DnnKind, mask: &DnnMask) {
        if let Some(rec) = &self.recorder {
            rec.borrow_mut().record(&ObsEvent::BudgetClamp {
                stream: self.obs_stream,
                t: self.now,
                requested,
                granted,
                mask: mask_to_bits(mask),
            });
        }
    }

    /// Handle to the governor (e.g. to share it with another stream).
    pub fn budget(&self) -> SharedBudget {
        self.budget.clone()
    }

    /// Heaviest feasible DNN no heavier than `chosen`; the lightest
    /// DNN when nothing is feasible. Walks the ladder itself rather
    /// than indexing back through `from_index`, so no unrepresentable
    /// index can arise.
    fn demote(chosen: DnnKind, mask: &DnnMask) -> DnnKind {
        for (d, feasible) in
            DnnKind::ALL.iter().zip(mask).take(chosen.index() + 1).rev()
        {
            if *feasible {
                return *d;
            }
        }
        DnnKind::ALL[0]
    }

    /// Feasible argmax of projected AP; ties go to the lower
    /// energy-per-frame; lightest DNN when nothing is feasible.
    fn argmax_select(
        table: &CalibrationTable,
        budget: &PowerBudget,
        mask: &DnnMask,
        features: &FrameFeatures,
    ) -> DnnKind {
        let mut best: Option<(DnnKind, f64, f64)> = None;
        for k in DnnKind::ALL {
            if !mask[k.index()] {
                continue;
            }
            let ap = table.project_features(k, features);
            let energy = budget.energy_per_frame_j(k);
            let better = match best {
                None => true,
                Some((_, bap, be)) => {
                    ap > bap || (ap == bap && energy < be)
                }
            };
            if better {
                best = Some((k, ap, energy));
            }
        }
        best.map(|(k, _, _)| k).unwrap_or(DnnKind::ALL[0])
    }
}

impl SelectionPolicy for BudgetedPolicy {
    fn select(&mut self, features: &FrameFeatures) -> DnnKind {
        let budget = self.budget.borrow();
        let mask = budget.feasible(self.now);
        match &mut self.mode {
            Mode::Mask(inner) => {
                let chosen = inner.select(features);
                if mask[chosen.index()] {
                    chosen
                } else {
                    let granted = Self::demote(chosen, &mask);
                    let (recorder, stream, now) =
                        (&self.recorder, self.obs_stream, self.now);
                    if let Some(rec) = recorder {
                        rec.borrow_mut().record(&ObsEvent::BudgetClamp {
                            stream,
                            t: now,
                            requested: chosen,
                            granted,
                            mask: mask_to_bits(&mask),
                        });
                    }
                    granted
                }
            }
            Mode::Argmax { table } => {
                let granted =
                    Self::argmax_select(table, &budget, &mask, features);
                // clamp = the pick the table *wanted* was masked off;
                // only worth computing when someone is listening
                if self.recorder.is_some() && mask != [true; DnnKind::COUNT] {
                    let unconstrained = Self::argmax_select(
                        table,
                        &budget,
                        &[true; DnnKind::COUNT],
                        features,
                    );
                    if unconstrained != granted {
                        self.emit_clamp(unconstrained, granted, &mask);
                    }
                }
                granted
            }
        }
    }

    fn label(&self) -> String {
        let desc = self.budget.borrow().descriptor();
        match &self.mode {
            Mode::Mask(inner) => {
                format!("budgeted{{{}|{}}}", inner.label(), desc)
            }
            Mode::Argmax { table } => {
                format!("budgeted{{argmax@{}fps|{}}}", table.fps, desc)
            }
        }
    }

    fn on_frame(&mut self, t_s: f64) {
        self.now = t_s;
        self.budget.borrow_mut().advance_to(t_s);
        if let Mode::Mask(inner) = &mut self.mode {
            inner.on_frame(t_s);
        }
    }

    fn on_inferred(&mut self, start_s: f64, end_s: f64, dnn: DnnKind) {
        self.budget.borrow_mut().record(start_s, end_s, dnn);
        if let Mode::Mask(inner) = &mut self.mode {
            inner.on_inferred(start_s, end_s, dnn);
        }
    }

    fn governs(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::{FixedPolicy, MbbsPolicy};
    use crate::coordinator::projected::ProjectedAccuracyPolicy;
    use crate::coordinator::policy::Thresholds;
    use crate::sim::latency::LatencyModel;

    fn det() -> LatencyModel {
        LatencyModel::deterministic()
    }

    #[test]
    fn unbounded_mask_matches_inner_exactly() {
        let mut bare = MbbsPolicy::tod_default();
        let mut wrapped = BudgetedPolicy::masking(
            Box::new(MbbsPolicy::tod_default()),
            PowerBudget::unbounded(),
        );
        for i in 0..500 {
            let f = FrameFeatures::mbbs_only(i as f64 * 2e-4);
            assert_eq!(wrapped.select(&f), bare.select(&f));
        }
    }

    #[test]
    fn infeasible_choice_demotes_to_heaviest_feasible() {
        // cold start under 6.5 W: Y-288/Y-416 infeasible, so a fixed
        // Y-416 policy demotes to tiny-416
        let mut p = BudgetedPolicy::masking(
            Box::new(FixedPolicy(DnnKind::Y416)),
            PowerBudget::watts(6.5, &det()),
        );
        p.on_frame(0.0);
        assert_eq!(
            p.select(&FrameFeatures::mbbs_only(0.0)),
            DnnKind::TinyY416
        );
    }

    #[test]
    fn saturated_window_falls_back_to_lightest() {
        let mut p = BudgetedPolicy::masking(
            Box::new(FixedPolicy(DnnKind::Y416)),
            PowerBudget::watts(6.5, &det()),
        );
        p.on_inferred(0.0, 2.0, DnnKind::Y416);
        p.on_frame(2.0);
        assert_eq!(
            p.select(&FrameFeatures::mbbs_only(0.0)),
            DnnKind::TinyY288
        );
    }

    #[test]
    fn recovered_headroom_restores_inner_choice() {
        let mut p = BudgetedPolicy::masking(
            Box::new(FixedPolicy(DnnKind::Y416)),
            PowerBudget::watts(6.5, &det()),
        );
        p.on_inferred(0.0, 1.0, DnnKind::Y416);
        // two windows of idle later, Y-416 is feasible again
        p.on_frame(3.0);
        assert_eq!(
            p.select(&FrameFeatures::mbbs_only(0.0)),
            DnnKind::Y416
        );
    }

    #[test]
    fn unbounded_argmax_matches_projected_policy() {
        let th = Thresholds::h_opt();
        let table = CalibrationTable::from_ladder(&th, &DnnKind::ALL);
        let proj = ProjectedAccuracyPolicy::new(table.clone(), &det());
        let mut arg =
            BudgetedPolicy::argmax(table, PowerBudget::unbounded());
        for i in 0..2000 {
            let f = FrameFeatures::mbbs_only((i as f64 + 0.5) * 5e-5);
            assert_eq!(
                arg.select(&f),
                proj.select_pure(&f),
                "diverged at {f:?}"
            );
        }
    }

    #[test]
    fn argmax_respects_the_mask() {
        // ladder table says Y-416 for tiny MBBS, but under a cold-start
        // 6.5 W cap the argmax lands on the best *feasible* rung
        let th = Thresholds::h_opt();
        let table = CalibrationTable::from_ladder(&th, &DnnKind::ALL);
        let mut arg = BudgetedPolicy::argmax(
            table,
            PowerBudget::watts(6.5, &det()),
        );
        arg.on_frame(0.0);
        let pick = arg.select(&FrameFeatures::mbbs_only(0.001));
        assert!(
            pick == DnnKind::TinyY416,
            "expected the heaviest feasible rung, got {pick:?}"
        );
    }

    #[test]
    fn argmax_ties_break_to_lower_energy() {
        // flat table: every DNN projects identically -> lowest energy
        // per frame (the lightest) must win
        let ap = (0..DnnKind::COUNT)
            .map(|_| vec![vec![0.5; 1]; 1])
            .collect();
        let table =
            CalibrationTable::new(30.0, vec![0.01], vec![0.0], ap);
        let mut arg =
            BudgetedPolicy::argmax(table, PowerBudget::unbounded());
        assert_eq!(
            arg.select(&FrameFeatures::mbbs_only(0.02)),
            DnnKind::TinyY288
        );
    }

    #[test]
    fn labels_identify_mode_and_budget() {
        let p = BudgetedPolicy::masking(
            Box::new(MbbsPolicy::tod_default()),
            PowerBudget::watts(6.5, &det()),
        );
        assert_eq!(
            p.label(),
            "budgeted{TOD{0.007,0.03,0.04}|W<=6.5,win=1s}"
        );
        let th = Thresholds::h_opt();
        let a = BudgetedPolicy::argmax(
            CalibrationTable::from_ladder(&th, &DnnKind::ALL),
            PowerBudget::unbounded(),
        );
        assert_eq!(a.label(), "budgeted{argmax@30fps|unbounded}");
    }

    #[test]
    fn budgeted_policy_governs_even_through_a_box() {
        let p = BudgetedPolicy::masking(
            Box::new(MbbsPolicy::tod_default()),
            PowerBudget::unbounded(),
        );
        assert!(p.governs());
        let boxed: Box<dyn SelectionPolicy> = Box::new(p);
        assert!(boxed.governs());
    }

    #[test]
    fn shared_budget_sees_both_streams() {
        let shared = PowerBudget::watts(6.5, &det()).shared();
        let mut a = BudgetedPolicy::masking_shared(
            Box::new(FixedPolicy(DnnKind::Y416)),
            shared.clone(),
        );
        let mut b = BudgetedPolicy::masking_shared(
            Box::new(FixedPolicy(DnnKind::Y416)),
            shared.clone(),
        );
        // stream A saturates the shared window; stream B is masked too
        a.on_inferred(0.0, 2.0, DnnKind::Y416);
        b.on_frame(2.0);
        assert_eq!(
            b.select(&FrameFeatures::mbbs_only(0.0)),
            DnnKind::TinyY288
        );
        assert_eq!(shared.borrow().n_retained(), 1);
    }
}
