//! Algorithm 1: the MBBS-thresholded runtime DNN selector.
//!
//! With `n` DNNs ordered lightest → heaviest and `n-1` ascending
//! thresholds `h_1 < ... < h_{n-1}` (object area as a fraction of the
//! frame), the policy picks
//!
//! * the lightest DNN when `MBBS > h_{n-1}` (large objects — a light
//!   net is enough, per Huang et al. [6]),
//! * ...down to the heaviest DNN when `MBBS <= h_1` (small objects need
//!   capacity). An empty previous frame (`MBBS = 0`) therefore selects
//!   the heaviest DNN, matching the paper's `median(bboxes)_0 = 0`
//!   initialisation and YOLOv4-416 default.
//!
//! The selection itself is O(n) compares on one f64 — the "negligible
//! computational overhead" the paper claims; see the `policy` bench.

use crate::features::FrameFeatures;
use crate::DnnKind;

/// Why a threshold set was rejected. Threshold values arrive from the
/// CLI and config files (user input), so construction reports errors
/// instead of panicking.
#[derive(Debug, Clone, PartialEq)]
pub enum ThresholdError {
    /// No thresholds supplied (Algorithm 1 needs at least one rung).
    Empty,
    /// Values are not strictly ascending.
    NotAscending(Vec<f64>),
    /// A value falls outside the [0, 1) area-fraction range.
    OutOfRange(Vec<f64>),
}

impl std::fmt::Display for ThresholdError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ThresholdError::Empty => {
                write!(f, "need at least one threshold")
            }
            ThresholdError::NotAscending(h) => {
                write!(f, "thresholds must be strictly ascending: {h:?}")
            }
            ThresholdError::OutOfRange(h) => {
                write!(f, "thresholds are area fractions in [0,1): {h:?}")
            }
        }
    }
}

impl std::error::Error for ThresholdError {}

/// Ascending MBBS thresholds (fractions of frame area).
#[derive(Debug, Clone, PartialEq)]
pub struct Thresholds(Vec<f64>);

impl Thresholds {
    /// Build from ascending values in [0, 1).
    pub fn new(h: Vec<f64>) -> Result<Self, ThresholdError> {
        if h.is_empty() {
            return Err(ThresholdError::Empty);
        }
        if !h.windows(2).all(|w| w[0] < w[1]) {
            return Err(ThresholdError::NotAscending(h));
        }
        if !h.iter().all(|v| (0.0..1.0).contains(v)) {
            return Err(ThresholdError::OutOfRange(h));
        }
        Ok(Thresholds(h))
    }

    /// The paper's optimum: `H_opt = {0.007, 0.03, 0.04}` (§III.B.4).
    /// Constructed directly — the literal is strictly ascending and in
    /// range (asserted by test), so no fallible validation runs on the
    /// serving path.
    pub fn h_opt() -> Self {
        Thresholds(vec![0.007, 0.03, 0.04])
    }

    pub fn values(&self) -> &[f64] {
        &self.0
    }

    /// Number of DNNs this threshold set selects among.
    pub fn n_dnn(&self) -> usize {
        self.0.len() + 1
    }
}

/// A per-frame DNN selection policy.
///
/// Policies consume the full per-frame [`FrameFeatures`] vector
/// (computed by the scheduler from the *previous* frame's detections).
/// Threshold policies read only the size channel; the
/// projected-accuracy policy ([`super::projected`]) also reads the
/// speed channel. Callers without an extractor can feed the degenerate
/// [`FrameFeatures::mbbs_only`] view.
pub trait SelectionPolicy {
    /// Select the DNN for the next frame given the previous frame's
    /// stream features.
    fn select(&mut self, features: &FrameFeatures) -> DnnKind;

    /// Human-readable label for reports.
    fn label(&self) -> String;

    /// Stream-time notification: a frame is being presented at stream
    /// time `t_s` (its capture start, seconds). Stateless policies
    /// ignore this; governors (e.g. [`crate::power::BudgetedPolicy`])
    /// use it as the decision clock for sliding-window budgets. The
    /// default is a no-op, so existing policies are unaffected.
    fn on_frame(&mut self, t_s: f64) {
        let _ = t_s;
    }

    /// Completion notification: the accelerator ran `dnn` over
    /// `[start_s, end_s]` for this stream. Default no-op.
    fn on_inferred(&mut self, start_s: f64, end_s: f64, dnn: DnnKind) {
        let _ = (start_s, end_s, dnn);
    }

    /// Whether this policy runs a budget governor pass inside its
    /// selection. Span-emitting callers use it to attribute a
    /// `budget_govern` stage span (DESIGN.md §15); plain policies keep
    /// the default `false`.
    fn governs(&self) -> bool {
        false
    }
}

/// Mutable references forward the policy, so callers can hand a
/// `&mut dyn SelectionPolicy` to an owning consumer (e.g.
/// [`crate::coordinator::session::StreamSession`]).
impl<P: SelectionPolicy + ?Sized> SelectionPolicy for &mut P {
    fn select(&mut self, features: &FrameFeatures) -> DnnKind {
        (**self).select(features)
    }

    fn label(&self) -> String {
        (**self).label()
    }

    fn on_frame(&mut self, t_s: f64) {
        (**self).on_frame(t_s)
    }

    fn on_inferred(&mut self, start_s: f64, end_s: f64, dnn: DnnKind) {
        (**self).on_inferred(start_s, end_s, dnn)
    }

    fn governs(&self) -> bool {
        (**self).governs()
    }
}

/// Boxed policies forward too (CLI policy parsing produces
/// `Box<dyn SelectionPolicy>`).
impl<P: SelectionPolicy + ?Sized> SelectionPolicy for Box<P> {
    fn select(&mut self, features: &FrameFeatures) -> DnnKind {
        (**self).select(features)
    }

    fn label(&self) -> String {
        (**self).label()
    }

    fn on_frame(&mut self, t_s: f64) {
        (**self).on_frame(t_s)
    }

    fn on_inferred(&mut self, start_s: f64, end_s: f64, dnn: DnnKind) {
        (**self).on_inferred(start_s, end_s, dnn)
    }

    fn governs(&self) -> bool {
        (**self).governs()
    }
}

/// Algorithm 1 with the standard four-variant ladder.
#[derive(Debug, Clone)]
pub struct MbbsPolicy {
    thresholds: Thresholds,
    /// DNNs lightest → heaviest; `thresholds.n_dnn()` entries.
    ladder: Vec<DnnKind>,
}

impl MbbsPolicy {
    /// Policy over the full four-DNN ladder (requires 3 thresholds).
    pub fn new(thresholds: Thresholds) -> Self {
        Self::with_ladder(thresholds, DnnKind::ALL.to_vec())
    }

    /// Policy over a custom ladder (lightest first). The Discussion
    /// section's RTX-2080-style deployments drop the tiny variants —
    /// that's a 2- or 3-rung ladder here.
    pub fn with_ladder(thresholds: Thresholds, ladder: Vec<DnnKind>) -> Self {
        assert_eq!(
            thresholds.n_dnn(),
            ladder.len(),
            "need |ladder| - 1 thresholds"
        );
        assert!(
            ladder.windows(2).all(|w| w[0].index() < w[1].index()),
            "ladder must be ordered lightest -> heaviest"
        );
        MbbsPolicy { thresholds, ladder }
    }

    /// The paper's TOD configuration.
    pub fn tod_default() -> Self {
        MbbsPolicy::new(Thresholds::h_opt())
    }

    pub fn thresholds(&self) -> &Thresholds {
        &self.thresholds
    }

    /// Pure selection function (exposed for property tests and benches).
    #[inline]
    pub fn select_pure(&self, mbbs: f64) -> DnnKind {
        // c = number of thresholds strictly below mbbs
        // (paper: h_i < MBBS <= h_{i+1} picks rung n-1-i)
        let c = self
            .thresholds
            .values()
            .iter()
            .filter(|&&h| mbbs > h)
            .count();
        self.ladder[self.ladder.len() - 1 - c]
    }
}

impl SelectionPolicy for MbbsPolicy {
    fn select(&mut self, features: &FrameFeatures) -> DnnKind {
        self.select_pure(features.mbbs)
    }

    fn label(&self) -> String {
        let h: Vec<String> = self
            .thresholds
            .values()
            .iter()
            .map(|v| format!("{v}"))
            .collect();
        format!("TOD{{{}}}", h.join(","))
    }
}

/// Always-the-same-DNN baseline (the four bars of Figs. 4/6/8).
#[derive(Debug, Clone, Copy)]
pub struct FixedPolicy(pub DnnKind);

impl SelectionPolicy for FixedPolicy {
    fn select(&mut self, _features: &FrameFeatures) -> DnnKind {
        self.0
    }

    fn label(&self) -> String {
        self.0.artifact_name().to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_regions() {
        // §III.B.3's policy table with H_opt
        let p = MbbsPolicy::tod_default();
        assert_eq!(p.select_pure(0.0), DnnKind::Y416); // empty frame
        assert_eq!(p.select_pure(0.004), DnnKind::Y416); // <= h1
        assert_eq!(p.select_pure(0.007), DnnKind::Y416); // boundary: <= h1
        assert_eq!(p.select_pure(0.0071), DnnKind::Y288);
        assert_eq!(p.select_pure(0.03), DnnKind::Y288); // boundary: <= h2
        assert_eq!(p.select_pure(0.035), DnnKind::TinyY416);
        assert_eq!(p.select_pure(0.04), DnnKind::TinyY416); // <= h3
        assert_eq!(p.select_pure(0.05), DnnKind::TinyY288); // > h3
        assert_eq!(p.select_pure(0.9), DnnKind::TinyY288);
    }

    #[test]
    fn monotone_larger_mbbs_never_heavier() {
        let p = MbbsPolicy::tod_default();
        let mut prev = 4usize;
        for i in 0..2000 {
            let m = i as f64 / 2000.0 * 0.2;
            let idx = p.select_pure(m).index();
            // lighter nets have smaller index; weight must not increase
            assert!(
                idx <= prev,
                "mbbs {m} picked heavier net than a smaller mbbs"
            );
            prev = idx;
        }
    }

    #[test]
    fn h_opt_passes_validation() {
        // h_opt() constructs directly to stay panic-free; this pins
        // the literal to the same invariants new() enforces
        let direct = Thresholds::h_opt();
        let validated = Thresholds::new(direct.values().to_vec()).unwrap();
        assert_eq!(direct, validated);
    }

    #[test]
    fn thresholds_validation() {
        assert_eq!(Thresholds::new(vec![]), Err(ThresholdError::Empty));
        assert_eq!(
            Thresholds::new(vec![0.03, 0.01]),
            Err(ThresholdError::NotAscending(vec![0.03, 0.01]))
        );
        assert_eq!(
            Thresholds::new(vec![0.01, 0.01]),
            Err(ThresholdError::NotAscending(vec![0.01, 0.01]))
        );
        assert_eq!(
            Thresholds::new(vec![-0.1, 0.5]),
            Err(ThresholdError::OutOfRange(vec![-0.1, 0.5]))
        );
        assert_eq!(
            Thresholds::new(vec![0.5, 1.0]),
            Err(ThresholdError::OutOfRange(vec![0.5, 1.0]))
        );
        assert!(Thresholds::new(vec![0.007, 0.03, 0.04]).is_ok());
        assert_eq!(Thresholds::h_opt().n_dnn(), 4);
    }

    #[test]
    fn threshold_errors_explain_themselves() {
        // CLI-facing errors must name the offending values
        let e = Thresholds::new(vec![0.03, 0.01]).unwrap_err();
        assert!(e.to_string().contains("ascending"));
        assert!(e.to_string().contains("0.03"));
        let e = Thresholds::new(vec![2.0]).unwrap_err();
        assert!(e.to_string().contains("[0,1)"));
    }

    #[test]
    fn two_rung_ladder() {
        // the Discussion's "RTX 2080 drops the tiny variants" shape
        let p = MbbsPolicy::with_ladder(
            Thresholds::new(vec![0.01]).unwrap(),
            vec![DnnKind::Y288, DnnKind::Y416],
        );
        assert_eq!(p.select_pure(0.5), DnnKind::Y288);
        assert_eq!(p.select_pure(0.005), DnnKind::Y416);
    }

    #[test]
    #[should_panic(expected = "ladder must be ordered")]
    fn unordered_ladder_rejected() {
        MbbsPolicy::with_ladder(
            Thresholds::new(vec![0.01]).unwrap(),
            vec![DnnKind::Y416, DnnKind::Y288],
        );
    }

    #[test]
    fn fixed_policy_is_constant() {
        let mut p = FixedPolicy(DnnKind::Y288);
        for m in [0.0, 0.01, 0.5] {
            assert_eq!(
                p.select(&FrameFeatures::mbbs_only(m)),
                DnnKind::Y288
            );
        }
        assert_eq!(p.label(), "yolov4-288");
    }

    #[test]
    fn mbbs_policy_ignores_non_size_channels() {
        // the trait widening must keep threshold policies bit-identical:
        // only the size channel may influence the choice
        let mut p = MbbsPolicy::tod_default();
        let busy = FrameFeatures {
            mbbs: 0.004,
            count: 40,
            density: 0.5,
            speed: 0.02,
        };
        assert_eq!(p.select(&busy), p.select_pure(0.004));
    }

    #[test]
    fn labels_identify_config() {
        let p = MbbsPolicy::tod_default();
        assert_eq!(p.label(), "TOD{0.007,0.03,0.04}");
    }

    #[test]
    fn plain_policies_do_not_govern_and_wrappers_forward_it() {
        // the forwarding impls must pass governs() through, or a boxed
        // governor would silently lose its budget_govern span
        let mut p = MbbsPolicy::tod_default();
        assert!(!p.governs());
        let by_ref: &mut dyn SelectionPolicy = &mut p;
        assert!(!by_ref.governs());
        let boxed: Box<dyn SelectionPolicy> =
            Box::new(FixedPolicy(DnnKind::Y288));
        assert!(!boxed.governs());
    }
}
