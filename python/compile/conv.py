"""Convolution = im2col patch extraction + fused Pallas matmul.

The paper's inference hot-spot is the Darknet conv stack of YOLOv4; on our
TPU-shaped substrate every conv becomes

    patches = im2col(x)                    # (N*OH*OW, KH*KW*CIN)
    out     = fused_matmul_bias_act(...)   # L1 Pallas kernel

so the whole backbone funnels through the L1 kernel and lowers into a
single HLO module per detector variant.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import fused_matmul_bias_act
from .kernels import ref as kref


def im2col(x: jax.Array, kh: int, kw: int, stride: int) -> jax.Array:
    """Extract SAME-padded (kh, kw) patches from an NHWC tensor.

    Returns (N, OH, OW, kh*kw*C) with the patch axis ordered (kh, kw, c)
    to match a (kh, kw, cin, cout) weight reshaped to (kh*kw*cin, cout).
    """
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    # conv_general_dilated_patches returns channels ordered (c, kh, kw) on
    # the feature axis; reorder to (kh, kw, c) for the HWIO weight layout.
    n, oh, ow, _ = patches.shape
    c = x.shape[-1]
    patches = patches.reshape(n, oh, ow, c, kh * kw)
    patches = jnp.moveaxis(patches, -2, -1)  # (..., kh*kw, c)
    return patches.reshape(n, oh, ow, kh * kw * c)


def conv2d_fused(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    stride: int = 1,
    activation: str = "leaky_relu",
    use_pallas: bool = True,
) -> jax.Array:
    """SAME conv + bias + activation through the L1 Pallas kernel.

    Args:
      x: (N, H, W, CIN).
      w: (KH, KW, CIN, COUT).
      b: (COUT,).
      stride: spatial stride.
      activation: forwarded to the kernel.
      use_pallas: when False, falls back to the pure-lax oracle — used by
        tests and by HLO-size ablations (see DESIGN.md §Perf).
    """
    if not use_pallas:
        return kref.ref_conv2d_bias_act(x, w, b, stride=stride,
                                        activation=activation)
    kh, kw, cin, cout = w.shape
    n = x.shape[0]
    patches = im2col(x, kh, kw, stride)
    _, oh, ow, k = patches.shape
    out = fused_matmul_bias_act(
        patches.reshape(n * oh * ow, k),
        w.reshape(kh * kw * cin, cout),
        b,
        activation=activation,
    )
    return out.reshape(n, oh, ow, cout)
