//! Online energy metering: busy intervals → joules, watts, GPU busy.
//!
//! [`EnergyMeter`] is the incremental counterpart of sampling a
//! finished [`ScheduleTrace`] with
//! [`crate::telemetry::tegrastats::TegrastatsSim`]: each inference's
//! busy interval is folded into per-DNN busy seconds as it completes
//! (one `on_interval` call per [`crate::coordinator::session::
//! StreamSession::step`] that infers), and the idle floor is integrated
//! by advancing the meter's clock as frames are presented. Folding a
//! whole trace with [`EnergyMeter::from_trace`] yields exactly the same
//! summary, which is pinned by the power integration tests — online
//! metering is the post-hoc telemetry, paid in O(1) per inference.

use crate::sim::profiles::{DnnProfile, GPU_IDLE_PCT, POWER_IDLE_W};
use crate::telemetry::tegrastats::ScheduleTrace;
use crate::DnnKind;

/// Snapshot of everything an [`EnergyMeter`] has accounted so far.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerSummary {
    /// Metered stream time, seconds.
    pub duration_s: f64,
    /// Total board energy over the metered time, joules (idle floor
    /// included).
    pub energy_j: f64,
    /// Mean board power, watts (`energy_j / duration_s`; the idle
    /// power for a zero-length meter).
    pub avg_power_w: f64,
    /// Fraction of the metered time the accelerator was busy — the
    /// paper's "GPU resource" axis (45.1% is the MOT17-05 headline).
    pub gpu_busy_frac: f64,
    /// Mean tegrastats-style GPU utilisation, percent.
    pub avg_gpu_pct: f64,
    /// Inferences metered.
    pub inferences: u64,
    /// Busy seconds per DNN variant.
    pub busy_per_dnn_s: [f64; DnnKind::COUNT],
    /// Board energy attributed to each DNN (board power while that DNN
    /// was executing × its busy time), joules.
    pub energy_per_dnn_j: [f64; DnnKind::COUNT],
}

impl PowerSummary {
    /// One-line human-readable report.
    pub fn report(&self) -> String {
        format!(
            "{:.1}s metered | {:.1} J | avg {:.2} W | GPU busy {:.1}% \
             (util {:.1}%) | {} inferences",
            self.duration_s,
            self.energy_j,
            self.avg_power_w,
            self.gpu_busy_frac * 100.0,
            self.avg_gpu_pct,
            self.inferences
        )
    }
}

/// Incremental per-stream (or per-board) energy/utilisation accountant.
///
/// The power model matches the telemetry simulator: the board draws
/// [`POWER_IDLE_W`] whenever no inference is in flight and each DNN's
/// calibrated `power_active_w` while it executes, so
///
/// `energy = idle · duration + Σ_dnn busy_dnn · (active_dnn − idle) · s`
///
/// where `s` is the optional DVFS active-power scale (see
/// [`EnergyMeter::with_active_scale`]; 1.0 = nominal clocks).
#[derive(Debug, Clone)]
pub struct EnergyMeter {
    busy_s: [f64; DnnKind::COUNT],
    inferences: u64,
    /// Latest stream time seen (idle integrates up to here).
    now: f64,
    /// DVFS scale on the active-above-idle power term.
    active_scale: f64,
}

impl Default for EnergyMeter {
    fn default() -> Self {
        EnergyMeter::new()
    }
}

impl EnergyMeter {
    pub fn new() -> Self {
        EnergyMeter {
            busy_s: [0.0; DnnKind::COUNT],
            inferences: 0,
            now: 0.0,
            active_scale: 1.0,
        }
    }

    /// Meter under a DVFS-style rate cap: the active-above-idle power
    /// of every inference is multiplied by `scale` (see
    /// [`super::RateCap::power_factor`]).
    pub fn with_active_scale(scale: f64) -> Self {
        assert!(
            scale > 0.0 && scale.is_finite(),
            "active-power scale must be positive and finite"
        );
        EnergyMeter { active_scale: scale, ..EnergyMeter::new() }
    }

    /// Fold one completed busy interval (stream seconds).
    pub fn on_interval(&mut self, start: f64, end: f64, dnn: DnnKind) {
        debug_assert!(end >= start, "interval ends before it starts");
        self.busy_s[dnn.index()] += (end - start).max(0.0);
        self.inferences += 1;
        self.now = self.now.max(end);
    }

    /// Advance the idle-integration horizon to stream time `t`
    /// (monotone: earlier times are no-ops).
    pub fn advance_to(&mut self, t: f64) {
        self.now = self.now.max(t);
    }

    /// Meter a finished trace in one pass — the post-hoc equivalent of
    /// per-step metering (pinned equal by the power tests).
    pub fn from_trace(trace: &ScheduleTrace) -> Self {
        let mut m = EnergyMeter::new();
        m.fold_trace(trace);
        m
    }

    /// Fold every interval of `trace` and advance to its duration.
    /// Goes through [`ScheduleTrace::normalised_busy`], so an
    /// out-of-order or double-booked trace meters its *union* busy
    /// time — the same repair the tegrastats sampler applies, keeping
    /// the two readouts equal on any input.
    pub fn fold_trace(&mut self, trace: &ScheduleTrace) {
        for &(s, e, d) in trace.normalised_busy().iter() {
            self.on_interval(s, e, d);
        }
        self.advance_to(trace.duration);
    }

    /// Metered stream time, seconds.
    pub fn duration_s(&self) -> f64 {
        self.now
    }

    /// Busy seconds per DNN.
    pub fn busy_per_dnn_s(&self) -> [f64; DnnKind::COUNT] {
        self.busy_s
    }

    /// Total accelerator-busy seconds.
    pub fn busy_total_s(&self) -> f64 {
        self.busy_s.iter().sum()
    }

    /// Fraction of the metered time the accelerator was busy.
    pub fn gpu_busy_frac(&self) -> f64 {
        if self.now <= 0.0 {
            0.0
        } else {
            self.busy_total_s() / self.now
        }
    }

    /// Total board energy, joules (idle floor included).
    pub fn energy_j(&self) -> f64 {
        let mut e = POWER_IDLE_W * self.now;
        for k in DnnKind::ALL {
            let p = DnnProfile::of(k);
            e += self.busy_s[k.index()]
                * (p.power_active_w - POWER_IDLE_W)
                * self.active_scale;
        }
        e
    }

    /// Board energy attributed to each DNN: board power while that DNN
    /// executes × its busy seconds.
    pub fn energy_per_dnn_j(&self) -> [f64; DnnKind::COUNT] {
        let mut out = [0.0; DnnKind::COUNT];
        for k in DnnKind::ALL {
            let p = DnnProfile::of(k);
            let active = POWER_IDLE_W
                + (p.power_active_w - POWER_IDLE_W) * self.active_scale;
            out[k.index()] = self.busy_s[k.index()] * active;
        }
        out
    }

    /// Mean board power, watts. The idle floor for an empty meter.
    pub fn avg_power_w(&self) -> f64 {
        if self.now <= 0.0 {
            POWER_IDLE_W
        } else {
            self.energy_j() / self.now
        }
    }

    /// Mean tegrastats-style GPU utilisation, percent.
    pub fn avg_gpu_pct(&self) -> f64 {
        if self.now <= 0.0 {
            return GPU_IDLE_PCT;
        }
        let mut g = GPU_IDLE_PCT;
        for k in DnnKind::ALL {
            let p = DnnProfile::of(k);
            g += self.busy_s[k.index()] / self.now
                * (p.gpu_util_pct - GPU_IDLE_PCT);
        }
        g.min(100.0)
    }

    /// Inferences metered so far.
    pub fn inferences(&self) -> u64 {
        self.inferences
    }

    /// Snapshot everything.
    pub fn summary(&self) -> PowerSummary {
        PowerSummary {
            duration_s: self.duration_s(),
            energy_j: self.energy_j(),
            avg_power_w: self.avg_power_w(),
            gpu_busy_frac: self.gpu_busy_frac(),
            avg_gpu_pct: self.avg_gpu_pct(),
            inferences: self.inferences,
            busy_per_dnn_s: self.busy_s,
            energy_per_dnn_j: self.energy_per_dnn_j(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_meter_reads_idle() {
        let m = EnergyMeter::new();
        assert_eq!(m.duration_s(), 0.0);
        assert_eq!(m.energy_j(), 0.0);
        assert_eq!(m.avg_power_w(), POWER_IDLE_W);
        assert_eq!(m.avg_gpu_pct(), GPU_IDLE_PCT);
        assert_eq!(m.gpu_busy_frac(), 0.0);
        assert_eq!(m.inferences(), 0);
    }

    #[test]
    fn single_interval_math_is_exact() {
        let mut m = EnergyMeter::new();
        m.on_interval(0.0, 2.0, DnnKind::Y416);
        m.advance_to(10.0);
        // 10 s idle floor + 2 s of (7.5 - 2.6) W above idle
        let expect = POWER_IDLE_W * 10.0 + 2.0 * (7.5 - POWER_IDLE_W);
        assert!((m.energy_j() - expect).abs() < 1e-12);
        assert!((m.avg_power_w() - expect / 10.0).abs() < 1e-12);
        assert!((m.gpu_busy_frac() - 0.2).abs() < 1e-12);
        // mean GPU: idle + 20% of (91 - idle)
        let gpu = GPU_IDLE_PCT + 0.2 * (91.0 - GPU_IDLE_PCT);
        assert!((m.avg_gpu_pct() - gpu).abs() < 1e-12);
        assert_eq!(m.inferences(), 1);
        assert!(
            (m.energy_per_dnn_j()[DnnKind::Y416.index()] - 2.0 * 7.5).abs()
                < 1e-12
        );
    }

    #[test]
    fn advance_is_monotone() {
        let mut m = EnergyMeter::new();
        m.advance_to(5.0);
        m.advance_to(2.0); // no-op
        assert_eq!(m.duration_s(), 5.0);
        m.on_interval(1.0, 7.0, DnnKind::TinyY288);
        assert_eq!(m.duration_s(), 7.0);
    }

    #[test]
    fn from_trace_matches_incremental() {
        let mut t = ScheduleTrace::default();
        t.push(0.0, 0.027, DnnKind::TinyY288);
        t.push(0.1, 0.253, DnnKind::Y416);
        t.duration = 2.0;
        let post = EnergyMeter::from_trace(&t);

        let mut inc = EnergyMeter::new();
        inc.on_interval(0.0, 0.027, DnnKind::TinyY288);
        inc.on_interval(0.1, 0.253, DnnKind::Y416);
        inc.advance_to(2.0);
        assert_eq!(post.summary(), inc.summary());
    }

    #[test]
    fn from_trace_repairs_double_booked_input() {
        // overlapping intervals meter their union, exactly like the
        // tegrastats sampler's normalised view
        let mut t = ScheduleTrace::default();
        t.push(0.0, 1.0, DnnKind::Y416);
        t.push(0.5, 1.5, DnnKind::Y416);
        t.duration = 2.0;
        let m = EnergyMeter::from_trace(&t);
        assert!((m.busy_total_s() - 1.5).abs() < 1e-12);
        assert!((m.gpu_busy_frac() - 0.75).abs() < 1e-12);
        let expect =
            POWER_IDLE_W * 2.0 + 1.5 * (7.5 - POWER_IDLE_W);
        assert!((m.energy_j() - expect).abs() < 1e-12);
    }

    #[test]
    fn saturated_run_reads_active_power() {
        let mut m = EnergyMeter::new();
        m.on_interval(0.0, 30.0, DnnKind::Y288);
        assert!((m.avg_power_w() - 7.2).abs() < 1e-12);
        assert!((m.avg_gpu_pct() - 84.0).abs() < 1e-12);
        assert!((m.gpu_busy_frac() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn active_scale_cuts_dynamic_power_only() {
        let mut nominal = EnergyMeter::new();
        let mut capped = EnergyMeter::with_active_scale(0.49);
        for m in [&mut nominal, &mut capped] {
            m.on_interval(0.0, 1.0, DnnKind::Y416);
            m.advance_to(2.0);
        }
        let idle = POWER_IDLE_W * 2.0;
        let nom_active = nominal.energy_j() - idle;
        let cap_active = capped.energy_j() - idle;
        assert!((cap_active - 0.49 * nom_active).abs() < 1e-12);
        // utilisation is unaffected by the power scale
        assert_eq!(nominal.gpu_busy_frac(), capped.gpu_busy_frac());
    }

    #[test]
    fn zero_length_intervals_add_nothing() {
        let mut m = EnergyMeter::new();
        m.on_interval(1.0, 1.0, DnnKind::Y416);
        assert_eq!(m.busy_total_s(), 0.0);
        assert_eq!(m.inferences(), 1);
        assert_eq!(m.duration_s(), 1.0);
    }
}
