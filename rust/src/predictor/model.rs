//! The per-DNN projected-accuracy model: a binned size×speed lookup
//! table with bilinear interpolation.
//!
//! Each cell holds the AP a DNN achieved on a calibration sequence whose
//! objects match the cell's (size, speed) operating point *under the
//! real-time drop-frame accounting* — so a cell value already prices in
//! the DNN's computational demand (a heavy net that drops four of every
//! five frames and carries stale boxes scores poorly at high speed even
//! though its per-frame accuracy is the best). Projecting accuracy is
//! then a pure table lookup, which is what keeps runtime selection in
//! the paper's "negligible overhead" envelope.

use crate::coordinator::policy::Thresholds;
use crate::features::FrameFeatures;
use crate::DnnKind;

/// Current schema version of the persisted table (see `store.rs`).
pub const TABLE_VERSION: u32 = 1;

/// Relative half-width of the boundary blend band used by
/// [`CalibrationTable::from_ladder`]: interpolation between regions is
/// confined to `h * (1 ± LADDER_EPS)` around each threshold.
const LADDER_EPS: f64 = 1e-9;

/// Binned size×speed projected-accuracy table for the four DNNs.
///
/// Axes hold ascending *cell-center* coordinates: `size_axis` in MBBS
/// units (box area as a fraction of the frame), `speed_axis` in frame
/// diagonals per frame (the [`crate::features`] speed unit). Lookups
/// interpolate bilinearly between neighbouring centers and clamp at the
/// edges.
#[derive(Debug, Clone, PartialEq)]
pub struct CalibrationTable {
    /// Evaluation FPS the table was calibrated under (drop-frame cost
    /// depends on the frame budget, so tables are per-FPS).
    pub fps: f64,
    /// Ascending MBBS cell centers.
    pub size_axis: Vec<f64>,
    /// Ascending speed cell centers, frame diagonals per frame.
    pub speed_axis: Vec<f64>,
    /// `ap[dnn.index()][size_idx][speed_idx]`, each in [0, 1].
    pub ap: Vec<Vec<Vec<f64>>>,
}

impl CalibrationTable {
    /// Build and validate a table. Panics on malformed shapes — tables
    /// from untrusted input go through `store::from_json`, which
    /// validates first and reports errors instead.
    pub fn new(
        fps: f64,
        size_axis: Vec<f64>,
        speed_axis: Vec<f64>,
        ap: Vec<Vec<Vec<f64>>>,
    ) -> Self {
        let t = CalibrationTable { fps, size_axis, speed_axis, ap };
        if let Err(e) = t.validate() {
            panic!("invalid calibration table: {e}");
        }
        t
    }

    /// Structural validation shared by the constructor and the store.
    pub fn validate(&self) -> Result<(), String> {
        if !(self.fps > 0.0) {
            return Err(format!("fps must be positive, got {}", self.fps));
        }
        for (name, axis) in
            [("size_axis", &self.size_axis), ("speed_axis", &self.speed_axis)]
        {
            if axis.is_empty() {
                return Err(format!("{name} must be non-empty"));
            }
            if !axis.windows(2).all(|w| w[0] < w[1]) {
                return Err(format!("{name} must be strictly ascending"));
            }
            if !axis.iter().all(|v| v.is_finite() && *v >= 0.0) {
                return Err(format!("{name} must be finite and >= 0"));
            }
        }
        if self.ap.len() != DnnKind::COUNT {
            return Err(format!(
                "need {} DNN grids, got {}",
                DnnKind::COUNT,
                self.ap.len()
            ));
        }
        for (d, grid) in self.ap.iter().enumerate() {
            if grid.len() != self.size_axis.len() {
                return Err(format!(
                    "dnn {d}: {} size rows, axis has {}",
                    grid.len(),
                    self.size_axis.len()
                ));
            }
            for row in grid {
                if row.len() != self.speed_axis.len() {
                    return Err(format!(
                        "dnn {d}: {} speed cells, axis has {}",
                        row.len(),
                        self.speed_axis.len()
                    ));
                }
                if !row.iter().all(|v| v.is_finite() && (0.0..=1.0).contains(v))
                {
                    return Err(format!("dnn {d}: AP cells must be in [0,1]"));
                }
            }
        }
        Ok(())
    }

    /// Projected AP of `dnn` at an operating point, by bilinear
    /// interpolation over the cell centers (clamped at the axis edges).
    pub fn project(&self, dnn: DnnKind, size: f64, speed: f64) -> f64 {
        let (i0, i1, t) = bracket(&self.size_axis, size);
        let (j0, j1, u) = bracket(&self.speed_axis, speed);
        let g = &self.ap[dnn.index()];
        (1.0 - t) * (1.0 - u) * g[i0][j0]
            + t * (1.0 - u) * g[i1][j0]
            + (1.0 - t) * u * g[i0][j1]
            + t * u * g[i1][j1]
    }

    /// Projected AP for a feature vector (size = MBBS, speed channel).
    pub fn project_features(&self, dnn: DnnKind, f: &FrameFeatures) -> f64 {
        self.project(dnn, f.mbbs, f.speed)
    }

    /// Total number of (dnn × size × speed) cells.
    pub fn n_cells(&self) -> usize {
        DnnKind::COUNT * self.size_axis.len() * self.speed_axis.len()
    }

    /// A degenerate, size-only table that reproduces an MBBS threshold
    /// ladder: one speed bin, and size cells arranged so that the
    /// argmax-projected DNN in each threshold region is exactly the rung
    /// Algorithm 1 would pick. Used by the golden equivalence test and
    /// as a calibration-free fallback.
    ///
    /// Cell centers sit just inside each region boundary
    /// (`h * (1 ± 1e-9)`), so interpolation only blends regions within a
    /// vanishing band around the thresholds themselves.
    pub fn from_ladder(thresholds: &Thresholds, ladder: &[DnnKind]) -> Self {
        let h = thresholds.values();
        assert_eq!(
            h.len() + 1,
            ladder.len(),
            "need |ladder| - 1 thresholds"
        );
        // region r (ascending size) selects ladder[len - 1 - r]
        let n_regions = ladder.len();
        let mut size_axis = Vec::new();
        let mut regions: Vec<usize> = Vec::new(); // region of each center
        for (r, &hv) in h.iter().enumerate() {
            size_axis.push(hv * (1.0 - LADDER_EPS));
            regions.push(r);
            size_axis.push(hv * (1.0 + LADDER_EPS));
            regions.push(r + 1);
        }
        let mut ap =
            vec![
                vec![vec![0.0; 1]; size_axis.len()];
                DnnKind::COUNT
            ];
        for (ci, &r) in regions.iter().enumerate() {
            let intended = n_regions - 1 - r; // ladder position
            for (pos, &dnn) in ladder.iter().enumerate() {
                let dist = (pos as i64 - intended as i64).unsigned_abs();
                ap[dnn.index()][ci][0] = 1.0 - 0.2 * dist as f64;
            }
        }
        CalibrationTable::new(30.0, size_axis, vec![0.0], ap)
    }
}

/// Find the bracketing indices and interpolation weight of `x` on an
/// ascending axis: returns `(i0, i1, t)` with `t` in [0, 1]; clamps
/// outside the axis range.
fn bracket(axis: &[f64], x: f64) -> (usize, usize, f64) {
    let n = axis.len();
    if n == 1 || x <= axis[0] {
        return (0, 0, 0.0);
    }
    if x >= axis[n - 1] {
        return (n - 1, n - 1, 0.0);
    }
    // Monotone bin lookup via binary search. With the edge clamps above,
    // a finite x is strictly inside (axis[0], axis[n-1]), so the
    // partition point of `v < x` lies in [1, n-1] and `i` reproduces the
    // reference linear scan's "last cell with axis[i+1] >= x not yet
    // passed" exactly (pinned by `bracket_matches_reference_scan`).
    // A NaN x makes every compare false (partition point 0); .max(1)
    // lands on the scan's i = 0 / t = NaN result instead of
    // underflowing. Calibration axes today are tiny, but AyE-Edge-style
    // deployment search sweeps dense tables where the per-lookup O(n)
    // scan was measurable.
    let i = axis.partition_point(|v| *v < x).max(1) - 1;
    let t = (x - axis[i]) / (axis[i + 1] - axis[i]);
    (i, i + 1, t)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flat_table(values: [f64; 4]) -> CalibrationTable {
        let ap = values
            .iter()
            .map(|&v| vec![vec![v; 2]; 2])
            .collect();
        CalibrationTable::new(
            30.0,
            vec![0.01, 0.05],
            vec![0.0, 0.01],
            ap,
        )
    }

    /// The pre-optimisation linear scan, kept as the equivalence oracle
    /// for the `partition_point` lookup.
    fn bracket_reference(axis: &[f64], x: f64) -> (usize, usize, f64) {
        let n = axis.len();
        if n == 1 || x <= axis[0] {
            return (0, 0, 0.0);
        }
        if x >= axis[n - 1] {
            return (n - 1, n - 1, 0.0);
        }
        let mut i = 0;
        while axis[i + 1] < x {
            i += 1;
        }
        let t = (x - axis[i]) / (axis[i + 1] - axis[i]);
        (i, i + 1, t)
    }

    #[test]
    fn bracket_matches_reference_scan() {
        use crate::testing::prop::PropConfig;
        PropConfig::default().run("bracket == linear scan", |g| {
            // strictly ascending axis of 1..12 cells
            let n = g.usize_in(1, 12);
            let mut axis = Vec::with_capacity(n);
            let mut v = g.f64_in(0.0, 0.01);
            for _ in 0..n {
                axis.push(v);
                v += g.f64_in(1e-9, 0.05);
            }
            // probe inside, outside, and exactly on cell centers
            let x = match g.usize_in(0, 3) {
                0 => g.f64_in(-0.05, 0.6),
                1 => axis[g.usize_in(0, n - 1)],
                2 => f64::NAN,
                _ => g.f64_in(0.0, 0.3),
            };
            let got = bracket(&axis, x);
            let want = bracket_reference(&axis, x);
            // NaN t values compare equal only via bits
            got.0 == want.0
                && got.1 == want.1
                && (got.2 == want.2
                    || (got.2.is_nan() && want.2.is_nan()))
        });
    }

    #[test]
    fn bracket_clamps_and_interpolates() {
        let axis = [1.0, 2.0, 4.0];
        assert_eq!(bracket(&axis, 0.5), (0, 0, 0.0));
        assert_eq!(bracket(&axis, 9.0), (2, 2, 0.0));
        let (i0, i1, t) = bracket(&axis, 3.0);
        assert_eq!((i0, i1), (1, 2));
        assert!((t - 0.5).abs() < 1e-12);
        assert_eq!(bracket(&[5.0], 100.0), (0, 0, 0.0));
    }

    #[test]
    fn flat_table_projects_constant() {
        let t = flat_table([0.1, 0.2, 0.3, 0.4]);
        for (i, k) in DnnKind::ALL.iter().enumerate() {
            for (s, v) in [(0.0, 0.0), (0.03, 0.005), (1.0, 1.0)] {
                let p = t.project(*k, s, v);
                assert!((p - 0.1 * (i + 1) as f64).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn bilinear_midpoint() {
        // one dnn grid with distinct corners; query the center
        let mut ap = vec![vec![vec![0.0; 2]; 2]; 4];
        ap[0] = vec![vec![0.0, 1.0], vec![1.0, 1.0]];
        let t = CalibrationTable::new(
            30.0,
            vec![0.0, 0.1],
            vec![0.0, 0.02],
            ap,
        );
        let mid = t.project(DnnKind::TinyY288, 0.05, 0.01);
        assert!((mid - 0.75).abs() < 1e-12);
        // corner values are reproduced exactly
        assert_eq!(t.project(DnnKind::TinyY288, 0.0, 0.0), 0.0);
        assert_eq!(t.project(DnnKind::TinyY288, 0.1, 0.02), 1.0);
    }

    #[test]
    fn ladder_table_argmax_matches_regions() {
        let th = Thresholds::h_opt();
        let t = CalibrationTable::from_ladder(&th, &DnnKind::ALL);
        let argmax = |size: f64| {
            let mut best = DnnKind::TinyY288;
            let mut best_v = f64::NEG_INFINITY;
            for k in DnnKind::ALL {
                let v = t.project(k, size, 0.0);
                if v > best_v {
                    best_v = v;
                    best = k;
                }
            }
            best
        };
        assert_eq!(argmax(0.0), DnnKind::Y416);
        assert_eq!(argmax(0.004), DnnKind::Y416);
        assert_eq!(argmax(0.0071), DnnKind::Y288);
        assert_eq!(argmax(0.02), DnnKind::Y288);
        assert_eq!(argmax(0.035), DnnKind::TinyY416);
        assert_eq!(argmax(0.05), DnnKind::TinyY288);
        assert_eq!(argmax(0.9), DnnKind::TinyY288);
    }

    #[test]
    fn ladder_table_supports_short_ladders() {
        let th = Thresholds::new(vec![0.01]).unwrap();
        let t = CalibrationTable::from_ladder(
            &th,
            &[DnnKind::Y288, DnnKind::Y416],
        );
        // DNNs outside the ladder project to 0 and can never win
        assert_eq!(t.project(DnnKind::TinyY288, 0.5, 0.0), 0.0);
        assert!(t.project(DnnKind::Y288, 0.5, 0.0) > 0.9);
        assert!(t.project(DnnKind::Y416, 0.005, 0.0) > 0.9);
    }

    #[test]
    fn validate_rejects_malformed() {
        let good = flat_table([0.1, 0.2, 0.3, 0.4]);
        assert!(good.validate().is_ok());
        let mut bad = good.clone();
        bad.size_axis = vec![0.05, 0.01]; // descending
        assert!(bad.validate().is_err());
        let mut bad = good.clone();
        bad.ap[2][1] = vec![0.5]; // ragged speed row
        assert!(bad.validate().is_err());
        let mut bad = good.clone();
        bad.ap[0][0][0] = 1.5; // out of [0,1]
        assert!(bad.validate().is_err());
        let mut bad = good.clone();
        bad.ap.pop(); // missing a dnn grid
        assert!(bad.validate().is_err());
        let mut bad = good;
        bad.fps = 0.0;
        assert!(bad.validate().is_err());
    }
}
