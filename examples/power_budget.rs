//! The paper's resource-saving claim, end to end: on the MOT17-05-like
//! stream, budgeted TOD keeps (or beats) the accuracy of the best
//! budget-feasible fixed DNN while its metered board power and GPU-busy
//! fraction stay far below an always-YOLOv4-416 deployment — the shape
//! of the paper's "45.1% of GPU resource, 62.7% of board power" result.
//!
//! ```bash
//! cargo run --release --example power_budget
//! ```

use tod::coordinator::policy::{FixedPolicy, MbbsPolicy, SelectionPolicy};
use tod::coordinator::scheduler::{run_realtime, OracleBackend, RunResult};
use tod::dataset::catalog::{generate, SequenceId};
use tod::power::{BudgetedPolicy, PowerBudget, RateCap};
use tod::sim::latency::LatencyModel;
use tod::sim::oracle::OracleDetector;
use tod::DnnKind;

fn main() {
    let id = SequenceId::Mot05;
    let seq = generate(id);
    let fps = id.eval_fps();
    let watts_cap = tod::app::DEFAULT_WATTS_BUDGET;
    let make_detector = || {
        OracleBackend(OracleDetector::new(
            seq.spec.seed,
            seq.spec.width as f64,
            seq.spec.height as f64,
        ))
    };
    let run = |policy: &mut dyn SelectionPolicy| -> RunResult {
        let mut lat = LatencyModel::deterministic();
        run_realtime(&seq, policy, &mut make_detector(), &mut lat, fps)
    };

    println!(
        "{} @ {fps} FPS under a {watts_cap} W budget (1 s window)\n",
        id.name()
    );

    // 1. Every fixed DNN: which ones are even budget-feasible?
    let mut fixed: Vec<RunResult> = Vec::new();
    for k in DnnKind::ALL {
        fixed.push(run(&mut FixedPolicy(k)));
    }

    // 2. Plain TOD and budget-governed TOD.
    let r_tod = run(&mut MbbsPolicy::tod_default());
    let mut budgeted = BudgetedPolicy::masking(
        Box::new(MbbsPolicy::tod_default()),
        PowerBudget::watts(watts_cap, &LatencyModel::deterministic()),
    );
    let r_budgeted = run(&mut budgeted);

    // 3. A DVFS alternative: cap the clock instead of masking DNNs.
    let rc = RateCap::new(0.7);
    let mut lat_capped = rc.stretch(&LatencyModel::deterministic());
    let mut tod_pol = MbbsPolicy::tod_default();
    let r_capped = run_realtime(
        &seq,
        &mut tod_pol,
        &mut make_detector(),
        &mut lat_capped,
        fps,
    );

    println!(
        "{:<34} {:>6} {:>8} {:>10} {:>9}",
        "policy", "AP", "power W", "GPU busy%", "feasible?"
    );
    for r in fixed.iter().chain([&r_tod, &r_budgeted]) {
        println!(
            "{:<34} {:>6.3} {:>8.2} {:>10.1} {:>9}",
            r.policy,
            r.ap,
            r.power.avg_power_w,
            r.power.gpu_busy_frac * 100.0,
            if r.power.avg_power_w <= watts_cap { "yes" } else { "NO" }
        );
    }
    println!(
        "{:<34} {:>6.3} {:>8} {:>10.1}   (latency x{:.2})",
        format!("{} @ rate-cap 0.7", r_capped.policy),
        r_capped.ap,
        "-",
        r_capped.power.gpu_busy_frac * 100.0,
        rc.latency_factor()
    );

    let y416 = &fixed[DnnKind::Y416.index()];
    println!(
        "\nbudgeted TOD vs always-Y-416: {:.1}% of the power, {:.1}% of \
         the GPU\n(paper §IV.D reports 62.7% and 45.1% on MOT17-05)",
        r_budgeted.power.avg_power_w / y416.power.avg_power_w * 100.0,
        r_budgeted.power.gpu_busy_frac / y416.power.gpu_busy_frac * 100.0
    );
}
