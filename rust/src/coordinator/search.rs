//! Table I: the hyperparameter grid search for `{h1, h2, h3}`.
//!
//! The paper sweeps `h1 ∈ {0.0007, 0.007}`, `h2 ∈ {0.008, 0.03}`,
//! `h3 ∈ {0.04, 0.1}` over the six MOT17Det training sequences at 30 FPS
//! and picks the set with the best mean AP, tie-breaking towards the set
//! that "can utilise the most lightweight DNN more often" (lower `h3`).

use crate::coordinator::policy::{MbbsPolicy, ThresholdError, Thresholds};
use crate::coordinator::scheduler::{run_realtime, Detector, RunResult};
use crate::dataset::synth::Sequence;
use crate::sim::latency::LatencyModel;
use crate::sim::oracle::OracleDetector;

/// The candidate values per threshold.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    pub h1: Vec<f64>,
    pub h2: Vec<f64>,
    pub h3: Vec<f64>,
}

impl SearchSpace {
    /// The paper's 2x2x2 grid (§III.B.4).
    pub fn paper() -> Self {
        SearchSpace {
            h1: vec![0.0007, 0.007],
            h2: vec![0.008, 0.03],
            h3: vec![0.04, 0.1],
        }
    }

    /// All valid (ascending) combinations. Non-ascending orderings in
    /// the grid are skipped, as in the paper's Table I; out-of-range
    /// values are a misconfigured space and panic loudly rather than
    /// silently shrinking the search.
    pub fn combinations(&self) -> Vec<Thresholds> {
        let mut out = Vec::new();
        for &a in &self.h1 {
            for &b in &self.h2 {
                for &c in &self.h3 {
                    match Thresholds::new(vec![a, b, c]) {
                        Ok(t) => out.push(t),
                        Err(ThresholdError::NotAscending(_)) => {}
                        // tod-lint: allow(srv-panic) reason="offline grid-search tool rejecting a malformed axis; never on the serving path"
                        Err(e) => panic!("invalid search space: {e}"),
                    }
                }
            }
        }
        out
    }
}

/// One grid-search row (one hyperparameter set).
#[derive(Debug, Clone)]
pub struct GridRow {
    pub thresholds: Thresholds,
    /// AP per training sequence, in input order.
    pub per_sequence_ap: Vec<f64>,
    pub mean_ap: f64,
}

/// Full grid-search output.
#[derive(Debug, Clone)]
pub struct GridSearchResult {
    pub rows: Vec<GridRow>,
    /// Index of the selected row in `rows`.
    pub best: usize,
}

impl GridSearchResult {
    pub fn best_thresholds(&self) -> &Thresholds {
        &self.rows[self.best].thresholds
    }
}

/// AP ties within this margin (about the paper's print precision)
/// break towards lighter DNN usage, mirroring the paper's choice of
/// h3 = 0.04 over 0.1 at equal 0.537 mean AP.
pub const TIE_EPS: f64 = 2.5e-3;

/// Run the grid search over training sequences at their eval FPS.
///
/// `make_detector` builds a fresh backend per sequence (the oracle is
/// per-sequence because frame sizes differ).
pub fn grid_search(
    space: &SearchSpace,
    train: &[(&Sequence, f64)],
    mut make_detector: impl FnMut(&Sequence) -> Box<dyn Detector>,
) -> GridSearchResult {
    let mut rows = Vec::new();
    for th in space.combinations() {
        let mut aps = Vec::with_capacity(train.len());
        for &(seq, fps) in train {
            let mut policy = MbbsPolicy::new(th.clone());
            let mut det = make_detector(seq);
            // paired comparisons: deterministic latency, per-seq oracle
            let mut lat = LatencyModel::deterministic();
            let r: RunResult =
                run_realtime(seq, &mut policy, det.as_mut(), &mut lat, fps);
            aps.push(r.ap);
        }
        let mean = aps.iter().sum::<f64>() / aps.len().max(1) as f64;
        rows.push(GridRow {
            thresholds: th,
            per_sequence_ap: aps,
            mean_ap: mean,
        });
    }
    // best mean AP; ties (within 0.0005, the paper's print precision)
    // break towards lighter usage: lower h3, then lower h2, then lower h1
    let mut best = 0usize;
    for i in 1..rows.len() {
        let cur = &rows[i];
        let b = &rows[best];
        if cur.mean_ap > b.mean_ap + TIE_EPS {
            best = i;
        } else if (cur.mean_ap - b.mean_ap).abs() <= TIE_EPS {
            let (c, bb) = (cur.thresholds.values(), b.thresholds.values());
            if (c[2], c[1], c[0]) < (bb[2], bb[1], bb[0]) {
                best = i;
            }
        }
    }
    GridSearchResult { rows, best }
}

/// Convenience: oracle-backed grid search.
pub fn grid_search_oracle(
    space: &SearchSpace,
    train: &[(&Sequence, f64)],
) -> GridSearchResult {
    grid_search(space, train, |seq| {
        Box::new(crate::coordinator::scheduler::OracleBackend(
            OracleDetector::new(
                seq.spec.seed,
                seq.spec.width as f64,
                seq.spec.height as f64,
            ),
        ))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth::{CameraMotion, SequenceSpec};

    fn seq(ref_height: f64, camera: CameraMotion, seed: u64) -> Sequence {
        Sequence::generate(SequenceSpec {
            name: format!("S{seed}"),
            width: 960,
            height: 540,
            fps: 30.0,
            frames: 90,
            density: 8,
            ref_height,
            depth_range: (1.0, 2.0),
            walk_speed: 1.5,
            camera,
            seed,
        })
    }

    #[test]
    fn paper_space_has_eight_sets() {
        let space = SearchSpace::paper();
        assert_eq!(space.combinations().len(), 8);
    }

    #[test]
    fn invalid_orderings_filtered() {
        let space = SearchSpace {
            h1: vec![0.01, 0.05],
            h2: vec![0.03],
            h3: vec![0.04],
        };
        // (0.05, 0.03, 0.04) violates ascending order -> only 1 combo
        assert_eq!(space.combinations().len(), 1);
    }

    #[test]
    fn search_returns_rows_for_every_set() {
        let s1 = seq(90.0, CameraMotion::Static, 1);
        let s2 = seq(280.0, CameraMotion::Walking { pan_speed: 5.0 }, 2);
        let train = vec![(&s1, 30.0), (&s2, 30.0)];
        let res = grid_search_oracle(&SearchSpace::paper(), &train);
        assert_eq!(res.rows.len(), 8);
        for row in &res.rows {
            assert_eq!(row.per_sequence_ap.len(), 2);
            for ap in &row.per_sequence_ap {
                assert!((0.0..=1.0).contains(ap));
            }
            let mean = row.per_sequence_ap.iter().sum::<f64>() / 2.0;
            assert!((mean - row.mean_ap).abs() < 1e-12);
        }
        let best = &res.rows[res.best];
        for row in &res.rows {
            assert!(best.mean_ap >= row.mean_ap - 5e-4);
        }
    }

    #[test]
    fn tie_break_prefers_lighter_usage() {
        // two identical sequences -> if two rows tie, lower h3 wins;
        // simulate directly on the result structure
        let rows = vec![
            GridRow {
                thresholds: Thresholds::new(vec![0.007, 0.03, 0.1]).unwrap(),
                per_sequence_ap: vec![0.5],
                mean_ap: 0.5,
            },
            GridRow {
                thresholds: Thresholds::new(vec![0.007, 0.03, 0.04]).unwrap(),
                per_sequence_ap: vec![0.5],
                mean_ap: 0.5,
            },
        ];
        // re-run the selection logic via grid_search on a stub space is
        // awkward; instead assert the comparator ordering directly
        let c = rows[1].thresholds.values();
        let b = rows[0].thresholds.values();
        assert!((c[2], c[1], c[0]) < (b[2], b[1], b[0]));
    }

    #[test]
    fn deterministic_search() {
        let s1 = seq(150.0, CameraMotion::Static, 3);
        let train = vec![(&s1, 30.0)];
        let a = grid_search_oracle(&SearchSpace::paper(), &train);
        let b = grid_search_oracle(&SearchSpace::paper(), &train);
        assert_eq!(a.best, b.best);
        for (ra, rb) in a.rows.iter().zip(&b.rows) {
            assert_eq!(ra.mean_ap, rb.mean_ap);
        }
    }
}
