//! Axis-aligned boxes and the IoU machinery underlying matching, NMS and
//! the MBBS statistic.

/// Axis-aligned bounding box: top-left corner + size, in pixels.
/// This matches the MOT ground-truth convention (`bb_left, bb_top,
/// bb_width, bb_height`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BBox {
    pub x: f64,
    pub y: f64,
    pub w: f64,
    pub h: f64,
}

impl BBox {
    pub fn new(x: f64, y: f64, w: f64, h: f64) -> Self {
        BBox { x, y, w, h }
    }

    /// Construct from a center point + size.
    pub fn from_center(cx: f64, cy: f64, w: f64, h: f64) -> Self {
        BBox { x: cx - w / 2.0, y: cy - h / 2.0, w, h }
    }

    pub fn center(&self) -> (f64, f64) {
        (self.x + self.w / 2.0, self.y + self.h / 2.0)
    }

    pub fn right(&self) -> f64 {
        self.x + self.w
    }

    pub fn bottom(&self) -> f64 {
        self.y + self.h
    }

    /// Area in square pixels; degenerate boxes have zero area.
    pub fn area(&self) -> f64 {
        self.w.max(0.0) * self.h.max(0.0)
    }

    /// Area as a fraction of a `fw x fh` frame — the unit of the paper's
    /// MBBS hyperparameters (`h1%` of the image etc.).
    pub fn area_frac(&self, fw: f64, fh: f64) -> f64 {
        if fw <= 0.0 || fh <= 0.0 {
            return 0.0;
        }
        self.area() / (fw * fh)
    }

    /// Intersection area with another box.
    pub fn intersection(&self, other: &BBox) -> f64 {
        let ix = (self.right().min(other.right()) - self.x.max(other.x))
            .max(0.0);
        let iy = (self.bottom().min(other.bottom()) - self.y.max(other.y))
            .max(0.0);
        ix * iy
    }

    /// Intersection-over-union in `[0, 1]`.
    pub fn iou(&self, other: &BBox) -> f64 {
        let inter = self.intersection(other);
        let union = self.area() + other.area() - inter;
        if union <= 0.0 {
            0.0
        } else {
            inter / union
        }
    }

    /// Clip to a `fw x fh` frame. Boxes fully outside collapse to zero
    /// width/height at the frame edge.
    pub fn clip(&self, fw: f64, fh: f64) -> BBox {
        let x0 = self.x.clamp(0.0, fw);
        let y0 = self.y.clamp(0.0, fh);
        let x1 = self.right().clamp(0.0, fw);
        let y1 = self.bottom().clamp(0.0, fh);
        BBox { x: x0, y: y0, w: (x1 - x0).max(0.0), h: (y1 - y0).max(0.0) }
    }

    /// Translate by (dx, dy).
    pub fn shifted(&self, dx: f64, dy: f64) -> BBox {
        BBox { x: self.x + dx, y: self.y + dy, ..*self }
    }

    /// Scale around the box center.
    pub fn scaled(&self, sx: f64, sy: f64) -> BBox {
        let (cx, cy) = self.center();
        BBox::from_center(cx, cy, self.w * sx, self.h * sy)
    }

    pub fn is_degenerate(&self) -> bool {
        self.w <= 0.0 || self.h <= 0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn b(x: f64, y: f64, w: f64, h: f64) -> BBox {
        BBox::new(x, y, w, h)
    }

    #[test]
    fn iou_identical_is_one() {
        let a = b(10.0, 20.0, 30.0, 40.0);
        assert!((a.iou(&a) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn iou_disjoint_is_zero() {
        assert_eq!(b(0., 0., 10., 10.).iou(&b(20., 20., 5., 5.)), 0.0);
        // touching edges count as zero intersection
        assert_eq!(b(0., 0., 10., 10.).iou(&b(10., 0., 10., 10.)), 0.0);
    }

    #[test]
    fn iou_half_overlap() {
        // two 10x10 boxes overlapping in a 5x10 strip: inter 50, union 150
        let a = b(0., 0., 10., 10.);
        let c = b(5., 0., 10., 10.);
        assert!((a.iou(&c) - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn iou_is_symmetric() {
        let a = b(0., 0., 12., 7.);
        let c = b(3., 2., 9., 11.);
        assert!((a.iou(&c) - c.iou(&a)).abs() < 1e-15);
    }

    #[test]
    fn degenerate_boxes() {
        let z = b(5., 5., 0., 10.);
        assert!(z.is_degenerate());
        assert_eq!(z.area(), 0.0);
        assert_eq!(z.iou(&b(0., 0., 10., 10.)), 0.0);
    }

    #[test]
    fn area_frac() {
        let a = b(0., 0., 96., 108.);
        // 96*108 / (1920*1080) = 0.005
        assert!((a.area_frac(1920., 1080.) - 0.005).abs() < 1e-12);
        assert_eq!(a.area_frac(0., 100.), 0.0);
    }

    #[test]
    fn clip_inside_partial_outside() {
        let a = b(-10., -10., 30., 30.);
        let c = a.clip(100., 100.);
        assert_eq!((c.x, c.y, c.w, c.h), (0., 0., 20., 20.));
        let far = b(500., 500., 10., 10.).clip(100., 100.);
        assert!(far.is_degenerate());
        let inside = b(10., 10., 5., 5.);
        assert_eq!(inside.clip(100., 100.), inside);
    }

    #[test]
    fn center_roundtrip() {
        let a = BBox::from_center(50., 60., 20., 10.);
        assert_eq!(a.center(), (50., 60.));
        assert_eq!((a.x, a.y), (40., 55.));
    }

    #[test]
    fn shift_and_scale() {
        let a = b(10., 10., 10., 10.);
        let s = a.shifted(5., -5.);
        assert_eq!((s.x, s.y), (15., 5.));
        let sc = a.scaled(2.0, 0.5);
        assert_eq!(sc.center(), a.center());
        assert!((sc.w - 20.0).abs() < 1e-12);
        assert!((sc.h - 5.0).abs() < 1e-12);
    }
}
