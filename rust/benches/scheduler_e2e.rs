//! Bench: end-to-end scheduled runs — the cost of regenerating each
//! paper artifact (Table I cell = one of these per hyperparameter set).

use tod::bench::{black_box, Bench};
use tod::coordinator::policy::{FixedPolicy, MbbsPolicy};
use tod::coordinator::scheduler::{run_offline, run_realtime, OracleBackend};
use tod::dataset::catalog::{generate, SequenceId};
use tod::sim::latency::LatencyModel;
use tod::sim::oracle::OracleDetector;
use tod::DnnKind;

fn main() {
    let mut b = Bench::slow();
    let seq = generate(SequenceId::Mot05);
    let mk = || {
        OracleBackend(OracleDetector::new(
            seq.spec.seed,
            seq.spec.width as f64,
            seq.spec.height as f64,
        ))
    };

    b.case("run_realtime/tod_mot05_837f", || {
        let mut pol = MbbsPolicy::tod_default();
        let mut lat = LatencyModel::deterministic();
        black_box(run_realtime(&seq, &mut pol, &mut mk(), &mut lat, 14.0));
    });

    b.case("run_realtime/fixed_y416_mot05", || {
        let mut pol = FixedPolicy(DnnKind::Y416);
        let mut lat = LatencyModel::deterministic();
        black_box(run_realtime(&seq, &mut pol, &mut mk(), &mut lat, 14.0));
    });

    b.case("run_offline/y416_mot05", || {
        black_box(run_offline(&seq, DnnKind::Y416, &mut mk()));
    });

    // sequence generation itself (world simulation)
    b.case("dataset/generate_mot05", || {
        black_box(generate(SequenceId::Mot05));
    });

    b.save_csv("scheduler_e2e.csv").ok();
}
