//! The `power` experiment: the paper's Fig. 13/14-style resource table
//! with the budget governor in the comparison.
//!
//! For MOT17-05 (the paper's resource-headline sequence) and the full
//! synth catalog, reports accuracy, metered board power and GPU-busy
//! fraction for every fixed DNN, plain TOD, and TOD under the default
//! watts budget — plus each configuration's power/GPU ratio against the
//! unbudgeted always-YOLOv4-416 deployment (the paper's 62.7% / 45.1%
//! claim shape).

use crate::app::{Campaign, DEFAULT_WATTS_BUDGET};
use crate::dataset::catalog::SequenceId;
use crate::power::PowerSummary;
use crate::util::csv::CsvTable;
use crate::util::table::AsciiTable;
use crate::DnnKind;

use super::ExperimentOutput;

/// One configuration's row: MOT17-05 figures + catalog-mean AP.
struct Row {
    label: String,
    ap_mot05: f64,
    ap_catalog: f64,
    power: PowerSummary,
}

pub fn power_table(c: &mut Campaign) -> ExperimentOutput {
    let cap = DEFAULT_WATTS_BUDGET;
    let id = SequenceId::Mot05;
    let n = SequenceId::ALL.len() as f64;

    let mut rows: Vec<Row> = Vec::new();
    for k in DnnKind::ALL {
        let ap_catalog = SequenceId::ALL
            .iter()
            .map(|&s| c.realtime_fixed(s, k).ap)
            .sum::<f64>()
            / n;
        let r = c.realtime_fixed(id, k);
        rows.push(Row {
            label: k.artifact_name().to_string(),
            ap_mot05: r.ap,
            ap_catalog,
            power: r.power,
        });
    }
    let tod_catalog =
        SequenceId::ALL.iter().map(|&s| c.tod(s).ap).sum::<f64>() / n;
    let tod = c.tod(id);
    rows.push(Row {
        label: "TOD".into(),
        ap_mot05: tod.ap,
        ap_catalog: tod_catalog,
        power: tod.power,
    });
    let bud_catalog = SequenceId::ALL
        .iter()
        .map(|&s| c.power_budgeted(s, cap).ap)
        .sum::<f64>()
        / n;
    let bud = c.power_budgeted(id, cap);
    rows.push(Row {
        label: format!("TOD+budget({cap}W)"),
        ap_mot05: bud.ap,
        ap_catalog: bud_catalog,
        power: bud.power,
    });

    let y416 = rows[DnnKind::Y416.index()].power;
    let header = vec![
        "policy",
        "ap_mot05",
        "ap_catalog",
        "power_w_mot05",
        "gpu_busy_pct_mot05",
        "power_vs_y416_pct",
        "gpu_vs_y416_pct",
    ];
    let mut table = AsciiTable::new(
        "power — accuracy vs GPU/board-power budget (MOT17-05 + catalog)",
        header.clone(),
    );
    let mut csv = CsvTable::new(header);
    for r in &rows {
        let row = vec![
            r.label.clone(),
            format!("{:.3}", r.ap_mot05),
            format!("{:.3}", r.ap_catalog),
            format!("{:.2}", r.power.avg_power_w),
            format!("{:.1}", r.power.gpu_busy_frac * 100.0),
            format!(
                "{:.1}",
                r.power.avg_power_w / y416.avg_power_w * 100.0
            ),
            format!(
                "{:.1}",
                r.power.gpu_busy_frac / y416.gpu_busy_frac * 100.0
            ),
        ];
        table.push(row.clone());
        csv.push(row);
    }
    let bud_row = rows.last().expect("budgeted row exists");
    let text = format!(
        "{}\n(budget: {cap} W over a 1 s sliding window; paper §IV.D: \
         TOD reaches Y-416 accuracy on MOT17-05 at 45.1% GPU and 62.7% \
         power — budgeted TOD here runs at {:.1}% GPU and {:.1}% power \
         of always-Y-416)\n",
        table.render(),
        bud_row.power.gpu_busy_frac / y416.gpu_busy_frac * 100.0,
        bud_row.power.avg_power_w / y416.avg_power_w * 100.0,
    );
    ExperimentOutput {
        id: "power",
        title: "power: budgeted accuracy/energy table".into(),
        text,
        csv: vec![("power_budget.csv".into(), csv)],
    }
}
