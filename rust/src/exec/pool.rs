//! Fixed-size worker thread pool over the bounded channel.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::exec::channel::{bounded, Sender};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads executing submitted closures.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    in_flight: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `n` workers with a task queue of `queue_cap` (backpressure:
    /// `submit` blocks when the queue is full).
    pub fn new(n: usize, queue_cap: usize) -> Self {
        assert!(n > 0);
        let (tx, rx) = bounded::<Job>(queue_cap.max(1));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|i| {
                let rx = rx.clone();
                let in_flight = in_flight.clone();
                std::thread::Builder::new()
                    .name(format!("tod-worker-{i}"))
                    .spawn(move || {
                        while let Some(job) = rx.recv() {
                            job();
                            in_flight.fetch_sub(1, Ordering::SeqCst);
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, in_flight }
    }

    /// Submit a job; blocks when the queue is full.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .ok();
    }

    /// Jobs submitted but not yet finished.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// Busy-wait (with yield) until all submitted jobs finished.
    pub fn wait_idle(&self) {
        while self.in_flight() > 0 {
            std::thread::yield_now();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the queue
        for w in self.workers.drain(..) {
            w.join().ok();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4, 16);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn drop_joins_workers() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(2, 4);
            for _ in 0..10 {
                let c = counter.clone();
                pool.submit(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop waits for queue drain
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn parallelism_actually_happens() {
        let pool = ThreadPool::new(4, 8);
        let t0 = std::time::Instant::now();
        for _ in 0..8 {
            pool.submit(|| {
                std::thread::sleep(std::time::Duration::from_millis(25))
            });
        }
        pool.wait_idle();
        let elapsed = t0.elapsed();
        // serial would be 200 ms; 4 workers should finish in ~50 ms
        assert!(elapsed.as_millis() < 150, "elapsed {elapsed:?}");
    }
}
