//! Synthetic pedestrian-world generator: the MOT17Det stand-in.
//!
//! TOD's selection signal is the distribution of bounding-box *sizes* and
//! the apparent object *speed* — both of which this generator controls
//! directly, which is the substitution argument of DESIGN.md §3. Each
//! sequence simulates pedestrians on a ground plane seen through a
//! perspective camera:
//!
//! * a pedestrian at normalized depth `d` gets a screen box of height
//!   `h_ref / d` (perspective scaling) and moves at `v_world / d` px/frame;
//! * camera motion ([`CameraMotion`]) adds a global screen-space flow —
//!   static, walking-speed pan, or car-speed flow, mirroring the paper's
//!   three MOT17 camera groups;
//! * objects leave/enter the frame, occlude (visibility dips), and respawn
//!   so density stays roughly constant.
//!
//! Output is per-frame MOT ground truth ([`crate::dataset::mot::GtEntry`]),
//! deterministic in the sequence seed.

use crate::dataset::mot::{GtEntry, MotClass};
use crate::geometry::BBox;
use crate::util::rng::Rng;

/// Camera motion model (the paper's three dataset groups, §III.B.4).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CameraMotion {
    /// Fixed camera (MOT17-02, -04, -10).
    Static,
    /// Camera carried at walking speed: slow pan, px/frame at depth 1.
    Walking { pan_speed: f64 },
    /// Vehicle-mounted camera: fast global flow (MOT17-13).
    Vehicle { flow_speed: f64 },
}

impl CameraMotion {
    /// Screen-space flow added to every object, scaled by inverse depth.
    fn flow(&self, t: f64) -> (f64, f64) {
        match self {
            CameraMotion::Static => (0.0, 0.0),
            CameraMotion::Walking { pan_speed } => {
                // constant pan plus a gentle vertical sway (walking gait)
                (*pan_speed, 0.15 * pan_speed * (0.9 * t).sin())
            }
            CameraMotion::Vehicle { flow_speed } => (*flow_speed, 0.0),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            CameraMotion::Static => "static",
            CameraMotion::Walking { .. } => "walking",
            CameraMotion::Vehicle { .. } => "vehicle",
        }
    }
}

/// Everything needed to synthesize one sequence.
#[derive(Debug, Clone)]
pub struct SequenceSpec {
    /// MOT-style name, e.g. "MOT17-04".
    pub name: String,
    pub width: u32,
    pub height: u32,
    /// Native capture frame rate.
    pub fps: f64,
    pub frames: u64,
    /// Target number of simultaneously visible pedestrians.
    pub density: usize,
    /// Reference box height (px) for an object at depth 1.0.
    pub ref_height: f64,
    /// Depth range [near, far]; box height scales as ref_height / depth.
    pub depth_range: (f64, f64),
    /// Pedestrian world speed, px/frame at depth 1.0.
    pub walk_speed: f64,
    pub camera: CameraMotion,
    /// Seed for the deterministic world.
    pub seed: u64,
}

impl SequenceSpec {
    /// Apparent screen speed (px/frame) of a median-depth object,
    /// including camera flow — the "object moving speed" statistic the
    /// paper's hyperparameter search responds to.
    pub fn apparent_speed(&self) -> f64 {
        let d = (self.depth_range.0 + self.depth_range.1) / 2.0;
        let cam = match self.camera {
            CameraMotion::Static => 0.0,
            CameraMotion::Walking { pan_speed } => pan_speed.abs(),
            CameraMotion::Vehicle { flow_speed } => flow_speed.abs(),
        };
        self.walk_speed / d + cam / d
    }

    /// Median box area as a fraction of the frame, for a mid-depth
    /// object with the standard 0.41 aspect ratio.
    pub fn nominal_area_frac(&self) -> f64 {
        let d = (self.depth_range.0 + self.depth_range.1) / 2.0;
        let h = self.ref_height / d;
        let w = h * 0.41;
        (w * h) / (self.width as f64 * self.height as f64)
    }
}

#[derive(Debug, Clone)]
struct Pedestrian {
    id: i64,
    /// Center position, px.
    x: f64,
    y: f64,
    /// Normalized depth (1 = near).
    depth: f64,
    /// World-space velocity, px/frame at depth 1.
    vx: f64,
    vy: f64,
    /// Occlusion phase in [0, 2π), advanced per frame.
    occ_phase: f64,
    occ_rate: f64,
}

impl Pedestrian {
    fn bbox(&self, spec: &SequenceSpec) -> BBox {
        let h = spec.ref_height / self.depth;
        let w = h * 0.41; // pedestrian aspect ratio (MOT-typical)
        BBox::from_center(self.x, self.y, w, h)
    }

    fn visibility(&self) -> f64 {
        // smooth occlusion cycles; mostly visible with occasional dips
        let v = 0.75 + 0.35 * (self.occ_phase).sin();
        v.clamp(0.05, 1.0)
    }
}

/// A generated sequence: spec + per-frame ground truth.
#[derive(Debug, Clone)]
pub struct Sequence {
    pub spec: SequenceSpec,
    /// `frames[f]` = gt rows for frame f+1 (MOT frames are 1-based).
    pub frames: Vec<Vec<GtEntry>>,
}

impl Sequence {
    /// Generate the sequence from its spec (deterministic in spec.seed).
    pub fn generate(spec: SequenceSpec) -> Sequence {
        let mut rng = Rng::new(spec.seed);
        let mut next_id: i64 = 1;
        let mut peds: Vec<Pedestrian> = (0..spec.density)
            .map(|_| spawn(&spec, &mut rng, &mut next_id, true))
            .collect();
        let mut frames = Vec::with_capacity(spec.frames as usize);
        for f in 0..spec.frames {
            let t = f as f64;
            let (cam_vx, cam_vy) = spec.camera.flow(t);
            // advance world
            for p in peds.iter_mut() {
                p.x += p.vx / p.depth + cam_vx / p.depth;
                p.y += p.vy / p.depth + cam_vy / p.depth;
                p.occ_phase += p.occ_rate;
                // small velocity jitter: pedestrians weave
                p.vx += rng.normal(0.0, 0.02);
                p.vy += rng.normal(0.0, 0.01);
                // depth drift (walking towards/away from the camera)
                p.depth = (p.depth + rng.normal(0.0, 0.002)).clamp(
                    spec.depth_range.0 * 0.8,
                    spec.depth_range.1 * 1.2,
                );
            }
            // respawn pedestrians that left the frame
            let w = spec.width as f64;
            let h = spec.height as f64;
            for p in peds.iter_mut() {
                let b = p.bbox(&spec);
                if b.right() < -40.0
                    || b.x > w + 40.0
                    || b.bottom() < -40.0
                    || b.y > h + 40.0
                {
                    *p = spawn(&spec, &mut rng, &mut next_id, false);
                }
            }
            // emit ground truth
            let mut rows = Vec::with_capacity(peds.len());
            for p in &peds {
                let b = p.bbox(&spec).clip(w, h);
                if b.is_degenerate() || b.area() < 4.0 {
                    continue;
                }
                let class = if p.vx.abs() + p.vy.abs() < 0.05 {
                    MotClass::StaticPerson
                } else {
                    MotClass::Pedestrian
                };
                rows.push(GtEntry {
                    frame: f + 1,
                    id: p.id,
                    bbox: b,
                    conf: 1.0,
                    class,
                    visibility: p.visibility(),
                });
            }
            frames.push(rows);
        }
        Sequence { spec, frames }
    }

    /// Ground truth for a 1-based frame id.
    pub fn gt(&self, frame: u64) -> &[GtEntry] {
        &self.frames[(frame - 1) as usize]
    }

    pub fn n_frames(&self) -> u64 {
        self.frames.len() as u64
    }

    /// All gt rows flattened (for MOT file export).
    pub fn all_entries(&self) -> Vec<GtEntry> {
        self.frames.iter().flatten().cloned().collect()
    }

    /// Per-frame median gt box area fraction — the Fig. 9 series.
    pub fn mbbs_series(&self) -> Vec<f64> {
        let w = self.spec.width as f64;
        let h = self.spec.height as f64;
        self.frames
            .iter()
            .map(|rows| {
                let areas: Vec<f64> =
                    rows.iter().map(|r| r.bbox.area_frac(w, h)).collect();
                if areas.is_empty() {
                    0.0
                } else {
                    crate::util::stats::median(&areas)
                }
            })
            .collect()
    }
}

fn spawn(
    spec: &SequenceSpec,
    rng: &mut Rng,
    next_id: &mut i64,
    anywhere: bool,
) -> Pedestrian {
    let id = *next_id;
    *next_id += 1;
    let w = spec.width as f64;
    let h = spec.height as f64;
    let depth = rng.uniform(spec.depth_range.0, spec.depth_range.1);
    // spawn across the frame initially; later at the edges (entering)
    let x = if anywhere {
        rng.uniform(0.05 * w, 0.95 * w)
    } else if rng.chance(0.5) {
        rng.uniform(-30.0, 10.0)
    } else {
        rng.uniform(w - 10.0, w + 30.0)
    };
    let y = rng.uniform(0.35 * h, 0.9 * h);
    let speed = spec.walk_speed * rng.uniform(0.6, 1.4);
    let dir = if rng.chance(0.5) { 1.0 } else { -1.0 };
    Pedestrian {
        id,
        x,
        y,
        depth,
        vx: dir * speed,
        vy: rng.normal(0.0, 0.05 * speed.max(0.1)),
        occ_phase: rng.uniform(0.0, std::f64::consts::TAU),
        occ_rate: rng.uniform(0.01, 0.06),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> SequenceSpec {
        SequenceSpec {
            name: "TEST-01".into(),
            width: 640,
            height: 480,
            fps: 30.0,
            frames: 60,
            density: 8,
            ref_height: 120.0,
            depth_range: (1.0, 3.0),
            walk_speed: 2.0,
            camera: CameraMotion::Static,
            seed: 7,
        }
    }

    #[test]
    fn deterministic_generation() {
        let a = Sequence::generate(spec());
        let b = Sequence::generate(spec());
        assert_eq!(a.all_entries(), b.all_entries());
        let mut s2 = spec();
        s2.seed = 8;
        let c = Sequence::generate(s2);
        assert_ne!(a.all_entries(), c.all_entries());
    }

    #[test]
    fn frames_and_ids_are_valid() {
        let s = Sequence::generate(spec());
        assert_eq!(s.n_frames(), 60);
        for (i, rows) in s.frames.iter().enumerate() {
            for r in rows {
                assert_eq!(r.frame, i as u64 + 1);
                assert!(r.id >= 1);
                assert!(!r.bbox.is_degenerate());
                assert!(r.bbox.x >= 0.0 && r.bbox.y >= 0.0);
                assert!(r.bbox.right() <= 640.0 + 1e-9);
                assert!(r.bbox.bottom() <= 480.0 + 1e-9);
                assert!((0.0..=1.0).contains(&r.visibility));
            }
        }
    }

    #[test]
    fn density_roughly_maintained() {
        let s = Sequence::generate(spec());
        let counts: Vec<usize> = s.frames.iter().map(Vec::len).collect();
        let mean =
            counts.iter().sum::<usize>() as f64 / counts.len() as f64;
        assert!(mean > 4.0, "mean visible {mean}");
    }

    #[test]
    fn static_camera_boxes_move_slowly() {
        let s = Sequence::generate(spec());
        // track id 1 across consecutive frames; displacement stays small
        let mut prev: Option<BBox> = None;
        let mut max_step: f64 = 0.0;
        for rows in &s.frames {
            if let Some(r) = rows.iter().find(|r| r.id == 1) {
                if let Some(p) = prev {
                    let (cx, cy) = r.bbox.center();
                    let (px, py) = p.center();
                    max_step =
                        max_step.max(((cx - px).powi(2) + (cy - py).powi(2)).sqrt());
                }
                prev = Some(r.bbox);
            } else {
                prev = None;
            }
        }
        assert!(max_step < 15.0, "static-cam step {max_step}");
    }

    #[test]
    fn vehicle_camera_moves_boxes_fast() {
        let mut sp = spec();
        sp.camera = CameraMotion::Vehicle { flow_speed: 25.0 };
        sp.name = "TEST-CAR".into();
        let s = Sequence::generate(sp);
        // mean |dx| across tracked boxes must reflect the camera flow
        let mut steps = Vec::new();
        for w in s.frames.windows(2) {
            for r in &w[1] {
                if let Some(p) = w[0].iter().find(|p| p.id == r.id) {
                    steps.push((r.bbox.center().0 - p.bbox.center().0).abs());
                }
            }
        }
        let mean = steps.iter().sum::<f64>() / steps.len().max(1) as f64;
        assert!(mean > 5.0, "vehicle-cam mean step {mean}");
    }

    #[test]
    fn apparent_speed_pinned_for_all_motion_models() {
        // walk 2.0 at mid depth 2.0 contributes 1.0 px/frame everywhere
        let mut s = spec(); // Static
        assert!((s.apparent_speed() - 1.0).abs() < 1e-12);
        s.camera = CameraMotion::Walking { pan_speed: 6.0 };
        assert!((s.apparent_speed() - (1.0 + 3.0)).abs() < 1e-12);
        s.camera = CameraMotion::Walking { pan_speed: -6.0 };
        assert!((s.apparent_speed() - (1.0 + 3.0)).abs() < 1e-12);
        s.camera = CameraMotion::Vehicle { flow_speed: 25.0 };
        assert!((s.apparent_speed() - (1.0 + 12.5)).abs() < 1e-12);
    }

    #[test]
    fn walking_flow_is_constant_pan_plus_sway() {
        let cam = CameraMotion::Walking { pan_speed: 8.0 };
        for t in [0.0, 1.0, 7.5, 42.0] {
            let (vx, vy) = cam.flow(t);
            assert_eq!(vx, 8.0, "pan must be the constant pan_speed");
            let sway = 0.15 * 8.0 * (0.9 * t).sin();
            assert!((vy - sway).abs() < 1e-12);
            assert!(vy.abs() <= 0.15 * 8.0 + 1e-12);
        }
    }

    #[test]
    fn mbbs_series_in_range() {
        let s = Sequence::generate(spec());
        let series = s.mbbs_series();
        assert_eq!(series.len(), 60);
        for v in series {
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn nominal_area_matches_generated_median() {
        let s = Sequence::generate(spec());
        let series = s.mbbs_series();
        let med = crate::util::stats::median(&series);
        let nominal = s.spec.nominal_area_frac();
        // generated median within 3x of the analytic nominal
        assert!(
            med > nominal / 3.0 && med < nominal * 3.0,
            "median {med} vs nominal {nominal}"
        );
    }
}
