"""im2col + fused-matmul convolution vs direct lax.conv oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.conv import conv2d_fused, im2col
from compile.kernels import ref


def _rand(shape, seed, scale=0.2):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("kh,kw", [(1, 1), (3, 3)])
@pytest.mark.parametrize("h,w,cin,cout", [(8, 8, 3, 5), (16, 12, 7, 9)])
def test_conv_matches_lax(stride, kh, kw, h, w, cin, cout):
    x = _rand((1, h, w, cin), seed=h + w)
    wt = _rand((kh, kw, cin, cout), seed=cin * cout)
    b = _rand((cout,), seed=cout)
    out = conv2d_fused(x, wt, b, stride=stride)
    expect = ref.ref_conv2d_bias_act(x, wt, b, stride=stride)
    assert out.shape == expect.shape
    np.testing.assert_allclose(out, expect, rtol=5e-4, atol=5e-4)


def test_conv_activation_modes():
    x = _rand((1, 6, 6, 2), seed=0)
    wt = _rand((3, 3, 2, 4), seed=1)
    b = _rand((4,), seed=2)
    for act in ["linear", "relu", "leaky_relu"]:
        out = conv2d_fused(x, wt, b, activation=act)
        expect = ref.ref_conv2d_bias_act(x, wt, b, activation=act)
        np.testing.assert_allclose(out, expect, rtol=5e-4, atol=5e-4)


def test_im2col_layout_matches_hwio():
    """Patch feature axis must be ordered (kh, kw, c): a conv via im2col
    with identity-like weights must equal lax.conv exactly."""
    x = _rand((1, 5, 5, 3), seed=3)
    wt = _rand((3, 3, 3, 2), seed=4)
    patches = im2col(x, 3, 3, 1)
    out = patches.reshape(-1, 27) @ wt.reshape(27, 2)
    expect = ref.ref_conv2d_bias_act(
        x, wt, jnp.zeros((2,), jnp.float32), activation="linear"
    )
    np.testing.assert_allclose(
        out.reshape(1, 5, 5, 2), expect, rtol=1e-4, atol=1e-5
    )


def test_batch_dim():
    x = _rand((3, 8, 8, 2), seed=5)
    wt = _rand((3, 3, 2, 4), seed=6)
    b = _rand((4,), seed=7)
    out = conv2d_fused(x, wt, b)
    expect = ref.ref_conv2d_bias_act(x, wt, b)
    np.testing.assert_allclose(out, expect, rtol=5e-4, atol=5e-4)


@settings(max_examples=20, deadline=None)
@given(
    h=st.integers(2, 12).map(lambda v: 2 * v),
    cin=st.integers(1, 8),
    cout=st.integers(1, 12),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_conv_sweep(h, cin, cout, stride, seed):
    x = _rand((1, h, h, cin), seed=seed)
    wt = _rand((3, 3, cin, cout), seed=seed + 1)
    b = _rand((cout,), seed=seed + 2)
    out = conv2d_fused(x, wt, b, stride=stride)
    expect = ref.ref_conv2d_bias_act(x, wt, b, stride=stride)
    np.testing.assert_allclose(out, expect, rtol=1e-3, atol=1e-3)
