//! The paper's full pipeline on the pedestrian catalog: hyperparameter
//! search on the six training sequences, then evaluation of the chosen
//! H_opt against every fixed baseline on all seven sequences — with the
//! telemetry summary of §IV.D.
//!
//! ```bash
//! cargo run --release --example pedestrian_campaign
//! ```

use tod::app::Campaign;
use tod::coordinator::search::{grid_search_oracle, SearchSpace};
use tod::dataset::catalog::{generate, SequenceId};
use tod::telemetry::tegrastats::TegrastatsSim;
use tod::util::table::AsciiTable;
use tod::DnnKind;

fn main() {
    // ---- phase 1: hyperparameter search (Table I) --------------------
    println!("phase 1: hyperparameter grid search over training sequences");
    let train_seqs: Vec<_> =
        SequenceId::TRAIN.iter().map(|&id| generate(id)).collect();
    let train: Vec<(&_, f64)> =
        train_seqs.iter().map(|s| (s, 30.0)).collect();
    let result = grid_search_oracle(&SearchSpace::paper(), &train);
    let h = result.best_thresholds().clone();
    let hv = h.values().to_vec();
    println!(
        "  H_opt = {{{}, {}, {}}} (mean AP {:.3})\n",
        hv[0],
        hv[1],
        hv[2],
        result.rows[result.best].mean_ap
    );

    // ---- phase 2: campaign evaluation with H_opt ----------------------
    println!("phase 2: evaluating TOD{{H_opt}} vs fixed DNNs (real-time)");
    let mut campaign = Campaign::with_thresholds(h);
    let mut table = AsciiTable::new(
        "",
        vec!["sequence", "best-fixed", "AP", "TOD AP", "TOD picks"],
    );
    for id in SequenceId::ALL {
        let (best_kind, best_ap) = campaign.best_fixed_realtime(id);
        let tod = campaign.tod(id).clone();
        let freq = tod.deploy_freq();
        let dominant = DnnKind::ALL
            .iter()
            .max_by(|a, b| {
                freq[a.index()].partial_cmp(&freq[b.index()]).unwrap()
            })
            .unwrap();
        table.push(vec![
            id.name().to_string(),
            best_kind.artifact_name().to_string(),
            format!("{best_ap:.3}"),
            format!("{:.3}", tod.ap),
            format!(
                "{} {:.0}%",
                dominant.short_label(),
                freq[dominant.index()] * 100.0
            ),
        ]);
    }
    println!("{}", table.render());

    let imp = campaign.improvement_over_fixed();
    println!(
        "TOD mean-AP improvement: {:+.1}% vs tiny-288, {:+.1}% vs tiny-416, \
         {:+.1}% vs 288, {:+.1}% vs 416",
        imp[0], imp[1], imp[2], imp[3]
    );

    // ---- phase 3: telemetry (§IV.D) -----------------------------------
    let sim = TegrastatsSim::default();
    let tod_trace = campaign.tod(SequenceId::Mot05).trace.clone();
    let y416_trace = campaign
        .realtime_fixed(SequenceId::Mot05, DnnKind::Y416)
        .trace
        .clone();
    println!(
        "\nMOT17-05 telemetry: TOD {:.1} W / {:.1}% GPU vs always-Y-416 \
         {:.1} W / {:.1}% GPU",
        sim.mean_power(&tod_trace),
        sim.mean_gpu(&tod_trace),
        sim.mean_power(&y416_trace),
        sim.mean_gpu(&y416_trace),
    );
}
