//! 1 Hz sampling of power and GPU utilisation from a schedule's busy
//! intervals (the NVidia tegrastats default resolution the paper uses).

use crate::sim::profiles::{DnnProfile, GPU_IDLE_PCT, POWER_IDLE_W};
use crate::DnnKind;

/// The DNN-busy intervals produced by one scheduled run.
#[derive(Debug, Clone, Default)]
pub struct ScheduleTrace {
    /// (start, end, dnn) in stream seconds. Producers append in
    /// schedule order; consumers that need ordering/non-overlap go
    /// through [`ScheduleTrace::normalised_busy`], which repairs
    /// out-of-order or overlapping input in release builds too (the
    /// multi-stream merge can interleave streams arbitrarily).
    pub busy: Vec<(f64, f64, DnnKind)>,
    /// Total stream duration, seconds.
    pub duration: f64,
}

impl ScheduleTrace {
    pub fn push(&mut self, start: f64, end: f64, dnn: DnnKind) {
        debug_assert!(end >= start, "interval ends before it starts");
        self.busy.push((start, end, dnn));
        self.duration = self.duration.max(end);
    }

    /// True when `busy` is sorted by start and non-overlapping — the
    /// invariant every serialised scheduler maintains.
    fn is_normalised(&self) -> bool {
        let mut prev_end = f64::NEG_INFINITY;
        for &(s, e, _) in &self.busy {
            if s < prev_end || e < s {
                return false;
            }
            prev_end = e;
        }
        true
    }

    /// The busy list with ordering/non-overlap guaranteed: the common
    /// (already valid) case borrows; out-of-order or overlapping input
    /// is sorted and overlap-clipped (later intervals keep only the
    /// time not already claimed — busy time becomes the union, so
    /// duty cycles and 1 Hz samples can never double-count a
    /// double-booked accelerator).
    pub fn normalised_busy(
        &self,
    ) -> std::borrow::Cow<'_, [(f64, f64, DnnKind)]> {
        if self.is_normalised() {
            return std::borrow::Cow::Borrowed(&self.busy);
        }
        let mut sorted = self.busy.clone();
        sorted.sort_by(|a, b| {
            (a.0, a.1).partial_cmp(&(b.0, b.1)).expect("finite times")
        });
        let mut out: Vec<(f64, f64, DnnKind)> =
            Vec::with_capacity(sorted.len());
        let mut claimed_until = f64::NEG_INFINITY;
        for (s, e, d) in sorted {
            let clipped = s.max(claimed_until);
            if e > clipped {
                out.push((clipped, e, d));
                claimed_until = e;
            }
        }
        std::borrow::Cow::Owned(out)
    }

    /// Busy fraction per DNN over the whole run (overlap-repaired).
    pub fn duty_cycle(&self) -> [f64; DnnKind::COUNT] {
        let mut out = [0.0; DnnKind::COUNT];
        if self.duration <= 0.0 {
            return out;
        }
        for &(s, e, d) in self.normalised_busy().iter() {
            out[d.index()] += (e - s) / self.duration;
        }
        out
    }
}

/// One tegrastats sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TelemetrySample {
    /// Window start, seconds.
    pub t: f64,
    /// Mean board power over the window, watts.
    pub power_w: f64,
    /// Mean GPU utilisation over the window, percent.
    pub gpu_util_pct: f64,
}

/// The sampler.
#[derive(Debug, Clone)]
pub struct TegrastatsSim {
    profiles: [DnnProfile; DnnKind::COUNT],
    /// Sampling resolution, seconds (tegrastats default: 1.0).
    pub resolution: f64,
}

impl Default for TegrastatsSim {
    fn default() -> Self {
        TegrastatsSim {
            profiles: DnnKind::ALL.map(DnnProfile::of),
            resolution: 1.0,
        }
    }
}

impl TegrastatsSim {
    /// Length of the sampling window starting at `t` — the resolution,
    /// except for the final partial window, which is clipped to the
    /// trace duration so its mean covers only elapsed time.
    fn window_len(&self, trace: &ScheduleTrace, t: f64) -> f64 {
        (trace.duration - t).min(self.resolution)
    }

    /// Sample a schedule trace at the configured resolution. Each
    /// sample is the mean power/GPU over its (possibly clipped final)
    /// window, so `Σ power · window_len` equals the trace's total
    /// energy exactly — pinned by the energy-conservation tests and by
    /// equality with [`crate::power::EnergyMeter`].
    pub fn sample(&self, trace: &ScheduleTrace) -> Vec<TelemetrySample> {
        let n = (trace.duration / self.resolution).ceil() as usize;
        let busy = trace.normalised_busy();
        let mut samples = Vec::with_capacity(n);
        for i in 0..n {
            let w0 = i as f64 * self.resolution;
            let len = self.window_len(trace, w0);
            if len <= 0.0 {
                break;
            }
            let w1 = w0 + len;
            let mut busy_frac = [0.0f64; DnnKind::COUNT];
            for &(s, e, d) in busy.iter() {
                let overlap = (e.min(w1) - s.max(w0)).max(0.0);
                busy_frac[d.index()] += overlap / len;
            }
            let mut power = POWER_IDLE_W;
            let mut gpu = GPU_IDLE_PCT;
            for (k, frac) in busy_frac.iter().enumerate() {
                let p = &self.profiles[k];
                power += frac * (p.power_active_w - POWER_IDLE_W);
                gpu += frac * (p.gpu_util_pct - GPU_IDLE_PCT);
            }
            samples.push(TelemetrySample {
                t: w0,
                power_w: power,
                gpu_util_pct: gpu.min(100.0),
            });
        }
        samples
    }

    /// Mean power over a trace, watts (time-weighted — the final
    /// partial window counts only its elapsed length, so this equals
    /// total energy over total duration).
    pub fn mean_power(&self, trace: &ScheduleTrace) -> f64 {
        self.weighted_mean(trace, |s| s.power_w, POWER_IDLE_W)
    }

    /// Mean GPU utilisation over a trace, percent (time-weighted).
    pub fn mean_gpu(&self, trace: &ScheduleTrace) -> f64 {
        self.weighted_mean(trace, |s| s.gpu_util_pct, GPU_IDLE_PCT)
    }

    fn weighted_mean(
        &self,
        trace: &ScheduleTrace,
        value: impl Fn(&TelemetrySample) -> f64,
        empty: f64,
    ) -> f64 {
        let samples = self.sample(trace);
        let mut acc = 0.0;
        let mut total = 0.0;
        for s in &samples {
            let len = self.window_len(trace, s.t);
            acc += value(s) * len;
            total += len;
        }
        if total <= 0.0 {
            empty
        } else {
            acc / total
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::power::EnergyMeter;
    use crate::sim::profiles::mem_loaded_gb;

    fn saturated_trace(dnn: DnnKind, secs: f64) -> ScheduleTrace {
        let mut t = ScheduleTrace::default();
        // back-to-back inferences with no idle gaps
        let lat = DnnProfile::of(dnn).latency_mean_s;
        let mut now = 0.0;
        while now < secs {
            t.push(now, (now + lat).min(secs), dnn);
            now += lat;
        }
        t.duration = secs;
        t
    }

    /// Σ sample power × window length — the discrete energy readout.
    fn sampled_energy_j(sim: &TegrastatsSim, t: &ScheduleTrace) -> f64 {
        sim.sample(t)
            .iter()
            .map(|s| s.power_w * sim.window_len(t, s.t))
            .sum()
    }

    #[test]
    fn saturated_y416_hits_active_power() {
        let sim = TegrastatsSim::default();
        let t = saturated_trace(DnnKind::Y416, 30.0);
        let p = sim.mean_power(&t);
        assert!((p - 7.5).abs() < 0.05, "power {p}");
        let g = sim.mean_gpu(&t);
        assert!((g - 91.0).abs() < 0.5, "gpu {g}");
    }

    #[test]
    fn idle_trace_is_idle() {
        let sim = TegrastatsSim::default();
        let t = ScheduleTrace { busy: vec![], duration: 10.0 };
        assert!((sim.mean_power(&t) - POWER_IDLE_W).abs() < 1e-9);
        assert!((sim.mean_gpu(&t) - GPU_IDLE_PCT).abs() < 1e-9);
    }

    #[test]
    fn empty_zero_duration_trace_yields_no_samples() {
        let sim = TegrastatsSim::default();
        let t = ScheduleTrace::default();
        assert!(sim.sample(&t).is_empty());
        assert_eq!(sim.mean_power(&t), POWER_IDLE_W);
        assert_eq!(sim.mean_gpu(&t), GPU_IDLE_PCT);
        assert_eq!(t.duty_cycle(), [0.0; DnnKind::COUNT]);
    }

    #[test]
    fn zero_duration_interval_adds_no_energy() {
        let sim = TegrastatsSim::default();
        let mut t = ScheduleTrace::default();
        t.push(0.5, 0.5, DnnKind::Y416);
        t.duration = 2.0;
        assert!((sim.mean_power(&t) - POWER_IDLE_W).abs() < 1e-12);
        assert!(
            (sampled_energy_j(&sim, &t) - POWER_IDLE_W * 2.0).abs() < 1e-12
        );
    }

    #[test]
    fn duty_cycle_scales_power() {
        // tiny-288 at 30 FPS: busy 27/33.3 ms = 81% of the time
        let sim = TegrastatsSim::default();
        let mut t = ScheduleTrace::default();
        let mut now = 0.0f64;
        for _ in 0..300 {
            t.push(now, now + 0.027, DnnKind::TinyY288);
            now += 1.0 / 30.0;
        }
        t.duration = now;
        let duty = t.duty_cycle()[0];
        assert!((duty - 0.81).abs() < 0.01, "duty {duty}");
        let p = sim.mean_power(&t);
        let expect = POWER_IDLE_W + duty * (3.8 - POWER_IDLE_W);
        assert!((p - expect).abs() < 0.05, "power {p} vs {expect}");
    }

    #[test]
    fn samples_cover_duration_at_1hz() {
        let sim = TegrastatsSim::default();
        let t = saturated_trace(DnnKind::Y288, 12.5);
        let s = sim.sample(&t);
        assert_eq!(s.len(), 13);
        assert_eq!(s[0].t, 0.0);
        assert_eq!(s[12].t, 12.0);
        // the final partial window is saturated too: its mean is over
        // the elapsed half-second, not a phantom full second
        assert!((s[12].power_w - 7.2).abs() < 1e-9);
    }

    #[test]
    fn interval_spanning_a_window_boundary_splits_energy() {
        let sim = TegrastatsSim::default();
        let mut t = ScheduleTrace::default();
        // 0.8..1.2: 0.2 s in window 0, 0.2 s in window 1
        t.push(0.8, 1.2, DnnKind::Y416);
        t.duration = 2.0;
        let s = sim.sample(&t);
        assert_eq!(s.len(), 2);
        let expect = POWER_IDLE_W + 0.2 * (7.5 - POWER_IDLE_W);
        assert!((s[0].power_w - expect).abs() < 1e-12);
        assert!((s[1].power_w - expect).abs() < 1e-12);
        // and the split conserves the interval's energy
        let meter = EnergyMeter::from_trace(&t);
        assert!(
            (sampled_energy_j(&sim, &t) - meter.energy_j()).abs() < 1e-9
        );
    }

    #[test]
    fn partial_final_window_conserves_energy() {
        // 2.3 s trace: 3 windows, the last 0.3 s long; window energies
        // must sum to the trace energy exactly
        let sim = TegrastatsSim::default();
        let mut t = ScheduleTrace::default();
        t.push(0.1, 0.8, DnnKind::TinyY416);
        t.push(1.9, 2.3, DnnKind::Y288);
        t.duration = 2.3;
        let s = sim.sample(&t);
        assert_eq!(s.len(), 3);
        let meter = EnergyMeter::from_trace(&t);
        assert!(
            (sampled_energy_j(&sim, &t) - meter.energy_j()).abs() < 1e-9,
            "sampled {} vs metered {}",
            sampled_energy_j(&sim, &t),
            meter.energy_j()
        );
        // time-weighted mean power equals the meter's average power
        assert!(
            (sim.mean_power(&t) - meter.avg_power_w()).abs() < 1e-9
        );
        assert!((sim.mean_gpu(&t) - meter.avg_gpu_pct()).abs() < 1e-9);
    }

    #[test]
    fn mixed_schedule_power_between_extremes() {
        let sim = TegrastatsSim::default();
        let mut t = ScheduleTrace::default();
        // half the time tiny-288, half Y-416, saturated
        let mut now = 0.0;
        while now < 10.0 {
            t.push(now, now + 0.027, DnnKind::TinyY288);
            now += 0.027;
        }
        while now < 20.0 {
            t.push(now, now + 0.153, DnnKind::Y416);
            now += 0.153;
        }
        t.duration = 20.0;
        let p = sim.mean_power(&t);
        assert!(p > 3.8 && p < 7.5, "power {p}");
    }

    #[test]
    fn gpu_never_exceeds_100() {
        let sim = TegrastatsSim::default();
        let mut t = ScheduleTrace::default();
        // pathological overlapping intervals
        t.push(0.0, 1.0, DnnKind::Y416);
        t.push(0.0, 1.0, DnnKind::Y288);
        t.duration = 1.0;
        for s in sim.sample(&t) {
            assert!(s.gpu_util_pct <= 100.0);
        }
    }

    #[test]
    fn out_of_order_trace_is_repaired() {
        // multistream merges can interleave; sampling and duty cycles
        // must not depend on push order
        let mut ordered = ScheduleTrace::default();
        ordered.push(0.2, 0.4, DnnKind::TinyY288);
        ordered.push(1.1, 1.3, DnnKind::Y416);
        ordered.duration = 2.0;
        let mut shuffled = ScheduleTrace::default();
        shuffled.busy.push((1.1, 1.3, DnnKind::Y416));
        shuffled.busy.push((0.2, 0.4, DnnKind::TinyY288));
        shuffled.duration = 2.0;
        assert_eq!(ordered.duty_cycle(), shuffled.duty_cycle());
        let sim = TegrastatsSim::default();
        assert_eq!(sim.sample(&ordered), sim.sample(&shuffled));
        assert_eq!(
            shuffled.normalised_busy().as_ref(),
            ordered.busy.as_slice()
        );
    }

    #[test]
    fn overlapping_trace_counts_union_busy_time() {
        // a double-booked accelerator cannot read above active power
        let mut t = ScheduleTrace::default();
        t.push(0.0, 1.0, DnnKind::Y416);
        t.push(0.5, 1.5, DnnKind::Y416);
        t.duration = 2.0;
        // union busy = 1.5 s of 2.0 s
        let duty = t.duty_cycle()[DnnKind::Y416.index()];
        assert!((duty - 0.75).abs() < 1e-12, "duty {duty}");
        let sim = TegrastatsSim::default();
        let p = sim.mean_power(&t);
        let expect = POWER_IDLE_W + 0.75 * (7.5 - POWER_IDLE_W);
        assert!((p - expect).abs() < 1e-9, "power {p} vs {expect}");
        // fully contained duplicates vanish entirely
        let mut c = ScheduleTrace::default();
        c.push(0.0, 2.0, DnnKind::Y416);
        c.push(0.5, 1.0, DnnKind::Y288);
        c.duration = 2.0;
        assert_eq!(c.normalised_busy().len(), 1);
    }

    #[test]
    fn memory_model_fig11_consistency() {
        // singles below all-loaded; TOD (all four) comparable to Y-416
        let all = mem_loaded_gb(&DnnKind::ALL);
        for k in DnnKind::ALL {
            assert!(mem_loaded_gb(&[k]) < all);
        }
    }
}
