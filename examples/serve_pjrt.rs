//! END-TO-END driver: the full three-layer stack on a real workload.
//!
//! Loads the four AOT-compiled detector variants (JAX + Pallas -> HLO
//! text, built once by `make artifacts`), preloads them on the PJRT CPU
//! client, and serves a synthetic pedestrian stream through the TOD
//! coordinator with REAL inference on every request: rasterize frame ->
//! PJRT execute -> Rust YOLO decode -> MBBS -> Algorithm 1 selection for
//! the next frame. Python never runs here.
//!
//! Reports per-variant latency percentiles and end-to-end throughput;
//! the run is recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```bash
//! make artifacts   # once
//! cargo run --release --example serve_pjrt -- [n_frames]
//! ```

use std::path::PathBuf;

use tod::coordinator::policy::MbbsPolicy;
use tod::dataset::synth::{CameraMotion, Sequence, SequenceSpec};
use tod::runtime::pool::EnginePool;
use tod::runtime::serve::serve_sequence;

fn main() {
    let frames: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(40);
    let artifacts = PathBuf::from(
        std::env::var("TOD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );

    println!("loading + compiling 4 AOT variants from {artifacts:?} ...");
    let t0 = std::time::Instant::now();
    let pool = match EnginePool::load(&artifacts) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("cannot load artifacts: {e:#}\nrun `make artifacts`");
            std::process::exit(1);
        }
    };
    println!(
        "pool ready in {:.1?}: {:?}\n",
        t0.elapsed(),
        pool.loaded()
            .iter()
            .map(|k| k.artifact_name())
            .collect::<Vec<_>>()
    );

    // a close-range walking-camera stream (MOT17-05-like, scaled down)
    let seq = Sequence::generate(SequenceSpec {
        name: "SERVE".into(),
        width: 640,
        height: 480,
        fps: 30.0,
        frames,
        density: 6,
        ref_height: 260.0,
        depth_range: (1.0, 2.2),
        walk_speed: 1.5,
        camera: CameraMotion::Walking { pan_speed: 8.0 },
        seed: 42,
    });

    let mut policy = MbbsPolicy::tod_default();
    let report = serve_sequence(&pool, &seq, &mut policy).expect("serve");
    println!("{report}");
    println!(
        "note: absolute latencies are CPU-PJRT with interpret-mode Pallas \
         grids — see DESIGN.md §Hardware-Adaptation; the Jetson-calibrated \
         latency model drives the accuracy experiments."
    );
}
