//! Trace replay and analysis: parse a JSONL trace back into events,
//! summarise it, and reconstruct *why* each frame was dropped
//! (`tod trace summarize/grep/explain-drop`).
//!
//! Drop causation works backwards from the drop anchor: a
//! [`Event::FrameDropped`] carries `busy_until`, the instant the
//! blocking accelerator work would free the device. The inference whose
//! `end` equals that instant *is* the blocking work; if that
//! inference's selection was demoted by a power budget (a
//! [`Event::BudgetClamp`] at its selection time), the drop chain is
//! budget → clamp → busy, otherwise plain busy-accelerator. Frames
//! rejected by batch admission control are shed, not dropped, and chain
//! to their [`Event::BatchShed`].

use std::collections::BTreeMap;
use std::fmt;

use crate::obs::{Event, SCHEMA_TAG, SCHEMA_VERSION};
use crate::util::json::Json;
use crate::DnnKind;

/// Timestamp-equality slop. Trace floats are shortest-roundtrip
/// serialised so re-parsed values are bit-exact; the epsilon only papers
/// over summed-epoch arithmetic done before emission.
const T_EPS: f64 = 1e-9;

/// Parse a JSONL trace: optional header line (schema-checked), then one
/// event per line. Blank lines are ignored.
pub fn parse_trace(text: &str) -> Result<(Option<Json>, Vec<Event>), String> {
    let mut header = None;
    let mut events = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let v = Json::parse(line)
            .map_err(|e| format!("line {}: {e:?}", i + 1))?;
        if i == 0 {
            if let Some(tag) = v.get("schema").and_then(Json::as_str) {
                if tag != SCHEMA_TAG {
                    return Err(format!(
                        "line 1: schema {tag:?} is not {SCHEMA_TAG:?}"
                    ));
                }
                let version =
                    v.get("version").and_then(Json::as_f64).unwrap_or(0.0)
                        as u64;
                if version != SCHEMA_VERSION {
                    return Err(format!(
                        "line 1: trace version {version} != supported \
                         {SCHEMA_VERSION}"
                    ));
                }
                header = Some(v);
                continue;
            }
        }
        events.push(
            Event::from_json(&v).map_err(|e| format!("line {}: {e}", i + 1))?,
        );
    }
    Ok((header, events))
}

/// Why a frame was not inferred.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DropCause {
    /// Batch admission control rejected it (queue full, shed mode).
    Shed,
    /// The accelerator was busy with work whose selection had been
    /// demoted by a power budget: the drop chains back to the clamp.
    BusyAfterClamp { requested: DnnKind, granted: DnnKind },
    /// The accelerator was simply busy with the blocking inference.
    BusyAccelerator,
    /// No blocking work found in the trace (e.g. flight-recorder window
    /// truncated before the blocking inference).
    Unknown,
}

/// One dropped frame with its reconstructed cause chain.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DropExplanation {
    pub stream: u32,
    pub frame: u64,
    /// Arrival (capture) time of the dropped frame.
    pub t: f64,
    /// When the blocking work frees the accelerator.
    pub busy_until: f64,
    pub cause: DropCause,
    /// The blocking inference `(frame, dnn, start, end)`, when found.
    pub blocking: Option<(u64, DnnKind, f64, f64)>,
}

impl fmt::Display for DropExplanation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "stream {} frame {} @ {:.3}s: ",
            self.stream, self.frame, self.t
        )?;
        match self.cause {
            DropCause::Shed => write!(f, "shed by batch admission control"),
            DropCause::BusyAfterClamp { requested, granted } => {
                let (bf, _, s, e) = match self.blocking {
                    Some(b) => b,
                    None => (0, granted, 0.0, self.busy_until),
                };
                write!(
                    f,
                    "budget clamp {} -> {} on frame {bf}, which held the \
                     accelerator [{s:.3}, {e:.3}]s past this arrival",
                    requested.artifact_name(),
                    granted.artifact_name(),
                )
            }
            DropCause::BusyAccelerator => match self.blocking {
                Some((bf, dnn, s, e)) => write!(
                    f,
                    "accelerator busy with frame {bf} ({}) over \
                     [{s:.3}, {e:.3}]s",
                    dnn.artifact_name()
                ),
                None => write!(f, "accelerator busy until {:.3}s", self.busy_until),
            },
            DropCause::Unknown => write!(
                f,
                "no blocking work found before busy_until {:.3}s \
                 (trace window truncated?)",
                self.busy_until
            ),
        }
    }
}

/// Reconstruct the cause chain for every dropped frame in the trace.
pub fn explain_drops(events: &[Event]) -> Vec<DropExplanation> {
    let mut out = Vec::new();
    for ev in events {
        let (stream, frame, t, busy_until) = match *ev {
            Event::FrameDropped { stream, frame, t, busy_until } => {
                (stream, frame, t, busy_until)
            }
            _ => continue,
        };

        // (1) shed, not a capacity drop?
        let shed = events.iter().any(|e| {
            matches!(*e, Event::BatchShed { stream: s, frame: f, .. }
                if s == stream && f == frame)
        });
        if shed {
            out.push(DropExplanation {
                stream,
                frame,
                t,
                busy_until,
                cause: DropCause::Shed,
                blocking: None,
            });
            continue;
        }

        // (2) the blocking inference: same stream, ends exactly when the
        // accelerator frees; fall back to the latest inference ending at
        // or before busy_until (clock-clamped starts).
        let infer_of = |e: &Event| match *e {
            Event::FrameInferred { stream: s, frame: f, dnn, start, end }
            | Event::InferenceFailed { stream: s, frame: f, dnn, start, end }
                if s == stream =>
            {
                Some((f, dnn, start, end))
            }
            _ => None,
        };
        let blocking = events
            .iter()
            .filter_map(infer_of)
            .find(|&(_, _, _, end)| (end - busy_until).abs() < T_EPS)
            .or_else(|| {
                events
                    .iter()
                    .filter_map(infer_of)
                    .filter(|&(_, _, _, end)| end <= busy_until + T_EPS)
                    .max_by(|a, b| a.3.total_cmp(&b.3))
            });

        let cause = match blocking {
            None => DropCause::Unknown,
            Some((bframe, _, _, _)) => {
                // (3) was the blocking inference's selection clamped?
                // The clamp fires inside select() at the frame's capture
                // time, immediately before its DnnSelected.
                let t_sel = events.iter().find_map(|e| match *e {
                    Event::DnnSelected { stream: s, frame: f, t, .. }
                        if s == stream && f == bframe =>
                    {
                        Some(t)
                    }
                    _ => None,
                });
                let clamp = t_sel.and_then(|ts| {
                    events.iter().find_map(|e| match *e {
                        Event::BudgetClamp { stream: s, t, requested, granted, .. }
                            if s == stream && (t - ts).abs() < T_EPS =>
                        {
                            Some((requested, granted))
                        }
                        _ => None,
                    })
                });
                match clamp {
                    Some((requested, granted)) => {
                        DropCause::BusyAfterClamp { requested, granted }
                    }
                    None => DropCause::BusyAccelerator,
                }
            }
        };
        out.push(DropExplanation { stream, frame, t, busy_until, cause, blocking });
    }
    out
}

/// Human-readable multi-line trace summary (deterministic ordering).
pub fn summarize(events: &[Event]) -> String {
    use std::fmt::Write as _;

    #[derive(Default)]
    struct StreamAgg {
        presented: u64,
        inferred: u64,
        dropped: u64,
        failed: u64,
        shed: u64,
        clamps: u64,
    }

    let mut by_type: BTreeMap<&'static str, u64> = BTreeMap::new();
    let mut by_stream: BTreeMap<u32, StreamAgg> = BTreeMap::new();
    let mut deploy = [0u64; DnnKind::COUNT];
    let mut t_max = 0.0f64;
    for ev in events {
        *by_type.entry(ev.type_tag()).or_insert(0) += 1;
        t_max = t_max.max(match *ev {
            Event::FrameInferred { end, .. }
            | Event::InferenceFailed { end, .. } => end,
            _ => ev.time(),
        });
        if let Some(s) = ev.stream() {
            let agg = by_stream.entry(s).or_default();
            match *ev {
                Event::FramePresented { .. } => agg.presented += 1,
                Event::FrameInferred { dnn, .. } => {
                    agg.inferred += 1;
                    deploy[dnn.index()] += 1;
                }
                Event::InferenceFailed { .. } => agg.failed += 1,
                Event::FrameDropped { .. } => agg.dropped += 1,
                Event::BatchShed { .. } => agg.shed += 1,
                Event::BudgetClamp { .. } => agg.clamps += 1,
                _ => {}
            }
        }
    }

    let mut out = String::new();
    let _ = writeln!(
        out,
        "{} events | {} streams | span {:.3}s",
        events.len(),
        by_stream.len(),
        t_max
    );
    let _ = writeln!(out, "by type:");
    for (tag, n) in &by_type {
        let _ = writeln!(out, "  {tag:<18} {n}");
    }
    let _ = writeln!(out, "per stream:");
    for (s, a) in &by_stream {
        let _ = writeln!(
            out,
            "  stream {s}: presented {} | inferred {} | dropped {} | \
             failed {} | shed {} | clamps {}",
            a.presented, a.inferred, a.dropped, a.failed, a.shed, a.clamps
        );
    }
    let per: Vec<String> = DnnKind::ALL
        .iter()
        .map(|d| format!("{} {}", d.short_label(), deploy[d.index()]))
        .collect();
    let _ = writeln!(out, "deploys: {}", per.join(" "));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::JsonlSink;
    use crate::obs::Recorder;

    fn busy_drop_trace() -> Vec<Event> {
        vec![
            Event::StreamJoined { stream: 0, t: 0.0 },
            Event::FramePresented { stream: 0, frame: 1, t: 0.0 },
            Event::DnnSelected { stream: 0, frame: 1, t: 0.0, dnn: DnnKind::Y416 },
            Event::FrameInferred {
                stream: 0,
                frame: 1,
                dnn: DnnKind::Y416,
                start: 0.0,
                end: 0.1,
            },
            Event::FramePresented { stream: 0, frame: 2, t: 0.033 },
            Event::FrameDropped {
                stream: 0,
                frame: 2,
                t: 0.033,
                busy_until: 0.1,
            },
        ]
    }

    #[test]
    fn parse_trace_roundtrips_a_sink() {
        let mut sink = JsonlSink::new("unit");
        let evs = busy_drop_trace();
        for ev in &evs {
            sink.record(ev);
        }
        let (header, parsed) = parse_trace(sink.contents()).unwrap();
        assert_eq!(
            header.unwrap().get("label").unwrap().as_str(),
            Some("unit")
        );
        assert_eq!(parsed, evs);
    }

    #[test]
    fn parse_trace_rejects_bad_versions_and_lines() {
        assert!(parse_trace("{\"schema\":\"tod-trace\",\"version\":99}\n")
            .is_err());
        assert!(parse_trace("{\"schema\":\"bogus\",\"version\":1}\n").is_err());
        assert!(parse_trace("not json\n").is_err());
        // headerless traces are accepted
        let line = Event::StreamJoined { stream: 0, t: 0.0 }
            .to_json()
            .to_string();
        let (h, evs) = parse_trace(&line).unwrap();
        assert!(h.is_none());
        assert_eq!(evs.len(), 1);
    }

    #[test]
    fn explains_plain_busy_drop() {
        let ex = explain_drops(&busy_drop_trace());
        assert_eq!(ex.len(), 1);
        assert_eq!(ex[0].cause, DropCause::BusyAccelerator);
        assert_eq!(ex[0].blocking, Some((1, DnnKind::Y416, 0.0, 0.1)));
        assert!(ex[0].to_string().contains("accelerator busy with frame 1"));
    }

    #[test]
    fn explains_clamped_busy_drop() {
        let mut evs = busy_drop_trace();
        // the blocking inference's selection was demoted at its capture time
        evs.insert(
            2,
            Event::BudgetClamp {
                stream: 0,
                t: 0.0,
                requested: DnnKind::Y416,
                granted: DnnKind::TinyY416,
                mask: 0b0011,
            },
        );
        let ex = explain_drops(&evs);
        assert_eq!(ex.len(), 1);
        assert_eq!(
            ex[0].cause,
            DropCause::BusyAfterClamp {
                requested: DnnKind::Y416,
                granted: DnnKind::TinyY416
            }
        );
        assert!(ex[0].to_string().contains("budget clamp"));
    }

    #[test]
    fn explains_shed_frames() {
        let evs = vec![
            Event::FramePresented { stream: 1, frame: 5, t: 0.1 },
            Event::BatchShed { stream: 1, frame: 5, t: 0.1 },
            Event::FrameDropped {
                stream: 1,
                frame: 5,
                t: 0.1,
                busy_until: 0.2,
            },
        ];
        let ex = explain_drops(&evs);
        assert_eq!(ex[0].cause, DropCause::Shed);
    }

    #[test]
    fn shed_takes_precedence_over_a_busy_chain() {
        // the shed frame *also* has a blocking inference ending at its
        // busy_until and a clamp on that inference's selection — but
        // admission control rejected the work before capacity mattered,
        // so Shed must win over BusyAfterClamp
        let mut evs = busy_drop_trace();
        evs.insert(
            2,
            Event::BudgetClamp {
                stream: 0,
                t: 0.0,
                requested: DnnKind::Y416,
                granted: DnnKind::TinyY416,
                mask: 0b0011,
            },
        );
        evs.push(Event::BatchShed { stream: 0, frame: 2, t: 0.033 });
        let ex = explain_drops(&evs);
        assert_eq!(ex.len(), 1);
        assert_eq!(ex[0].cause, DropCause::Shed);
        assert_eq!(ex[0].blocking, None, "shed drops have no blocker");
    }

    #[test]
    fn shed_on_another_stream_does_not_leak() {
        // same frame id, different stream: the shed must not explain
        // this stream's capacity drop
        let mut evs = busy_drop_trace();
        evs.push(Event::BatchShed { stream: 7, frame: 2, t: 0.033 });
        let ex = explain_drops(&evs);
        assert_eq!(ex[0].cause, DropCause::BusyAccelerator);
        assert!(ex[0].blocking.is_some());
    }

    #[test]
    fn unknown_when_blocking_work_is_outside_the_window() {
        let evs = vec![Event::FrameDropped {
            stream: 0,
            frame: 9,
            t: 1.0,
            busy_until: 1.05,
        }];
        let ex = explain_drops(&evs);
        assert_eq!(ex[0].cause, DropCause::Unknown);
        assert!(ex[0].to_string().contains("truncated"));
    }

    #[test]
    fn clamp_on_another_frame_does_not_leak() {
        let mut evs = busy_drop_trace();
        // a clamp on a *later* selection must not explain this drop
        evs.push(Event::BudgetClamp {
            stream: 0,
            t: 0.2,
            requested: DnnKind::Y416,
            granted: DnnKind::Y288,
            mask: 0b0111,
        });
        let ex = explain_drops(&evs);
        assert_eq!(ex[0].cause, DropCause::BusyAccelerator);
    }

    #[test]
    fn summarize_is_deterministic_and_complete() {
        let evs = busy_drop_trace();
        let a = summarize(&evs);
        assert_eq!(a, summarize(&evs));
        assert!(a.contains("6 events"));
        assert!(a.contains("frame_dropped"));
        assert!(a.contains("stream 0: presented 2 | inferred 1 | dropped 1"));
        assert!(a.contains("span 0.100s"));
    }
}
