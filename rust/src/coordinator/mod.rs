//! The paper's contribution: the TOD runtime scheduler.
//!
//! [`policy`] implements Algorithm 1 (the MBBS-thresholded DNN selector),
//! [`scheduler`] runs a policy over a sequence under the Algorithm 2
//! drop-frame accounting, [`search`] is the Table I hyperparameter grid
//! search, and [`baselines`] provides the comparison points (fixed single
//! DNN, and a Chameleon-style periodic re-profiler).

pub mod baselines;
pub mod policy;
pub mod scheduler;
pub mod search;

pub use policy::{FixedPolicy, MbbsPolicy, SelectionPolicy, Thresholds};
pub use scheduler::{run_offline, run_realtime, Detector, OracleBackend, RunResult};
pub use search::{grid_search, GridSearchResult, SearchSpace};
