//! Contention-aware scheduling of N streams over one shared accelerator.
//!
//! The paper evaluates one camera per Jetson board; production edge
//! deployments (ROMA, and the parallel-detection work in PAPERS.md)
//! multiplex many cameras onto one accelerator. This module interleaves
//! N [`StreamSession`]s in virtual time:
//!
//! * the accelerator runs **one inference at a time** — per-stream busy
//!   intervals never overlap on the shared device;
//! * each inference's latency is inflated by the
//!   [`ContentionModel`] according to how many streams were waiting at
//!   dispatch time (engine swaps / bandwidth sharing);
//! * frames that arrive while the accelerator serves *any* stream are
//!   dropped with the same Algorithm 2 carry-forward accounting the
//!   single-stream loop uses — multi-stream pressure shows up as higher
//!   per-stream drop rates and staler carried boxes, exactly the
//!   mechanism behind the paper's Fig. 7.
//!
//! Two dispatch orders are provided: round-robin (fair, oblivious) and
//! earliest-deadline-first (dispatch the stream whose pending frame is
//! superseded soonest). A 1-stream scheduler reduces to the legacy
//! `run_realtime` exactly: no waiting peers means no inflation and no
//! foreign busy time, so every step is bit-identical.
//!
//! [`BatchingSim`] additionally models the runtime's cross-stream
//! micro-batching ([`crate::runtime::server`]) in virtual time:
//! back-to-back same-DNN dispatches share one setup cost
//! ([`crate::sim::latency::BatchLatencyModel`]), which is the
//! deterministic counterpart of the wall-clock batching win.

use crate::obs::SharedRecorder;
use crate::power::{EnergyMeter, PowerSummary};
use crate::runtime::batch::BatchStats;
use crate::sim::latency::{BatchLatencyModel, ContentionModel, LatencyModel};
use crate::telemetry::utilisation::UtilisationSummary;
use crate::DnnKind;

use super::dispatch::DispatchQueue;
use super::scheduler::{Detector, RunResult};
use super::session::{SessionEvent, StreamSession};

/// The stream's next dispatch candidate as the queue stores it.
fn candidate_of(session: &StreamSession<'_>) -> Option<(f64, f64)> {
    Some((session.next_infer_ready()?, session.next_infer_deadline()?))
}

/// Cross-stream micro-batching for the virtual-time scheduler.
///
/// The runtime's batching server amortises per-dispatch setup across
/// same-variant requests ([`crate::runtime::server`]); this is its
/// virtual-clock counterpart, so the batching win can be quantified
/// deterministically. Each dispatch is priced from the scheduler's
/// *own* latency model sample (jitter, DVFS stretches and other
/// calibrations stay in effect — see
/// [`crate::sim::latency::LatencyModel::stretched`]): a dispatch that
/// *starts* a batch run pays the full sample, while one that
/// *continues* a run — same DNN as the previous dispatch, back to back
/// (no accelerator idle gap), still under `max_batch` items — pays
/// `sample * (1 - setup_frac)`, the marginal share. With
/// `max_batch == 1` every dispatch pays the full sample: the schedule
/// is bit-identical to the unbatched scheduler, jittered or not. For
/// a deterministic model the prices coincide exactly with
/// [`BatchLatencyModel::first`] / [`BatchLatencyModel::marginal`].
#[derive(Debug, Clone)]
pub struct BatchingSim {
    /// Share of a dispatch amortised away inside a batch, in [0, 1)
    /// (see [`BatchLatencyModel::from_means`]).
    pub setup_frac: f64,
    /// Largest same-DNN run that shares one setup (>= 1).
    pub max_batch: usize,
}

impl BatchingSim {
    pub fn new(setup_frac: f64, max_batch: usize) -> Self {
        assert!(max_batch >= 1, "max_batch must be >= 1");
        assert!(
            (0.0..1.0).contains(&setup_frac),
            "setup fraction must be in [0, 1), got {setup_frac}"
        );
        BatchingSim { setup_frac, max_batch }
    }

    /// The Jetson-Nano default setup share with the given batch bound.
    pub fn jetson_nano(max_batch: usize) -> Self {
        Self::new(BatchLatencyModel::DEFAULT_SETUP_FRAC, max_batch)
    }
}

/// Order in which waiting streams get the shared accelerator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum DispatchPolicy {
    /// Cycle stream indices, skipping streams with nothing to infer.
    RoundRobin,
    /// Dispatch the stream whose next inferable frame is superseded
    /// (goes stale) earliest.
    EarliestDeadlineFirst,
}

impl DispatchPolicy {
    pub fn label(self) -> &'static str {
        match self {
            DispatchPolicy::RoundRobin => "round-robin",
            DispatchPolicy::EarliestDeadlineFirst => "edf",
        }
    }
}

impl std::fmt::Display for DispatchPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

impl std::str::FromStr for DispatchPolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "rr" | "round-robin" | "roundrobin" => {
                Ok(DispatchPolicy::RoundRobin)
            }
            "edf" | "earliest-deadline-first" => {
                Ok(DispatchPolicy::EarliestDeadlineFirst)
            }
            other => Err(format!(
                "unknown dispatch policy: {other} (want rr|edf)"
            )),
        }
    }
}

/// Everything an N-stream run produces.
#[derive(Debug, Clone)]
pub struct MultiStreamResult {
    /// Per-stream run summaries, in `add_stream` order. Each carries its
    /// own `ScheduleTrace` of (non-overlapping) busy intervals.
    pub per_stream: Vec<RunResult>,
    /// Dispatch order the run used.
    pub dispatch: DispatchPolicy,
    /// Aggregate accelerator utilisation over the merged timeline.
    pub utilisation: UtilisationSummary,
    /// Board-level energy/power summary over the merged timeline
    /// (what a shared [`crate::power::PowerBudget`] governs).
    pub power: PowerSummary,
    /// Micro-batch accounting when the run used [`BatchingSim`]
    /// (`None` for unbatched runs). A "batch" is a maximal same-DNN
    /// back-to-back dispatch run sharing one setup cost.
    pub batching: Option<BatchStats>,
}

impl MultiStreamResult {
    /// Mean AP across streams.
    pub fn mean_ap(&self) -> f64 {
        if self.per_stream.is_empty() {
            return 0.0;
        }
        self.per_stream.iter().map(|r| r.ap).sum::<f64>()
            / self.per_stream.len() as f64
    }

    /// Aggregate drop rate (dropped frames over all frames).
    pub fn drop_rate(&self) -> f64 {
        let frames: u64 = self.per_stream.iter().map(|r| r.n_frames).sum();
        let dropped: u64 = self.per_stream.iter().map(|r| r.n_dropped).sum();
        if frames == 0 {
            0.0
        } else {
            dropped as f64 / frames as f64
        }
    }
}

/// One stream slot: a session plus the detector backend computing its
/// frames' detections. (Detection *math* is per-stream — the oracle is
/// seeded per sequence — while detection *time* is shared through the
/// scheduler's single virtual accelerator.)
struct StreamSlot<'a> {
    session: StreamSession<'a>,
    detector: Box<dyn Detector + 'a>,
}

/// Interleaves N [`StreamSession`]s over one shared virtual accelerator.
pub struct MultiStreamScheduler<'a> {
    streams: Vec<StreamSlot<'a>>,
    latency: LatencyModel,
    contention: ContentionModel,
    dispatch: DispatchPolicy,
    batching: Option<BatchingSim>,
    /// Observability sink handed to every subsequently added stream's
    /// session (sessions emit the events and spans; the scheduler adds
    /// nothing of its own, so unobserved runs stay bit-identical).
    recorder: Option<SharedRecorder>,
}

impl<'a> MultiStreamScheduler<'a> {
    pub fn new(
        dispatch: DispatchPolicy,
        contention: ContentionModel,
        latency: LatencyModel,
    ) -> Self {
        MultiStreamScheduler {
            streams: Vec::new(),
            latency,
            contention,
            dispatch,
            batching: None,
            recorder: None,
        }
    }

    /// Enable deterministic cross-stream micro-batching (see
    /// [`BatchingSim`]).
    pub fn with_batching(mut self, batching: BatchingSim) -> Self {
        self.batching = Some(batching);
        self
    }

    /// Attach an observability recorder. Streams registered *after*
    /// this call join it (stream ids follow `add_stream` order; all
    /// scheduler streams share epoch 0 — churn lives in the scenario
    /// harness), emitting the full event + span vocabulary.
    pub fn with_recorder(mut self, recorder: SharedRecorder) -> Self {
        self.recorder = Some(recorder);
        self
    }

    /// Register a stream (its session plus detector backend).
    pub fn add_stream(
        &mut self,
        session: StreamSession<'a>,
        detector: Box<dyn Detector + 'a>,
    ) {
        let session = match &self.recorder {
            Some(rec) => session.with_recorder(
                rec.clone(),
                self.streams.len() as u32,
                0.0,
            ),
            None => session,
        };
        self.streams.push(StreamSlot { session, detector });
    }

    pub fn n_streams(&self) -> usize {
        self.streams.len()
    }

    /// Run every stream to completion; returns per-stream results plus
    /// the aggregate utilisation summary.
    pub fn run(self) -> MultiStreamResult {
        let MultiStreamScheduler {
            mut streams,
            mut latency,
            contention,
            dispatch,
            batching,
            // Sessions already hold their recorder clones; the
            // scheduler keeps none of its own emission state.
            recorder: _,
        } = self;
        let mut gpu_free = 0.0f64;
        let mut rr_cursor = 0usize;
        // micro-batch run state: the accelerator's current same-DNN
        // back-to-back dispatch run (batched mode only)
        let mut run_dnn: Option<DnnKind> = None;
        let mut run_len = 0usize;
        let mut run_end = f64::NEG_INFINITY;
        let mut batch_stats =
            batching.as_ref().map(|_| BatchStats::default());

        // incremental candidate set: only the stream just stepped can
        // change between epochs, so the per-epoch rebuild-and-scan is
        // replaced by queue updates (see [`DispatchQueue`])
        let mut queue = DispatchQueue::new(streams.len());
        for (i, s) in streams.iter().enumerate() {
            queue.update(i, candidate_of(&s.session));
        }

        loop {
            let chosen = match dispatch {
                DispatchPolicy::RoundRobin => {
                    queue.next_round_robin(rr_cursor)
                }
                DispatchPolicy::EarliestDeadlineFirst => queue.peek_edf(),
            };
            let Some((idx, ready, _)) = chosen else {
                break;
            };
            // contention: streams whose pending frame is waiting when
            // this inference starts (the dispatched one included)
            let start_est = gpu_free.max(ready);
            let occupancy = queue.occupancy(start_est).max(1);
            let inflation = contention.factor(occupancy);

            // drain the stream's doomed frames, then run its inference
            let slot = &mut streams[idx];
            loop {
                // the pricing closure records its continuation verdict
                // here, so the stats block below cannot drift from the
                // predicate that actually priced the dispatch
                let was_cont = std::cell::Cell::new(false);
                let event = match &batching {
                    Some(b) => {
                        // continuation = same DNN, still under
                        // max_batch, and back to back with the current
                        // run (the frame was waiting when it ended)
                        let (rd, rl, re) = (run_dnn, run_len, run_end);
                        let max_batch = b.max_batch;
                        let setup_frac = b.setup_frac;
                        let was_cont = &was_cont;
                        slot.session.step_with(
                            slot.detector.as_mut(),
                            &mut |dnn| {
                                let cont = rd == Some(dnn)
                                    && rl < max_batch
                                    && start_est <= re + 1e-12;
                                was_cont.set(cont);
                                // full sample on a run start; marginal
                                // share on a continuation — jitter and
                                // stretches stay in effect either way
                                let base = latency.sample(dnn);
                                let base = if cont {
                                    base * (1.0 - setup_frac)
                                } else {
                                    base
                                };
                                if inflation == 1.0 {
                                    base
                                } else {
                                    base * inflation
                                }
                            },
                            gpu_free,
                        )
                    }
                    None => slot.session.step_shared(
                        slot.detector.as_mut(),
                        &mut latency,
                        gpu_free,
                        inflation,
                    ),
                };
                match event {
                    SessionEvent::Inferred { dnn, interval: (_, end), .. }
                    | SessionEvent::InferenceFailed {
                        dnn,
                        interval: (_, end),
                        ..
                    } => {
                        if let Some(stats) = batch_stats.as_mut() {
                            if was_cont.get() {
                                run_len += 1;
                                let v = &mut stats.per_dnn[dnn.index()];
                                v.items += 1;
                                v.largest = v.largest.max(run_len);
                            } else {
                                run_dnn = Some(dnn);
                                run_len = 1;
                                stats.record(dnn, 1);
                            }
                            run_end = end;
                        }
                        gpu_free = gpu_free.max(end);
                        break;
                    }
                    SessionEvent::Dropped { .. } => continue,
                    SessionEvent::Finished => break,
                }
            }
            rr_cursor = (idx + 1) % streams.len();
            queue.update(idx, candidate_of(&streams[idx].session));
        }

        // drain streams whose remaining frames are all destined to drop
        for slot in &mut streams {
            while !slot.session.is_finished() {
                slot.session.step_shared(
                    slot.detector.as_mut(),
                    &mut latency,
                    gpu_free,
                    1.0,
                );
            }
        }

        let per_stream: Vec<RunResult> = streams
            .into_iter()
            .map(|s| s.session.finish())
            .collect();
        let traces: Vec<&crate::telemetry::tegrastats::ScheduleTrace> =
            per_stream.iter().map(|r| &r.trace).collect();
        let failed_busy: f64 =
            per_stream.iter().map(|r| r.failed_busy_s).sum();
        let utilisation = UtilisationSummary::from_traces(&traces)
            .with_failed_busy(failed_busy);
        let power = EnergyMeter::from_trace(&utilisation.merged).summary();
        MultiStreamResult {
            per_stream,
            dispatch,
            utilisation,
            power,
            batching: batch_stats,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::MbbsPolicy;
    use crate::coordinator::scheduler::{run_realtime, OracleBackend};
    use crate::dataset::synth::{CameraMotion, Sequence, SequenceSpec};
    use crate::sim::oracle::OracleDetector;

    fn seq(seed: u64, frames: u64) -> Sequence {
        Sequence::generate(SequenceSpec {
            name: format!("MS-{seed}"),
            width: 960,
            height: 540,
            fps: 30.0,
            frames,
            density: 6,
            ref_height: 220.0,
            depth_range: (1.0, 2.0),
            walk_speed: 1.5,
            camera: CameraMotion::Static,
            seed,
        })
    }

    fn oracle(s: &Sequence) -> OracleBackend {
        OracleBackend(OracleDetector::new(
            s.spec.seed,
            s.spec.width as f64,
            s.spec.height as f64,
        ))
    }

    fn run_n(
        seqs: &[Sequence],
        dispatch: DispatchPolicy,
        contention: ContentionModel,
    ) -> MultiStreamResult {
        let mut sched = MultiStreamScheduler::new(
            dispatch,
            contention,
            LatencyModel::deterministic(),
        );
        for s in seqs {
            sched.add_stream(
                StreamSession::new(s, MbbsPolicy::tod_default(), 30.0),
                Box::new(oracle(s)),
            );
        }
        sched.run()
    }

    #[test]
    fn dispatch_policy_parses() {
        assert_eq!(
            "rr".parse::<DispatchPolicy>().unwrap(),
            DispatchPolicy::RoundRobin
        );
        assert_eq!(
            "EDF".parse::<DispatchPolicy>().unwrap(),
            DispatchPolicy::EarliestDeadlineFirst
        );
        assert!("lifo".parse::<DispatchPolicy>().is_err());
        assert_eq!(DispatchPolicy::RoundRobin.to_string(), "round-robin");
    }

    #[test]
    fn one_stream_matches_legacy_run_realtime() {
        let s = seq(11, 150);
        let mut det = oracle(&s);
        let mut pol = MbbsPolicy::tod_default();
        let mut lat = LatencyModel::deterministic();
        let legacy = run_realtime(&s, &mut pol, &mut det, &mut lat, 30.0);
        let multi = run_n(
            &[s.clone()],
            DispatchPolicy::RoundRobin,
            ContentionModel::jetson_nano(),
        );
        let r = &multi.per_stream[0];
        assert_eq!(r.ap, legacy.ap);
        assert_eq!(r.deploy_counts, legacy.deploy_counts);
        assert_eq!(r.n_dropped, legacy.n_dropped);
        assert_eq!(r.switches, legacy.switches);
        assert_eq!(r.mbbs_series, legacy.mbbs_series);
        assert_eq!(r.dnn_series, legacy.dnn_series);
        assert_eq!(r.trace.busy, legacy.trace.busy);
        assert_eq!(r.trace.duration, legacy.trace.duration);
    }

    #[test]
    fn shared_accelerator_never_double_booked() {
        for dispatch in [
            DispatchPolicy::RoundRobin,
            DispatchPolicy::EarliestDeadlineFirst,
        ] {
            let seqs: Vec<Sequence> =
                (0..4).map(|i| seq(100 + i, 90)).collect();
            let r = run_n(&seqs, dispatch, ContentionModel::jetson_nano());
            assert_eq!(r.per_stream.len(), 4);
            assert!(
                r.utilisation.overlap_seconds() < 1e-9,
                "overlap under {dispatch}"
            );
            for s in &r.per_stream {
                assert_eq!(s.n_inferred + s.n_dropped, s.n_frames);
            }
        }
    }

    #[test]
    fn contention_raises_drop_rate() {
        let one = run_n(
            &[seq(7, 120)],
            DispatchPolicy::RoundRobin,
            ContentionModel::jetson_nano(),
        );
        let seqs: Vec<Sequence> = (0..6).map(|i| seq(7 + i, 120)).collect();
        let six = run_n(
            &seqs,
            DispatchPolicy::RoundRobin,
            ContentionModel::jetson_nano(),
        );
        assert!(
            six.drop_rate() > one.drop_rate(),
            "6-stream drop {} vs 1-stream {}",
            six.drop_rate(),
            one.drop_rate()
        );
        // an oversubscribed accelerator should be busy almost always
        assert!(
            six.utilisation.utilisation() > 0.8,
            "util {}",
            six.utilisation.utilisation()
        );
    }

    #[test]
    fn zero_streams_is_benign() {
        let sched = MultiStreamScheduler::new(
            DispatchPolicy::RoundRobin,
            ContentionModel::none(),
            LatencyModel::deterministic(),
        );
        let r = sched.run();
        assert!(r.per_stream.is_empty());
        assert_eq!(r.mean_ap(), 0.0);
        assert_eq!(r.drop_rate(), 0.0);
        assert!(r.batching.is_none());
    }

    fn run_n_batched(
        seqs: &[Sequence],
        max_batch: usize,
    ) -> MultiStreamResult {
        let mut sched = MultiStreamScheduler::new(
            DispatchPolicy::RoundRobin,
            ContentionModel::jetson_nano(),
            LatencyModel::deterministic(),
        )
        .with_batching(BatchingSim::jetson_nano(max_batch));
        for s in seqs {
            sched.add_stream(
                StreamSession::new(s, MbbsPolicy::tod_default(), 30.0),
                Box::new(oracle(s)),
            );
        }
        sched.run()
    }

    #[test]
    fn batched_max_batch_one_is_bit_identical_to_unbatched() {
        // BatchLatencyModel::first == the unbatched mean, so a batch
        // bound of 1 reproduces the unbatched schedule bit for bit
        let seqs: Vec<Sequence> = (0..3).map(|i| seq(40 + i, 90)).collect();
        let plain = run_n(
            &seqs,
            DispatchPolicy::RoundRobin,
            ContentionModel::jetson_nano(),
        );
        let batched = run_n_batched(&seqs, 1);
        for (a, b) in plain.per_stream.iter().zip(&batched.per_stream) {
            assert_eq!(a.ap, b.ap);
            assert_eq!(a.deploy_counts, b.deploy_counts);
            assert_eq!(a.n_dropped, b.n_dropped);
            assert_eq!(a.trace.busy, b.trace.busy);
        }
        let stats = batched.batching.as_ref().unwrap();
        assert!((stats.mean_batch() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn batched_max_batch_one_is_bit_identical_under_jitter() {
        // regression: batched pricing draws from the scheduler's own
        // latency model (same RNG sequence), so the bit-identity of a
        // 1-batch schedule holds for jittered models too
        let seqs: Vec<Sequence> = (0..3).map(|i| seq(60 + i, 90)).collect();
        let run = |batched: bool| {
            let mut sched = MultiStreamScheduler::new(
                DispatchPolicy::RoundRobin,
                ContentionModel::jetson_nano(),
                LatencyModel::jetson_nano(7),
            );
            if batched {
                sched = sched.with_batching(BatchingSim::jetson_nano(1));
            }
            for s in &seqs {
                sched.add_stream(
                    StreamSession::new(s, MbbsPolicy::tod_default(), 30.0),
                    Box::new(oracle(s)),
                );
            }
            sched.run()
        };
        let plain = run(false);
        let batched = run(true);
        for (a, b) in plain.per_stream.iter().zip(&batched.per_stream) {
            assert_eq!(a.ap, b.ap);
            assert_eq!(a.deploy_counts, b.deploy_counts);
            assert_eq!(a.n_dropped, b.n_dropped);
            assert_eq!(a.trace.busy, b.trace.busy);
        }
    }

    #[test]
    fn batching_raises_throughput_on_identical_streams() {
        // four replicas of one scene select the same DNN, so RR
        // dispatch forms same-DNN runs and amortises the setup cost
        let seqs: Vec<Sequence> = (0..4).map(|_| seq(7, 120)).collect();
        let plain = run_n(
            &seqs,
            DispatchPolicy::RoundRobin,
            ContentionModel::jetson_nano(),
        );
        let batched = run_n_batched(&seqs, 4);
        assert!(
            batched.utilisation.throughput_ips()
                > plain.utilisation.throughput_ips(),
            "batched {} <= unbatched {} inf/s",
            batched.utilisation.throughput_ips(),
            plain.utilisation.throughput_ips()
        );
        assert!(
            batched.drop_rate() <= plain.drop_rate() + 1e-12,
            "batching must not raise the drop rate: {} vs {}",
            batched.drop_rate(),
            plain.drop_rate()
        );
        let stats = batched.batching.as_ref().unwrap();
        assert!(
            stats.mean_batch() > 1.2,
            "no real batches formed: {stats}"
        );
        // the accelerator is still never double-booked: batching
        // shortens intervals, it does not overlap them
        assert!(batched.utilisation.overlap_seconds() < 1e-9);
    }
}
