//! One compiled PJRT executable: HLO text -> compile once -> execute on
//! the request path (the `xla` crate over xla_extension's PJRT C API).

use std::path::Path;
use std::time::Instant;

use crate::ext::anyhow::{bail, Context, Result};
use crate::ext::xla;

use crate::runtime::manifest::VariantSpec;

/// Raw output of one head: row-major (1, grid, grid, channels) floats.
#[derive(Debug, Clone)]
pub struct HeadTensor {
    pub grid: usize,
    pub channels: usize,
    pub data: Vec<f32>,
}

/// A compiled detector variant bound to a PJRT client.
pub struct Engine {
    spec: VariantSpec,
    exe: xla::PjRtLoadedExecutable,
    /// Cumulative executions (for the pool's stats).
    n_runs: std::cell::Cell<u64>,
}

impl Engine {
    /// Load `<dir>/<artifact>` and compile it on `client`.
    pub fn load(
        client: &xla::PjRtClient,
        dir: &Path,
        spec: &VariantSpec,
    ) -> Result<Engine> {
        let path = dir.join(&spec.artifact);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", spec.artifact))?;
        tracing_log(&format!(
            "compiled {} in {:.2?}",
            spec.artifact,
            t0.elapsed()
        ));
        Ok(Engine { spec: spec.clone(), exe, n_runs: 0.into() })
    }

    pub fn spec(&self) -> &VariantSpec {
        &self.spec
    }

    pub fn n_runs(&self) -> u64 {
        self.n_runs.get()
    }

    /// Execute on a rasterized image of shape (1, S, S, 3), values in
    /// [0, 1], row-major. Returns one tensor per detection head.
    pub fn infer(&self, image: &[f32]) -> Result<Vec<HeadTensor>> {
        let s = self.spec.input_size;
        if image.len() != s * s * 3 {
            bail!(
                "image length {} != {} ({}x{}x3)",
                image.len(),
                s * s * 3,
                s,
                s
            );
        }
        let lit = xla::Literal::vec1(image)
            .reshape(&[1, s as i64, s as i64, 3])?;
        let mut result = self.exe.execute::<xla::Literal>(&[lit])?[0][0]
            .to_literal_sync()?;
        let outs = result.decompose_tuple()?;
        if outs.len() != self.spec.heads.len() {
            bail!(
                "{}: expected {} heads, got {}",
                self.spec.artifact,
                self.spec.heads.len(),
                outs.len()
            );
        }
        let mut heads = Vec::with_capacity(outs.len());
        for (out, hs) in outs.into_iter().zip(&self.spec.heads) {
            let shape = out.array_shape()?;
            let dims = shape.dims();
            let expect: Vec<i64> = vec![
                1,
                hs.grid as i64,
                hs.grid as i64,
                hs.channels as i64,
            ];
            if dims != expect.as_slice() {
                bail!(
                    "{}: head shape {:?} != manifest {:?}",
                    self.spec.artifact,
                    dims,
                    expect
                );
            }
            heads.push(HeadTensor {
                grid: hs.grid,
                channels: hs.channels,
                data: out.to_vec::<f32>()?,
            });
        }
        self.n_runs.set(self.n_runs.get() + 1);
        Ok(heads)
    }
}

fn tracing_log(msg: &str) {
    if std::env::var_os("TOD_QUIET").is_none() {
        eprintln!("[runtime] {msg}");
    }
}
