//! Accuracy evaluation: IoU matching and average precision, implemented
//! from the MOT devkit's detection-evaluation definition (the paper's
//! "Matlab interface MOT evaluation tool kit").

pub mod ap;
pub mod matching;

pub use ap::{average_precision, pr_curve, ApMethod, SequenceEval};
pub use matching::{match_frame, FrameMatch};
