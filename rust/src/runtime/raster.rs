//! Frame rasterizer: synthetic world ground truth -> (1, S, S, 3) image
//! tensor for the PJRT engines.
//!
//! The paper feeds camera frames; our stand-in paints each pedestrian as
//! a filled, shaded box over a textured background so the network input
//! varies realistically with the scene (per-id colour, per-frame noise).

use crate::dataset::mot::GtEntry;

/// Rasterize ground truth into a row-major (S, S, 3) float image in
/// [0, 1], resized from the (frame_w, frame_h) source geometry.
pub fn rasterize(
    gt: &[GtEntry],
    frame_w: f64,
    frame_h: f64,
    size: usize,
    frame_seed: u64,
) -> Vec<f32> {
    let mut img = vec![0.0f32; size * size * 3];
    // background: horizontal gradient + hash noise (cheap texture)
    for y in 0..size {
        let fy = y as f32 / size as f32;
        for x in 0..size {
            let fx = x as f32 / size as f32;
            let n = hash01(frame_seed ^ ((y * size + x) as u64)) * 0.08;
            let o = (y * size + x) * 3;
            img[o] = 0.35 + 0.2 * fx + n;
            img[o + 1] = 0.40 + 0.15 * fy + n;
            img[o + 2] = 0.45 + 0.1 * (fx + fy) / 2.0 + n;
        }
    }
    let sx = size as f64 / frame_w;
    let sy = size as f64 / frame_h;
    for g in gt {
        if !g.class.is_person() {
            continue;
        }
        let x0 = (g.bbox.x * sx).max(0.0) as usize;
        let y0 = (g.bbox.y * sy).max(0.0) as usize;
        let x1 = ((g.bbox.right() * sx).ceil() as usize).min(size);
        let y1 = ((g.bbox.bottom() * sy).ceil() as usize).min(size);
        // per-id colour so the network sees distinct objects
        let idh = g.id as u64;
        let (r, gg, b) = (
            0.15 + 0.7 * hash01(idh.wrapping_mul(3)),
            0.15 + 0.7 * hash01(idh.wrapping_mul(5)),
            0.15 + 0.7 * hash01(idh.wrapping_mul(7)),
        );
        for y in y0..y1 {
            for x in x0..x1 {
                let o = (y * size + x) * 3;
                // vertical shading: darker feet, lighter head
                let shade = 0.8
                    + 0.2
                        * (1.0
                            - (y.saturating_sub(y0)) as f32
                                / ((y1 - y0).max(1)) as f32);
                img[o] = (r * shade).min(1.0);
                img[o + 1] = (gg * shade).min(1.0);
                img[o + 2] = (b * shade).min(1.0);
            }
        }
    }
    img
}

#[inline]
fn hash01(x: u64) -> f32 {
    let mut z = x.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    ((z ^ (z >> 31)) >> 40) as f32 / (1u64 << 24) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::mot::MotClass;
    use crate::geometry::BBox;

    fn gt(x: f64, y: f64, w: f64, h: f64, id: i64) -> GtEntry {
        GtEntry {
            frame: 1,
            id,
            bbox: BBox::new(x, y, w, h),
            conf: 1.0,
            class: MotClass::Pedestrian,
            visibility: 1.0,
        }
    }

    #[test]
    fn output_shape_and_range() {
        let img = rasterize(&[gt(10.0, 10.0, 50.0, 100.0, 1)], 640.0, 480.0,
                            288, 0);
        assert_eq!(img.len(), 288 * 288 * 3);
        for v in &img {
            assert!((0.0..=1.0).contains(v), "pixel {v}");
        }
    }

    #[test]
    fn person_region_differs_from_background() {
        let e = gt(100.0, 100.0, 200.0, 200.0, 7);
        let with = rasterize(&[e], 640.0, 480.0, 288, 1);
        let without = rasterize(&[], 640.0, 480.0, 288, 1);
        // center of the box (scaled): x=200/640*288=90, y=200/480*288=120
        let o = (120 * 288 + 90) * 3;
        let d = (with[o] - without[o]).abs()
            + (with[o + 1] - without[o + 1]).abs()
            + (with[o + 2] - without[o + 2]).abs();
        assert!(d > 0.05, "painted region should differ, d={d}");
        // far corner unchanged
        let c = (10 * 288 + 270) * 3;
        assert_eq!(with[c], without[c]);
    }

    #[test]
    fn deterministic_in_seed() {
        let e = gt(50.0, 50.0, 80.0, 160.0, 3);
        let a = rasterize(&[e.clone()], 640.0, 480.0, 96, 42);
        let b = rasterize(&[e.clone()], 640.0, 480.0, 96, 42);
        let c = rasterize(&[e], 640.0, 480.0, 96, 43);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn non_person_classes_not_painted() {
        let mut e = gt(100.0, 100.0, 200.0, 200.0, 7);
        e.class = MotClass::Car;
        let with = rasterize(&[e], 640.0, 480.0, 96, 1);
        let without = rasterize(&[], 640.0, 480.0, 96, 1);
        assert_eq!(with, without);
    }

    #[test]
    fn boxes_outside_frame_are_safe() {
        // must not panic or write out of bounds
        let e = gt(-50.0, -50.0, 100.0, 100.0, 1);
        let img = rasterize(&[e], 640.0, 480.0, 64, 0);
        assert_eq!(img.len(), 64 * 64 * 3);
    }
}
