//! The full evaluation campaign: every (sequence × DNN × mode) run the
//! paper's figures draw from, computed once and memoized.

use std::collections::BTreeMap;

use crate::coordinator::baselines::{run_chameleon_lite, ChameleonConfig};
use crate::coordinator::multistream::{
    BatchingSim, DispatchPolicy, MultiStreamResult, MultiStreamScheduler,
};
use crate::coordinator::policy::{FixedPolicy, MbbsPolicy, Thresholds};
use crate::coordinator::projected::ProjectedAccuracyPolicy;
use crate::coordinator::scheduler::{
    run_offline, run_realtime, OracleBackend, RunResult,
};
use crate::coordinator::session::StreamSession;
use crate::dataset::catalog::{generate, SequenceId};
use crate::dataset::synth::Sequence;
use crate::power::{BudgetedPolicy, PowerBudget};
use crate::predictor::{calibrate, CalibrationConfig, CalibrationTable};
use crate::scenario::conformance::{run_report, ScenarioReport};
use crate::scenario::matrix::{scenario_spec, ScenarioId};
use crate::sim::latency::{ContentionModel, LatencyModel};
use crate::sim::oracle::OracleDetector;
use crate::DnnKind;

/// Stream counts the multi-stream scaling study sweeps (1 → 8 streams
/// packed onto one accelerator).
pub const MULTISTREAM_SCALE: [usize; 4] = [1, 2, 4, 8];

/// Default watts budget for the `power` experiment: below the active
/// power of both full-YOLO variants (7.2 / 7.5 W, Fig. 14), so a
/// saturated heavy-DNN deployment is infeasible, while both tiny
/// variants stay admissible.
pub const DEFAULT_WATTS_BUDGET: f64 = 6.5;

/// One row of the multi-stream scaling study.
#[derive(Debug, Clone)]
pub struct MultiStreamScalingRow {
    pub n_streams: usize,
    /// Mean AP across the concurrent streams.
    pub mean_ap: f64,
    /// Aggregate drop rate over all streams' frames.
    pub drop_rate: f64,
    /// Accelerator busy fraction over the makespan.
    pub utilisation: f64,
    /// Aggregate inferences per virtual second.
    pub throughput_ips: f64,
}

/// Memoized campaign over the seven catalog sequences.
pub struct Campaign {
    sequences: BTreeMap<SequenceId, Sequence>,
    offline: BTreeMap<(SequenceId, DnnKind), RunResult>,
    realtime: BTreeMap<(SequenceId, DnnKind), RunResult>,
    tod: BTreeMap<SequenceId, RunResult>,
    chameleon: BTreeMap<SequenceId, RunResult>,
    projected: BTreeMap<SequenceId, RunResult>,
    /// Budgeted TOD runs keyed by (sequence, watts-cap bits).
    power_budgeted: BTreeMap<(SequenceId, u64), RunResult>,
    /// Calibration tables keyed by eval-FPS bits (drop cost is per-FPS).
    calibrations: BTreeMap<u64, CalibrationTable>,
    multistream: BTreeMap<(usize, DispatchPolicy), MultiStreamResult>,
    /// Batched multi-stream runs keyed by (streams, dispatch,
    /// max_batch) under the Jetson batched latency model.
    multistream_batched:
        BTreeMap<(usize, DispatchPolicy, usize), MultiStreamResult>,
    /// Conformance reports of the scenario matrix (the `scenario`
    /// experiment), one per scenario id.
    scenario_reports: BTreeMap<ScenarioId, ScenarioReport>,
    thresholds: Thresholds,
}

impl Campaign {
    /// Generate all sequences (cheap; detections are computed lazily).
    pub fn new() -> Self {
        Campaign::with_thresholds(Thresholds::h_opt())
    }

    pub fn with_thresholds(thresholds: Thresholds) -> Self {
        let sequences = SequenceId::ALL
            .iter()
            .map(|&id| (id, generate(id)))
            .collect();
        Campaign {
            sequences,
            offline: BTreeMap::new(),
            realtime: BTreeMap::new(),
            tod: BTreeMap::new(),
            chameleon: BTreeMap::new(),
            projected: BTreeMap::new(),
            power_budgeted: BTreeMap::new(),
            calibrations: BTreeMap::new(),
            multistream: BTreeMap::new(),
            multistream_batched: BTreeMap::new(),
            scenario_reports: BTreeMap::new(),
            thresholds,
        }
    }

    pub fn thresholds(&self) -> &Thresholds {
        &self.thresholds
    }

    pub fn sequence(&self, id: SequenceId) -> &Sequence {
        &self.sequences[&id]
    }

    fn oracle_for(&self, id: SequenceId) -> OracleBackend {
        let s = &self.sequences[&id];
        OracleBackend(OracleDetector::new(
            s.spec.seed,
            s.spec.width as f64,
            s.spec.height as f64,
        ))
    }

    /// Offline-mode run (Fig. 4): all frames, no clock.
    pub fn offline(&mut self, id: SequenceId, dnn: DnnKind) -> &RunResult {
        if !self.offline.contains_key(&(id, dnn)) {
            let mut det = self.oracle_for(id);
            let r = run_offline(&self.sequences[&id], dnn, &mut det);
            self.offline.insert((id, dnn), r);
        }
        &self.offline[&(id, dnn)]
    }

    /// Real-time fixed-DNN run (Fig. 6) at the sequence's eval FPS.
    pub fn realtime_fixed(
        &mut self,
        id: SequenceId,
        dnn: DnnKind,
    ) -> &RunResult {
        if !self.realtime.contains_key(&(id, dnn)) {
            let mut det = self.oracle_for(id);
            let mut pol = FixedPolicy(dnn);
            let mut lat = LatencyModel::deterministic();
            let r = run_realtime(
                &self.sequences[&id],
                &mut pol,
                &mut det,
                &mut lat,
                id.eval_fps(),
            );
            self.realtime.insert((id, dnn), r);
        }
        &self.realtime[&(id, dnn)]
    }

    /// TOD run with the campaign thresholds (Figs. 8, 10, 12, 13, 15).
    pub fn tod(&mut self, id: SequenceId) -> &RunResult {
        if !self.tod.contains_key(&id) {
            let mut det = self.oracle_for(id);
            let mut pol = MbbsPolicy::new(self.thresholds.clone());
            let mut lat = LatencyModel::deterministic();
            let r = run_realtime(
                &self.sequences[&id],
                &mut pol,
                &mut det,
                &mut lat,
                id.eval_fps(),
            );
            self.tod.insert(id, r);
        }
        &self.tod[&id]
    }

    /// The default calibration table for an eval FPS (computed once,
    /// memoized — the calibration campaign is the expensive part of the
    /// predictor experiments).
    pub fn calibration(&mut self, fps: f64) -> &CalibrationTable {
        self.calibrations
            .entry(fps.to_bits())
            .or_insert_with(|| calibrate(&CalibrationConfig::default_for_fps(fps)))
    }

    /// Projected-accuracy policy run (the `predictor` experiment): the
    /// calibrated size×speed table at the sequence's eval FPS, no
    /// latency budget (demand is priced by the table itself).
    pub fn projected(&mut self, id: SequenceId) -> &RunResult {
        if !self.projected.contains_key(&id) {
            let table = self.calibration(id.eval_fps()).clone();
            let mut det = self.oracle_for(id);
            let mut pol = ProjectedAccuracyPolicy::new(
                table,
                &LatencyModel::deterministic(),
            );
            let mut lat = LatencyModel::deterministic();
            let r = run_realtime(
                &self.sequences[&id],
                &mut pol,
                &mut det,
                &mut lat,
                id.eval_fps(),
            );
            self.projected.insert(id, r);
        }
        &self.projected[&id]
    }

    /// Budgeted TOD run (the `power` experiment): the campaign's MBBS
    /// ladder wrapped in a [`PowerBudget`] watts governor (1 s sliding
    /// window), at the sequence's eval FPS. `RunResult::power` carries
    /// the online-metered joules / watts / GPU-busy figures.
    pub fn power_budgeted(
        &mut self,
        id: SequenceId,
        watts_cap: f64,
    ) -> &RunResult {
        let key = (id, watts_cap.to_bits());
        if !self.power_budgeted.contains_key(&key) {
            let mut det = self.oracle_for(id);
            let mut lat = LatencyModel::deterministic();
            let mut pol = BudgetedPolicy::masking(
                Box::new(MbbsPolicy::new(self.thresholds.clone())),
                PowerBudget::watts(watts_cap, &lat),
            );
            let r = run_realtime(
                &self.sequences[&id],
                &mut pol,
                &mut det,
                &mut lat,
                id.eval_fps(),
            );
            self.power_budgeted.insert(key, r);
        }
        &self.power_budgeted[&key]
    }

    /// Chameleon-lite baseline run (related-work comparison).
    pub fn chameleon(&mut self, id: SequenceId) -> &RunResult {
        if !self.chameleon.contains_key(&id) {
            let mut det = self.oracle_for(id);
            let mut lat = LatencyModel::deterministic();
            let r = run_chameleon_lite(
                &self.sequences[&id],
                &mut det,
                &mut lat,
                id.eval_fps(),
                &ChameleonConfig::default(),
            );
            self.chameleon.insert(id, r);
        }
        &self.chameleon[&id]
    }

    /// Run `n` concurrent TOD streams (stream `i` replays catalog
    /// sequence `ALL[i % 7]` at its eval FPS) over one shared
    /// accelerator with the Jetson contention default — the one
    /// construction both the unbatched and batched campaign entry
    /// points go through, so their runs stay comparable.
    fn run_multistream(
        &self,
        n: usize,
        dispatch: DispatchPolicy,
        batching: Option<BatchingSim>,
    ) -> MultiStreamResult {
        let mut sched = MultiStreamScheduler::new(
            dispatch,
            ContentionModel::jetson_nano(),
            LatencyModel::deterministic(),
        );
        if let Some(b) = batching {
            sched = sched.with_batching(b);
        }
        for i in 0..n {
            let id = SequenceId::ALL[i % SequenceId::ALL.len()];
            let seq = &self.sequences[&id];
            let det = OracleBackend(OracleDetector::new(
                seq.spec.seed,
                seq.spec.width as f64,
                seq.spec.height as f64,
            ));
            sched.add_stream(
                StreamSession::new(
                    seq,
                    MbbsPolicy::new(self.thresholds.clone()),
                    id.eval_fps(),
                ),
                Box::new(det),
            );
        }
        sched.run()
    }

    /// `n` concurrent TOD streams packed onto one shared accelerator
    /// with the Jetson contention default (see
    /// [`run_multistream`](Self::run_multistream)).
    pub fn multistream(
        &mut self,
        n: usize,
        dispatch: DispatchPolicy,
    ) -> &MultiStreamResult {
        if !self.multistream.contains_key(&(n, dispatch)) {
            let r = self.run_multistream(n, dispatch, None);
            self.multistream.insert((n, dispatch), r);
        }
        &self.multistream[&(n, dispatch)]
    }

    /// Like [`multistream`](Self::multistream), with deterministic
    /// cross-stream micro-batching under the Jetson setup share
    /// ([`BatchingSim`]): the virtual-time quantification of the
    /// batching server's throughput win. `max_batch == 1` reproduces
    /// the unbatched run bit for bit.
    pub fn multistream_batched(
        &mut self,
        n: usize,
        dispatch: DispatchPolicy,
        max_batch: usize,
    ) -> &MultiStreamResult {
        let key = (n, dispatch, max_batch);
        if !self.multistream_batched.contains_key(&key) {
            let r = self.run_multistream(
                n,
                dispatch,
                Some(BatchingSim::jetson_nano(max_batch)),
            );
            self.multistream_batched.insert(key, r);
        }
        &self.multistream_batched[&key]
    }

    /// The multi-stream scaling study: aggregate AP / drop-rate /
    /// utilisation as stream count grows over [`MULTISTREAM_SCALE`].
    pub fn multistream_scaling(
        &mut self,
        dispatch: DispatchPolicy,
    ) -> Vec<MultiStreamScalingRow> {
        MULTISTREAM_SCALE
            .iter()
            .map(|&n| {
                let r = self.multistream(n, dispatch);
                MultiStreamScalingRow {
                    n_streams: n,
                    mean_ap: r.mean_ap(),
                    drop_rate: r.drop_rate(),
                    utilisation: r.utilisation.utilisation(),
                    throughput_ips: r.utilisation.throughput_ips(),
                }
            })
            .collect()
    }

    /// Conformance report of one matrix scenario (all canonical
    /// configurations plus the differential margins), memoized. The
    /// matrix specs are validated at 30 FPS by construction, so replay
    /// cannot fail.
    pub fn scenario_report(&mut self, id: ScenarioId) -> &ScenarioReport {
        if !self.scenario_reports.contains_key(&id) {
            let report = run_report(&scenario_spec(id))
                .expect("matrix scenarios are valid by construction");
            self.scenario_reports.insert(id, report);
        }
        &self.scenario_reports[&id]
    }

    /// Best fixed-DNN real-time AP on a sequence (the paper's
    /// "best accuracy out of individual DNNs").
    pub fn best_fixed_realtime(&mut self, id: SequenceId) -> (DnnKind, f64) {
        let mut best = (DnnKind::TinyY288, f64::NEG_INFINITY);
        for k in DnnKind::ALL {
            let ap = self.realtime_fixed(id, k).ap;
            if ap > best.1 {
                best = (k, ap);
            }
        }
        best
    }

    /// Mean TOD improvement over each fixed DNN across all sequences,
    /// in percent (the paper's headline 34.7 / 7.0 / 3.9 / 2.0 numbers).
    pub fn improvement_over_fixed(&mut self) -> [f64; DnnKind::COUNT] {
        let mut out = [0.0; DnnKind::COUNT];
        for (i, k) in DnnKind::ALL.iter().enumerate() {
            let mut tod_mean = 0.0;
            let mut fixed_mean = 0.0;
            for id in SequenceId::ALL {
                tod_mean += self.tod(id).ap;
                fixed_mean += self.realtime_fixed(id, *k).ap;
            }
            out[i] = (tod_mean / fixed_mean - 1.0) * 100.0;
        }
        out
    }
}

impl Default for Campaign {
    fn default() -> Self {
        Campaign::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: campaign-level behaviour (orderings across all sequences) is
    // exercised by the integration tests; unit tests here only cover
    // memoization plumbing on the cheapest sequence.

    #[test]
    fn memoization_returns_same_result() {
        let mut c = Campaign::new();
        let a = c.offline(SequenceId::Mot09, DnnKind::TinyY288).ap;
        let b = c.offline(SequenceId::Mot09, DnnKind::TinyY288).ap;
        assert_eq!(a, b);
        let t1 = c.tod(SequenceId::Mot09).ap;
        let t2 = c.tod(SequenceId::Mot09).ap;
        assert_eq!(t1, t2);
    }

    #[test]
    fn one_stream_multistream_matches_single_stream_tod() {
        // stream 0 replays SequenceId::ALL[0] with the campaign
        // thresholds, so a 1-stream scheduler must reproduce tod()
        let mut c = Campaign::new();
        let single = c.tod(SequenceId::ALL[0]).ap;
        let multi =
            c.multistream(1, DispatchPolicy::RoundRobin).per_stream[0].ap;
        assert_eq!(single, multi);
    }

    #[test]
    fn multistream_memoized_and_scaling_shapes() {
        let mut c = Campaign::new();
        let a = c.multistream(2, DispatchPolicy::RoundRobin).mean_ap();
        let b = c.multistream(2, DispatchPolicy::RoundRobin).mean_ap();
        assert_eq!(a, b);
        let rows = c.multistream_scaling(DispatchPolicy::RoundRobin);
        assert_eq!(rows.len(), MULTISTREAM_SCALE.len());
        assert_eq!(rows[0].n_streams, 1);
        assert_eq!(rows.last().unwrap().n_streams, 8);
        // packing more streams onto one accelerator must not lower the
        // aggregate drop rate
        assert!(rows.last().unwrap().drop_rate >= rows[0].drop_rate);
    }

    #[test]
    fn multistream_batched_memoized_and_wins_throughput() {
        let mut c = Campaign::new();
        let plain = c.multistream(4, DispatchPolicy::RoundRobin);
        let plain_ips = plain.utilisation.throughput_ips();
        // max_batch 1 is the unbatched schedule bit for bit
        let b1 = c.multistream_batched(4, DispatchPolicy::RoundRobin, 1);
        assert_eq!(
            b1.utilisation.throughput_ips(),
            plain_ips,
            "max_batch=1 must be bit-identical"
        );
        let b4 = c.multistream_batched(4, DispatchPolicy::RoundRobin, 4);
        let b4_ips = b4.utilisation.throughput_ips();
        assert!(
            b4_ips >= plain_ips,
            "batching must not lose throughput"
        );
        assert!(b4.batching.is_some());
        let again =
            c.multistream_batched(4, DispatchPolicy::RoundRobin, 4);
        assert_eq!(again.utilisation.throughput_ips(), b4_ips);
    }

    #[test]
    fn power_budgeted_memoized_and_labelled() {
        let mut c = Campaign::new();
        let a = c.power_budgeted(SequenceId::Mot09, DEFAULT_WATTS_BUDGET);
        let label = a.policy.clone();
        let ap = a.ap;
        assert!(label.starts_with("budgeted{"), "{label}");
        let b = c.power_budgeted(SequenceId::Mot09, DEFAULT_WATTS_BUDGET);
        assert_eq!(ap, b.ap);
        // metered power respects the cap (the governor's whole point)
        assert!(b.power.avg_power_w <= DEFAULT_WATTS_BUDGET + 0.25);
    }

    #[test]
    fn best_fixed_is_max() {
        let mut c = Campaign::new();
        let (_, best) = c.best_fixed_realtime(SequenceId::Mot09);
        for k in DnnKind::ALL {
            assert!(best >= c.realtime_fixed(SequenceId::Mot09, k).ap);
        }
    }
}
