//! Quickstart: run TOD on one synthetic sequence and compare against the
//! fixed-DNN baselines in a dozen lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use tod::coordinator::policy::{FixedPolicy, MbbsPolicy};
use tod::coordinator::scheduler::{run_realtime, OracleBackend};
use tod::dataset::catalog::{generate, SequenceId};
use tod::sim::latency::LatencyModel;
use tod::sim::oracle::OracleDetector;
use tod::DnnKind;

fn main() {
    // 1. A video stream: the MOT17-05-like walking-camera sequence at
    //    its native 14 FPS.
    let id = SequenceId::Mot05;
    let seq = generate(id);
    let make_detector = || {
        OracleBackend(OracleDetector::new(
            seq.spec.seed,
            seq.spec.width as f64,
            seq.spec.height as f64,
        ))
    };

    // 2. The four fixed-DNN baselines.
    println!("sequence {} @ {} FPS\n", id.name(), id.eval_fps());
    for kind in DnnKind::ALL {
        let mut policy = FixedPolicy(kind);
        let mut latency = LatencyModel::deterministic();
        let r = run_realtime(
            &seq,
            &mut policy,
            &mut make_detector(),
            &mut latency,
            id.eval_fps(),
        );
        println!(
            "  {:16} AP {:.3}  dropped {:4} frames",
            kind.artifact_name(),
            r.ap,
            r.n_dropped
        );
    }

    // 3. TOD with the paper's H_opt = {0.007, 0.03, 0.04}.
    let mut policy = MbbsPolicy::tod_default();
    let mut latency = LatencyModel::deterministic();
    let r = run_realtime(
        &seq,
        &mut policy,
        &mut make_detector(),
        &mut latency,
        id.eval_fps(),
    );
    let freq = r.deploy_freq();
    println!(
        "\n  {:16} AP {:.3}  dropped {:4} frames  switches {}",
        "TOD", r.ap, r.n_dropped, r.switches
    );
    println!(
        "  TOD deployment: YT-288 {:.0}%  YT-416 {:.0}%  Y-288 {:.0}%  \
         Y-416 {:.0}%",
        freq[0] * 100.0,
        freq[1] * 100.0,
        freq[2] * 100.0,
        freq[3] * 100.0
    );
}
