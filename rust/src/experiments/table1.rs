//! Table I: hyperparameter search over the 2x2x2 grid, six training
//! sequences at 30 FPS.

use crate::coordinator::search::{grid_search_oracle, SearchSpace};
use crate::dataset::catalog::{generate, SequenceId};
use crate::util::csv::CsvTable;
use crate::util::table::AsciiTable;

use super::ExperimentOutput;

pub fn run() -> ExperimentOutput {
    let seqs: Vec<_> =
        SequenceId::TRAIN.iter().map(|&id| generate(id)).collect();
    // Table I evaluates the training sequences under a 30 FPS constraint
    let train: Vec<(&_, f64)> = seqs.iter().map(|s| (s, 30.0)).collect();
    let res = grid_search_oracle(&SearchSpace::paper(), &train);

    let mut header = vec!["".to_string()];
    for row in &res.rows {
        let h = row.thresholds.values();
        header.push(format!("{}/{}/{}", h[0], h[1], h[2]));
    }
    let mut table = AsciiTable::new(
        "Table I — Hyperparameter Search (AP per training sequence)",
        header.iter().map(String::as_str).collect(),
    );
    let mut csv = CsvTable::new(
        std::iter::once("sequence".to_string())
            .chain(header[1..].iter().cloned())
            .collect::<Vec<_>>(),
    );
    for (si, id) in SequenceId::TRAIN.iter().enumerate() {
        let mut row = vec![id.name().to_string()];
        for r in &res.rows {
            row.push(format!("{:.2}", r.per_sequence_ap[si]));
        }
        table.push(row.clone());
        csv.push(row);
    }
    let mut avg = vec!["AVG(AP)".to_string()];
    for r in &res.rows {
        avg.push(format!("{:.3}", r.mean_ap));
    }
    table.push(avg.clone());
    csv.push(avg);

    let best = res.best_thresholds().values().to_vec();
    let text = format!(
        "{}\nSelected H_opt = {{{}, {}, {}}} (paper: {{0.007, 0.03, 0.04}})\n",
        table.render(),
        best[0],
        best[1],
        best[2]
    );
    ExperimentOutput {
        id: "table1",
        title: "Table I: hyperparameter search".into(),
        text,
        csv: vec![("table1.csv".into(), csv)],
    }
}
