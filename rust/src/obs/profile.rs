//! Offline span-profile fold: self-time vs child-time attribution
//! (DESIGN.md §15).
//!
//! [`fold_into`] replays the [`crate::obs::Event::SpanOpen`] /
//! [`crate::obs::Event::SpanClose`] pairs of a recorded trace and
//! attributes each span's *self time* — its duration minus the summed
//! durations of its direct children — to its [`SpanKind`]. Stage
//! self-times feed the per-stage histograms of
//! [`MetricsRegistry::observe_stage`] and aggregate into a versioned
//! [`ProfileReport`] (`tod trace profile`). [`per_frame`] returns the
//! same attribution per inferred frame, which is what the conformance
//! tests use to assert that stage self-times sum exactly to each frame
//! span.
//!
//! This is the offline tier: allocation is fine, nothing here runs on
//! the stepping path. Events must be in recorder emission order (which
//! [`crate::obs::EventLog`] and `tod trace` files preserve); spans are
//! keyed per stream so interleaving across streams is harmless.

use std::collections::BTreeMap;

use crate::obs::metrics::MetricsRegistry;
use crate::obs::span::SpanKind;
use crate::obs::Event;
use crate::util::json::Json;

/// Schema tag of the profile-report JSON.
pub const PROFILE_TAG: &str = "tod-profile";

/// Version of the profile-report JSON. Bump when fields change meaning.
pub const PROFILE_VERSION: u64 = 1;

/// Aggregate for one [`SpanKind`] across a trace.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StageAgg {
    /// Closed spans of this kind.
    pub count: u64,
    /// Summed self time (duration minus direct children), seconds.
    pub self_s: f64,
    /// Summed inclusive duration, seconds.
    pub total_s: f64,
}

/// Per-stage attribution over a whole trace.
#[derive(Debug, Clone, PartialEq)]
pub struct ProfileReport {
    /// One aggregate per [`SpanKind`], indexed by [`SpanKind::index`].
    pub stages: [StageAgg; SpanKind::COUNT],
    /// Closed frame spans seen.
    pub frames: u64,
    /// Summed stream-span duration (total traced stream time), seconds.
    pub total_s: f64,
    /// Spans still open when the trace ended (0 for a clean run).
    pub unclosed: u64,
}

impl ProfileReport {
    /// Aggregate for one kind.
    pub fn stage(&self, kind: SpanKind) -> StageAgg {
        self.stages[kind.index()]
    }

    /// Versioned JSON encoding (all stages, fixed arity, sorted keys).
    pub fn to_json(&self) -> Json {
        let stages = SpanKind::ALL
            .iter()
            .map(|&k| {
                let agg = self.stage(k);
                Json::obj(vec![
                    ("stage", Json::str(k.label())),
                    ("count", Json::num(agg.count as f64)),
                    ("self_s", Json::num(agg.self_s)),
                    ("total_s", Json::num(agg.total_s)),
                ])
            })
            .collect::<Vec<_>>();
        Json::obj(vec![
            ("schema", Json::str(PROFILE_TAG)),
            ("version", Json::num(PROFILE_VERSION as f64)),
            ("frames", Json::num(self.frames as f64)),
            ("total_s", Json::num(self.total_s)),
            ("unclosed", Json::num(self.unclosed as f64)),
            ("stages", Json::arr(stages)),
        ])
    }
}

/// Stage attribution for one frame span.
#[derive(Debug, Clone, PartialEq)]
pub struct FrameProfile {
    pub stream: u32,
    pub frame: u64,
    /// Inclusive duration of the frame span, seconds.
    pub total_s: f64,
    /// Self time per kind, indexed by [`SpanKind::index`]. The frame
    /// span's own self time sits at [`SpanKind::Frame`]'s slot and is 0
    /// exactly when its stage children tile the frame interval.
    pub stage_self_s: [f64; SpanKind::COUNT],
}

/// One open span during replay.
struct OpenSpan {
    t: f64,
    kind: SpanKind,
    parent: u32,
    frame: u64,
    child_s: f64,
}

/// Replay span events, folding stage self-times into `metrics` (via
/// [`MetricsRegistry::observe_stage`]) and returning the aggregate
/// [`ProfileReport`]. Non-span events are ignored.
pub fn fold_into(
    events: &[Event],
    metrics: &mut MetricsRegistry,
) -> ProfileReport {
    let (report, _) = replay(events, Some(metrics));
    report
}

/// Aggregate profile without a metrics registry.
pub fn profile(events: &[Event]) -> ProfileReport {
    let (report, _) = replay(events, None);
    report
}

/// Per-frame stage attribution, in frame-close order. Only frames whose
/// frame span closed are included (a trace cut mid-frame drops it).
pub fn per_frame(events: &[Event]) -> Vec<FrameProfile> {
    let (_, frames) = replay(events, None);
    frames
}

fn replay(
    events: &[Event],
    mut metrics: Option<&mut MetricsRegistry>,
) -> (ProfileReport, Vec<FrameProfile>) {
    // (stream, span id) -> open span state; parents stay open until
    // all their children closed, so child attribution lands in the map.
    let mut open: BTreeMap<(u32, u32), OpenSpan> = BTreeMap::new();
    // (stream, frame) -> accumulating per-frame attribution
    let mut by_frame: BTreeMap<(u32, u64), [f64; SpanKind::COUNT]> =
        BTreeMap::new();
    let mut frames_done: Vec<FrameProfile> = Vec::new();
    let mut report = ProfileReport {
        stages: [StageAgg::default(); SpanKind::COUNT],
        frames: 0,
        total_s: 0.0,
        unclosed: 0,
    };
    for ev in events {
        match *ev {
            Event::SpanOpen { stream, frame, span, parent, kind, t } => {
                open.insert(
                    (stream, span),
                    OpenSpan { t, kind, parent, frame, child_s: 0.0 },
                );
            }
            Event::SpanClose { stream, span, t } => {
                let Some(sp) = open.remove(&(stream, span)) else {
                    // close without an open: validate_spans reports
                    // this; the profile just skips it
                    continue;
                };
                let total = (t - sp.t).max(0.0);
                let self_s = (total - sp.child_s).max(0.0);
                let agg = &mut report.stages[sp.kind.index()];
                agg.count += 1;
                agg.self_s += self_s;
                agg.total_s += total;
                if let Some(m) = metrics.as_deref_mut() {
                    m.observe_stage(sp.kind, self_s);
                }
                if sp.parent != 0 {
                    if let Some(parent) = open.get_mut(&(stream, sp.parent))
                    {
                        parent.child_s += total;
                    }
                }
                match sp.kind {
                    SpanKind::Stream => report.total_s += total,
                    SpanKind::Frame => {
                        report.frames += 1;
                        let mut stage_self_s = by_frame
                            .remove(&(stream, sp.frame))
                            .unwrap_or([0.0; SpanKind::COUNT]);
                        stage_self_s[SpanKind::Frame.index()] += self_s;
                        frames_done.push(FrameProfile {
                            stream,
                            frame: sp.frame,
                            total_s: total,
                            stage_self_s,
                        });
                    }
                    _ if sp.frame != 0 => {
                        by_frame
                            .entry((stream, sp.frame))
                            .or_insert([0.0; SpanKind::COUNT])
                            [sp.kind.index()] += self_s;
                    }
                    _ => {}
                }
            }
            _ => {}
        }
    }
    report.unclosed = open.len() as u64;
    (report, frames_done)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn open(
        stream: u32,
        frame: u64,
        span: u32,
        parent: u32,
        kind: SpanKind,
        t: f64,
    ) -> Event {
        Event::SpanOpen { stream, frame, span, parent, kind, t }
    }

    fn close(stream: u32, span: u32, t: f64) -> Event {
        Event::SpanClose { stream, span, t }
    }

    /// stream span [0, 1.0] holding one frame [0.1, 0.4] with a
    /// dispatch_wait [0.1, 0.15] and an inference [0.15, 0.4].
    fn one_frame_trace() -> Vec<Event> {
        vec![
            open(0, 0, 1, 0, SpanKind::Stream, 0.0),
            open(0, 5, 2, 1, SpanKind::Frame, 0.1),
            open(0, 5, 3, 2, SpanKind::FeatureExtract, 0.1),
            close(0, 3, 0.1),
            open(0, 5, 4, 2, SpanKind::DispatchWait, 0.1),
            close(0, 4, 0.15),
            open(0, 5, 5, 2, SpanKind::Inference, 0.15),
            close(0, 5, 0.4),
            close(0, 2, 0.4),
            close(0, 1, 1.0),
        ]
    }

    #[test]
    fn self_time_excludes_children() {
        let report = profile(&one_frame_trace());
        assert_eq!(report.frames, 1);
        assert_eq!(report.unclosed, 0);
        assert!((report.total_s - 1.0).abs() < 1e-12);
        let frame = report.stage(SpanKind::Frame);
        assert_eq!(frame.count, 1);
        assert!((frame.total_s - 0.3).abs() < 1e-12);
        // children tile the frame: zero frame self time
        assert!(frame.self_s.abs() < 1e-12, "self {}", frame.self_s);
        let infer = report.stage(SpanKind::Inference);
        assert!((infer.self_s - 0.25).abs() < 1e-12);
        let wait = report.stage(SpanKind::DispatchWait);
        assert!((wait.self_s - 0.05).abs() < 1e-12);
        // the stream span's self time excludes the frame
        let stream = report.stage(SpanKind::Stream);
        assert!((stream.self_s - 0.7).abs() < 1e-12);
    }

    #[test]
    fn per_frame_attribution_sums_to_the_frame_span() {
        let frames = per_frame(&one_frame_trace());
        assert_eq!(frames.len(), 1);
        let f = &frames[0];
        assert_eq!((f.stream, f.frame), (0, 5));
        assert!((f.total_s - 0.3).abs() < 1e-12);
        let sum: f64 = f.stage_self_s.iter().sum();
        assert!(
            (sum - f.total_s).abs() < 1e-9,
            "stage self-times {sum} != frame total {}",
            f.total_s
        );
    }

    #[test]
    fn fold_feeds_stage_histograms() {
        let mut m = MetricsRegistry::default();
        let report = fold_into(&one_frame_trace(), &mut m);
        assert_eq!(report.frames, 1);
        // one observation per closed span
        let snap = m.to_json().to_string();
        assert!(snap.contains("stage_self_s"));
    }

    #[test]
    fn unclosed_spans_are_counted_not_attributed() {
        let evs = vec![
            open(0, 0, 1, 0, SpanKind::Stream, 0.0),
            open(0, 3, 2, 1, SpanKind::Frame, 0.1),
            // trace ends mid-frame
        ];
        let report = profile(&evs);
        assert_eq!(report.unclosed, 2);
        assert_eq!(report.frames, 0);
        assert!(per_frame(&evs).is_empty());
    }

    #[test]
    fn report_json_is_versioned_with_fixed_stage_arity() {
        let report = profile(&one_frame_trace());
        let v = report.to_json();
        assert_eq!(v.get("schema").and_then(Json::as_str), Some(PROFILE_TAG));
        assert_eq!(
            v.get("version").and_then(Json::as_f64),
            Some(PROFILE_VERSION as f64)
        );
        let stages = v.get("stages").and_then(Json::as_arr).unwrap();
        assert_eq!(stages.len(), SpanKind::COUNT);
        assert_eq!(
            stages[0].get("stage").and_then(Json::as_str),
            Some("stream")
        );
        // deterministic text
        assert_eq!(v.to_string(), profile(&one_frame_trace()).to_json().to_string());
    }
}
