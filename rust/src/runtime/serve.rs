//! End-to-end serving on the PJRT request path: rasterize -> infer ->
//! decode -> policy, with all four engines preloaded. Python never runs
//! here — the binary is self-contained once `make artifacts` has built
//! the HLO text.
//!
//! Two shapes are provided: [`serve_sequence`] drives one stream with
//! per-request dispatch, and [`serve_batched`] multiplexes N streams
//! through the micro-batching [`ServerCore`] (client threads submit,
//! the engine-owning thread pumps batches — compiled executables never
//! cross threads). Both are panic-free: an engine failure fails its own
//! frame (counted, detections carried forward), never the process.

// Serving path: engine failures and NaNs must degrade per frame, not
// panic the loop.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use crate::ext::anyhow::{anyhow, bail, Result};

use crate::coordinator::policy::{MbbsPolicy, SelectionPolicy};
use crate::coordinator::scheduler::{DetectError, Detector};
use crate::dataset::mot::GtEntry;
use crate::dataset::synth::{CameraMotion, Sequence, SequenceSpec};
use crate::detection::{Detection, FrameDetections};
use crate::features::FeatureExtractor;
use crate::runtime::batch::{BatchConfig, BatchStats};
use crate::runtime::decode::decode;
use crate::runtime::pool::EnginePool;
use crate::runtime::raster::rasterize;
use crate::runtime::server::{
    BatchPoll, InferRequest, ServeError, ServerCore,
};
use crate::util::stats::percentile;
use crate::DnnKind;

/// A [`Detector`] backend that runs real PJRT inference (used by the
/// integration tests and the serving examples).
pub struct PjrtBackend<'a> {
    pub pool: &'a EnginePool,
    pub frame_w: f64,
    pub frame_h: f64,
    /// Wall-clock seconds spent per inference, appended per call.
    pub latencies: Vec<(DnnKind, f64)>,
}

impl<'a> PjrtBackend<'a> {
    pub fn new(pool: &'a EnginePool, frame_w: f64, frame_h: f64) -> Self {
        PjrtBackend { pool, frame_w, frame_h, latencies: Vec::new() }
    }
}

impl<'a> Detector for PjrtBackend<'a> {
    /// Fallible by contract: a missing variant or failed PJRT call
    /// propagates as an error for *this frame* instead of crashing the
    /// serving loop.
    fn detect(
        &mut self,
        frame: u64,
        gt: &[GtEntry],
        dnn: DnnKind,
    ) -> std::result::Result<Vec<Detection>, DetectError> {
        let engine = self
            .pool
            .engine(dnn)
            .map_err(|e| DetectError(format!("{e:#}")))?;
        let spec = engine.spec().clone();
        let img =
            rasterize(gt, self.frame_w, self.frame_h, spec.input_size, frame);
        let t0 = Instant::now();
        let heads = engine
            .infer(&img)
            .map_err(|e| DetectError(format!("{e:#}")))?;
        self.latencies.push((dnn, t0.elapsed().as_secs_f64()));
        Ok(decode(&heads, &spec, self.frame_w, self.frame_h))
    }
}

/// Run one request directly against the pool (shared by the batched
/// pump and any caller that owns the engines on the current thread).
pub fn infer_on_pool(
    pool: &EnginePool,
    req: &InferRequest,
) -> std::result::Result<Vec<Detection>, ServeError> {
    let engine = pool
        .engine(req.dnn)
        .map_err(|e| ServeError::Engine(format!("{e:#}")))?;
    let spec = engine.spec();
    let img = rasterize(
        &req.gt,
        req.frame_w,
        req.frame_h,
        spec.input_size,
        req.frame,
    );
    let heads = engine
        .infer(&img)
        .map_err(|e| ServeError::Engine(format!("{e:#}")))?;
    Ok(decode(&heads, spec, req.frame_w, req.frame_h))
}

/// Latency/throughput report for one serving run.
pub struct ServeReport {
    pub frames: u64,
    pub wall_s: f64,
    /// (p50_ms, p95_ms, n) per DNN.
    pub per_dnn: Vec<(DnnKind, f64, f64, usize)>,
    pub deploy: [u64; DnnKind::COUNT],
    pub switches: u64,
    /// Frames whose inference failed (detections carried forward).
    pub failed: u64,
}

impl std::fmt::Display for ServeReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "served {} frames in {:.2}s ({:.2} frames/s, real CPU-PJRT \
             inference on the request path)",
            self.frames,
            self.wall_s,
            self.frames as f64 / self.wall_s
        )?;
        for (k, p50, p95, n) in &self.per_dnn {
            writeln!(
                f,
                "  {:16} p50 {:7.1} ms  p95 {:7.1} ms  ({} runs)",
                k.artifact_name(),
                p50,
                p95,
                n
            )?;
        }
        if self.failed > 0 {
            writeln!(
                f,
                "  {} frames failed inference (carried forward)",
                self.failed
            )?;
        }
        writeln!(
            f,
            "  deploy counts (YT-288/YT-416/Y-288/Y-416): {:?}, switches {}",
            self.deploy, self.switches
        )
    }
}

/// The `tod serve` demo: a TOD loop over a synthetic stream with real
/// inference. Every frame is inferred (no virtual drop-clock here — the
/// point is to exercise the full stack and measure actual latencies; the
/// drop-frame accounting is exercised by the simulation campaign).
pub fn serve_demo(artifacts: &Path, frames: u64) -> Result<String> {
    let pool = EnginePool::load(artifacts)?;
    let seq = demo_sequence(0, frames);
    let report = serve_sequence(&pool, &seq, &mut MbbsPolicy::tod_default())?;
    Ok(report.to_string())
}

/// A deterministic synthetic demo stream; `stream` varies the seed so
/// multi-stream demos don't serve four copies of one scene.
fn demo_sequence(stream: u64, frames: u64) -> Sequence {
    Sequence::generate(SequenceSpec {
        name: format!("SERVE-DEMO-{stream}"),
        width: 640,
        height: 480,
        fps: 30.0,
        frames,
        density: 6,
        ref_height: 240.0,
        depth_range: (1.0, 2.5),
        walk_speed: 1.5,
        camera: CameraMotion::Walking { pan_speed: 6.0 },
        seed: 2021 + stream,
    })
}

/// Per-stream serving bookkeeping shared by the per-request loop
/// ([`serve_sequence`]) and the batched client loop: the select ->
/// infer -> carry-forward discipline lives in exactly one place, so
/// the batched path cannot drift from the unbatched semantics the
/// bit-identical-per-request guarantee rests on.
struct StreamState {
    features: FeatureExtractor,
    carried: Vec<Detection>,
    deploy: [u64; DnnKind::COUNT],
    switches: u64,
    failed: u64,
    last: Option<DnnKind>,
}

impl StreamState {
    fn new(frame_w: f64, frame_h: f64) -> Self {
        StreamState {
            features: FeatureExtractor::new(frame_w, frame_h),
            carried: Vec::new(),
            deploy: [0; DnnKind::COUNT],
            switches: 0,
            failed: 0,
            last: None,
        }
    }

    /// Select the DNN for the next frame from the carried detections.
    fn select(&mut self, policy: &mut dyn SelectionPolicy) -> DnnKind {
        let feats = self.features.features(&self.carried);
        policy.select(&feats)
    }

    /// Fold one frame's outcome. `Some(raw)` replaces the carried set
    /// and advances the speed estimate; `None` (a failed request)
    /// keeps the carried detections and counts the failure. `spent`
    /// says whether the backend actually ran — deploy/switch
    /// accounting mirrors the session loop, counting only spent
    /// accelerator time (a shed/never-admitted request deploys
    /// nothing).
    fn on_result(
        &mut self,
        frame: u64,
        dnn: DnnKind,
        raw: Option<Vec<Detection>>,
        spent: bool,
    ) {
        if spent {
            self.deploy[dnn.index()] += 1;
            if let Some(prev) = self.last {
                if prev != dnn {
                    self.switches += 1;
                }
            }
            self.last = Some(dnn);
        }
        match raw {
            Some(raw) => {
                self.carried = FrameDetections { frame, detections: raw }
                    .filtered()
                    .detections;
                self.features.on_detections(frame, &self.carried);
            }
            None => self.failed += 1,
        }
    }
}

/// Run a policy over a sequence with real PJRT inference on every
/// frame. A failed inference fails only its own frame: the previous
/// detections carry forward and the failure is counted in the report.
pub fn serve_sequence(
    pool: &EnginePool,
    seq: &Sequence,
    policy: &mut dyn SelectionPolicy,
) -> Result<ServeReport> {
    let (fw, fh) = (seq.spec.width as f64, seq.spec.height as f64);
    let mut backend = PjrtBackend::new(pool, fw, fh);
    let mut state = StreamState::new(fw, fh);
    let t0 = Instant::now();
    for f in 1..=seq.n_frames() {
        let dnn = state.select(policy);
        // the engine ran (spent time) whether or not it succeeded
        let raw = backend.detect(f, seq.gt(f), dnn).ok();
        state.on_result(f, dnn, raw, true);
    }
    let wall = t0.elapsed().as_secs_f64();
    Ok(ServeReport {
        frames: seq.n_frames(),
        wall_s: wall,
        per_dnn: per_dnn_percentiles(&backend.latencies),
        deploy: state.deploy,
        switches: state.switches,
        failed: state.failed,
    })
}

/// (p50_ms, p95_ms, n) per DNN from (dnn, seconds) samples.
fn per_dnn_percentiles(
    latencies: &[(DnnKind, f64)],
) -> Vec<(DnnKind, f64, f64, usize)> {
    let mut out = Vec::new();
    for k in DnnKind::ALL {
        let ms: Vec<f64> = latencies
            .iter()
            .filter(|(d, _)| *d == k)
            .map(|(_, s)| s * 1e3)
            .collect();
        if !ms.is_empty() {
            out.push((
                k,
                percentile(&ms, 50.0),
                percentile(&ms, 95.0),
                ms.len(),
            ));
        }
    }
    out
}

/// Report for one batched multi-stream serving run.
pub struct BatchedServeReport {
    pub streams: usize,
    /// Total frames served across every stream.
    pub frames: u64,
    pub wall_s: f64,
    /// Requests that resolved with an error (their frames carried the
    /// previous detections forward).
    pub failed: u64,
    pub deploy: [u64; DnnKind::COUNT],
    pub switches: u64,
    /// Micro-batch statistics (batches formed, mean/largest size).
    pub stats: BatchStats,
    /// (p50_ms, p95_ms, n) per DNN measured per *batch* dispatch.
    pub per_dnn_batch: Vec<(DnnKind, f64, f64, usize)>,
}

impl std::fmt::Display for BatchedServeReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "served {} frames from {} concurrent streams in {:.2}s \
             ({:.2} frames/s, micro-batched CPU-PJRT)",
            self.frames,
            self.streams,
            self.wall_s,
            self.frames as f64 / self.wall_s
        )?;
        writeln!(f, "  batching: {}", self.stats)?;
        for (k, p50, p95, n) in &self.per_dnn_batch {
            writeln!(
                f,
                "  {:16} batch p50 {:7.1} ms  p95 {:7.1} ms  ({} batches)",
                k.artifact_name(),
                p50,
                p95,
                n
            )?;
        }
        if self.failed > 0 {
            writeln!(
                f,
                "  {} requests failed (each failed only its own frame)",
                self.failed
            )?;
        }
        writeln!(
            f,
            "  deploy counts (YT-288/YT-416/Y-288/Y-416): {:?}, switches {}",
            self.deploy, self.switches
        )
    }
}

/// Per-stream outcome of a batched serving client.
struct StreamOutcome {
    frames: u64,
    failed: u64,
    deploy: [u64; DnnKind::COUNT],
    switches: u64,
}

/// One stream's client loop: select -> submit -> wait -> carry.
/// Identical per-stream semantics to [`serve_sequence`] (the shared
/// [`StreamState`] bookkeeping), so batched results are bit-identical
/// per request to unbatched execution.
fn run_stream_client(
    core: &ServerCore,
    stream: u64,
    seq: &Sequence,
    mut policy: Box<dyn SelectionPolicy>,
) -> StreamOutcome {
    let (fw, fh) = (seq.spec.width as f64, seq.spec.height as f64);
    let mut state = StreamState::new(fw, fh);
    for f in 1..=seq.n_frames() {
        let dnn = state.select(policy.as_mut());
        let submitted = core.submit(InferRequest {
            stream,
            frame: f,
            dnn,
            frame_w: fw,
            frame_h: fh,
            gt: seq.gt(f).to_vec(),
        });
        let outcome = match submitted {
            Ok(handle) => handle.wait(),
            Err(e) => Err(ServeError::NotAdmitted(e)),
        };
        // shed, shutdown or engine failure: this frame keeps the
        // carried detections and the stream continues. Only requests
        // the backend actually executed count as deployed.
        match outcome {
            Ok(raw) => state.on_result(f, dnn, Some(raw), true),
            Err(
                ServeError::NotAdmitted(_) | ServeError::Shutdown,
            ) => state.on_result(f, dnn, None, false),
            Err(_) => state.on_result(f, dnn, None, true),
        }
    }
    StreamOutcome {
        frames: seq.n_frames(),
        failed: state.failed,
        deploy: state.deploy,
        switches: state.switches,
    }
}

/// Serve N concurrent streams through the micro-batching server with
/// real PJRT inference.
///
/// Client threads run the per-stream policy loops and submit requests;
/// *this* thread — the one that owns the [`EnginePool`] — pumps the
/// [`ServerCore`] and executes each micro-batch, so compiled PJRT
/// executables never cross a thread boundary.
pub fn serve_batched(
    pool: &EnginePool,
    seqs: &[Sequence],
    cfg: BatchConfig,
    make_policy: &(dyn Fn() -> Box<dyn SelectionPolicy> + Sync),
) -> Result<BatchedServeReport> {
    if seqs.is_empty() {
        bail!("serve_batched needs at least one stream");
    }
    if let Err(e) = cfg.validate() {
        bail!("invalid batch config: {e}");
    }
    let core = ServerCore::new(cfg);
    let live = AtomicUsize::new(seqs.len());
    let mut batch_lat: Vec<(DnnKind, f64)> = Vec::new();
    let t0 = Instant::now();
    let outcomes: Vec<StreamOutcome> =
        std::thread::scope(|s| -> Result<Vec<StreamOutcome>> {
            let handles: Vec<_> = seqs
                .iter()
                .enumerate()
                .map(|(si, seq)| {
                    let core = core.clone();
                    let live = &live;
                    s.spawn(move || {
                        // decrement on drop so a panicking client still
                        // releases the pump (mirrors ThreadPool's slot
                        // guard)
                        struct Live<'a>(&'a AtomicUsize);
                        impl Drop for Live<'_> {
                            fn drop(&mut self) {
                                self.0.fetch_sub(1, Ordering::SeqCst);
                            }
                        }
                        let _live = Live(live);
                        run_stream_client(
                            &core,
                            si as u64,
                            seq,
                            make_policy(),
                        )
                    })
                })
                .collect();
            // pump: execute micro-batches on the engine-owning thread
            while live.load(Ordering::SeqCst) > 0 {
                if let BatchPoll::Batch(batch) =
                    core.next_batch(Duration::from_millis(2))
                {
                    let dnn = batch.dnn();
                    let bt = Instant::now();
                    batch.run_with(&mut |req| infer_on_pool(pool, req));
                    batch_lat.push((dnn, bt.elapsed().as_secs_f64()));
                }
            }
            // drain anything a dying client left behind
            core.close();
            loop {
                match core.next_batch(Duration::from_millis(1)) {
                    BatchPoll::Batch(batch) => {
                        batch.run_with(&mut |req| infer_on_pool(pool, req));
                    }
                    BatchPoll::Idle => continue,
                    BatchPoll::Drained => break,
                }
            }
            let mut outs = Vec::with_capacity(handles.len());
            for h in handles {
                outs.push(h.join().map_err(|_| {
                    anyhow!("a stream client thread panicked")
                })?);
            }
            Ok(outs)
        })?;
    let wall = t0.elapsed().as_secs_f64();

    let mut deploy = [0u64; DnnKind::COUNT];
    let mut frames = 0u64;
    let mut failed = 0u64;
    let mut switches = 0u64;
    for o in &outcomes {
        frames += o.frames;
        failed += o.failed;
        switches += o.switches;
        for (total, n) in deploy.iter_mut().zip(o.deploy.iter()) {
            *total += n;
        }
    }
    Ok(BatchedServeReport {
        streams: seqs.len(),
        frames,
        wall_s: wall,
        failed,
        deploy,
        switches,
        stats: core.stats(),
        per_dnn_batch: per_dnn_percentiles(&batch_lat),
    })
}

/// The `tod serve --batch` demo: N synthetic streams through the
/// micro-batching server.
pub fn serve_batched_demo(
    artifacts: &Path,
    frames: u64,
    streams: usize,
    cfg: BatchConfig,
) -> Result<String> {
    let pool = EnginePool::load(artifacts)?;
    let seqs: Vec<Sequence> = (0..streams.max(1) as u64)
        .map(|i| demo_sequence(i, frames))
        .collect();
    let report = serve_batched(&pool, &seqs, cfg, &|| {
        Box::new(MbbsPolicy::tod_default())
    })?;
    Ok(report.to_string())
}
