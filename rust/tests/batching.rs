//! Batching-server acceptance suite (DESIGN.md §11):
//!
//! * batched results are a permutation-invariant, bit-identical match
//!   of unbatched results per request;
//! * an injected engine error or backend panic fails only the affected
//!   requests — the process, the workers and the other streams survive;
//! * a NaN detection score degrades one ranking instead of aborting an
//!   evaluation (regression for the `partial_cmp().unwrap()` panics);
//! * the batched latency model shows a deterministic throughput win for
//!   >= 4 concurrent streams, with `max_batch == 1` bit-identical to
//!   per-request dispatch.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use tod::coordinator::multistream::{
    BatchingSim, DispatchPolicy, MultiStreamResult, MultiStreamScheduler,
};
use tod::coordinator::policy::MbbsPolicy;
use tod::coordinator::scheduler::{
    run_realtime, DetectError, Detector, OracleBackend,
};
use tod::coordinator::session::StreamSession;
use tod::dataset::mot::GtEntry;
use tod::dataset::synth::Sequence;
use tod::detection::{Detection, PERSON_CLASS};
use tod::geometry::BBox;
use tod::runtime::batch::{AdmissionPolicy, BatchConfig};
use tod::runtime::server::{
    BatchDetector, InferRequest, InferenceServer, ResultHandle, ServeError,
    ServeResult,
};
use tod::sim::latency::{ContentionModel, LatencyModel};
use tod::testing::fixtures::{oracle_for as oracle, synth_stream};
use tod::testing::prop::PropConfig;
use tod::DnnKind;

fn request(stream: u64, frame: u64, dnn: DnnKind) -> InferRequest {
    InferRequest {
        stream,
        frame,
        dnn,
        frame_w: 640.0,
        frame_h: 480.0,
        gt: Vec::new(),
    }
}

/// Pure function of the request identity: what any deterministic
/// backend must reproduce regardless of batch composition or order.
fn expected_detections(req: &InferRequest) -> Vec<Detection> {
    vec![Detection::new(
        BBox::new(
            (req.frame % 600) as f64,
            (req.stream * 7 % 400) as f64,
            10.0 + req.dnn.index() as f64,
            20.0,
        ),
        0.5 + 0.1 * req.dnn.index() as f32,
        PERSON_CLASS,
    )]
}

/// Deterministic synthetic engine.
struct SynthEngine;

impl BatchDetector for SynthEngine {
    fn infer(&self, req: &InferRequest) -> ServeResult {
        Ok(expected_detections(req))
    }
}

/// Engine that errors on one variant and panics on one frame id.
struct FaultyEngine {
    error_dnn: DnnKind,
    panic_frame: u64,
}

impl BatchDetector for FaultyEngine {
    fn infer(&self, req: &InferRequest) -> ServeResult {
        if req.dnn == self.error_dnn {
            return Err(ServeError::Engine(format!(
                "injected failure for {}",
                req.dnn
            )));
        }
        assert!(req.frame != self.panic_frame, "injected panic");
        Ok(expected_detections(req))
    }
}

#[test]
fn batched_results_match_unbatched_per_request() {
    // property: for random request sets, every request's result through
    // the batching server is bit-identical to direct execution, for
    // several batch shapes (permutation invariance: the assignment of
    // requests to batches must not leak into any result)
    PropConfig::with_cases(8).run("batched == direct per request", |g| {
        let n_req = g.usize_in(8, 40);
        let reqs: Vec<InferRequest> = (0..n_req)
            .map(|i| {
                let dnn = *g.choice(&DnnKind::ALL);
                request(g.usize_in(0, 3) as u64, i as u64, dnn)
            })
            .collect();
        let max_batch = g.usize_in(1, 6);
        let server = InferenceServer::start(
            Arc::new(SynthEngine),
            BatchConfig {
                max_batch,
                max_wait: Duration::from_millis(1),
                ..BatchConfig::default()
            },
            g.usize_in(1, 4),
        );
        let handles: Vec<(InferRequest, ResultHandle)> = reqs
            .iter()
            .map(|r| {
                (r.clone(), server.submit(r.clone()).expect("admitted"))
            })
            .collect();
        let mut ok = true;
        for (req, h) in handles {
            let got = h.wait().expect("synthetic engine never fails");
            ok &= got == expected_detections(&req);
        }
        let stats = server.shutdown();
        ok && stats.total_items() == n_req as u64
    });
}

#[test]
fn injected_engine_error_fails_only_its_requests() {
    let server = InferenceServer::start(
        Arc::new(FaultyEngine {
            error_dnn: DnnKind::Y416,
            panic_frame: u64::MAX, // no panics in this test
        }),
        BatchConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            ..BatchConfig::default()
        },
        2,
    );
    let mut handles = Vec::new();
    for i in 0..24u64 {
        let dnn = DnnKind::ALL[(i % 4) as usize];
        handles.push((dnn, server.submit(request(0, i, dnn)).unwrap()));
    }
    let mut failed = 0;
    let mut succeeded = 0;
    for (dnn, h) in handles {
        match h.wait() {
            Ok(dets) => {
                assert_ne!(dnn, DnnKind::Y416, "Y-416 must have failed");
                assert!(!dets.is_empty());
                succeeded += 1;
            }
            Err(ServeError::Engine(msg)) => {
                assert_eq!(dnn, DnnKind::Y416, "only Y-416 may fail");
                assert!(msg.contains("injected"));
                failed += 1;
            }
            Err(other) => panic!("unexpected error: {other:?}"),
        }
    }
    assert_eq!(failed, 6);
    assert_eq!(succeeded, 18);
    // the server is still healthy after the failures
    let h = server.submit(request(0, 1000, DnnKind::TinyY288)).unwrap();
    assert!(h.wait().is_ok());
}

#[test]
fn backend_panic_fails_only_its_own_request() {
    let server = InferenceServer::start(
        Arc::new(FaultyEngine {
            error_dnn: DnnKind::Y416,
            panic_frame: 13,
        }),
        BatchConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            ..BatchConfig::default()
        },
        2,
    );
    // frames 10..18 on one variant: frame 13 shares a batch with
    // healthy neighbours
    let handles: Vec<(u64, ResultHandle)> = (10..18u64)
        .map(|f| {
            (f, server.submit(request(0, f, DnnKind::TinyY288)).unwrap())
        })
        .collect();
    for (f, h) in handles {
        match h.wait() {
            Ok(_) => assert_ne!(f, 13, "the panicking frame cannot succeed"),
            Err(ServeError::BatchPanicked) => assert_eq!(f, 13),
            Err(other) => panic!("frame {f}: unexpected error {other:?}"),
        }
    }
    // workers caught the panic: the server still serves
    let h = server.submit(request(0, 1, DnnKind::Y288)).unwrap();
    assert!(h.wait().is_ok());
    server.shutdown();
}

#[test]
fn shed_admission_is_request_scoped() {
    // a server with a tiny queue and shedding admission: overload
    // errors are per request and the queue recovers
    let server = InferenceServer::start(
        Arc::new(SynthEngine),
        BatchConfig {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
            queue_cap: 2,
            admission: AdmissionPolicy::Shed,
        },
        1,
    );
    let mut admitted = Vec::new();
    let mut shed = 0u64;
    for i in 0..200u64 {
        match server.submit(request(0, i, DnnKind::Y288)) {
            Ok(h) => admitted.push(h),
            Err(e) => {
                assert_eq!(e.to_string(), "request shed: pending queue full");
                shed += 1;
            }
        }
    }
    for h in admitted {
        assert!(h.wait().is_ok(), "admitted requests must complete");
    }
    let stats = server.shutdown();
    assert_eq!(stats.shed, shed);
    assert_eq!(stats.total_items() + shed, 200);
}

/// A detector that pollutes the oracle's output with NaNs each frame:
/// one NaN-*scored* detection (exercises the score filter and NaN-safe
/// score sorts) and one NaN-*sized* detection with a valid score
/// (exercises the NaN-safe area/IoU comparators in mbbs, matching and
/// the feature extractor — the exact `partial_cmp().unwrap()` sites
/// this PR fixed).
struct NanDetector(OracleBackend);

impl Detector for NanDetector {
    fn detect(
        &mut self,
        frame: u64,
        gt: &[GtEntry],
        dnn: DnnKind,
    ) -> Result<Vec<Detection>, DetectError> {
        let mut dets = self.0.detect(frame, gt, dnn)?;
        dets.push(Detection::new(
            BBox::new(5.0, 5.0, 30.0, 60.0),
            f32::NAN,
            PERSON_CLASS,
        ));
        dets.push(Detection::new(
            BBox::new(10.0, 10.0, f64::NAN, 60.0),
            0.9,
            PERSON_CLASS,
        ));
        Ok(dets)
    }
}

/// A detector that always fails.
struct DeadEngine;

impl Detector for DeadEngine {
    fn detect(
        &mut self,
        _frame: u64,
        _gt: &[GtEntry],
        _dnn: DnnKind,
    ) -> Result<Vec<Detection>, DetectError> {
        Err(DetectError("engine lost".into()))
    }
}

fn small_seq(seed: u64, frames: u64) -> Sequence {
    synth_stream("BATCH", seed, frames)
}

#[test]
fn nan_score_does_not_abort_a_scheduled_run() {
    // AP regression: a detector emitting NaN scores and NaN-sized
    // boxes must not panic the evaluator, the MBBS statistic or the
    // feature extractor anywhere on the realtime path
    let seq = small_seq(3, 90);
    let mut det = NanDetector(oracle(&seq));
    let mut pol = MbbsPolicy::tod_default();
    let mut lat = LatencyModel::deterministic();
    let r = run_realtime(&seq, &mut pol, &mut det, &mut lat, 30.0);
    assert!(r.ap.is_finite());
    assert!((0.0..=1.0).contains(&r.ap));
    assert_eq!(r.n_failed, 0);
    assert_eq!(r.n_inferred + r.n_dropped, r.n_frames);
}

#[test]
fn failing_engine_fails_frames_not_the_process() {
    // every inference errors: the stream completes with zero AP and
    // full failure accounting instead of crashing
    let seq = small_seq(4, 60);
    let mut det = DeadEngine;
    let mut pol = MbbsPolicy::tod_default();
    let mut lat = LatencyModel::deterministic();
    let r = run_realtime(&seq, &mut pol, &mut det, &mut lat, 30.0);
    assert_eq!(r.n_failed, r.n_inferred);
    assert!(r.n_failed > 0);
    assert_eq!(r.ap, 0.0, "no detections ever arrive");
    assert_eq!(r.n_inferred + r.n_dropped, r.n_frames);
}

fn run_streams(
    seqs: &[Sequence],
    batching: Option<BatchingSim>,
) -> MultiStreamResult {
    let mut sched = MultiStreamScheduler::new(
        DispatchPolicy::RoundRobin,
        ContentionModel::jetson_nano(),
        LatencyModel::deterministic(),
    );
    if let Some(b) = batching {
        sched = sched.with_batching(b);
    }
    for s in seqs {
        sched.add_stream(
            StreamSession::new(s, MbbsPolicy::tod_default(), 30.0),
            Box::new(oracle(s)),
        );
    }
    sched.run()
}

#[test]
fn batched_latency_model_wins_throughput_for_four_streams() {
    // the acceptance number: >= 4 concurrent synthetic streams must
    // show higher frames/s (inferences per virtual second) under the
    // batched latency model than under per-request dispatch
    let seqs: Vec<Sequence> = (0..4).map(|_| small_seq(11, 120)).collect();
    let plain = run_streams(&seqs, None);
    let batched = run_streams(&seqs, Some(BatchingSim::jetson_nano(4)));
    assert!(
        batched.utilisation.throughput_ips()
            > plain.utilisation.throughput_ips(),
        "batched {} <= per-request {} inf/s",
        batched.utilisation.throughput_ips(),
        plain.utilisation.throughput_ips()
    );
    let stats = batched.batching.as_ref().expect("batched stats");
    assert!(stats.mean_batch() > 1.2, "no batches formed: {stats}");
    // per-stream accounting still conserves
    for s in &batched.per_stream {
        assert_eq!(s.n_inferred + s.n_dropped, s.n_frames);
    }
}

#[test]
fn batched_max_batch_one_matches_per_request_bit_for_bit() {
    let seqs: Vec<Sequence> =
        (0..4).map(|i| small_seq(20 + i, 90)).collect();
    let plain = run_streams(&seqs, None);
    let batched = run_streams(&seqs, Some(BatchingSim::jetson_nano(1)));
    for (a, b) in plain.per_stream.iter().zip(&batched.per_stream) {
        assert_eq!(a.ap, b.ap);
        assert_eq!(a.deploy_counts, b.deploy_counts);
        assert_eq!(a.n_dropped, b.n_dropped);
        assert_eq!(a.mbbs_series, b.mbbs_series);
        assert_eq!(a.dnn_series, b.dnn_series);
        assert_eq!(a.trace.busy, b.trace.busy);
    }
}

#[test]
fn concurrent_streams_through_the_server_stay_isolated() {
    // end-to-end: 4 client threads share one server; one stream's
    // variant always fails, the other streams are untouched
    let server = Arc::new(InferenceServer::start(
        Arc::new(FaultyEngine {
            error_dnn: DnnKind::Y416,
            panic_frame: u64::MAX,
        }),
        BatchConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            ..BatchConfig::default()
        },
        3,
    ));
    let mut clients = Vec::new();
    for stream in 0..4u64 {
        let server = server.clone();
        clients.push(std::thread::spawn(move || {
            // stream 3 insists on the failing variant
            let dnn = if stream == 3 {
                DnnKind::Y416
            } else {
                DnnKind::ALL[stream as usize]
            };
            let mut failures = 0u64;
            for f in 1..=30u64 {
                let h = server
                    .submit(request(stream, f, dnn))
                    .expect("admitted");
                match h.wait() {
                    Ok(dets) => assert!(!dets.is_empty()),
                    Err(ServeError::Engine(_)) => failures += 1,
                    Err(other) => panic!("unexpected: {other:?}"),
                }
            }
            failures
        }));
    }
    let failures: Vec<u64> =
        clients.into_iter().map(|c| c.join().unwrap()).collect();
    assert_eq!(failures, vec![0, 0, 0, 30], "only stream 3 may fail");
    // aggregated per-request counts survive in the stats
    let per_dnn_results: HashMap<usize, u64> = server
        .stats()
        .per_dnn
        .iter()
        .enumerate()
        .map(|(i, v)| (i, v.items))
        .collect();
    assert_eq!(per_dnn_results[&DnnKind::Y416.index()], 30);
}
