//! The `scenario` experiment: the matrix differential table.
//!
//! For every scenario of the curated matrix, reports the best fixed
//! DNN, projected selection, and the watts-budgeted selector — mean AP,
//! drop rate, board power — plus the adaptive-vs-fixed margins the
//! conformance layer pins per scenario (DESIGN.md §12). This is the
//! human-readable face of the goldens under `rust/tests/goldens/`.

use crate::app::Campaign;
use crate::scenario::matrix::ScenarioId;
use crate::util::csv::CsvTable;
use crate::util::table::AsciiTable;

use super::ExperimentOutput;

pub fn scenario_table(c: &mut Campaign) -> ExperimentOutput {
    let header = vec![
        "scenario",
        "best_fixed",
        "best_fixed_ap",
        "projected_ap",
        "projected_margin",
        "watts_cap",
        "budgeted_ap",
        "budgeted_margin",
        "drop_pct_projected",
        "board_w_budgeted",
    ];
    let mut table = AsciiTable::new(
        "scenario — adaptive vs best-fixed margins across the matrix",
        header.clone(),
    );
    let mut csv = CsvTable::new(header);
    let mut worst_projected = f64::INFINITY;
    let mut worst_budgeted = f64::INFINITY;
    for id in ScenarioId::ALL {
        let report = c.scenario_report(id).clone();
        let d = &report.differential;
        let projected = report
            .records
            .iter()
            .find(|r| r.config == "projected")
            .expect("canonical projected run");
        let budgeted = report
            .records
            .iter()
            .find(|r| r.config.starts_with("projected@"))
            .expect("canonical budgeted run");
        let drop_pct = if projected.aggregate.frames == 0 {
            0.0
        } else {
            projected.aggregate.dropped as f64
                / projected.aggregate.frames as f64
                * 100.0
        };
        worst_projected = worst_projected.min(d.projected_margin);
        worst_budgeted = worst_budgeted.min(d.budgeted_margin);
        let row = vec![
            report.scenario.clone(),
            d.best_fixed.trim_start_matches("fixed:").to_string(),
            format!("{:.3}", d.best_fixed_ap),
            format!("{:.3}", d.projected_ap),
            format!("{:+.3}", d.projected_margin),
            format!("{:.1}", d.watts_budget),
            format!("{:.3}", d.budgeted_ap),
            format!("{:+.3}", d.budgeted_margin),
            format!("{drop_pct:.1}"),
            format!("{:.2}", budgeted.aggregate.avg_power_w),
        ];
        table.push(row.clone());
        csv.push(row);
    }
    let text = format!(
        "{}\n(margins: projected vs best fixed, budgeted vs best \
         budget-feasible fixed; worst projected margin {worst_projected:+.3}, \
         worst budgeted margin {worst_budgeted:+.3} — the conformance \
         suite requires both >= 0 on every scenario)\n",
        table.render(),
    );
    ExperimentOutput {
        id: "scenario",
        title: "scenario: matrix differential table".into(),
        text,
        csv: vec![("scenario_matrix.csv".into(), csv)],
    }
}
