//! Metrics registry: monotone counters, fixed-bucket histograms and
//! gauges over the unified event stream (DESIGN.md §14).
//!
//! A [`MetricsRegistry`] fills two ways:
//!
//! * **event-driven** — it implements [`Recorder`], so attaching it to
//!   a session/harness run counts frames, drops, clamps and batch
//!   activity as they happen (all field updates on pre-allocated
//!   storage: recording never allocates);
//! * **summary-driven** — `observe_run` / `observe_batch` /
//!   `observe_power` / `observe_utilisation` fold the existing siloed
//!   aggregates ([`RunResult`], [`BatchStats`], [`PowerSummary`],
//!   [`UtilisationSummary`]) into the same registry, which is how the
//!   wall-clock batching server (whose threads cannot hold the
//!   single-threaded [`SharedRecorder`]) and already-finished runs
//!   report in.
//!
//! Export is Prometheus-style text exposition ([`MetricsRegistry::
//! to_prometheus`], `tod metrics --prom`) or a versioned JSON snapshot
//! ([`MetricsRegistry::to_json`] / [`MetricsRegistry::from_json`],
//! round-trip pinned by tests) that the scenario harness dumps next to
//! the flight recorder on conformance failures.

use crate::coordinator::scheduler::RunResult;
use crate::obs::span::SpanKind;
use crate::obs::{Event, Recorder};
use crate::power::PowerSummary;
use crate::runtime::batch::BatchStats;
use crate::telemetry::utilisation::UtilisationSummary;
use crate::util::json::Json;
use crate::DnnKind;

/// Version of the metrics snapshot schema. v2 added span/SLO counters
/// and the per-stage self-time histograms (DESIGN.md §15).
pub const SNAPSHOT_VERSION: u64 = 2;

/// Schema tag of the snapshot JSON.
pub const SNAPSHOT_TAG: &str = "tod-metrics";

/// Inference-latency bucket upper bounds, seconds. Spans the ladder
/// from TinyYOLO-288 (~7 ms) through contention-inflated YOLO-416
/// (hundreds of ms).
pub const LATENCY_BUCKETS_S: [f64; 8] =
    [0.005, 0.01, 0.02, 0.04, 0.08, 0.16, 0.32, 0.64];

/// Batch-size bucket upper bounds (items per flushed batch).
pub const BATCH_BUCKETS: [f64; 5] = [1.0, 2.0, 4.0, 8.0, 16.0];

/// Fixed-bucket histogram: cumulative-friendly counts, pre-allocated at
/// construction so `record` is a pure field update.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    /// Observations above the last bound.
    overflow: u64,
    sum: f64,
    n: u64,
}

impl Histogram {
    /// A histogram over the given strictly-increasing upper bounds.
    pub fn new(bounds: &[f64]) -> Self {
        debug_assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly increasing"
        );
        Histogram {
            bounds: bounds.to_vec(),
            counts: vec![0; bounds.len()],
            overflow: 0,
            sum: 0.0,
            n: 0,
        }
    }

    /// Record one observation (allocation-free).
    #[inline]
    pub fn record(&mut self, v: f64) {
        let idx = self.bounds.partition_point(|&b| b < v);
        if let Some(c) = self.counts.get_mut(idx) {
            *c += 1;
        } else {
            self.overflow += 1;
        }
        self.sum += v;
        self.n += 1;
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sum of observations.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Mean observation (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// `(upper_bound, cumulative_count)` per bucket, Prometheus-style;
    /// the `+Inf` bucket is implied by [`Histogram::count`].
    pub fn cumulative(&self) -> Vec<(f64, u64)> {
        let mut acc = 0u64;
        self.bounds
            .iter()
            .zip(&self.counts)
            .map(|(&b, &c)| {
                acc += c;
                (b, acc)
            })
            .collect()
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bounds", Json::arr(self.bounds.iter().map(|&b| Json::num(b)).collect())),
            (
                "counts",
                Json::arr(self.counts.iter().map(|&c| Json::num(c as f64)).collect()),
            ),
            ("overflow", Json::num(self.overflow as f64)),
            ("sum", Json::num(self.sum)),
            ("n", Json::num(self.n as f64)),
        ])
    }

    fn from_json(v: &Json) -> Result<Histogram, String> {
        let arr = |k: &str| -> Result<Vec<f64>, String> {
            v.get(k)
                .and_then(Json::as_arr)
                .ok_or_else(|| format!("histogram: missing array {k:?}"))?
                .iter()
                .map(|x| x.as_f64().ok_or_else(|| format!("histogram: bad {k:?}")))
                .collect()
        };
        let num = |k: &str| -> Result<f64, String> {
            v.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("histogram: missing number {k:?}"))
        };
        let bounds = arr("bounds")?;
        let counts: Vec<u64> =
            arr("counts")?.into_iter().map(|c| c as u64).collect();
        if counts.len() != bounds.len() {
            return Err("histogram: counts/bounds length mismatch".into());
        }
        Ok(Histogram {
            bounds,
            counts,
            overflow: num("overflow")? as u64,
            sum: num("sum")?,
            n: num("n")? as u64,
        })
    }
}

/// The unified metrics registry. All counters are monotone; gauges hold
/// the latest observed value; histograms use the fixed buckets above.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsRegistry {
    // ---- frame lifecycle counters ----
    pub frames_presented: u64,
    pub frames_inferred: u64,
    pub frames_dropped: u64,
    pub frames_failed: u64,
    pub frames_shed: u64,
    // ---- decision counters ----
    pub budget_clamps: u64,
    pub streams_joined: u64,
    pub streams_left: u64,
    /// Inferences per DNN variant (deployment frequency numerator).
    pub deploy: [u64; DnnKind::COUNT],
    pub switches: u64,
    // ---- batching counters ----
    pub batches_formed: u64,
    pub batches_flushed: u64,
    pub batch_items: u64,
    // ---- span / SLO counters (live recording only counts; stage
    //      attribution is folded offline by `obs::profile`) ----
    pub spans_opened: u64,
    pub spans_closed: u64,
    pub slo_breaches: u64,
    pub slo_recoveries: u64,
    // ---- busy-time accumulators (virtual seconds) ----
    pub busy_per_dnn_s: [f64; DnnKind::COUNT],
    /// Accelerator-busy seconds spent on inferences that then failed.
    pub busy_failed_s: f64,
    // ---- gauges (latest observation wins) ----
    pub queue_depth_high_water: u64,
    pub energy_j: f64,
    pub avg_power_w: f64,
    pub gpu_busy_frac: f64,
    pub makespan_s: f64,
    // ---- histograms ----
    pub infer_latency_s: Histogram,
    pub batch_size: Histogram,
    /// Per-stage span self-time, indexed by [`SpanKind::index`]. Fed by
    /// [`MetricsRegistry::observe_stage`] (the offline profile fold),
    /// not by live `record`, so recording stays a pure counter bump.
    pub stage_self_s: [Histogram; SpanKind::COUNT],
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        MetricsRegistry {
            frames_presented: 0,
            frames_inferred: 0,
            frames_dropped: 0,
            frames_failed: 0,
            frames_shed: 0,
            budget_clamps: 0,
            streams_joined: 0,
            streams_left: 0,
            deploy: [0; DnnKind::COUNT],
            switches: 0,
            batches_formed: 0,
            batches_flushed: 0,
            batch_items: 0,
            spans_opened: 0,
            spans_closed: 0,
            slo_breaches: 0,
            slo_recoveries: 0,
            busy_per_dnn_s: [0.0; DnnKind::COUNT],
            busy_failed_s: 0.0,
            queue_depth_high_water: 0,
            energy_j: 0.0,
            avg_power_w: 0.0,
            gpu_busy_frac: 0.0,
            makespan_s: 0.0,
            infer_latency_s: Histogram::new(&LATENCY_BUCKETS_S),
            batch_size: Histogram::new(&BATCH_BUCKETS),
            stage_self_s: std::array::from_fn(|_| {
                Histogram::new(&LATENCY_BUCKETS_S)
            }),
        }
    }
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Dropped + shed fraction of presented frames.
    pub fn loss_rate(&self) -> f64 {
        if self.frames_presented == 0 {
            0.0
        } else {
            (self.frames_dropped + self.frames_shed) as f64
                / self.frames_presented as f64
        }
    }

    /// Fold a finished run's aggregates into the registry (for paths
    /// that ran without an attached recorder).
    pub fn observe_run(&mut self, r: &RunResult) {
        self.frames_presented += r.n_frames;
        self.frames_inferred += r.n_inferred;
        self.frames_dropped += r.n_dropped;
        self.frames_failed += r.n_failed;
        self.switches += r.switches;
        for i in 0..DnnKind::COUNT {
            self.deploy[i] += r.deploy_counts[i];
        }
        for &(s, e, d) in &r.trace.busy {
            self.busy_per_dnn_s[d.index()] += e - s;
            self.infer_latency_s.record(e - s);
        }
        self.busy_failed_s += r.failed_busy_s;
        self.makespan_s = self.makespan_s.max(r.trace.duration);
        self.observe_power(&r.power);
    }

    /// Fold a batching server/sim summary into the registry.
    pub fn observe_batch(&mut self, b: &BatchStats) {
        for v in &b.per_dnn {
            self.batches_flushed += v.batches;
            self.batch_items += v.items;
            for _ in 0..v.batches {
                // per-batch sizes are not retained by BatchStats; spread
                // the mean so histogram mass matches the dispatch count
                self.batch_size.record(v.mean_batch());
            }
        }
        self.frames_shed += b.shed;
    }

    /// Fold a power/energy summary into the registry.
    pub fn observe_power(&mut self, p: &PowerSummary) {
        self.energy_j += p.energy_j;
        self.avg_power_w = p.avg_power_w;
        self.gpu_busy_frac = p.gpu_busy_frac;
    }

    /// Fold a multi-stream utilisation summary into the registry.
    pub fn observe_utilisation(&mut self, u: &UtilisationSummary) {
        self.makespan_s = self.makespan_s.max(u.makespan);
        self.busy_failed_s += u.busy_failed;
    }

    /// Note a queue-depth high-water mark (keeps the maximum).
    pub fn observe_queue_depth(&mut self, depth: u64) {
        self.queue_depth_high_water = self.queue_depth_high_water.max(depth);
    }

    /// Fold one closed span's self-time into the per-stage histogram
    /// (driven by [`crate::obs::profile::fold_into`] after a run).
    pub fn observe_stage(&mut self, kind: SpanKind, self_s: f64) {
        self.stage_self_s[kind.index()].record(self_s);
    }

    /// Prometheus-style text exposition (deterministic ordering).
    pub fn to_prometheus(&self) -> String {
        use std::fmt::Write as _;
        fn counter_into(out: &mut String, name: &str, help: &str, v: u64) {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {v}");
        }
        fn gauge_into(out: &mut String, name: &str, help: &str, v: f64) {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} gauge");
            let _ = writeln!(out, "{name} {v}");
        }
        fn histo_into(out: &mut String, name: &str, help: &str, h: &Histogram) {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} histogram");
            for (bound, cum) in h.cumulative() {
                let _ = writeln!(out, "{name}_bucket{{le=\"{bound}\"}} {cum}");
            }
            let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", h.count());
            let _ = writeln!(out, "{name}_sum {}", h.sum());
            let _ = writeln!(out, "{name}_count {}", h.count());
        }
        let mut out = String::with_capacity(2048);
        let counters: [(&str, &str, u64); 16] = [
            (
                "tod_frames_presented_total",
                "Frames presented to the selector.",
                self.frames_presented,
            ),
            (
                "tod_frames_inferred_total",
                "Frames whose inference succeeded.",
                self.frames_inferred,
            ),
            (
                "tod_frames_dropped_total",
                "Frames dropped on arrival (accelerator busy).",
                self.frames_dropped,
            ),
            (
                "tod_frames_failed_total",
                "Frames whose inference ran but failed.",
                self.frames_failed,
            ),
            (
                "tod_frames_shed_total",
                "Frames rejected by batch admission control.",
                self.frames_shed,
            ),
            (
                "tod_budget_clamps_total",
                "Selections demoted by a power budget.",
                self.budget_clamps,
            ),
            (
                "tod_streams_joined_total",
                "Streams registered.",
                self.streams_joined,
            ),
            ("tod_streams_left_total", "Streams finished.", self.streams_left),
            (
                "tod_dnn_switches_total",
                "DNN switches between consecutive inferences.",
                self.switches,
            ),
            (
                "tod_batches_formed_total",
                "Micro-batch runs opened (full setup paid).",
                self.batches_formed,
            ),
            (
                "tod_batches_flushed_total",
                "Micro-batches dispatched.",
                self.batches_flushed,
            ),
            (
                "tod_batch_items_total",
                "Requests carried by dispatched batches.",
                self.batch_items,
            ),
            (
                "tod_spans_opened_total",
                "Pipeline spans opened.",
                self.spans_opened,
            ),
            (
                "tod_spans_closed_total",
                "Pipeline spans closed.",
                self.spans_closed,
            ),
            (
                "tod_slo_breaches_total",
                "SLO signals crossing their limit.",
                self.slo_breaches,
            ),
            (
                "tod_slo_recoveries_total",
                "SLO signals returning inside their limit.",
                self.slo_recoveries,
            ),
        ];
        for (name, help, v) in counters {
            counter_into(&mut out, name, help, v);
        }

        let _ = writeln!(
            out,
            "# HELP tod_dnn_deploy_total Inferences per DNN variant."
        );
        let _ = writeln!(out, "# TYPE tod_dnn_deploy_total counter");
        for d in DnnKind::ALL {
            let _ = writeln!(
                out,
                "tod_dnn_deploy_total{{dnn=\"{}\"}} {}",
                d.artifact_name(),
                self.deploy[d.index()]
            );
        }
        let _ = writeln!(
            out,
            "# HELP tod_dnn_busy_seconds Accelerator-busy seconds per DNN."
        );
        let _ = writeln!(out, "# TYPE tod_dnn_busy_seconds counter");
        for d in DnnKind::ALL {
            let _ = writeln!(
                out,
                "tod_dnn_busy_seconds{{dnn=\"{}\"}} {}",
                d.artifact_name(),
                self.busy_per_dnn_s[d.index()]
            );
        }

        let gauges: [(&str, &str, f64); 6] = [
            (
                "tod_busy_failed_seconds",
                "Busy seconds spent on failed inferences.",
                self.busy_failed_s,
            ),
            (
                "tod_queue_depth_high_water",
                "Deepest batch queue observed.",
                self.queue_depth_high_water as f64,
            ),
            ("tod_energy_joules", "Metered energy.", self.energy_j),
            (
                "tod_avg_power_watts",
                "Average metered power.",
                self.avg_power_w,
            ),
            (
                "tod_gpu_busy_frac",
                "Accelerator busy fraction.",
                self.gpu_busy_frac,
            ),
            (
                "tod_makespan_seconds",
                "Latest run makespan.",
                self.makespan_s,
            ),
        ];
        for (name, help, v) in gauges {
            gauge_into(&mut out, name, help, v);
        }

        histo_into(
            &mut out,
            "tod_infer_latency_seconds",
            "Per-inference accelerator latency.",
            &self.infer_latency_s,
        );
        histo_into(
            &mut out,
            "tod_batch_size_items",
            "Items per dispatched micro-batch.",
            &self.batch_size,
        );

        // per-stage self-time histograms, one labelled series per stage
        // (skipped entirely while empty to keep expositions compact)
        if self.stage_self_s.iter().any(|h| h.count() > 0) {
            let name = "tod_stage_self_seconds";
            let _ = writeln!(
                out,
                "# HELP {name} Span self-time per pipeline stage."
            );
            let _ = writeln!(out, "# TYPE {name} histogram");
            for k in SpanKind::ALL {
                let h = &self.stage_self_s[k.index()];
                if h.count() == 0 {
                    continue;
                }
                let stage = k.label();
                for (bound, cum) in h.cumulative() {
                    let _ = writeln!(
                        out,
                        "{name}_bucket{{stage=\"{stage}\",le=\"{bound}\"}} {cum}"
                    );
                }
                let _ = writeln!(
                    out,
                    "{name}_bucket{{stage=\"{stage}\",le=\"+Inf\"}} {}",
                    h.count()
                );
                let _ = writeln!(
                    out,
                    "{name}_sum{{stage=\"{stage}\"}} {}",
                    h.sum()
                );
                let _ = writeln!(
                    out,
                    "{name}_count{{stage=\"{stage}\"}} {}",
                    h.count()
                );
            }
        }
        out
    }

    /// Versioned JSON snapshot (sorted keys → byte-stable).
    pub fn to_json(&self) -> Json {
        let dnn_arr = |xs: &[f64; DnnKind::COUNT]| {
            Json::arr(xs.iter().map(|&x| Json::num(x)).collect())
        };
        let dnn_arr_u = |xs: &[u64; DnnKind::COUNT]| {
            Json::arr(xs.iter().map(|&x| Json::num(x as f64)).collect())
        };
        Json::obj(vec![
            ("schema", Json::str(SNAPSHOT_TAG)),
            ("version", Json::num(SNAPSHOT_VERSION as f64)),
            ("frames_presented", Json::num(self.frames_presented as f64)),
            ("frames_inferred", Json::num(self.frames_inferred as f64)),
            ("frames_dropped", Json::num(self.frames_dropped as f64)),
            ("frames_failed", Json::num(self.frames_failed as f64)),
            ("frames_shed", Json::num(self.frames_shed as f64)),
            ("budget_clamps", Json::num(self.budget_clamps as f64)),
            ("streams_joined", Json::num(self.streams_joined as f64)),
            ("streams_left", Json::num(self.streams_left as f64)),
            ("deploy", dnn_arr_u(&self.deploy)),
            ("switches", Json::num(self.switches as f64)),
            ("batches_formed", Json::num(self.batches_formed as f64)),
            ("batches_flushed", Json::num(self.batches_flushed as f64)),
            ("batch_items", Json::num(self.batch_items as f64)),
            ("spans_opened", Json::num(self.spans_opened as f64)),
            ("spans_closed", Json::num(self.spans_closed as f64)),
            ("slo_breaches", Json::num(self.slo_breaches as f64)),
            ("slo_recoveries", Json::num(self.slo_recoveries as f64)),
            ("busy_per_dnn_s", dnn_arr(&self.busy_per_dnn_s)),
            ("busy_failed_s", Json::num(self.busy_failed_s)),
            (
                "queue_depth_high_water",
                Json::num(self.queue_depth_high_water as f64),
            ),
            ("energy_j", Json::num(self.energy_j)),
            ("avg_power_w", Json::num(self.avg_power_w)),
            ("gpu_busy_frac", Json::num(self.gpu_busy_frac)),
            ("makespan_s", Json::num(self.makespan_s)),
            ("infer_latency_s", self.infer_latency_s.to_json()),
            ("batch_size", self.batch_size.to_json()),
            (
                "stage_self_s",
                Json::arr(
                    self.stage_self_s.iter().map(|h| h.to_json()).collect(),
                ),
            ),
        ])
    }

    /// Parse a snapshot produced by [`MetricsRegistry::to_json`].
    pub fn from_json(v: &Json) -> Result<MetricsRegistry, String> {
        let tag = v.get("schema").and_then(Json::as_str).unwrap_or("");
        if tag != SNAPSHOT_TAG {
            return Err(format!("not a {SNAPSHOT_TAG} snapshot: {tag:?}"));
        }
        let version =
            v.get("version").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        if version != SNAPSHOT_VERSION {
            return Err(format!(
                "snapshot version {version} != supported {SNAPSHOT_VERSION}"
            ));
        }
        let num = |k: &str| -> Result<f64, String> {
            v.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("snapshot: missing number {k:?}"))
        };
        let uint = |k: &str| -> Result<u64, String> { Ok(num(k)? as u64) };
        let dnn_f = |k: &str| -> Result<[f64; DnnKind::COUNT], String> {
            let a = v
                .get(k)
                .and_then(Json::as_arr)
                .ok_or_else(|| format!("snapshot: missing array {k:?}"))?;
            if a.len() != DnnKind::COUNT {
                return Err(format!("snapshot: {k:?} has wrong arity"));
            }
            let mut out = [0.0; DnnKind::COUNT];
            for (slot, x) in out.iter_mut().zip(a) {
                *slot = x
                    .as_f64()
                    .ok_or_else(|| format!("snapshot: bad entry in {k:?}"))?;
            }
            Ok(out)
        };
        let hist = |k: &str| -> Result<Histogram, String> {
            Histogram::from_json(
                v.get(k)
                    .ok_or_else(|| format!("snapshot: missing {k:?}"))?,
            )
        };
        let deploy_f = dnn_f("deploy")?;
        let mut deploy = [0u64; DnnKind::COUNT];
        for (d, &f) in deploy.iter_mut().zip(&deploy_f) {
            *d = f as u64;
        }
        let stage_arr = v
            .get("stage_self_s")
            .and_then(Json::as_arr)
            .ok_or("snapshot: missing array \"stage_self_s\"")?;
        if stage_arr.len() != SpanKind::COUNT {
            return Err("snapshot: \"stage_self_s\" has wrong arity".into());
        }
        let mut stage_self_s: [Histogram; SpanKind::COUNT] =
            std::array::from_fn(|_| Histogram::new(&LATENCY_BUCKETS_S));
        for (slot, h) in stage_self_s.iter_mut().zip(stage_arr) {
            *slot = Histogram::from_json(h)?;
        }
        Ok(MetricsRegistry {
            frames_presented: uint("frames_presented")?,
            frames_inferred: uint("frames_inferred")?,
            frames_dropped: uint("frames_dropped")?,
            frames_failed: uint("frames_failed")?,
            frames_shed: uint("frames_shed")?,
            budget_clamps: uint("budget_clamps")?,
            streams_joined: uint("streams_joined")?,
            streams_left: uint("streams_left")?,
            deploy,
            switches: uint("switches")?,
            batches_formed: uint("batches_formed")?,
            batches_flushed: uint("batches_flushed")?,
            batch_items: uint("batch_items")?,
            spans_opened: uint("spans_opened")?,
            spans_closed: uint("spans_closed")?,
            slo_breaches: uint("slo_breaches")?,
            slo_recoveries: uint("slo_recoveries")?,
            busy_per_dnn_s: dnn_f("busy_per_dnn_s")?,
            busy_failed_s: num("busy_failed_s")?,
            queue_depth_high_water: uint("queue_depth_high_water")?,
            energy_j: num("energy_j")?,
            avg_power_w: num("avg_power_w")?,
            gpu_busy_frac: num("gpu_busy_frac")?,
            makespan_s: num("makespan_s")?,
            infer_latency_s: hist("infer_latency_s")?,
            batch_size: hist("batch_size")?,
            stage_self_s,
        })
    }
}

impl Recorder for MetricsRegistry {
    #[inline]
    fn record(&mut self, ev: &Event) {
        match *ev {
            Event::StreamJoined { .. } => self.streams_joined += 1,
            Event::StreamLeft { .. } => self.streams_left += 1,
            Event::FramePresented { .. } => self.frames_presented += 1,
            Event::DnnSelected { .. } => {}
            Event::BudgetClamp { .. } => self.budget_clamps += 1,
            Event::FrameInferred { dnn, start, end, .. } => {
                self.frames_inferred += 1;
                self.deploy[dnn.index()] += 1;
                self.busy_per_dnn_s[dnn.index()] += end - start;
                self.infer_latency_s.record(end - start);
                self.makespan_s = self.makespan_s.max(end);
            }
            Event::InferenceFailed { dnn, start, end, .. } => {
                self.frames_failed += 1;
                self.busy_per_dnn_s[dnn.index()] += end - start;
                self.busy_failed_s += end - start;
                self.infer_latency_s.record(end - start);
                self.makespan_s = self.makespan_s.max(end);
            }
            Event::FrameDropped { .. } => self.frames_dropped += 1,
            Event::BatchFormed { .. } => self.batches_formed += 1,
            Event::BatchExtended { .. } => {}
            Event::BatchFlushed { len, .. } => {
                self.batches_flushed += 1;
                self.batch_items += len as u64;
                self.batch_size.record(len as f64);
            }
            Event::BatchShed { .. } => self.frames_shed += 1,
            Event::SpanOpen { .. } => self.spans_opened += 1,
            Event::SpanClose { .. } => self.spans_closed += 1,
            Event::SloBreach { .. } => self.slo_breaches += 1,
            Event::SloRecovered { .. } => self.slo_recoveries += 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perf::count_allocs;

    #[test]
    fn histogram_buckets_and_overflow() {
        let mut h = Histogram::new(&[0.01, 0.02, 0.04]);
        for v in [0.005, 0.01, 0.015, 0.03, 0.05, 1.0] {
            h.record(v);
        }
        assert_eq!(h.count(), 6);
        // bounds are inclusive upper edges: 0.01 lands in the first bucket
        assert_eq!(h.cumulative(), vec![(0.01, 2), (0.02, 3), (0.04, 4)]);
        assert!((h.sum() - 1.11).abs() < 1e-12);
        assert!((h.mean() - 0.185).abs() < 1e-12);
    }

    #[test]
    fn registry_counts_events() {
        let mut m = MetricsRegistry::new();
        let evs = [
            Event::StreamJoined { stream: 0, t: 0.0 },
            Event::FramePresented { stream: 0, frame: 1, t: 0.0 },
            Event::BudgetClamp {
                stream: 0,
                t: 0.0,
                requested: DnnKind::Y416,
                granted: DnnKind::TinyY416,
                mask: 0b0011,
            },
            Event::DnnSelected {
                stream: 0,
                frame: 1,
                t: 0.0,
                dnn: DnnKind::TinyY416,
            },
            Event::FrameInferred {
                stream: 0,
                frame: 1,
                dnn: DnnKind::TinyY416,
                start: 0.0,
                end: 0.018,
            },
            Event::FramePresented { stream: 0, frame: 2, t: 0.033 },
            Event::FrameDropped {
                stream: 0,
                frame: 2,
                t: 0.033,
                busy_until: 0.05,
            },
            Event::InferenceFailed {
                stream: 0,
                frame: 3,
                dnn: DnnKind::Y288,
                start: 0.07,
                end: 0.12,
            },
            Event::BatchFormed { stream: 0, dnn: DnnKind::TinyY416, t: 0.0 },
            Event::BatchFlushed { dnn: DnnKind::TinyY416, len: 3, t: 0.2 },
            Event::BatchShed { stream: 1, frame: 9, t: 0.3 },
            Event::SpanOpen {
                stream: 0,
                frame: 1,
                span: 2,
                parent: 1,
                kind: SpanKind::Frame,
                t: 0.0,
            },
            Event::SpanClose { stream: 0, span: 2, t: 0.018 },
            Event::SloBreach {
                stream: 0,
                t: 0.5,
                signal: crate::obs::SloSignal::Watts,
                value: 7.0,
                limit: 5.8,
            },
            Event::SloRecovered {
                stream: 0,
                t: 0.9,
                signal: crate::obs::SloSignal::Watts,
                value: 5.0,
                limit: 5.8,
            },
            Event::StreamLeft {
                stream: 0,
                t: 1.0,
                frames: 3,
                inferred: 1,
                dropped: 1,
                failed: 1,
            },
        ];
        for ev in &evs {
            m.record(ev);
        }
        assert_eq!(m.frames_presented, 2);
        assert_eq!(m.frames_inferred, 1);
        assert_eq!(m.frames_dropped, 1);
        assert_eq!(m.frames_failed, 1);
        assert_eq!(m.frames_shed, 1);
        assert_eq!(m.budget_clamps, 1);
        assert_eq!(m.streams_joined, 1);
        assert_eq!(m.streams_left, 1);
        assert_eq!(m.deploy[DnnKind::TinyY416.index()], 1);
        assert_eq!(m.batches_formed, 1);
        assert_eq!(m.batches_flushed, 1);
        assert_eq!(m.batch_items, 3);
        assert!((m.busy_failed_s - 0.05).abs() < 1e-12);
        assert!(
            (m.busy_per_dnn_s[DnnKind::Y288.index()] - 0.05).abs() < 1e-12
        );
        assert_eq!(m.infer_latency_s.count(), 2);
        assert!((m.loss_rate() - 1.0).abs() < 1e-12);
        assert!((m.makespan_s - 0.12).abs() < 1e-12);
        assert_eq!(m.spans_opened, 1);
        assert_eq!(m.spans_closed, 1);
        assert_eq!(m.slo_breaches, 1);
        assert_eq!(m.slo_recoveries, 1);
        // live recording never fills the stage histograms (offline fold)
        assert!(m.stage_self_s.iter().all(|h| h.count() == 0));
    }

    #[test]
    fn recording_is_allocation_free() {
        let mut m = MetricsRegistry::new();
        let evs = [
            Event::FramePresented { stream: 0, frame: 1, t: 0.0 },
            Event::FrameInferred {
                stream: 0,
                frame: 1,
                dnn: DnnKind::Y416,
                start: 0.0,
                end: 0.1,
            },
            Event::FrameDropped {
                stream: 0,
                frame: 2,
                t: 0.03,
                busy_until: 0.1,
            },
            Event::BatchFlushed { dnn: DnnKind::Y416, len: 2, t: 0.2 },
        ];
        let (delta, ()) = count_allocs(|| {
            for _ in 0..256 {
                for ev in &evs {
                    m.record(ev);
                }
            }
        });
        assert_eq!(
            delta.allocs, 0,
            "metrics recording allocated {} times",
            delta.allocs
        );
    }

    #[test]
    fn snapshot_roundtrips_through_json() {
        let mut m = MetricsRegistry::new();
        m.record(&Event::FramePresented { stream: 0, frame: 1, t: 0.0 });
        m.record(&Event::FrameInferred {
            stream: 0,
            frame: 1,
            dnn: DnnKind::Y288,
            start: 0.0,
            end: 0.041,
        });
        m.record(&Event::BatchFlushed { dnn: DnnKind::Y288, len: 4, t: 0.5 });
        m.observe_queue_depth(17);
        m.observe_stage(SpanKind::Inference, 0.041);
        m.observe_stage(SpanKind::DispatchWait, 0.002);
        m.busy_failed_s = 0.25;
        m.energy_j = 12.5;

        let snap = m.to_json();
        let back = MetricsRegistry::from_json(&snap).unwrap();
        assert_eq!(back, m);
        // and the serialised text is stable
        assert_eq!(back.to_json().to_string(), snap.to_string());
    }

    #[test]
    fn snapshot_rejects_wrong_schema_or_version() {
        assert!(MetricsRegistry::from_json(&Json::Null).is_err());
        assert!(MetricsRegistry::from_json(&Json::obj(vec![(
            "schema",
            Json::str("bogus")
        )]))
        .is_err());
        let mut snap = MetricsRegistry::new().to_json();
        if let Json::Obj(map) = &mut snap {
            map.insert("version".into(), Json::num(99.0));
        }
        assert!(MetricsRegistry::from_json(&snap).is_err());
    }

    #[test]
    fn prometheus_exposition_is_deterministic_and_well_formed() {
        let mut m = MetricsRegistry::new();
        m.record(&Event::FramePresented { stream: 0, frame: 1, t: 0.0 });
        m.record(&Event::FrameInferred {
            stream: 0,
            frame: 1,
            dnn: DnnKind::Y416,
            start: 0.0,
            end: 0.1,
        });
        m.observe_stage(SpanKind::Inference, 0.1);
        let a = m.to_prometheus();
        let b = m.to_prometheus();
        assert_eq!(a, b);
        assert!(a.contains("tod_frames_presented_total 1"));
        assert!(a.contains("tod_dnn_deploy_total{dnn=\"yolov4-416\"} 1"));
        assert!(a.contains("tod_infer_latency_seconds_bucket{le=\"+Inf\"} 1"));
        assert!(a.contains("tod_infer_latency_seconds_count 1"));
        assert!(a.contains(
            "tod_stage_self_seconds_bucket{stage=\"inference\",le=\"+Inf\"} 1"
        ));
        assert!(a.contains("tod_stage_self_seconds_count{stage=\"inference\"} 1"));
        // stages with no observations emit no series at all
        assert!(!a.contains("stage=\"postprocess\""));
        // every non-comment line is "name[{labels}] value"
        for line in a.lines().filter(|l| !l.starts_with('#')) {
            assert_eq!(
                line.split_whitespace().count(),
                2,
                "malformed exposition line: {line:?}"
            );
        }
    }

    #[test]
    fn observe_batch_folds_summary_counts() {
        let mut stats = BatchStats::default();
        stats.record(DnnKind::Y288, 3);
        stats.record(DnnKind::Y288, 1);
        stats.shed = 2;
        let mut m = MetricsRegistry::new();
        m.observe_batch(&stats);
        assert_eq!(m.batches_flushed, 2);
        assert_eq!(m.batch_items, 4);
        assert_eq!(m.frames_shed, 2);
        assert_eq!(m.batch_size.count(), 2);
    }
}
