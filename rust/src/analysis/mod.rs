//! Zone-aware static analysis over the crate's own sources (`tod
//! lint`, DESIGN.md §16).
//!
//! The dynamic suites pin the reproduction's invariants after the
//! fact: byte-identical traces and goldens (determinism), panic-free
//! property tests (serving), counting-allocator assertions (hot
//! path). This subsystem enforces the same three invariants at the
//! source level, before anything runs — which matters doubly in this
//! repo, where several PRs were authored on machines without a
//! toolchain and convention was the only guard.
//!
//! * [`scanner`] — two-pass token/AST-lite scan: mask comments and
//!   string literals (preserving line structure), then annotate each
//!   line with `#[cfg(test)]` membership and its enclosing-function
//!   stack. No `syn`, no new dependencies.
//! * [`zones`] — the zone model and the versioned policy file
//!   (`rust/lint-policy.json`, schema `tod-lint-policy` v1) mapping
//!   paths to the determinism/serving zones and enumerating hot-path
//!   functions. Zones are data: the analyser hardcodes no path.
//! * [`rules`] — the per-zone rule table and needle matching.
//! * [`waivers`] — the inline `// tod-lint: allow(<rule>)
//!   reason="..."` protocol; honoured but always enumerated.
//! * [`report`] — the versioned `tod-lint` JSON report and its
//!   human rendering.
//!
//! [`run_lint`] is the whole pipeline: walk `rust/src`
//! deterministically, scan, match rules per zone, resolve waivers,
//! and return a [`LintReport`] whose [`LintReport::clean`] drives the
//! `--check` exit code in CI.

pub mod report;
pub mod rules;
pub mod scanner;
pub mod waivers;
pub mod zones;

use std::path::{Path, PathBuf};

pub use report::{Finding, LintReport, WaivedFinding};
pub use zones::{Policy, Severity, Zone};

use crate::analysis::rules::{index_sites, needle_matches, Rule, RULES};
use crate::analysis::scanner::{scan_source, ScannedFile};
use crate::analysis::waivers::Waiver;

/// Run the full lint pass over every `.rs` file under `src_root`.
pub fn run_lint(
    src_root: &Path,
    policy: &Policy,
) -> Result<LintReport, String> {
    let files = collect_rs_files(src_root)?;
    let mut rep = LintReport {
        policy_version: policy.version,
        files_scanned: files.len(),
        ..Default::default()
    };
    for path in &files {
        let rel = rel_path(src_root, path);
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        lint_file(&scan_source(&rel, &text), policy, &mut rep);
    }
    rep.sort();
    Ok(rep)
}

/// All `.rs` files under `root`, depth-first, sorted by relative path
/// so reports are byte-stable across platforms and readdir orders.
fn collect_rs_files(root: &Path) -> Result<Vec<PathBuf>, String> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let entries = std::fs::read_dir(&dir)
            .map_err(|e| format!("read dir {}: {e}", dir.display()))?;
        for entry in entries {
            let entry =
                entry.map_err(|e| format!("read dir entry: {e}"))?;
            let path = entry.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().and_then(|e| e.to_str())
                == Some("rs")
            {
                out.push(path);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// `/`-separated path of `path` relative to `root`.
fn rel_path(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

/// Lint one scanned file into the report.
fn lint_file(scanned: &ScannedFile, policy: &Policy, rep: &mut LintReport) {
    let path_zone = policy.path_zone(&scanned.rel_path);
    let (waivers, problems) = waivers::collect(scanned);
    let mut waiver_used = vec![false; waivers.len()];

    for (idx, info) in scanned.lines.iter().enumerate() {
        let lineno = idx + 1;
        if info.in_test || info.masked.trim().is_empty() {
            continue;
        }
        let in_hot = info
            .functions
            .iter()
            .any(|f| policy.is_hot_function(f));
        for rule in RULES {
            let applies = match rule.zone {
                Zone::HotPath => in_hot,
                z => path_zone == Some(z),
            };
            if !applies {
                continue;
            }
            let severity =
                policy.severity_for(rule.id, rule.default_severity);
            if severity == Severity::Off {
                continue;
            }
            if !rule_hits(rule, &info.masked) {
                continue;
            }
            let finding = Finding {
                file: scanned.rel_path.clone(),
                line: lineno,
                rule: rule.id.to_string(),
                zone: rule.zone.tag(),
                severity,
                message: rule.message.to_string(),
            };
            match waiving(&waivers, &mut waiver_used, lineno, rule.id) {
                Some(reason) => rep.waived.push(WaivedFinding {
                    finding,
                    reason: reason.to_string(),
                }),
                None => match severity {
                    Severity::Deny => rep.findings.push(finding),
                    Severity::Warn => rep.warnings.push(finding),
                    Severity::Off => {}
                },
            }
        }
    }

    // malformed / reason-less waivers are deny findings themselves
    for p in &problems {
        rep.findings.push(Finding {
            file: scanned.rel_path.clone(),
            line: p.line,
            rule: "waiver-missing-reason".to_string(),
            zone: "waiver",
            severity: Severity::Deny,
            message: p.message.clone(),
        });
    }
    // waivers that matched nothing are advisories (stale exemptions)
    for (w, used) in waivers.iter().zip(&waiver_used) {
        if !used {
            rep.advisories.push(Finding {
                file: scanned.rel_path.clone(),
                line: w.decl_line,
                rule: "unused-waiver".to_string(),
                zone: "waiver",
                severity: Severity::Warn,
                message: format!(
                    "waiver for {} matches no finding — remove it",
                    w.rules.join(", ")
                ),
            });
        }
    }
}

/// Does the rule fire on this masked line?
fn rule_hits(rule: &Rule, masked: &str) -> bool {
    if rule.id == "srv-slice-index" {
        !index_sites(masked).is_empty()
    } else {
        rule.needles.iter().any(|n| needle_matches(masked, n))
    }
}

/// First waiver covering (line, rule), marking it used.
fn waiving<'w>(
    waivers: &'w [Waiver],
    used: &mut [bool],
    lineno: usize,
    rule_id: &str,
) -> Option<&'w str> {
    for (i, w) in waivers.iter().enumerate() {
        if w.target_line == lineno
            && w.rules.iter().any(|r| r == rule_id)
        {
            used[i] = true;
            return Some(&w.reason);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_policy() -> Policy {
        Policy::parse(
            r#"{
              "schema": "tod-lint-policy",
              "schema_version": 1,
              "version": 1,
              "zones": {
                "determinism": {"paths": ["obs/"]},
                "serving": {"paths": ["runtime/"]},
                "hot_path": {"functions": ["Core::step"]}
              },
              "severity": {"srv-slice-index": "warn"}
            }"#,
        )
        .unwrap()
    }

    fn lint_one(rel: &str, src: &str) -> LintReport {
        let mut rep = LintReport::default();
        lint_file(&scan_source(rel, src), &test_policy(), &mut rep);
        rep.sort();
        rep
    }

    #[test]
    fn serving_rules_fire_outside_tests_only() {
        let rep = lint_one(
            "runtime/x.rs",
            concat!(
                "fn live() { x.unwrap(); }\n",
                "#[cfg(test)]\n",
                "mod tests { fn t() { y.unwrap(); } }\n",
            ),
        );
        assert_eq!(rep.findings.len(), 1);
        assert_eq!(rep.findings[0].rule, "srv-unwrap");
        assert_eq!(rep.findings[0].line, 1);
    }

    #[test]
    fn hot_rules_scope_to_policy_functions() {
        let src = concat!(
            "impl Core {\n",
            "    fn step(&self) { let v = xs.to_vec(); }\n",
            "    fn cold(&self) { let v = xs.to_vec(); }\n",
            "}\n",
        );
        let rep = lint_one("other/x.rs", src);
        assert_eq!(rep.findings.len(), 1);
        assert_eq!(rep.findings[0].rule, "hot-format");
        assert_eq!(rep.findings[0].line, 2);
    }

    #[test]
    fn waiver_moves_finding_to_waived_and_unused_is_advisory() {
        let rep = lint_one(
            "runtime/x.rs",
            concat!(
                "fn f() {\n",
                "    // tod-lint: allow(srv-panic) reason=\"contract\"\n",
                "    panic!();\n",
                "    // tod-lint: allow(srv-unwrap) reason=\"stale\"\n",
                "    ok();\n",
                "}\n",
            ),
        );
        assert!(rep.findings.is_empty());
        assert_eq!(rep.waived.len(), 1);
        assert_eq!(rep.waived[0].finding.rule, "srv-panic");
        assert_eq!(rep.waived[0].reason, "contract");
        assert_eq!(rep.advisories.len(), 1);
        assert_eq!(rep.advisories[0].rule, "unused-waiver");
    }

    #[test]
    fn slice_index_severity_downgrade_applies() {
        let rep = lint_one("runtime/x.rs", "fn f() { let x = a[i]; }\n");
        assert!(rep.findings.is_empty());
        assert_eq!(rep.warnings.len(), 1);
        assert_eq!(rep.warnings[0].rule, "srv-slice-index");
    }

    #[test]
    fn determinism_rules_fire_in_obs() {
        let rep = lint_one(
            "obs/x.rs",
            "fn f() { let t = Instant::now(); }\n",
        );
        assert_eq!(rep.findings.len(), 1);
        assert_eq!(rep.findings[0].rule, "det-wall-clock");
        // same construct outside the zone is silent
        let rep2 = lint_one(
            "video/x.rs",
            "fn f() { let t = Instant::now(); }\n",
        );
        assert!(rep2.findings.is_empty());
    }
}
