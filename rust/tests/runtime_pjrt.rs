//! PJRT runtime integration: load the real AOT artifacts, run inference
//! on the request path, decode. Skipped when `make artifacts` has not
//! been run (e.g. a fresh checkout without Python).

use std::path::PathBuf;

use tod::coordinator::policy::FixedPolicy;
use tod::coordinator::scheduler::Detector;
use tod::dataset::synth::{CameraMotion, Sequence, SequenceSpec};
use tod::runtime::pool::EnginePool;
use tod::runtime::raster::rasterize;
use tod::runtime::serve::{serve_sequence, PjrtBackend};
use tod::DnnKind;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping PJRT test: artifacts not built");
        None
    }
}

fn tiny_seq(frames: u64) -> Sequence {
    Sequence::generate(SequenceSpec {
        name: "PJRT".into(),
        width: 640,
        height: 480,
        fps: 30.0,
        frames,
        density: 4,
        ref_height: 200.0,
        depth_range: (1.0, 2.0),
        walk_speed: 1.5,
        camera: CameraMotion::Static,
        seed: 77,
    })
}

#[test]
fn pool_loads_all_four_variants() {
    let Some(dir) = artifacts_dir() else { return };
    std::env::set_var("TOD_QUIET", "1");
    let pool = EnginePool::load(&dir).expect("load pool");
    assert_eq!(pool.loaded(), DnnKind::ALL.to_vec());
    assert!(pool.manifest().is_complete());
    assert!(pool.manifest().pallas, "artifacts must be the pallas build");
}

#[test]
fn all_variants_infer_and_outputs_are_finite() {
    let Some(dir) = artifacts_dir() else { return };
    std::env::set_var("TOD_QUIET", "1");
    let pool = EnginePool::load(&dir).expect("load pool");
    let seq = tiny_seq(1);
    for k in DnnKind::ALL {
        let engine = pool.engine(k).unwrap();
        let spec = engine.spec();
        let img = rasterize(seq.gt(1), 640.0, 480.0, spec.input_size, 1);
        let heads = engine.infer(&img).expect("infer");
        assert_eq!(heads.len(), spec.heads.len());
        for (h, hs) in heads.iter().zip(&spec.heads) {
            assert_eq!(h.data.len(), hs.grid * hs.grid * hs.channels);
            assert!(h.data.iter().all(|v| v.is_finite()), "{k}: non-finite");
            // untrained but non-degenerate: outputs must vary
            let mean = h.data.iter().sum::<f32>() / h.data.len() as f32;
            let var = h
                .data
                .iter()
                .map(|v| (v - mean) * (v - mean))
                .sum::<f32>()
                / h.data.len() as f32;
            assert!(var > 1e-10, "{k}: constant head output");
        }
    }
    assert_eq!(pool.total_runs(), 4);
}

#[test]
fn inference_is_deterministic() {
    let Some(dir) = artifacts_dir() else { return };
    std::env::set_var("TOD_QUIET", "1");
    let pool = EnginePool::load(&dir).expect("load pool");
    let engine = pool.engine(DnnKind::TinyY288).unwrap();
    let seq = tiny_seq(1);
    let img = rasterize(seq.gt(1), 640.0, 480.0, 288, 1);
    let a = engine.infer(&img).unwrap();
    let b = engine.infer(&img).unwrap();
    assert_eq!(a[0].data, b[0].data);
}

#[test]
fn input_changes_change_output() {
    let Some(dir) = artifacts_dir() else { return };
    std::env::set_var("TOD_QUIET", "1");
    let pool = EnginePool::load(&dir).expect("load pool");
    let engine = pool.engine(DnnKind::TinyY288).unwrap();
    let seq = tiny_seq(2);
    let a = engine
        .infer(&rasterize(seq.gt(1), 640.0, 480.0, 288, 1))
        .unwrap();
    let b = engine
        .infer(&rasterize(seq.gt(2), 640.0, 480.0, 288, 2))
        .unwrap();
    assert_ne!(a[0].data, b[0].data, "different frames, same logits");
}

#[test]
fn backend_detect_roundtrip_through_decode() {
    let Some(dir) = artifacts_dir() else { return };
    std::env::set_var("TOD_QUIET", "1");
    let pool = EnginePool::load(&dir).expect("load pool");
    let seq = tiny_seq(3);
    let mut backend = PjrtBackend::new(&pool, 640.0, 480.0);
    for k in DnnKind::ALL {
        let dets = backend.detect(1, seq.gt(1), k).expect("detect");
        // untrained weights: boxes may be arbitrary but must be valid
        for d in &dets {
            assert!(d.bbox.x >= 0.0 && d.bbox.y >= 0.0);
            assert!(d.bbox.right() <= 640.0 + 1e-6);
            assert!(d.bbox.bottom() <= 480.0 + 1e-6);
            assert!((0.0..=1.0).contains(&(d.score as f64)));
        }
    }
    assert_eq!(backend.latencies.len(), 4);
    for (_, s) in &backend.latencies {
        assert!(*s > 0.0 && *s < 60.0);
    }
}

#[test]
fn serve_loop_with_fixed_policy() {
    let Some(dir) = artifacts_dir() else { return };
    std::env::set_var("TOD_QUIET", "1");
    let pool = EnginePool::load(&dir).expect("load pool");
    let seq = tiny_seq(3);
    let mut policy = FixedPolicy(DnnKind::TinyY288);
    let report = serve_sequence(&pool, &seq, &mut policy).expect("serve");
    assert_eq!(report.frames, 3);
    assert_eq!(report.deploy[0], 3);
    assert_eq!(report.switches, 0);
    assert_eq!(report.per_dnn.len(), 1);
    assert_eq!(report.failed, 0);
}

#[test]
fn batched_serving_matches_per_request_on_real_engines() {
    // two concurrent streams through the micro-batching server, real
    // PJRT inference; per-stream deploy decisions must match the
    // unbatched serve loop exactly (the policy sees identical inputs
    // because batched results are bit-identical per request)
    let Some(dir) = artifacts_dir() else { return };
    std::env::set_var("TOD_QUIET", "1");
    let pool = EnginePool::load(&dir).expect("load pool");
    let seqs = [tiny_seq(4), tiny_seq(4)];
    let cfg = tod::runtime::batch::BatchConfig {
        max_batch: 2,
        max_wait: std::time::Duration::from_millis(1),
        ..Default::default()
    };
    let report = tod::runtime::serve::serve_batched(
        &pool,
        &seqs,
        cfg,
        &|| Box::new(FixedPolicy(DnnKind::TinyY288)),
    )
    .expect("batched serve");
    assert_eq!(report.streams, 2);
    assert_eq!(report.frames, 8);
    assert_eq!(report.failed, 0);
    assert_eq!(report.deploy[DnnKind::TinyY288.index()], 8);
    assert_eq!(report.stats.total_items(), 8);

    let unbatched =
        serve_sequence(&pool, &seqs[0], &mut FixedPolicy(DnnKind::TinyY288))
            .expect("serve");
    assert_eq!(unbatched.deploy[DnnKind::TinyY288.index()], 4);
}
