//! The PJRT inference runtime: Python-free request path.
//!
//! `python/compile/aot.py` lowers the four detector variants to HLO text
//! once at build time; this module loads the text, compiles each variant
//! on the PJRT CPU client ([`engine`]), keeps all four executables
//! *preloaded* ([`pool`]) so a TOD switch is a pointer swap (§III.B.1),
//! rasterizes frames ([`raster`]), and decodes raw YOLO heads into
//! detections ([`decode`]) using the shapes/anchors recorded in
//! `artifacts/manifest.json` ([`manifest`]).
//!
//! Scaling layer: [`batch`] collects requests from concurrent streams
//! into per-DNN micro-batches and [`server`] serves them panic-free
//! behind bounded admission — see DESIGN.md §11.
//!
//! The `anyhow`/`xla` surface these modules consume is vendored in
//! [`crate::ext`] (the crate itself stays dependency-free): error
//! chaining is fully functional, while the PJRT facade fails cleanly
//! at `PjRtClient::cpu()` until a real backend is linked, so every
//! simulator/eval path runs without one.

// Serving zone (lint-policy.json): the request path must never die.
// The inner attribute covers every submodule file; tests are exempt
// via clippy.toml (allow-unwrap-in-tests / allow-expect-in-tests).
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod batch;
pub mod decode;
pub mod engine;
pub mod manifest;
pub mod pool;
pub mod raster;
pub mod serve;
pub mod server;

pub use batch::{AdmissionPolicy, BatchConfig, BatchStats};
pub use engine::Engine;
pub use manifest::{HeadSpec, Manifest, VariantSpec};
pub use pool::EnginePool;
pub use server::{
    AdmitError, BatchDetector, InferRequest, InferenceServer, ResultHandle,
    ServeError, ServerCore,
};
