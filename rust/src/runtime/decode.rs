//! YOLO head decoding in Rust: raw (1, G, G, A*(5+C)) tensors from the
//! PJRT engine -> pixel-space detections.
//!
//! Per cell (i, j) and anchor a the channels are [tx, ty, tw, th, obj,
//! cls...]:
//!
//! ```text
//! cx = (σ(tx) + j) * stride          w = anchor_w * exp(tw)
//! cy = (σ(ty) + i) * stride          h = anchor_h * exp(th)
//! score = σ(obj) * max_c σ(cls_c)
//! ```
//!
//! followed by scaling from network-input pixels to frame pixels and
//! class-aware NMS.

use crate::detection::{nms, Detection, PERSON_CLASS};
use crate::geometry::BBox;
use crate::runtime::engine::HeadTensor;
use crate::runtime::manifest::{HeadSpec, VariantSpec};

/// NMS IoU threshold used by the YOLO reference implementations.
pub const NMS_IOU: f64 = 0.45;

/// Decode-time score floor. §Perf: raised from 0.05 to 0.25 — scores
/// below the paper's 0.35 application threshold never survive anyway,
/// and pre-filtering here cuts the NMS candidate set ~10x (decode went
/// 2.0 ms -> well under 1 ms on the y-288 two-head variant).
pub const DECODE_SCORE_FLOOR: f32 = 0.25;

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Decode one head tensor into detections in *frame* pixel space.
pub fn decode_head(
    tensor: &HeadTensor,
    head: &HeadSpec,
    input_size: usize,
    frame_w: f64,
    frame_h: f64,
) -> Vec<Detection> {
    let g = head.grid;
    let na = head.anchors.len();
    let per = head.channels / na; // 5 + C
    debug_assert_eq!(tensor.data.len(), g * g * head.channels);
    let sx = frame_w / input_size as f64;
    let sy = frame_h / input_size as f64;
    let mut out = Vec::new();
    for i in 0..g {
        for j in 0..g {
            let base = (i * g + j) * head.channels;
            for (a, &(aw, ah)) in head.anchors.iter().enumerate() {
                let o = base + a * per;
                let tx = tensor.data[o];
                let ty = tensor.data[o + 1];
                let tw = tensor.data[o + 2];
                let th = tensor.data[o + 3];
                let obj = sigmoid(tensor.data[o + 4]);
                // best class prob (C = 1 for person-only models)
                let mut best_cls = 0.0f32;
                for c in 5..per {
                    best_cls = best_cls.max(sigmoid(tensor.data[o + c]));
                }
                let score = obj * best_cls;
                if score < DECODE_SCORE_FLOOR {
                    continue;
                }
                let cx = (sigmoid(tx) as f64 + j as f64)
                    * head.stride as f64;
                let cy = (sigmoid(ty) as f64 + i as f64)
                    * head.stride as f64;
                let w = aw * (tw.clamp(-8.0, 8.0) as f64).exp();
                let h = ah * (th.clamp(-8.0, 8.0) as f64).exp();
                let bbox = BBox::from_center(cx * sx, cy * sy, w * sx, h * sy)
                    .clip(frame_w, frame_h);
                if bbox.is_degenerate() {
                    continue;
                }
                out.push(Detection::new(bbox, score, PERSON_CLASS));
            }
        }
    }
    out
}

/// Decode all heads of a variant and apply NMS.
pub fn decode(
    tensors: &[HeadTensor],
    spec: &VariantSpec,
    frame_w: f64,
    frame_h: f64,
) -> Vec<Detection> {
    let mut all = Vec::new();
    for (t, h) in tensors.iter().zip(&spec.heads) {
        all.extend(decode_head(t, h, spec.input_size, frame_w, frame_h));
    }
    nms(&all, NMS_IOU)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::DnnKind;

    fn head_spec() -> HeadSpec {
        HeadSpec {
            stride: 32,
            grid: 9,
            channels: 18,
            anchors: vec![(23.0, 56.0), (52.0, 128.0), (110.0, 245.0)],
        }
    }

    fn empty_tensor(g: usize, ch: usize) -> HeadTensor {
        // large negative obj logit -> score ~ 0 everywhere
        HeadTensor { grid: g, channels: ch, data: vec![-20.0; g * g * ch] }
    }

    /// Place one activation in cell (i, j), anchor a.
    fn set_cell(
        t: &mut HeadTensor,
        i: usize,
        j: usize,
        a: usize,
        vals: [f32; 6],
    ) {
        let per = 6;
        let o = (i * t.grid + j) * t.channels + a * per;
        t.data[o..o + 6].copy_from_slice(&vals);
    }

    #[test]
    fn empty_head_decodes_to_nothing() {
        let spec = head_spec();
        let t = empty_tensor(9, 18);
        let dets = decode_head(&t, &spec, 288, 288.0, 288.0);
        assert!(dets.is_empty());
    }

    #[test]
    fn single_activation_lands_in_its_cell() {
        let spec = head_spec();
        let mut t = empty_tensor(9, 18);
        // cell (2, 5), anchor 1 (52x128); tx=ty=0 -> center of cell +0.5
        set_cell(&mut t, 2, 5, 1, [0.0, 0.0, 0.0, 0.0, 10.0, 10.0]);
        let dets = decode_head(&t, &spec, 288, 288.0, 288.0);
        assert_eq!(dets.len(), 1);
        let d = dets[0];
        let (cx, cy) = d.bbox.center();
        assert!((cx - 5.5 * 32.0).abs() < 1e-3, "cx {cx}");
        assert!((cy - 2.5 * 32.0).abs() < 1e-3, "cy {cy}");
        assert!((d.bbox.w - 52.0).abs() < 1e-3);
        assert!((d.bbox.h - 128.0).abs() < 1e-3);
        assert!(d.score > 0.99);
    }

    #[test]
    fn tw_th_scale_the_anchor() {
        let spec = head_spec();
        let mut t = empty_tensor(9, 18);
        let ln2 = std::f32::consts::LN_2;
        // middle cell so the clip to the frame doesn't trim the box
        set_cell(&mut t, 4, 4, 0, [0.0, 0.0, ln2, -ln2, 10.0, 10.0]);
        let dets = decode_head(&t, &spec, 288, 288.0, 288.0);
        assert_eq!(dets.len(), 1);
        assert!((dets[0].bbox.w - 46.0).abs() < 0.01); // 23 * 2
        assert!((dets[0].bbox.h - 28.0).abs() < 0.01); // 56 / 2
    }

    #[test]
    fn frame_scaling() {
        let spec = head_spec();
        let mut t = empty_tensor(9, 18);
        set_cell(&mut t, 4, 4, 0, [0.0, 0.0, 0.0, 0.0, 10.0, 10.0]);
        // 1920x1080 frame from a 288 net: sx = 6.67, sy = 3.75
        let dets = decode_head(&t, &spec, 288, 1920.0, 1080.0);
        let (cx, cy) = dets[0].bbox.center();
        assert!((cx - 4.5 * 32.0 * (1920.0 / 288.0)).abs() < 1e-3);
        assert!((cy - 4.5 * 32.0 * (1080.0 / 288.0)).abs() < 1e-3);
    }

    #[test]
    fn score_is_obj_times_class() {
        let spec = head_spec();
        let mut t = empty_tensor(9, 18);
        set_cell(&mut t, 0, 0, 0, [0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        let dets = decode_head(&t, &spec, 288, 288.0, 288.0);
        assert_eq!(dets.len(), 1);
        assert!((dets[0].score - 0.25).abs() < 1e-6); // 0.5 * 0.5
    }

    #[test]
    fn nms_merges_duplicate_cells() {
        let spec = VariantSpec {
            kind: DnnKind::TinyY288,
            artifact: "x".into(),
            input_size: 288,
            param_count: 0,
            heads: vec![head_spec()],
        };
        let mut t = empty_tensor(9, 18);
        // two anchors in the same cell firing on the same object
        set_cell(&mut t, 3, 3, 0, [0.0, 0.0, 1.2, 0.5, 10.0, 10.0]);
        set_cell(&mut t, 3, 3, 1, [0.0, 0.0, 0.0, 0.0, 5.0, 5.0]);
        let dets = decode(&[t], &spec, 288.0, 288.0);
        // 23*e^1.2 x 56*e^0.5 ≈ 76x92 overlaps 52x128 heavily -> one box
        assert_eq!(dets.len(), 1);
        assert!(dets[0].score > 0.99); // highest kept
    }

    #[test]
    fn out_of_frame_boxes_clipped() {
        let spec = head_spec();
        let mut t = empty_tensor(9, 18);
        // top-left cell with the huge anchor: box spills out of frame
        set_cell(&mut t, 0, 0, 2, [-5.0, -5.0, 0.0, 0.0, 10.0, 10.0]);
        let dets = decode_head(&t, &spec, 288, 288.0, 288.0);
        assert_eq!(dets.len(), 1);
        assert!(dets[0].bbox.x >= 0.0 && dets[0].bbox.y >= 0.0);
    }
}
