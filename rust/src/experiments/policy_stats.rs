//! Figures 9, 10, 12: MBBS series, deployment frequency, usage timeline.

use crate::app::Campaign;
use crate::dataset::catalog::SequenceId;
use crate::util::csv::CsvTable;
use crate::util::stats::median;
use crate::util::table::{sparkline, AsciiTable};
use crate::DnnKind;

use super::ExperimentOutput;

/// Fig. 9: per-frame medians of bounding-box sizes, MOT17-04 vs -11.
pub fn fig9_mbbs(c: &mut Campaign) -> ExperimentOutput {
    let ids = [SequenceId::Mot04, SequenceId::Mot11];
    let mut text = String::from(
        "Fig. 9 — Medians of Bounding Box Sizes (fraction of frame area)\n",
    );
    let mut csv = CsvTable::new(vec!["sequence", "frame", "mbbs"]);
    for id in ids {
        let series = c.sequence(id).mbbs_series();
        let med = median(&series);
        let var = {
            let m = series.iter().sum::<f64>() / series.len() as f64;
            series.iter().map(|v| (v - m).powi(2)).sum::<f64>()
                / series.len() as f64
        };
        // subsample the sparkline to 80 columns
        let step = (series.len() / 80).max(1);
        let sub: Vec<f64> =
            series.iter().step_by(step).copied().collect();
        text.push_str(&format!(
            "{}: median={:.4} variance={:.2e}\n  {}\n",
            id.name(),
            med,
            var,
            sparkline(&sub)
        ));
        for (i, v) in series.iter().enumerate() {
            csv.push(vec![
                id.name().to_string(),
                (i + 1).to_string(),
                format!("{v:.6}"),
            ]);
        }
    }
    text.push_str(
        "(paper: MOT17-04 low variance from a static camera; MOT17-11 high \
         variance from a moving camera)\n",
    );
    ExperimentOutput {
        id: "fig9",
        title: "Fig. 9: MBBS series".into(),
        text,
        csv: vec![("fig9_mbbs.csv".into(), csv)],
    }
}

/// Fig. 10: deployment frequency of each DNN under TOD.
pub fn fig10_deploy(c: &mut Campaign) -> ExperimentOutput {
    let mut header = vec!["sequence".to_string()];
    header.extend(DnnKind::ALL.iter().map(|k| k.short_label().to_string()));
    let mut table = AsciiTable::new(
        "Fig. 10 — Deployment Frequency of Each Network by TOD (%)",
        header.iter().map(String::as_str).collect(),
    );
    let mut csv = CsvTable::new(header);
    for id in SequenceId::ALL {
        let freq = c.tod(id).deploy_freq();
        let mut row = vec![id.name().to_string()];
        for f in freq {
            row.push(format!("{:.1}", f * 100.0));
        }
        table.push(row.clone());
        csv.push(row);
    }
    let text = format!(
        "{}\n(paper: TOD stays with YOLOv4-416 on MOT17-04 and uses \
         YOLOv4-tiny-288 84.5% on MOT17-05)\n",
        table.render()
    );
    ExperimentOutput {
        id: "fig10",
        title: "Fig. 10: deployment frequency".into(),
        text,
        csv: vec![("fig10_deploy.csv".into(), csv)],
    }
}

/// Fig. 12: which DNN TOD runs over time on MOT17-05.
pub fn fig12_usage(c: &mut Campaign) -> ExperimentOutput {
    let id = SequenceId::Mot05;
    let r = c.tod(id).clone();
    let fps = id.eval_fps();
    let mut csv = CsvTable::new(vec!["t_s", "dnn"]);
    // render as a timeline strip: one char per second of stream time,
    // showing the heaviest DNN used in that second
    let duration = r.n_frames as f64 / fps;
    let mut strip = String::new();
    for sec in 0..duration.ceil() as usize {
        let f0 = (sec as f64 * fps) as usize;
        let f1 = (((sec + 1) as f64) * fps) as usize;
        let mut heaviest: Option<DnnKind> = None;
        for f in f0..f1.min(r.dnn_series.len()) {
            if let Some(d) = r.dnn_series[f] {
                if heaviest.map(|h| d.index() > h.index()).unwrap_or(true) {
                    heaviest = Some(d);
                }
            }
        }
        let ch = match heaviest {
            Some(DnnKind::TinyY288) => '1',
            Some(DnnKind::TinyY416) => '2',
            Some(DnnKind::Y288) => '3',
            Some(DnnKind::Y416) => '4',
            None => '.',
        };
        strip.push(ch);
        csv.push(vec![
            sec.to_string(),
            heaviest
                .map(|d| d.short_label().to_string())
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    let freq = r.deploy_freq();
    let text = format!(
        "Fig. 12 — DNN Usage of TOD with MOT17-05 (per second; 1=YT-288, \
         2=YT-416, 3=Y-288, 4=Y-416, .=no inference)\n{}\nusage: \
         YT-288 {:.1}%  YT-416 {:.1}%  Y-288 {:.1}%  Y-416 {:.1}%  \
         (paper: YT-288 dominant at 84.5%)\n",
        strip,
        freq[0] * 100.0,
        freq[1] * 100.0,
        freq[2] * 100.0,
        freq[3] * 100.0
    );
    ExperimentOutput {
        id: "fig12",
        title: "Fig. 12: TOD DNN usage timeline".into(),
        text,
        csv: vec![("fig12_usage.csv".into(), csv)],
    }
}
