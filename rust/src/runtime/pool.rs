//! The preloaded engine pool: all four variants compiled and resident,
//! so Algorithm 1's switch is "just a pointer" (§III.B.1).

use std::path::Path;

use crate::ext::anyhow::{anyhow, Result};
use crate::ext::xla;

use crate::runtime::engine::Engine;
use crate::runtime::manifest::Manifest;
use crate::DnnKind;

/// All compiled variants plus the shared PJRT client.
pub struct EnginePool {
    _client: xla::PjRtClient,
    engines: Vec<Option<Engine>>,
    manifest: Manifest,
}

impl EnginePool {
    /// Load every variant present in `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<EnginePool> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut engines: Vec<Option<Engine>> =
            (0..4).map(|_| None).collect();
        for spec in &manifest.variants {
            engines[spec.kind.index()] =
                Some(Engine::load(&client, dir, spec)?);
        }
        Ok(EnginePool { _client: client, engines, manifest })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The engine for a variant — an O(1) slot lookup, the paper's
    /// pointer switch.
    pub fn engine(&self, kind: DnnKind) -> Result<&Engine> {
        self.engines[kind.index()]
            .as_ref()
            .ok_or_else(|| anyhow!("variant {kind} not loaded"))
    }

    /// Which variants are resident.
    pub fn loaded(&self) -> Vec<DnnKind> {
        DnnKind::ALL
            .iter()
            .copied()
            .filter(|k| self.engines[k.index()].is_some())
            .collect()
    }

    /// Total executions across all engines.
    pub fn total_runs(&self) -> u64 {
        self.engines
            .iter()
            .flatten()
            .map(|e| e.n_runs())
            .sum()
    }
}
