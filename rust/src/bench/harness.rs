//! Warmup + timed-iteration benchmark runner with percentile reporting.

use std::time::{Duration, Instant};

use crate::util::stats::percentile_sorted;

/// Opaque value sink preventing the optimizer from deleting benched work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    fn human(ns: f64) -> String {
        if ns < 1e3 {
            format!("{ns:8.1} ns")
        } else if ns < 1e6 {
            format!("{:8.2} µs", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:8.2} ms", ns / 1e6)
        } else {
            format!("{:8.2} s ", ns / 1e9)
        }
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:40} mean {}  p50 {}  p95 {}  min {}  ({} iters)",
            self.name,
            Self::human(self.mean_ns),
            Self::human(self.p50_ns),
            Self::human(self.p95_ns),
            Self::human(self.min_ns),
            self.iters
        )
    }
}

/// The bench runner: target time per case, automatic iteration count.
pub struct Bench {
    /// Minimum measurement time per case.
    pub target: Duration,
    /// Warmup time per case.
    pub warmup: Duration,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            target: Duration::from_millis(700),
            warmup: Duration::from_millis(150),
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick profile for slow cases (e.g. whole-sequence scheduling).
    pub fn slow() -> Self {
        Bench {
            target: Duration::from_secs(2),
            warmup: Duration::from_millis(200),
            results: Vec::new(),
        }
    }

    /// Run one case; `f` is invoked repeatedly and must do the work.
    pub fn case<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // warmup
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        // measure in batches; record per-call samples
        let mut samples_ns: Vec<f64> = Vec::new();
        let m0 = Instant::now();
        while m0.elapsed() < self.target {
            let t0 = Instant::now();
            f();
            samples_ns.push(t0.elapsed().as_nanos() as f64);
            if samples_ns.len() >= 1_000_000 {
                break;
            }
        }
        samples_ns.sort_by(|a, b| a.total_cmp(b));
        let mean =
            samples_ns.iter().sum::<f64>() / samples_ns.len().max(1) as f64;
        let result = BenchResult {
            name: name.to_string(),
            iters: samples_ns.len(),
            mean_ns: mean,
            p50_ns: percentile_sorted(&samples_ns, 50.0),
            p95_ns: percentile_sorted(&samples_ns, 95.0),
            min_ns: samples_ns.first().copied().unwrap_or(f64::NAN),
        };
        println!("{result}");
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Write results as CSV under `target/bench-results/<file>`.
    pub fn save_csv(&self, file: &str) -> std::io::Result<()> {
        let mut csv = crate::util::csv::CsvTable::new(vec![
            "name", "iters", "mean_ns", "p50_ns", "p95_ns", "min_ns",
        ]);
        for r in &self.results {
            csv.push(vec![
                r.name.clone(),
                r.iters.to_string(),
                format!("{:.1}", r.mean_ns),
                format!("{:.1}", r.p50_ns),
                format!("{:.1}", r.p95_ns),
                format!("{:.1}", r.min_ns),
            ]);
        }
        csv.save(&std::path::Path::new("target/bench-results").join(file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        let mut b = Bench {
            target: Duration::from_millis(30),
            warmup: Duration::from_millis(5),
            results: Vec::new(),
        };
        let mut acc = 0u64;
        let r = b
            .case("noop-ish", || {
                acc = black_box(acc.wrapping_add(1));
            })
            .clone();
        assert!(r.iters > 100);
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns <= r.p95_ns);
        assert!(r.min_ns <= r.p50_ns);
    }

    #[test]
    fn ordering_detects_slower_code() {
        let mut b = Bench {
            target: Duration::from_millis(40),
            warmup: Duration::from_millis(5),
            results: Vec::new(),
        };
        let fast = b
            .case("fast", || {
                black_box((0..10u64).sum::<u64>());
            })
            .mean_ns;
        let slow = b
            .case("slow", || {
                black_box((0..10_000u64).sum::<u64>());
            })
            .mean_ns;
        assert!(slow > fast * 5.0, "slow {slow} fast {fast}");
    }

    #[test]
    fn human_units() {
        assert!(BenchResult::human(500.0).contains("ns"));
        assert!(BenchResult::human(5e4).contains("µs"));
        assert!(BenchResult::human(5e7).contains("ms"));
        assert!(BenchResult::human(5e9).contains("s"));
    }
}
