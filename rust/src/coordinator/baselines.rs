//! Comparison baselines beyond the fixed single-DNN policies.
//!
//! [`run_chameleon_lite`] reproduces the cost structure of Chameleon
//! (Jiang et al., SIGCOMM'18) as the paper describes it: periodically
//! re-profile by running the candidate configurations — including the
//! most expensive DNN as pseudo-ground-truth — then commit to the
//! cheapest configuration that keeps enough of the heavyweight's
//! accuracy for the rest of the window. The periodic profiling burns
//! real inference time (it participates in the drop-frame accounting),
//! which is exactly the overhead TOD's proactive selection avoids (§II,
//! §V).

use crate::coordinator::scheduler::{Detector, RunResult};
use crate::dataset::mot::GtEntry;
use crate::dataset::synth::Sequence;
use crate::detection::{Detection, FrameDetections};
use crate::eval::ap::{ApMethod, SequenceEval};
use crate::eval::matching::{match_frame, IOU_THRESHOLD};
use crate::sim::latency::LatencyModel;
use crate::telemetry::tegrastats::ScheduleTrace;
use crate::video::dropframe::{DropFrameAccounting, FrameOutcome};
use crate::video::source::FrameSource;
use crate::DnnKind;

/// Configuration for the Chameleon-style baseline.
#[derive(Debug, Clone)]
pub struct ChameleonConfig {
    /// Re-profile every this many frames.
    pub window: u64,
    /// Keep a candidate if its F1 vs the heavyweight output ≥ this.
    pub f1_floor: f64,
}

impl Default for ChameleonConfig {
    fn default() -> Self {
        ChameleonConfig { window: 150, f1_floor: 0.75 }
    }
}

/// F1 agreement between candidate detections and reference detections
/// (the heavyweight's output as pseudo ground truth).
fn f1_vs_reference(cand: &[Detection], reference: &[Detection]) -> f64 {
    if reference.is_empty() {
        return if cand.is_empty() { 1.0 } else { 0.0 };
    }
    if cand.is_empty() {
        return 0.0;
    }
    let mut taken = vec![false; reference.len()];
    let mut tp = 0usize;
    for c in cand {
        let mut best: Option<(usize, f64)> = None;
        for (i, r) in reference.iter().enumerate() {
            if taken[i] {
                continue;
            }
            let iou = c.bbox.iou(&r.bbox);
            if iou >= IOU_THRESHOLD
                && best.map(|(_, b)| iou > b).unwrap_or(true)
            {
                best = Some((i, iou));
            }
        }
        if let Some((i, _)) = best {
            taken[i] = true;
            tp += 1;
        }
    }
    let precision = tp as f64 / cand.len() as f64;
    let recall = tp as f64 / reference.len() as f64;
    if precision + recall == 0.0 {
        0.0
    } else {
        2.0 * precision * recall / (precision + recall)
    }
}

/// Run the Chameleon-lite baseline over a sequence.
pub fn run_chameleon_lite(
    seq: &Sequence,
    detector: &mut dyn Detector,
    latency: &mut LatencyModel,
    eval_fps: f64,
    cfg: &ChameleonConfig,
) -> RunResult {
    let mut acc = DropFrameAccounting::new(eval_fps);
    let mut eval = SequenceEval::new();
    let mut trace = ScheduleTrace::default();
    let mut deploy = [0u64; DnnKind::COUNT];
    let mut switches = 0u64;
    let mut last_dnn: Option<DnnKind> = None;
    let mut carried: Vec<Detection> = Vec::new();
    let mut current = DnnKind::Y416; // until the first profile completes
    let mut mbbs_series = Vec::with_capacity(seq.n_frames() as usize);
    let mut dnn_series = Vec::with_capacity(seq.n_frames() as usize);
    let (fw, fh) = (seq.spec.width as f64, seq.spec.height as f64);
    let mut n_failed = 0u64;
    let mut failed_busy_s = 0.0f64;
    // a failed backend call marks the *frame* failed (n_failed counts
    // frames, matching RunResult::n_failed semantics — one profiling
    // frame issues several calls) and contributes an empty candidate
    // set; the baseline keeps running (panic-free serving discipline)
    fn detect_or_empty(
        det: &mut dyn Detector,
        frame_failed: &mut bool,
        f: u64,
        gt: &[GtEntry],
        k: DnnKind,
    ) -> Vec<Detection> {
        det.detect(f, gt, k).unwrap_or_else(|_| {
            *frame_failed = true;
            Vec::new()
        })
    }

    for frame in FrameSource::new(seq, eval_fps) {
        let profile_now = (frame.id - 1) % cfg.window == 0;
        let dnn = current;
        let total_time: f64 = if profile_now {
            // profiling runs ALL candidates back to back on this frame
            DnnKind::ALL.iter().map(|&k| latency.sample(k)).sum()
        } else {
            latency.sample(dnn)
        };
        let (outcome, interval) = acc.on_frame(frame.id, || total_time);
        match outcome {
            FrameOutcome::Inferred => {
                let mut frame_failed = false;
                if profile_now {
                    // evaluate every candidate against the heavyweight;
                    // a failed reference call keeps the carried set
                    // (carry-forward, like the session loop) instead of
                    // replacing it with nothing
                    let reference = match detector.detect(
                        frame.id,
                        frame.gt,
                        DnnKind::Y416,
                    ) {
                        Ok(raw) => {
                            FrameDetections {
                                frame: frame.id,
                                detections: raw,
                            }
                            .filtered()
                            .detections
                        }
                        Err(_) => {
                            frame_failed = true;
                            carried.clone()
                        }
                    };
                    let mut chosen = DnnKind::Y416;
                    for k in DnnKind::ALL {
                        // lightest first: first to pass the floor wins
                        let cand = FrameDetections {
                            frame: frame.id,
                            detections: detect_or_empty(
                                detector,
                                &mut frame_failed,
                                frame.id,
                                frame.gt,
                                k,
                            ),
                        }
                        .filtered()
                        .detections;
                        if f1_vs_reference(&cand, &reference) >= cfg.f1_floor {
                            chosen = k;
                            break;
                        }
                    }
                    current = chosen;
                    carried = reference; // best available output this frame
                    deploy[DnnKind::Y416.index()] += 1;
                } else {
                    match detector.detect(frame.id, frame.gt, dnn) {
                        Ok(raw) => {
                            carried = FrameDetections {
                                frame: frame.id,
                                detections: raw,
                            }
                            .filtered()
                            .detections;
                        }
                        // failed inference: keep the carried detections
                        Err(_) => frame_failed = true,
                    }
                    deploy[dnn.index()] += 1;
                }
                if frame_failed {
                    n_failed += 1;
                    if let Some((s, e)) = interval {
                        failed_busy_s += e - s;
                    }
                }
                if let Some((s, e)) = interval {
                    trace.push(s, e, if profile_now { DnnKind::Y416 } else { dnn });
                }
                let effective = if profile_now { DnnKind::Y416 } else { dnn };
                if let Some(prev) = last_dnn {
                    if prev != effective {
                        switches += 1;
                    }
                }
                last_dnn = Some(effective);
                dnn_series.push(Some(effective));
            }
            FrameOutcome::Dropped => dnn_series.push(None),
        }
        mbbs_series.push(crate::detection::mbbs(&carried, fw, fh));
        eval.push(&match_frame(&carried, frame.gt, IOU_THRESHOLD));
    }
    trace.duration = trace.duration.max(seq.n_frames() as f64 / eval_fps);

    RunResult {
        policy: format!("chameleon-lite{{w={}}}", cfg.window),
        sequence: seq.spec.name.clone(),
        fps: eval_fps,
        ap: eval.ap(ApMethod::AllPoint),
        n_frames: seq.n_frames(),
        n_inferred: acc.n_inferred(),
        n_dropped: acc.n_dropped(),
        n_failed,
        failed_busy_s,
        deploy_counts: deploy,
        switches,
        power: crate::power::EnergyMeter::from_trace(&trace).summary(),
        trace,
        mbbs_series,
        dnn_series,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::MbbsPolicy;
    use crate::coordinator::scheduler::{run_realtime, OracleBackend};
    use crate::dataset::synth::{CameraMotion, SequenceSpec};
    use crate::geometry::BBox;
    use crate::sim::oracle::OracleDetector;

    fn det(x: f64, score: f32) -> Detection {
        Detection::new(
            BBox::new(x, 0.0, 10.0, 10.0),
            score,
            crate::detection::PERSON_CLASS,
        )
    }

    #[test]
    fn f1_perfect_and_empty() {
        let a = vec![det(0.0, 0.9), det(50.0, 0.8)];
        assert!((f1_vs_reference(&a, &a) - 1.0).abs() < 1e-12);
        assert_eq!(f1_vs_reference(&[], &a), 0.0);
        assert_eq!(f1_vs_reference(&a, &[]), 0.0);
        assert_eq!(f1_vs_reference(&[], &[]), 1.0);
    }

    #[test]
    fn f1_half_recall() {
        let reference = vec![det(0.0, 0.9), det(50.0, 0.9)];
        let cand = vec![det(0.0, 0.9)];
        // precision 1, recall 0.5 -> f1 = 2/3
        assert!((f1_vs_reference(&cand, &reference) - 2.0 / 3.0).abs() < 1e-12);
    }

    fn seq(ref_height: f64, camera: CameraMotion) -> Sequence {
        Sequence::generate(SequenceSpec {
            name: "CHAM".into(),
            width: 960,
            height: 540,
            fps: 30.0,
            frames: 240,
            density: 8,
            ref_height,
            depth_range: (1.0, 2.0),
            walk_speed: 1.5,
            camera,
            seed: 5,
        })
    }

    #[test]
    fn chameleon_profiling_costs_frames() {
        let s = seq(300.0, CameraMotion::Static);
        let mut det = OracleBackend(OracleDetector::new(5, 960.0, 540.0));
        let mut lat = LatencyModel::deterministic();
        let r = run_chameleon_lite(
            &s,
            &mut det,
            &mut lat,
            30.0,
            &ChameleonConfig { window: 60, f1_floor: 0.75 },
        );
        // every profile burns ~0.32 s ≈ 9+ frames at 30 FPS
        assert!(r.n_dropped > 20, "profiling must drop frames: {}", r.n_dropped);
        assert_eq!(r.n_inferred + r.n_dropped, r.n_frames);
    }

    #[test]
    fn tod_beats_chameleon_on_large_objects() {
        // the paper's §II/§V argument: periodic heavyweight profiling
        // costs accuracy that TOD's proactive selection keeps
        let s = seq(320.0, CameraMotion::Walking { pan_speed: 5.0 });
        let mk = || OracleBackend(OracleDetector::new(5, 960.0, 540.0));
        let mut lat = LatencyModel::deterministic();
        let r_ch = run_chameleon_lite(
            &s,
            &mut mk(),
            &mut lat,
            30.0,
            &ChameleonConfig::default(),
        );
        let mut tod = MbbsPolicy::tod_default();
        let mut lat2 = LatencyModel::deterministic();
        let r_tod = run_realtime(&s, &mut tod, &mut mk(), &mut lat2, 30.0);
        assert!(
            r_tod.ap >= r_ch.ap - 0.02,
            "TOD {} should not lose to chameleon-lite {}",
            r_tod.ap,
            r_ch.ap
        );
    }
}
