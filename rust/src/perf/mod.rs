//! Performance measurement layer: the counting allocator, the canonical
//! bench suite and the versioned `BENCH_<n>.json` regression gate.
//!
//! Three pieces (see DESIGN.md §13):
//!
//! * [`alloc`] — a [`alloc::CountingAllocator`] installed as the crate's
//!   `#[global_allocator]`; thread-local counters make allocs/op a
//!   deterministic, noise-free metric.
//! * [`suite`] — [`suite::run_suite`] executes every hot-path scenario
//!   (NMS, matching, AP, features, selection, session step, multi-stream
//!   schedules) under the [`crate::bench`] harness.
//! * [`report`] — [`report::BenchReport`] serialises a run, loads the
//!   committed baseline and gates regressions: `min_ns` within 15%,
//!   allocs/op never up. `null` baseline metrics are record-only
//!   (bootstrap semantics for baselines authored without a toolchain).
//!   Every report stamps a `comment` provenance line
//!   ([`suite::default_provenance`], overridable with `--comment`) so a
//!   committed baseline says which machine/profile produced its numbers.
//!
//! Driven by `tod bench [--json] [--out PATH] [--baseline PATH] [--check]
//! [--comment TEXT]`.

pub mod alloc;
pub mod report;
pub mod suite;

pub use alloc::{count_allocs, AllocDelta, CountingAllocator};
pub use report::{BenchDiff, BenchReport, CaseReport, DEFAULT_TOLERANCE};
pub use suite::{run_suite, SuiteOptions, SUITE_GENERATION};
