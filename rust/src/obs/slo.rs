//! Rolling-window SLO watchdog over a recorded event trace
//! (DESIGN.md §15).
//!
//! [`check_events`] replays a trace offline and evaluates a
//! [`SloSpec`] — windowed p99 end-to-end frame latency, drop rate,
//! a projected-accuracy proxy and mean board watts — at every
//! frame-presentation tick once the first full window has elapsed.
//! Signal transitions are edge-triggered with hysteresis: crossing a
//! limit emits one [`crate::obs::Event::SloBreach`], and the signal
//! must come back *inside* the limit by a relative margin before
//! [`crate::obs::Event::SloRecovered`] fires, so a value oscillating
//! on the limit does not flap.
//!
//! Evaluation is a pure function of the event stream: the same trace
//! (same seed) yields the same report, which is what lets
//! `tod slo check` be pinned by golden scenario tests and run as a CI
//! gate. All timestamps are virtual board seconds.

use crate::obs::Event;
use crate::sim::profiles::{DnnProfile, POWER_IDLE_W};

/// Which windowed health signal an SLO event refers to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SloSignal {
    /// p99 of end-to-end frame latency (capture → inference end), s.
    LatencyP99,
    /// Dropped + shed frames as a fraction of presented frames.
    DropRate,
    /// Detection-freshness proxy for projected AP (higher is better).
    ApProxy,
    /// Mean board power over the window, watts.
    Watts,
}

impl SloSignal {
    /// All signals, evaluation order.
    pub const ALL: [SloSignal; 4] = [
        SloSignal::LatencyP99,
        SloSignal::DropRate,
        SloSignal::ApProxy,
        SloSignal::Watts,
    ];

    /// Stable label used in traces and `tod slo check` output.
    pub fn label(self) -> &'static str {
        match self {
            SloSignal::LatencyP99 => "latency_p99",
            SloSignal::DropRate => "drop_rate",
            SloSignal::ApProxy => "ap_proxy",
            SloSignal::Watts => "watts",
        }
    }

    /// Inverse of [`SloSignal::label`] (trace parsing).
    pub fn from_label(s: &str) -> Option<SloSignal> {
        SloSignal::ALL.iter().copied().find(|k| k.label() == s)
    }

    fn index(self) -> usize {
        match self {
            SloSignal::LatencyP99 => 0,
            SloSignal::DropRate => 1,
            SloSignal::ApProxy => 2,
            SloSignal::Watts => 3,
        }
    }
}

impl std::fmt::Display for SloSignal {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// Relative hysteresis margin: a breached signal recovers only once it
/// is back inside its limit by this fraction.
const HYSTERESIS: f64 = 0.02;

/// Rolling-window health limits. `None` disables a signal. Defaults are
/// deliberately generous — they flag a pipeline that has fallen over
/// (saturated device, runaway drops, starved detections), not one that
/// is merely busy. In particular the drop-rate limit sits at 0.9:
/// skipping frames while the accelerator is busy is the paper's
/// operating model (a heavy net at 30 fps legitimately drops ~3 of 4
/// frames), so only a near-total drop-out is a health failure.
#[derive(Debug, Clone, PartialEq)]
pub struct SloSpec {
    /// Rolling window length, virtual seconds.
    pub window_s: f64,
    /// Upper bound on windowed p99 end-to-end frame latency, seconds.
    pub latency_p99_s: Option<f64>,
    /// Upper bound on windowed drop rate (0..=1).
    pub max_drop_rate: Option<f64>,
    /// Lower bound on the windowed detection-freshness AP proxy.
    pub min_ap_proxy: Option<f64>,
    /// Upper bound on mean board watts over the window (the scenario's
    /// power budget, when it has one).
    pub watts_cap: Option<f64>,
}

impl Default for SloSpec {
    fn default() -> Self {
        SloSpec {
            window_s: 2.0,
            latency_p99_s: Some(0.5),
            max_drop_rate: Some(0.9),
            min_ap_proxy: Some(0.2),
            watts_cap: None,
        }
    }
}

impl SloSpec {
    /// Default spec plus a board power cap (scenario budget), watts.
    pub fn with_watts_cap(mut self, watts: f64) -> Self {
        self.watts_cap = Some(watts);
        self
    }
}

/// Result of [`check_events`]: the synthesized SLO transition events
/// plus evaluation counters.
#[derive(Debug, Clone, PartialEq)]
pub struct SloReport {
    /// `SloBreach` / `SloRecovered` transitions, evaluation order.
    pub events: Vec<Event>,
    /// Breach transitions (count of `SloBreach` events).
    pub breaches: u64,
    /// (tick, signal) evaluations performed.
    pub checks: u64,
}

impl SloReport {
    /// True when at least one signal crossed its limit.
    pub fn breached(&self) -> bool {
        self.breaches > 0
    }

    /// Breach transitions for one signal.
    pub fn breaches_of(&self, signal: SloSignal) -> u64 {
        self.events
            .iter()
            .filter(|ev| {
                matches!(ev, Event::SloBreach { signal: s, .. } if *s == signal)
            })
            .count() as u64
    }
}

/// Mutable per-stream freshness state for the AP proxy.
#[derive(Default)]
struct StreamFreshness {
    /// Presented frames since the last successful inference.
    age: u64,
}

/// Evaluate `spec` over a recorded trace. Events are stable-sorted by
/// timestamp first, so recorder interleaving across streams does not
/// matter. Returns the transitions and counters; the input events are
/// not modified.
pub fn check_events(events: &[Event], spec: &SloSpec) -> SloReport {
    let mut evs: Vec<Event> = events.to_vec();
    evs.sort_by(|a, b| a.time().total_cmp(&b.time()));

    let w = spec.window_s.max(1e-6);
    let t_first = evs.first().map(|e| e.time()).unwrap_or(0.0);

    // Window sample stores, each (timestamp, value). Offline replay:
    // allocation is fine here.
    let mut latency: Vec<(f64, f64)> = Vec::new();
    let mut presented: Vec<f64> = Vec::new();
    let mut dropped: Vec<f64> = Vec::new();
    let mut freshness: Vec<(f64, f64)> = Vec::new();
    // inference intervals (start, end, active watts)
    let mut busy: Vec<(f64, f64, f64)> = Vec::new();
    // (stream, frame) -> capture time, for end-to-end latency
    let mut capture: std::collections::BTreeMap<(u32, u64), f64> =
        std::collections::BTreeMap::new();
    let mut fresh: std::collections::BTreeMap<u32, StreamFreshness> =
        std::collections::BTreeMap::new();

    let mut report =
        SloReport { events: Vec::new(), breaches: 0, checks: 0 };
    // per-signal latched breach state
    let mut in_breach = [false; 4];
    let mut scratch: Vec<f64> = Vec::new();

    for ev in &evs {
        match *ev {
            Event::FramePresented { stream, frame, t } => {
                presented.push(t);
                capture.insert((stream, frame), t);
                let st = fresh.entry(stream).or_default();
                freshness.push((t, 1.0 / (1.0 + st.age as f64)));
                st.age += 1;
            }
            Event::FrameInferred { stream, frame, dnn, start, end } => {
                let t0 =
                    capture.get(&(stream, frame)).copied().unwrap_or(start);
                latency.push((end, end - t0));
                busy.push((start, end, DnnProfile::of(dnn).power_active_w));
                fresh.entry(stream).or_default().age = 0;
            }
            Event::InferenceFailed { stream, frame, dnn, start, end } => {
                // device time was spent and the frame completed its
                // pipeline pass, but detections did not refresh
                let t0 =
                    capture.get(&(stream, frame)).copied().unwrap_or(start);
                latency.push((end, end - t0));
                busy.push((start, end, DnnProfile::of(dnn).power_active_w));
            }
            Event::FrameDropped { t, .. } | Event::BatchShed { t, .. } => {
                dropped.push(t);
            }
            _ => {}
        }

        // Evaluate at presentation ticks once the first window is full
        // (a partial window would report startup transients).
        let Event::FramePresented { stream, t, .. } = *ev else {
            continue;
        };
        if t - t_first + 1e-9 < w {
            continue;
        }
        let lo = t - w;
        let win = |ts: f64| ts > lo + 1e-9 && ts <= t + 1e-9;

        let mut observed = [None; 4];
        if spec.latency_p99_s.is_some() {
            scratch.clear();
            scratch.extend(
                latency.iter().filter(|&&(ts, _)| win(ts)).map(|&(_, v)| v),
            );
            if !scratch.is_empty() {
                scratch.sort_by(f64::total_cmp);
                let idx = ((scratch.len() as f64) * 0.99).ceil() as usize;
                let idx = idx.saturating_sub(1).min(scratch.len() - 1);
                observed[SloSignal::LatencyP99.index()] =
                    scratch.get(idx).copied();
            }
        }
        if spec.max_drop_rate.is_some() {
            let shown =
                presented.iter().filter(|&&ts| win(ts)).count() as f64;
            let lost = dropped.iter().filter(|&&ts| win(ts)).count() as f64;
            if shown > 0.0 {
                observed[SloSignal::DropRate.index()] = Some(lost / shown);
            }
        }
        if spec.min_ap_proxy.is_some() {
            let (mut sum, mut n) = (0.0, 0u64);
            for &(ts, v) in &freshness {
                if win(ts) {
                    sum += v;
                    n += 1;
                }
            }
            if n > 0 {
                observed[SloSignal::ApProxy.index()] = Some(sum / n as f64);
            }
        }
        if spec.watts_cap.is_some() {
            let mut active_ws = 0.0; // watt-seconds above idle
            for &(s, e, active_w) in &busy {
                let overlap = (e.min(t) - s.max(lo)).max(0.0);
                active_ws += overlap * (active_w - POWER_IDLE_W);
            }
            observed[SloSignal::Watts.index()] =
                Some(POWER_IDLE_W + active_ws / w);
        }

        for signal in SloSignal::ALL {
            // (limit, true = value must stay below the limit)
            let (limit, upper) = match signal {
                SloSignal::LatencyP99 => (spec.latency_p99_s, true),
                SloSignal::DropRate => (spec.max_drop_rate, true),
                SloSignal::ApProxy => (spec.min_ap_proxy, false),
                SloSignal::Watts => (spec.watts_cap, true),
            };
            let (Some(limit), Some(value)) =
                (limit, observed[signal.index()])
            else {
                continue;
            };
            report.checks += 1;
            let latched = &mut in_breach[signal.index()];
            let (breach_now, recovered_now) = if upper {
                (value > limit, value <= limit * (1.0 - HYSTERESIS))
            } else {
                (value < limit, value >= limit * (1.0 + HYSTERESIS))
            };
            if breach_now && !*latched {
                *latched = true;
                report.breaches += 1;
                report.events.push(Event::SloBreach {
                    stream,
                    t,
                    signal,
                    value,
                    limit,
                });
            } else if recovered_now && *latched {
                *latched = false;
                report.events.push(Event::SloRecovered {
                    stream,
                    t,
                    signal,
                    value,
                    limit,
                });
            }
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::DnnKind;

    fn presented(stream: u32, frame: u64, t: f64) -> Event {
        Event::FramePresented { stream, frame, t }
    }

    fn inferred(stream: u32, frame: u64, start: f64, end: f64) -> Event {
        Event::FrameInferred {
            stream,
            frame,
            dnn: DnnKind::Y416,
            start,
            end,
        }
    }

    /// 30 fps stream, every frame inferred quickly on the big net.
    fn busy_trace(seconds: f64) -> Vec<Event> {
        let mut evs = Vec::new();
        let frames = (seconds * 30.0) as u64;
        for i in 0..frames {
            let t = i as f64 / 30.0;
            evs.push(presented(0, i + 1, t));
            evs.push(inferred(0, i + 1, t, t + 0.030));
        }
        evs
    }

    #[test]
    fn signal_labels_roundtrip_and_are_unique() {
        for s in SloSignal::ALL {
            assert_eq!(SloSignal::from_label(s.label()), Some(s));
            assert_eq!(format!("{s}"), s.label());
        }
        assert_eq!(SloSignal::from_label("bogus"), None);
        let mut labels: Vec<&str> =
            SloSignal::ALL.iter().map(|s| s.label()).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), SloSignal::ALL.len());
    }

    #[test]
    fn healthy_trace_passes_default_spec() {
        let report = check_events(&busy_trace(6.0), &SloSpec::default());
        assert!(!report.breached(), "events: {:?}", report.events);
        assert!(report.checks > 0);
        assert!(report.events.is_empty());
    }

    #[test]
    fn watts_cap_breach_fires_once_then_recovers() {
        // Y416 back to back keeps the device ~100% busy at 7.5 W;
        // cap it at 5.0 W and the breach must latch exactly once.
        let mut evs = Vec::new();
        let mut t = 0.0;
        let mut frame = 1;
        while t < 6.0 {
            evs.push(presented(0, frame, t));
            evs.push(inferred(0, frame, t, t + 0.153));
            t += 0.153;
            frame += 1;
        }
        // then a long idle tail: frames presented, nothing dispatched
        // (no drops either — the comparison here is only about watts)
        while t < 14.0 {
            evs.push(presented(0, frame, t));
            evs.push(inferred(0, frame, t, t + 0.001));
            t += 1.0 / 30.0;
            frame += 1;
        }
        let spec = SloSpec {
            latency_p99_s: None,
            max_drop_rate: None,
            min_ap_proxy: None,
            ..SloSpec::default().with_watts_cap(5.0)
        };
        let report = check_events(&evs, &spec);
        assert_eq!(report.breaches, 1, "events: {:?}", report.events);
        assert_eq!(report.breaches_of(SloSignal::Watts), 1);
        assert!(report.breached());
        // the idle tail brings mean watts back under the cap
        let kinds: Vec<&'static str> =
            report.events.iter().map(|e| e.type_tag()).collect();
        assert_eq!(kinds, vec!["slo_breach", "slo_recovered"]);
    }

    #[test]
    fn drop_storm_breaches_drop_rate() {
        let mut evs = Vec::new();
        for i in 0..120u64 {
            let t = i as f64 / 30.0;
            evs.push(presented(0, i + 1, t));
            // three of four frames dropped
            if i % 4 == 0 {
                evs.push(inferred(0, i + 1, t, t + 0.03));
            } else {
                evs.push(Event::FrameDropped {
                    stream: 0,
                    frame: i + 1,
                    t,
                    busy_until: t + 0.1,
                });
            }
        }
        let spec = SloSpec {
            latency_p99_s: None,
            max_drop_rate: Some(0.5),
            min_ap_proxy: None,
            ..SloSpec::default()
        };
        let report = check_events(&evs, &spec);
        assert!(report.breaches_of(SloSignal::DropRate) >= 1);
        // the routine-skipping default (0.9) tolerates the same trace
        let report = check_events(&evs, &SloSpec::default());
        assert_eq!(report.breaches_of(SloSignal::DropRate), 0);
    }

    #[test]
    fn starved_detections_breach_the_ap_proxy() {
        // frames keep arriving but nothing ever infers: freshness decays
        let mut evs = Vec::new();
        for i in 0..240u64 {
            evs.push(presented(0, i + 1, i as f64 / 30.0));
        }
        let spec = SloSpec {
            latency_p99_s: None,
            max_drop_rate: None,
            ..SloSpec::default()
        };
        let report = check_events(&evs, &spec);
        assert!(report.breaches_of(SloSignal::ApProxy) >= 1);
    }

    #[test]
    fn slow_end_to_end_latency_breaches_p99() {
        // inference ends 0.8 s after capture (dispatch queue backlog)
        let mut evs = Vec::new();
        for i in 0..180u64 {
            let t = i as f64 / 30.0;
            evs.push(presented(0, i + 1, t));
            evs.push(inferred(0, i + 1, t + 0.7, t + 0.8));
        }
        let spec = SloSpec {
            max_drop_rate: None,
            min_ap_proxy: None,
            ..SloSpec::default()
        };
        let report = check_events(&evs, &spec);
        assert!(report.breaches_of(SloSignal::LatencyP99) >= 1);
    }

    #[test]
    fn report_is_deterministic_and_order_insensitive() {
        let evs = busy_trace(5.0);
        let spec = SloSpec::default().with_watts_cap(6.0);
        let a = check_events(&evs, &spec);
        let b = check_events(&evs, &spec);
        assert_eq!(a, b);
        // reversing the input changes nothing: events are re-sorted
        let mut rev = evs.clone();
        rev.reverse();
        assert_eq!(check_events(&rev, &spec), a);
    }

    #[test]
    fn empty_trace_yields_an_empty_report() {
        let report = check_events(&[], &SloSpec::default());
        assert!(!report.breached());
        assert_eq!(report.checks, 0);
        assert!(report.events.is_empty());
    }
}
