//! The canonical bench suite behind `tod bench`.
//!
//! One function, [`run_suite`], executes every hot-path scenario the
//! standalone `rust/benches/*` binaries cover — NMS, IoU, greedy
//! matching, AP pooling, feature extraction, selection, the per-frame
//! session step and a whole multi-stream schedule — under the
//! deterministic [`crate::bench`] harness, and returns a
//! [`BenchReport`] ready to diff against the committed `BENCH_<n>.json`
//! baseline. Case names are a contract: the baseline pins the suite's
//! shape, so renaming a case is a schema change (record a new baseline
//! in the same PR).
//!
//! allocs/op is measured per case by running the closure under
//! [`crate::perf::alloc::count_allocs`] *after* a short warmup, so
//! steady-state scratch reuse shows up as 0 even when first-call setup
//! allocates.

use crate::bench::{black_box, Bench};
use crate::coordinator::policy::MbbsPolicy;
use crate::coordinator::projected::ProjectedAccuracyPolicy;
use crate::coordinator::multistream::{
    DispatchPolicy, MultiStreamScheduler,
};
use crate::coordinator::scheduler::OracleBackend;
use crate::coordinator::session::{SessionEvent, StreamSession};
use crate::dataset::catalog::{generate, SequenceId};
use crate::detection::{nms, Detection, PERSON_CLASS};
use crate::eval::ap::{ApMethod, SequenceEval};
use crate::eval::matching::{match_frame, FrameMatcher, IOU_THRESHOLD};
use crate::features::FeatureExtractor;
use crate::geometry::BBox;
use crate::obs::{shared, FlightRecorder, NullRecorder, SharedRecorder};
use crate::perf::alloc::count_allocs;
use crate::perf::report::{BenchReport, CaseReport};
use crate::predictor::{calibrate, CalibrationConfig};
use crate::sim::latency::{ContentionModel, LatencyModel};
use crate::sim::oracle::OracleDetector;
use crate::util::rng::Rng;
use crate::DnnKind;

/// Current report generation: the `<n>` of the committed `BENCH_<n>.json`.
pub const SUITE_GENERATION: u32 = 6;

/// Iterations measured under the allocation counter per case.
const ALLOC_ITERS: u64 = 64;

/// Suite configuration (CLI flags map 1:1).
#[derive(Debug, Clone, Default)]
pub struct SuiteOptions {
    /// Short target per case (~8x faster, noisier): CI and smoke runs.
    pub quick: bool,
    /// Only run cases whose name contains this substring. A filtered
    /// report fails a full-baseline diff (missing cases) by design.
    pub filter: Option<String>,
}

struct Suite {
    bench: Bench,
    filter: Option<String>,
    cases: Vec<CaseReport>,
}

impl Suite {
    fn new(opts: &SuiteOptions) -> Self {
        let mut bench = Bench::new();
        if opts.quick {
            bench.target = std::time::Duration::from_millis(90);
            bench.warmup = std::time::Duration::from_millis(20);
        }
        Suite { bench, filter: opts.filter.clone(), cases: Vec::new() }
    }

    /// Register + run one case: allocs/op first (doubles as scratch
    /// warmup), then the timing loop.
    fn case(&mut self, name: &str, mut f: impl FnMut()) {
        if let Some(flt) = &self.filter {
            if !name.contains(flt.as_str()) {
                return;
            }
        }
        for _ in 0..16 {
            f();
        }
        let (d, _) = count_allocs(|| {
            for _ in 0..ALLOC_ITERS {
                f();
            }
        });
        let allocs = d.allocs as f64 / ALLOC_ITERS as f64;
        let r = self.bench.case(name, f).clone();
        self.cases.push(CaseReport {
            name: r.name,
            iters: r.iters as u64,
            mean_ns: Some(r.mean_ns),
            p50_ns: Some(r.p50_ns),
            min_ns: Some(r.min_ns),
            allocs_per_op: Some(allocs),
            ops_per_s: if r.mean_ns > 0.0 {
                Some(1e9 / r.mean_ns)
            } else {
                None
            },
        });
    }

    fn finish(self, mode: &str) -> BenchReport {
        BenchReport {
            generation: SUITE_GENERATION,
            mode: mode.to_string(),
            comment: Some(default_provenance()),
            cases: self.cases,
        }
    }
}

/// Machine/toolchain provenance stamped into every report this binary
/// writes, so a committed baseline's numbers are interpretable later.
/// Only compile-time facts — no wall clock, no hostname — so the same
/// binary always stamps the same string.
pub fn default_provenance() -> String {
    format!(
        "recorded by tod bench: target {}-{}, {} build; pin protocol: \
         run `tod bench --out BENCH_{}.json` on the reference machine \
         and commit the result",
        std::env::consts::ARCH,
        std::env::consts::OS,
        if cfg!(debug_assertions) { "debug" } else { "release" },
        SUITE_GENERATION,
    )
}

/// Mixed-class detection set with MOT-like box geometry.
fn synth_dets(n: usize, seed: u64) -> Vec<Detection> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            Detection::new(
                BBox::new(
                    rng.uniform(0.0, 1800.0),
                    rng.uniform(0.0, 1000.0),
                    rng.uniform(10.0, 120.0),
                    rng.uniform(20.0, 280.0),
                ),
                rng.uniform(0.2, 1.0) as f32,
                if i % 7 == 0 { 2 } else { PERSON_CLASS },
            )
        })
        .collect()
}

fn shifted(dets: &[Detection], dx: f64, dy: f64) -> Vec<Detection> {
    dets.iter()
        .map(|d| Detection::new(d.bbox.shifted(dx, dy), d.score, d.class_id))
        .collect()
}

/// Run the full suite and collect a report.
pub fn run_suite(opts: &SuiteOptions) -> BenchReport {
    let mut s = Suite::new(opts);

    // -- detection: NMS + pairwise IoU -----------------------------------
    for n in [16usize, 64] {
        let dets = synth_dets(n, 11 + n as u64);
        s.case(&format!("detection/nms/n={n}"), || {
            black_box(nms(black_box(&dets), 0.5));
        });
    }
    {
        let dets = synth_dets(32, 7);
        s.case("detection/iou_matrix/n=32", || {
            let mut acc = 0.0f64;
            for a in &dets {
                for b in &dets {
                    acc += a.bbox.iou(&b.bbox);
                }
            }
            black_box(acc);
        });
    }

    // -- eval: greedy matching + AP pooling ------------------------------
    let seq = generate(SequenceId::Mot04);
    let oracle = OracleDetector::new(
        seq.spec.seed,
        seq.spec.width as f64,
        seq.spec.height as f64,
    );
    {
        let gt = seq.gt(10);
        let dets = oracle.detect(10, gt, DnnKind::Y416);
        s.case("eval/match_frame", || {
            black_box(match_frame(
                black_box(&dets),
                black_box(gt),
                IOU_THRESHOLD,
            ));
        });
        let mut matcher = FrameMatcher::new();
        let mut eval = SequenceEval::new();
        s.case("eval/matcher_steady", || {
            eval.clear();
            matcher.match_into(
                black_box(&dets),
                black_box(gt),
                IOU_THRESHOLD,
                &mut eval,
            );
            black_box(eval.n_scored());
        });
    }
    {
        let mut eval = SequenceEval::new();
        for f in 1..=60u64 {
            let gt = seq.gt(f);
            let dets = oracle.detect(f, gt, DnnKind::TinyY416);
            eval.push(&match_frame(&dets, gt, IOU_THRESHOLD));
        }
        s.case("eval/ap_all_point", || {
            black_box(eval.ap(ApMethod::AllPoint));
        });
    }

    // -- features: extraction + per-frame decision -----------------------
    {
        let dets = synth_dets(42, 42);
        let snap = shifted(&dets, 6.0, 1.0);
        let mut fx = FeatureExtractor::new(1920.0, 1080.0);
        let mut frame = 0u64;
        s.case("features/on_detections/n=42", || {
            frame += 1;
            let cur = if frame % 2 == 0 { &dets } else { &snap };
            fx.on_detections(frame, black_box(cur));
        });
        let policy = MbbsPolicy::tod_default();
        s.case("features/frame_decision/n=42", || {
            let f = fx.features(black_box(&dets));
            black_box(policy.select_pure(f.mbbs));
        });
    }

    // -- predictor: table projection -------------------------------------
    {
        let table = calibrate(&CalibrationConfig::quick(30.0));
        let projected = ProjectedAccuracyPolicy::new(
            table.clone(),
            &LatencyModel::deterministic(),
        );
        s.case("predictor/project", || {
            black_box(table.project(
                black_box(DnnKind::Y416),
                black_box(0.012),
                black_box(0.008),
            ));
        });
        let f = crate::features::FrameFeatures {
            mbbs: 0.012,
            count: 20,
            density: 0.2,
            speed: 0.008,
        };
        s.case("predictor/select", || {
            black_box(projected.select_pure(black_box(&f)));
        });
    }

    // -- coordinator: the per-frame session step -------------------------
    {
        let step_seq = generate(SequenceId::Mot02);
        let mut det = OracleBackend(OracleDetector::new(
            step_seq.spec.seed,
            step_seq.spec.width as f64,
            step_seq.spec.height as f64,
        ));
        let mut lat = LatencyModel::deterministic();
        let mut sess =
            StreamSession::new(&step_seq, MbbsPolicy::tod_default(), 30.0);
        s.case("session/step", || {
            if matches!(
                sess.step(&mut det, &mut lat),
                SessionEvent::Finished
            ) {
                // stream exhausted mid-measurement: reopen (allocates,
                // but only once per full sequence of steps)
                sess = StreamSession::new(
                    &step_seq,
                    MbbsPolicy::tod_default(),
                    30.0,
                );
                black_box(sess.step(&mut det, &mut lat));
            }
        });
    }

    // -- obs: the recorded step (event + span emission overhead) ---------
    // same step loop as `session/step`, with the emit path live: the
    // delta against the bare case is the whole observability tax
    for flight in [false, true] {
        let label = if flight { "flight" } else { "null" };
        let make_rec = move || -> SharedRecorder {
            if flight {
                shared(FlightRecorder::new(4096))
            } else {
                shared(NullRecorder)
            }
        };
        let step_seq = generate(SequenceId::Mot02);
        let mut det = OracleBackend(OracleDetector::new(
            step_seq.spec.seed,
            step_seq.spec.width as f64,
            step_seq.spec.height as f64,
        ));
        let mut lat = LatencyModel::deterministic();
        let mut sess =
            StreamSession::new(&step_seq, MbbsPolicy::tod_default(), 30.0)
                .with_recorder(make_rec(), 0, 0.0);
        s.case(&format!("session/step_recorded/{label}"), || {
            if matches!(
                sess.step(&mut det, &mut lat),
                SessionEvent::Finished
            ) {
                sess = StreamSession::new(
                    &step_seq,
                    MbbsPolicy::tod_default(),
                    30.0,
                )
                .with_recorder(make_rec(), 0, 0.0);
                black_box(sess.step(&mut det, &mut lat));
            }
        });
    }

    // -- coordinator: whole multi-stream schedules -----------------------
    {
        let seqs: Vec<(SequenceId, crate::dataset::synth::Sequence)> =
            SequenceId::ALL.iter().map(|&id| (id, generate(id))).collect();
        for (label, dispatch) in [
            ("rr", DispatchPolicy::RoundRobin),
            ("edf", DispatchPolicy::EarliestDeadlineFirst),
        ] {
            s.case(&format!("multistream/{label}_4stream"), || {
                let mut sched = MultiStreamScheduler::new(
                    dispatch,
                    ContentionModel::jetson_nano(),
                    LatencyModel::deterministic(),
                );
                for i in 0..4 {
                    let (id, sq) = &seqs[i % seqs.len()];
                    let backend = OracleBackend(OracleDetector::new(
                        sq.spec.seed,
                        sq.spec.width as f64,
                        sq.spec.height as f64,
                    ));
                    sched.add_stream(
                        StreamSession::new(
                            sq,
                            MbbsPolicy::tod_default(),
                            id.eval_fps(),
                        ),
                        Box::new(backend),
                    );
                }
                black_box(sched.run());
            });
        }
    }

    s.finish(if opts.quick { "quick" } else { "full" })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The suite must run end to end and report every case with finite
    /// numbers; keep this fast by filtering to the cheapest case.
    #[test]
    fn filtered_suite_reports_pinnable_numbers() {
        let opts = SuiteOptions {
            quick: true,
            filter: Some("predictor/select".to_string()),
        };
        let r = run_suite(&opts);
        assert_eq!(r.cases.len(), 1);
        let c = &r.cases[0];
        assert_eq!(c.name, "predictor/select");
        assert!(c.mean_ns.unwrap() > 0.0);
        assert!(c.min_ns.unwrap() <= c.mean_ns.unwrap());
        assert!(c.allocs_per_op.unwrap() >= 0.0);
    }

    /// Case names are a contract with the committed baseline.
    #[test]
    fn suite_shape_is_stable() {
        // cheap structural check: the names the baseline pins must all
        // be produced by a full (unfiltered) suite. We don't run the
        // timing loops here — just assert the name list below matches
        // the one `run_suite` registers (kept in one place on purpose).
        assert_eq!(SUITE_CASE_NAMES.len(), 15);
        let mut sorted = SUITE_CASE_NAMES.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), SUITE_CASE_NAMES.len(), "duplicate names");
    }
}

/// Every case name `run_suite` registers, in registration order — the
/// shape contract `BENCH_<n>.json` pins (see `report.rs` bootstrap
/// semantics).
pub const SUITE_CASE_NAMES: [&str; 15] = [
    "detection/nms/n=16",
    "detection/nms/n=64",
    "detection/iou_matrix/n=32",
    "eval/match_frame",
    "eval/matcher_steady",
    "eval/ap_all_point",
    "features/on_detections/n=42",
    "features/frame_decision/n=42",
    "predictor/project",
    "predictor/select",
    "session/step",
    "session/step_recorded/null",
    "session/step_recorded/flight",
    "multistream/rr_4stream",
    "multistream/edf_4stream",
];
