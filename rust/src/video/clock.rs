//! Virtual frame clock for a fixed-FPS stream.

/// Maps 1-based frame ids to arrival timestamps for a fixed frame rate.
#[derive(Debug, Clone, Copy)]
pub struct FrameClock {
    fps: f64,
}

impl FrameClock {
    pub fn new(fps: f64) -> Self {
        assert!(fps > 0.0, "fps must be positive");
        FrameClock { fps }
    }

    pub fn fps(&self) -> f64 {
        self.fps
    }

    /// Seconds between consecutive frames.
    pub fn period(&self) -> f64 {
        1.0 / self.fps
    }

    /// Arrival time of a 1-based frame id. The paper's Algorithm 2 uses
    /// `Frame#/FPS`, i.e. frame 1 arrives at 1/FPS.
    pub fn arrival(&self, frame: u64) -> f64 {
        frame as f64 / self.fps
    }

    /// The latest frame that has arrived by time `t` (0 if none).
    pub fn frame_at(&self, t: f64) -> u64 {
        if t < 0.0 {
            return 0;
        }
        (t * self.fps).floor() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn period_and_arrival() {
        let c = FrameClock::new(30.0);
        assert!((c.period() - 1.0 / 30.0).abs() < 1e-12);
        assert!((c.arrival(30) - 1.0).abs() < 1e-12);
        assert!((c.arrival(1) - 1.0 / 30.0).abs() < 1e-12);
    }

    #[test]
    fn frame_at_inverts_arrival() {
        let c = FrameClock::new(14.0);
        for f in 1..100u64 {
            assert_eq!(c.frame_at(c.arrival(f) + 1e-9), f);
        }
        assert_eq!(c.frame_at(-1.0), 0);
        assert_eq!(c.frame_at(0.0), 0);
    }

    #[test]
    #[should_panic(expected = "fps must be positive")]
    fn zero_fps_rejected() {
        FrameClock::new(0.0);
    }

    #[test]
    #[should_panic(expected = "fps must be positive")]
    fn negative_fps_rejected() {
        FrameClock::new(-30.0);
    }

    #[test]
    fn exact_boundary_maps_to_the_arriving_frame() {
        // power-of-two fps: arrivals are exact binary floats, so the
        // boundary behaviour is deterministic (no epsilon needed).
        // frame_at(t) is "latest frame that HAS arrived by t", and a
        // frame arriving exactly at t counts as arrived.
        let c = FrameClock::new(32.0);
        for f in 1..200u64 {
            let t = c.arrival(f);
            assert_eq!(c.frame_at(t), f, "boundary at frame {f}");
            // just before the boundary the previous frame is current
            assert_eq!(c.frame_at(t - 1e-9), f - 1);
        }
    }

    #[test]
    fn period_times_fps_is_one_frame() {
        for fps in [14.0, 24.0, 30.0, 32.0, 60.0] {
            let c = FrameClock::new(fps);
            assert!((c.arrival(1) - c.period()).abs() < 1e-12);
            assert_eq!(c.fps(), fps);
        }
    }
}
