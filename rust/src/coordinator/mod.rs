//! The paper's contribution: the TOD runtime scheduler.
//!
//! [`policy`] implements Algorithm 1 (the MBBS-thresholded DNN selector),
//! [`projected`] the feature/predictor-driven selector that supersedes
//! it (projected accuracy from a calibrated size×speed table under a
//! latency budget), [`session`] holds the resumable per-stream state machine
//! ([`StreamSession`]) that owns one stream's policy, drop-frame
//! accounting, carried detections and eval state, [`scheduler`] drives a
//! session over a sequence under the Algorithm 2 drop-frame accounting,
//! [`multistream`] interleaves many sessions over one shared accelerator
//! with contention-aware latency ([`dispatch`] holds its incremental
//! candidate queue), [`search`] is the Table I
//! hyperparameter grid search, and [`baselines`] provides the comparison
//! points (fixed single DNN, and a Chameleon-style periodic re-profiler).

// Serving zone (lint-policy.json): sessions and schedulers sit on the
// per-frame request path; a failed selection or inference must degrade
// the frame, never the process. Tests are exempt via clippy.toml.
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod baselines;
pub mod dispatch;
pub mod multistream;
pub mod policy;
pub mod projected;
pub mod scheduler;
pub mod search;
pub mod session;

pub use dispatch::DispatchQueue;
pub use multistream::{
    DispatchPolicy, MultiStreamResult, MultiStreamScheduler,
};
pub use policy::{
    FixedPolicy, MbbsPolicy, SelectionPolicy, ThresholdError, Thresholds,
};
pub use projected::ProjectedAccuracyPolicy;
pub use scheduler::{
    run_offline, run_realtime, run_realtime_observed, Detector, OracleBackend,
    RunResult,
};
pub use search::{grid_search, GridSearchResult, SearchSpace};
pub use session::{SessionEvent, StreamSession};
