//! The TOD runtime loop: select → (maybe) infer → carry forward.
//!
//! [`run_realtime`] replays a sequence against the FPS clock with the
//! Algorithm 2 drop-frame accounting: dropped frames inherit the previous
//! inference's detections (and are evaluated against *their own* ground
//! truth, which is where fast motion hurts heavy DNNs — Fig. 7).
//! [`run_offline`] evaluates every frame with no FPS constraint (Fig. 4).
//!
//! Both are thin drivers now: the per-frame state machine itself lives
//! in [`super::session::StreamSession`], which `run_realtime` steps to
//! completion on a dedicated accelerator. The multi-stream variant
//! ([`super::multistream`]) steps many sessions over one shared
//! accelerator instead.

use crate::dataset::mot::GtEntry;
use crate::dataset::synth::Sequence;
use crate::detection::{mbbs, Detection, FrameDetections};
use crate::eval::ap::{ApMethod, SequenceEval};
use crate::eval::matching::{match_frame, IOU_THRESHOLD};
use crate::power::{EnergyMeter, PowerSummary};
use crate::sim::latency::LatencyModel;
use crate::sim::oracle::OracleDetector;
use crate::telemetry::tegrastats::ScheduleTrace;
use crate::DnnKind;

use super::policy::SelectionPolicy;
use super::session::{SessionEvent, StreamSession};

/// Why one inference request failed (engine error, missing variant,
/// malformed output). Carried per frame so a single bad PJRT call can
/// fail its own frame without aborting the stream or the process.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DetectError(pub String);

impl std::fmt::Display for DetectError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "inference failed: {}", self.0)
    }
}

impl std::error::Error for DetectError {}

/// Inference backend abstraction: the oracle simulator or the PJRT
/// runtime (or anything else that maps a frame to detections).
///
/// `detect` is fallible by design: a real backend can lose an engine or
/// hit a bad PJRT call mid-stream, and the serving loop must degrade
/// (carry the previous detections forward, count the failure) instead
/// of panicking. Simulated backends simply always return `Ok`.
pub trait Detector {
    /// Produce raw detections for a frame, or report why the inference
    /// failed.
    fn detect(
        &mut self,
        frame: u64,
        gt: &[GtEntry],
        dnn: DnnKind,
    ) -> Result<Vec<Detection>, DetectError>;

    /// [`detect`](Self::detect) into a caller-owned buffer (cleared
    /// first, even on error) — the zero-alloc steady-state form the
    /// serving loop uses. The default delegates to `detect`; backends
    /// that can fill a buffer natively override it.
    fn detect_into(
        &mut self,
        frame: u64,
        gt: &[GtEntry],
        dnn: DnnKind,
        out: &mut Vec<Detection>,
    ) -> Result<(), DetectError> {
        out.clear();
        let dets = self.detect(frame, gt, dnn)?;
        out.extend_from_slice(&dets);
        Ok(())
    }
}

/// The oracle-backed detector (accuracy experiments; never fails).
pub struct OracleBackend(pub OracleDetector);

impl Detector for OracleBackend {
    fn detect(
        &mut self,
        frame: u64,
        gt: &[GtEntry],
        dnn: DnnKind,
    ) -> Result<Vec<Detection>, DetectError> {
        Ok(self.0.detect(frame, gt, dnn))
    }

    fn detect_into(
        &mut self,
        frame: u64,
        gt: &[GtEntry],
        dnn: DnnKind,
        out: &mut Vec<Detection>,
    ) -> Result<(), DetectError> {
        self.0.detect_into(frame, gt, dnn, out);
        Ok(())
    }
}

/// Everything one scheduled run produces.
#[derive(Debug, Clone)]
pub struct RunResult {
    /// Policy label (e.g. "TOD{0.007,0.03,0.04}" or a fixed DNN name).
    pub policy: String,
    pub sequence: String,
    /// Evaluation FPS (0.0 for offline mode).
    pub fps: f64,
    /// Average precision (all-point rule).
    pub ap: f64,
    pub n_frames: u64,
    pub n_inferred: u64,
    pub n_dropped: u64,
    /// Frames whose inference *ran* (accelerator time was spent) but
    /// the backend reported an error; their previous detections were
    /// carried forward. Always 0 for simulated backends.
    pub n_failed: u64,
    /// Accelerator-busy seconds spent on those failed inferences —
    /// busy time that bought no fresh detections (surfaced in
    /// [`crate::telemetry::utilisation::UtilisationSummary`]).
    pub failed_busy_s: f64,
    /// Inference count per DNN (Fig. 10's deployment frequency).
    pub deploy_counts: [u64; DnnKind::COUNT],
    /// Number of DNN switches between consecutive inferences.
    pub switches: u64,
    /// Metered energy/power/GPU summary (online accounting for
    /// scheduled runs; derived from the trace for offline/baselines).
    pub power: PowerSummary,
    /// Busy intervals for the telemetry simulator (Figs. 13–15).
    pub trace: ScheduleTrace,
    /// Per-frame MBBS seen by the policy (Fig. 9).
    pub mbbs_series: Vec<f64>,
    /// Per-frame DNN that ran (None = dropped frame) — Fig. 12.
    pub dnn_series: Vec<Option<DnnKind>>,
}

impl RunResult {
    /// Deployment frequency as fractions of inferred frames (Fig. 10).
    pub fn deploy_freq(&self) -> [f64; DnnKind::COUNT] {
        let total: u64 = self.deploy_counts.iter().sum();
        let mut out = [0.0; DnnKind::COUNT];
        if total > 0 {
            for i in 0..DnnKind::COUNT {
                out[i] = self.deploy_counts[i] as f64 / total as f64;
            }
        }
        out
    }

    pub fn drop_rate(&self) -> f64 {
        if self.n_frames == 0 {
            0.0
        } else {
            self.n_dropped as f64 / self.n_frames as f64
        }
    }
}

/// Real-time mode: Algorithm 1 selection + Algorithm 2 drop accounting.
///
/// Thin driver over [`StreamSession`]: opens a session for the sequence
/// and steps it to completion on a dedicated accelerator. Produces the
/// same `RunResult`, bit for bit, as the original monolithic loop.
pub fn run_realtime(
    seq: &Sequence,
    policy: &mut dyn SelectionPolicy,
    detector: &mut dyn Detector,
    latency: &mut LatencyModel,
    eval_fps: f64,
) -> RunResult {
    run_realtime_observed(seq, policy, detector, latency, eval_fps, None)
}

/// [`run_realtime`] with an optional observability recorder attached as
/// `(recorder, stream_id)` — the trace spine of `tod run --trace`.
pub fn run_realtime_observed(
    seq: &Sequence,
    policy: &mut dyn SelectionPolicy,
    detector: &mut dyn Detector,
    latency: &mut LatencyModel,
    eval_fps: f64,
    recorder: Option<(crate::obs::SharedRecorder, u32)>,
) -> RunResult {
    let mut session = StreamSession::new(seq, policy, eval_fps);
    if let Some((rec, stream)) = recorder {
        session = session.with_recorder(rec, stream, 0.0);
    }
    while session.step(detector, latency) != SessionEvent::Finished {}
    session.finish()
}

/// Offline mode: every frame inferred with a fixed DNN, no clock (Fig. 4).
pub fn run_offline(
    seq: &Sequence,
    dnn: DnnKind,
    detector: &mut dyn Detector,
) -> RunResult {
    let mut eval = SequenceEval::new();
    let mut trace = ScheduleTrace::default();
    let mut now = 0.0;
    let lat = crate::sim::profiles::DnnProfile::of(dnn).latency_mean_s;
    let mut mbbs_series = Vec::with_capacity(seq.n_frames() as usize);
    let (fw, fh) = (seq.spec.width as f64, seq.spec.height as f64);
    let mut dnn_series = Vec::with_capacity(seq.n_frames() as usize);
    let mut n_failed = 0u64;
    for f in 1..=seq.n_frames() {
        let gt = seq.gt(f);
        // offline mode has no carry-forward: a failed inference simply
        // contributes an empty detection set for its own frame
        let raw = detector.detect(f, gt, dnn).unwrap_or_else(|_| {
            n_failed += 1;
            Vec::new()
        });
        let dets =
            FrameDetections { frame: f, detections: raw }.filtered().detections;
        mbbs_series.push(mbbs(&dets, fw, fh));
        eval.push(&match_frame(&dets, gt, IOU_THRESHOLD));
        trace.push(now, now + lat, dnn);
        now += lat;
        dnn_series.push(Some(dnn));
    }
    // mirror run_realtime's explicit duration handling: define the
    // offline "stream" as lasting exactly its back-to-back inferences
    // (push() happens to track max interval end today, but telemetry
    // comparability across modes shouldn't hinge on that side effect)
    trace.duration = now;
    RunResult {
        policy: format!("{}-offline", dnn.artifact_name()),
        sequence: seq.spec.name.clone(),
        fps: 0.0,
        ap: eval.ap(ApMethod::AllPoint),
        n_frames: seq.n_frames(),
        n_inferred: seq.n_frames(),
        n_dropped: 0,
        n_failed,
        // offline failures spend virtual accelerator time too, but the
        // mode exists only for AP ceilings; attribute nothing
        failed_busy_s: 0.0,
        deploy_counts: {
            let mut d = [0u64; DnnKind::COUNT];
            d[dnn.index()] = seq.n_frames();
            d
        },
        switches: 0,
        power: EnergyMeter::from_trace(&trace).summary(),
        trace,
        mbbs_series,
        dnn_series,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::{FixedPolicy, MbbsPolicy};
    use crate::dataset::catalog::{generate, SequenceId};
    use crate::dataset::synth::{CameraMotion, SequenceSpec};

    fn small_seq(camera: CameraMotion, ref_height: f64) -> Sequence {
        Sequence::generate(SequenceSpec {
            name: "UNIT".into(),
            width: 960,
            height: 540,
            fps: 30.0,
            frames: 120,
            density: 8,
            ref_height,
            depth_range: (1.0, 2.0),
            walk_speed: 1.5,
            camera,
            seed: 99,
        })
    }

    fn oracle_for(seq: &Sequence) -> OracleBackend {
        OracleBackend(OracleDetector::new(
            seq.spec.seed,
            seq.spec.width as f64,
            seq.spec.height as f64,
        ))
    }

    #[test]
    fn offline_heavy_beats_light() {
        // small objects: Y-416 offline must clearly beat tiny-288
        let seq = small_seq(CameraMotion::Static, 80.0);
        let mut det = oracle_for(&seq);
        let heavy = run_offline(&seq, DnnKind::Y416, &mut det);
        let light = run_offline(&seq, DnnKind::TinyY288, &mut det);
        assert!(
            heavy.ap > light.ap + 0.1,
            "heavy {} vs light {}",
            heavy.ap,
            light.ap
        );
        assert_eq!(heavy.n_dropped, 0);
        assert_eq!(heavy.n_inferred, seq.n_frames());
    }

    #[test]
    fn realtime_conservation_and_counts() {
        let seq = small_seq(CameraMotion::Static, 200.0);
        let mut det = oracle_for(&seq);
        let mut pol = FixedPolicy(DnnKind::Y416);
        let mut lat = LatencyModel::deterministic();
        let r = run_realtime(&seq, &mut pol, &mut det, &mut lat, 30.0);
        assert_eq!(r.n_inferred + r.n_dropped, r.n_frames);
        assert!(r.n_dropped > 0, "Y-416 at 30 FPS must drop frames");
        assert_eq!(r.deploy_counts.iter().sum::<u64>(), r.n_inferred);
        assert_eq!(r.deploy_counts[DnnKind::Y416.index()], r.n_inferred);
        assert_eq!(r.switches, 0);
        assert_eq!(r.mbbs_series.len() as u64, r.n_frames);
        assert_eq!(r.dnn_series.len() as u64, r.n_frames);
    }

    #[test]
    fn tiny_never_drops_at_30fps() {
        let seq = small_seq(CameraMotion::Static, 200.0);
        let mut det = oracle_for(&seq);
        let mut pol = FixedPolicy(DnnKind::TinyY288);
        let mut lat = LatencyModel::deterministic();
        let r = run_realtime(&seq, &mut pol, &mut det, &mut lat, 30.0);
        assert_eq!(r.n_dropped, 0);
    }

    #[test]
    fn realtime_ap_not_above_offline_for_heavy_net() {
        // dropping frames cannot help a fixed DNN
        let seq = small_seq(CameraMotion::Walking { pan_speed: 5.0 }, 200.0);
        let mut det = oracle_for(&seq);
        let off = run_offline(&seq, DnnKind::Y416, &mut det);
        let mut pol = FixedPolicy(DnnKind::Y416);
        let mut lat = LatencyModel::deterministic();
        let rt = run_realtime(&seq, &mut pol, &mut det, &mut lat, 30.0);
        assert!(
            rt.ap <= off.ap + 0.02,
            "realtime {} must not beat offline {}",
            rt.ap,
            off.ap
        );
    }

    #[test]
    fn fast_motion_hurts_heavy_net_more() {
        // Fig. 7's mechanism: carried-forward boxes go stale faster when
        // the scene moves fast
        let slow = small_seq(CameraMotion::Static, 200.0);
        let fast = small_seq(CameraMotion::Vehicle { flow_speed: 30.0 }, 200.0);
        let drop = |seq: &Sequence| {
            let mut det = oracle_for(seq);
            let off = run_offline(seq, DnnKind::Y416, &mut det);
            let mut pol = FixedPolicy(DnnKind::Y416);
            let mut lat = LatencyModel::deterministic();
            let rt = run_realtime(seq, &mut pol, &mut det, &mut lat, 30.0);
            off.ap - rt.ap
        };
        let d_slow = drop(&slow);
        let d_fast = drop(&fast);
        assert!(
            d_fast > d_slow + 0.05,
            "fast-motion drop {d_fast} vs slow {d_slow}"
        );
    }

    #[test]
    fn tod_tracks_best_fixed_on_large_objects() {
        // large objects and fast camera: tiny nets win; TOD must follow
        let seq = small_seq(CameraMotion::Walking { pan_speed: 22.0 }, 440.0);
        let mut det = oracle_for(&seq);
        let mut lat = LatencyModel::deterministic();
        let mut tod = MbbsPolicy::tod_default();
        let r_tod =
            run_realtime(&seq, &mut tod, &mut det, &mut lat, 30.0);
        // TOD should mostly use tiny nets here
        let freq = r_tod.deploy_freq();
        assert!(
            freq[0] + freq[1] > 0.5,
            "expected mostly tiny selections: {freq:?}"
        );
        let mut best = 0.0f64;
        let mut worst = 1.0f64;
        for k in DnnKind::ALL {
            let mut pol = FixedPolicy(k);
            let r = run_realtime(&seq, &mut pol, &mut det, &mut lat, 30.0);
            best = best.max(r.ap);
            worst = worst.min(r.ap);
        }
        // the paper itself concedes up to ~0.1 AP vs the per-sequence
        // best on some sequences (§V); TOD must stay in that band and
        // clearly beat the worst fixed choice
        assert!(
            r_tod.ap > best - 0.12,
            "TOD {} vs best fixed {best}",
            r_tod.ap
        );
        assert!(
            r_tod.ap > worst + 0.05,
            "TOD {} vs worst fixed {worst}",
            r_tod.ap
        );
    }

    #[test]
    fn deterministic_runs() {
        let seq = generate(SequenceId::Mot09);
        let mut lat1 = LatencyModel::deterministic();
        let mut lat2 = LatencyModel::deterministic();
        let mut det1 = oracle_for(&seq);
        let mut det2 = oracle_for(&seq);
        let mut p1 = MbbsPolicy::tod_default();
        let mut p2 = MbbsPolicy::tod_default();
        let a = run_realtime(&seq, &mut p1, &mut det1, &mut lat1, 30.0);
        let b = run_realtime(&seq, &mut p2, &mut det2, &mut lat2, 30.0);
        assert_eq!(a.ap, b.ap);
        assert_eq!(a.deploy_counts, b.deploy_counts);
        assert_eq!(a.n_dropped, b.n_dropped);
    }

    #[test]
    fn offline_trace_duration_is_total_inference_time() {
        let seq = small_seq(CameraMotion::Static, 200.0);
        let mut det = oracle_for(&seq);
        let r = run_offline(&seq, DnnKind::Y288, &mut det);
        let lat =
            crate::sim::profiles::DnnProfile::of(DnnKind::Y288).latency_mean_s;
        let expect = seq.n_frames() as f64 * lat;
        assert!(
            (r.trace.duration - expect).abs() < 1e-9,
            "duration {} vs {expect}",
            r.trace.duration
        );
        // offline and realtime traces are now directly comparable: both
        // set an explicit duration the telemetry sampler can window over
        assert!(r.trace.duration > 0.0);
        assert_eq!(r.trace.busy.len() as u64, seq.n_frames());
    }

    #[test]
    fn trace_duration_covers_stream() {
        let seq = small_seq(CameraMotion::Static, 200.0);
        let mut det = oracle_for(&seq);
        let mut pol = FixedPolicy(DnnKind::TinyY288);
        let mut lat = LatencyModel::deterministic();
        let r = run_realtime(&seq, &mut pol, &mut det, &mut lat, 30.0);
        assert!(r.trace.duration >= 120.0 / 30.0 - 1e-9);
    }
}
