//! Telemetry simulator: the tegrastats stand-in (DESIGN.md §3).
//!
//! Figures 11–15 of the paper are functions of *which DNN runs when and
//! for how long* — exactly what the scheduler decides. This module maps a
//! schedule's busy intervals to 1 Hz power / GPU-utilisation traces using
//! the per-DNN steady-state calibration in [`crate::sim::profiles`], and
//! models memory as base + resident weights + shared workspace.

pub mod tegrastats;
pub mod utilisation;

pub use tegrastats::{ScheduleTrace, TegrastatsSim, TelemetrySample};
pub use utilisation::UtilisationSummary;
