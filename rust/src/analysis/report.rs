//! Versioned lint report (`tod-lint` schema v1): the JSON artifact
//! `tod lint --json` emits and CI archives, plus the human rendering.
//!
//! Like every other pinned artifact in the crate (traces, goldens,
//! bench reports) the JSON is byte-deterministic: findings are sorted
//! by `(file, line, rule)` and serialised through the BTreeMap-backed
//! [`crate::util::json::Json`].

use crate::analysis::zones::Severity;
use crate::util::json::Json;

/// Schema tag of the report document.
pub const REPORT_SCHEMA: &str = "tod-lint";
/// Current report schema version.
pub const REPORT_VERSION: u64 = 1;

/// One lint finding.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Path relative to the scan root, `/`-separated.
    pub file: String,
    /// 1-based source line.
    pub line: usize,
    /// Rule id (`srv-unwrap`, `waiver-missing-reason`, ...).
    pub rule: String,
    /// Zone tag (`determinism` | `serving` | `hot-path` | `waiver`).
    pub zone: &'static str,
    /// Effective severity after policy overrides.
    pub severity: Severity,
    /// One-line rationale.
    pub message: String,
}

impl Finding {
    /// Sort key pinning report order.
    fn key(&self) -> (String, usize, String) {
        (self.file.clone(), self.line, self.rule.clone())
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("file", Json::str(&self.file)),
            ("line", Json::num(self.line as f64)),
            ("rule", Json::str(&self.rule)),
            ("zone", Json::str(self.zone)),
            ("severity", Json::str(self.severity.tag())),
            ("message", Json::str(&self.message)),
        ])
    }

    fn render(&self) -> String {
        format!(
            "{} {} {}:{} [{}] {}",
            self.severity.tag(),
            self.rule,
            self.file,
            self.line,
            self.zone,
            self.message
        )
    }
}

/// A finding suppressed by an inline waiver (still enumerated).
#[derive(Debug, Clone)]
pub struct WaivedFinding {
    /// The finding the waiver covers.
    pub finding: Finding,
    /// The waiver's mandatory reason.
    pub reason: String,
}

/// Full output of one lint run.
#[derive(Debug, Clone, Default)]
pub struct LintReport {
    /// `version` field of the policy that drove the run.
    pub policy_version: u64,
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
    /// Unwaived deny findings — any entry fails `--check`.
    pub findings: Vec<Finding>,
    /// Unwaived warn findings — reported, never fail the gate.
    pub warnings: Vec<Finding>,
    /// Waived findings with their reasons.
    pub waived: Vec<WaivedFinding>,
    /// Advisories (unused waivers) — housekeeping, never fail.
    pub advisories: Vec<Finding>,
}

impl LintReport {
    /// No unwaived deny findings.
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Pin deterministic ordering (driver calls this once at the end).
    pub fn sort(&mut self) {
        self.findings.sort_by_key(Finding::key);
        self.warnings.sort_by_key(Finding::key);
        self.waived.sort_by_key(|w| w.finding.key());
        self.advisories.sort_by_key(Finding::key);
    }

    /// Serialise to the versioned `tod-lint` JSON document.
    pub fn to_json(&self) -> Json {
        let arr = |v: &[Finding]| {
            Json::arr(v.iter().map(Finding::to_json).collect())
        };
        Json::obj(vec![
            ("schema", Json::str(REPORT_SCHEMA)),
            ("schema_version", Json::num(REPORT_VERSION as f64)),
            ("policy_version", Json::num(self.policy_version as f64)),
            ("files_scanned", Json::num(self.files_scanned as f64)),
            (
                "summary",
                Json::obj(vec![
                    ("deny", Json::num(self.findings.len() as f64)),
                    ("warn", Json::num(self.warnings.len() as f64)),
                    ("waived", Json::num(self.waived.len() as f64)),
                    (
                        "advisory",
                        Json::num(self.advisories.len() as f64),
                    ),
                ]),
            ),
            ("findings", arr(&self.findings)),
            ("warnings", arr(&self.warnings)),
            (
                "waived",
                Json::arr(
                    self.waived
                        .iter()
                        .map(|w| {
                            let mut j = w.finding.to_json();
                            if let Json::Obj(m) = &mut j {
                                m.insert(
                                    "reason".to_string(),
                                    Json::str(&w.reason),
                                );
                            }
                            j
                        })
                        .collect(),
                ),
            ),
            ("advisories", arr(&self.advisories)),
        ])
    }

    /// Human rendering for the terminal / CI log.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&f.render());
            out.push('\n');
        }
        for f in &self.warnings {
            out.push_str(&f.render());
            out.push('\n');
        }
        for w in &self.waived {
            out.push_str(&format!(
                "waived {} {}:{} reason=\"{}\"\n",
                w.finding.rule, w.finding.file, w.finding.line, w.reason
            ));
        }
        for f in &self.advisories {
            out.push_str(&f.render());
            out.push('\n');
        }
        out.push_str(&format!(
            "tod-lint: {} file(s), {} deny, {} warn, {} waived, \
             {} advisory\n",
            self.files_scanned,
            self.findings.len(),
            self.warnings.len(),
            self.waived.len(),
            self.advisories.len()
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finding(file: &str, line: usize, rule: &str) -> Finding {
        Finding {
            file: file.to_string(),
            line,
            rule: rule.to_string(),
            zone: "serving",
            severity: Severity::Deny,
            message: "m".to_string(),
        }
    }

    #[test]
    fn json_is_sorted_and_versioned() {
        let mut r = LintReport {
            policy_version: 2,
            files_scanned: 3,
            findings: vec![
                finding("b.rs", 1, "srv-unwrap"),
                finding("a.rs", 9, "srv-panic"),
                finding("a.rs", 2, "srv-unwrap"),
            ],
            ..Default::default()
        };
        r.sort();
        assert_eq!(r.findings[0].file, "a.rs");
        assert_eq!(r.findings[0].line, 2);
        assert_eq!(r.findings[2].file, "b.rs");
        let j = r.to_json();
        assert_eq!(j.get("schema").and_then(Json::as_str), Some(REPORT_SCHEMA));
        assert_eq!(
            j.at(&["summary", "deny"]).and_then(Json::as_usize),
            Some(3)
        );
        // byte-determinism: serialising twice is identical
        assert_eq!(j.to_string(), r.to_json().to_string());
    }

    #[test]
    fn clean_and_render() {
        let mut r = LintReport::default();
        assert!(r.clean());
        r.warnings.push(finding("a.rs", 1, "srv-slice-index"));
        assert!(r.clean()); // warnings never fail the gate
        r.findings.push(finding("a.rs", 4, "srv-unwrap"));
        assert!(!r.clean());
        let text = r.render_text();
        assert!(text.contains("srv-unwrap a.rs:4"));
        assert!(text.contains("1 deny, 1 warn"));
    }
}
