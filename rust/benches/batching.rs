//! Bench: cross-stream micro-batching vs per-request dispatch.
//!
//! Two layers are measured. The *virtual* layer (deterministic) runs
//! the multi-stream scheduler with and without the batched latency
//! model and prints the frames/s and drop-rate win — the acceptance
//! figure: with >= 4 concurrent synthetic streams, batching must beat
//! per-request dispatch. The *host* layer times the real threaded
//! server (`InferenceServer`) against a synthetic backend whose
//! per-dispatch setup cost is real wall-clock work, so batch formation
//! itself shows up in frames/s.

use std::sync::Arc;
use std::time::Duration;

use tod::bench::{black_box, Bench};
use tod::coordinator::multistream::{
    BatchingSim, DispatchPolicy, MultiStreamResult, MultiStreamScheduler,
};
use tod::coordinator::policy::MbbsPolicy;
use tod::coordinator::scheduler::OracleBackend;
use tod::coordinator::session::StreamSession;
use tod::dataset::synth::{CameraMotion, Sequence, SequenceSpec};
use tod::detection::{Detection, PERSON_CLASS};
use tod::geometry::BBox;
use tod::runtime::batch::BatchConfig;
use tod::runtime::server::{
    BatchDetector, InferRequest, InferenceServer, ServeResult,
};
use tod::sim::latency::{ContentionModel, LatencyModel};
use tod::sim::oracle::OracleDetector;
use tod::DnnKind;

fn synth_seq(seed: u64, frames: u64) -> Sequence {
    Sequence::generate(SequenceSpec {
        name: format!("BENCH-BATCH-{seed}"),
        width: 960,
        height: 540,
        fps: 30.0,
        frames,
        density: 6,
        ref_height: 220.0,
        depth_range: (1.0, 2.0),
        walk_speed: 1.5,
        camera: CameraMotion::Static,
        seed,
    })
}

fn run_virtual(
    seqs: &[Sequence],
    batching: Option<BatchingSim>,
) -> MultiStreamResult {
    let mut sched = MultiStreamScheduler::new(
        DispatchPolicy::RoundRobin,
        ContentionModel::jetson_nano(),
        LatencyModel::deterministic(),
    );
    if let Some(b) = batching {
        sched = sched.with_batching(b);
    }
    for s in seqs {
        let det = OracleBackend(OracleDetector::new(
            s.spec.seed,
            s.spec.width as f64,
            s.spec.height as f64,
        ));
        sched.add_stream(
            StreamSession::new(s, MbbsPolicy::tod_default(), 30.0),
            Box::new(det),
        );
    }
    sched.run()
}

/// Synthetic backend with a real (wall-clock) per-dispatch setup cost:
/// what micro-batching amortises on actual hardware.
struct SpinEngine {
    setup: Duration,
    per_item: Duration,
}

fn spin_for(d: Duration) {
    let t0 = std::time::Instant::now();
    while t0.elapsed() < d {
        std::hint::spin_loop();
    }
}

impl BatchDetector for SpinEngine {
    fn infer(&self, req: &InferRequest) -> ServeResult {
        spin_for(self.per_item);
        Ok(vec![Detection::new(
            BBox::new(req.frame as f64 % 600.0, 0.0, 10.0, 20.0),
            0.9,
            PERSON_CLASS,
        )])
    }

    fn on_batch_start(&self, _dnn: DnnKind, _n: usize) {
        spin_for(self.setup);
    }
}

/// Drive `streams` client threads through a server; returns frames/s.
fn server_frames_per_s(streams: u64, frames: u64, max_batch: usize) -> f64 {
    let server = Arc::new(InferenceServer::start(
        Arc::new(SpinEngine {
            setup: Duration::from_micros(150),
            per_item: Duration::from_micros(60),
        }),
        BatchConfig {
            max_batch,
            max_wait: Duration::from_micros(300),
            ..BatchConfig::default()
        },
        2,
    ));
    let t0 = std::time::Instant::now();
    let clients: Vec<_> = (0..streams)
        .map(|s| {
            let server = server.clone();
            std::thread::spawn(move || {
                for f in 1..=frames {
                    let h = server
                        .submit(InferRequest {
                            stream: s,
                            frame: f,
                            dnn: DnnKind::Y416,
                            frame_w: 640.0,
                            frame_h: 480.0,
                            gt: Vec::new(),
                        })
                        .expect("admitted");
                    h.wait().expect("synthetic engine never fails");
                }
            })
        })
        .collect();
    for c in clients {
        c.join().expect("client");
    }
    (streams * frames) as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let mut b = Bench::slow();

    // ---- virtual layer: deterministic batching win -------------------
    for n in [4usize, 8] {
        let seqs: Vec<Sequence> =
            (0..n as u64).map(|_| synth_seq(11, 120)).collect();
        b.case(&format!("batching/virtual_plain_{n}stream"), || {
            black_box(run_virtual(&seqs, None));
        });
        b.case(&format!("batching/virtual_batched_{n}stream"), || {
            black_box(run_virtual(
                &seqs,
                Some(BatchingSim::jetson_nano(4)),
            ));
        });
        let plain = run_virtual(&seqs, None);
        let batched =
            run_virtual(&seqs, Some(BatchingSim::jetson_nano(4)));
        let plain_ips = plain.utilisation.throughput_ips();
        let batched_ips = batched.utilisation.throughput_ips();
        println!(
            "    -> {n} streams: per-request {plain_ips:.1} inf/s \
             (drop {:.1}%) vs micro-batched {batched_ips:.1} inf/s \
             (drop {:.1}%): x{:.2}",
            plain.drop_rate() * 100.0,
            batched.drop_rate() * 100.0,
            batched_ips / plain_ips.max(1e-12),
        );
        if let Some(stats) = &batched.batching {
            println!("       batching: {stats}");
        }
        assert!(
            batched_ips > plain_ips,
            "acceptance: batched serving must beat per-request \
             dispatch with {n} streams ({batched_ips} <= {plain_ips})"
        );
    }

    // ---- host layer: real threaded server ----------------------------
    let unbatched = server_frames_per_s(4, 150, 1);
    let batched = server_frames_per_s(4, 150, 4);
    println!(
        "    -> threaded server, 4 streams x 150 frames: per-request \
         {unbatched:.0} frames/s vs micro-batched {batched:.0} frames/s \
         (x{:.2})",
        batched / unbatched.max(1e-12)
    );

    b.case("batching/server_4stream_batched", || {
        black_box(server_frames_per_s(4, 40, 4));
    });

    b.save_csv("batching.csv").ok();
}
