//! Average precision over a sequence of matched frames.
//!
//! Detections from all frames are pooled, sorted by confidence, and the
//! precision-recall curve is integrated. Two integration rules are
//! provided: the continuous (VOC-2010 / MOT devkit) all-point rule used
//! by default, and the classic 11-point rule for cross-checking.

// Evaluation sits on the serving path (per-stream AP reports): a NaN
// confidence must degrade one ranking, never panic the process.
#![deny(clippy::unwrap_used)]

use crate::eval::matching::FrameMatch;

/// AP integration rule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ApMethod {
    /// Area under the monotone-envelope PR curve (all recall points).
    AllPoint,
    /// Mean of max precision at recall ∈ {0.0, 0.1, ..., 1.0}.
    ElevenPoint,
}

/// Pooled evaluation state for one sequence (or one campaign).
#[derive(Debug, Clone, Default)]
pub struct SequenceEval {
    scored: Vec<(f32, bool)>,
    n_gt: usize,
}

impl SequenceEval {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold in one matched frame.
    pub fn push(&mut self, m: &FrameMatch) {
        self.scored.extend_from_slice(&m.scored);
        self.n_gt += m.n_gt;
    }

    /// Fold in a single scored detection — the streaming form of
    /// [`push`](Self::push) used by
    /// [`FrameMatcher::match_into`](crate::eval::matching::FrameMatcher::match_into)
    /// to skip the intermediate `FrameMatch`.
    pub fn push_scored(&mut self, score: f32, is_tp: bool) {
        self.scored.push((score, is_tp));
    }

    /// Add considered ground-truth boxes without scored detections
    /// (companion to [`push_scored`](Self::push_scored)).
    pub fn add_gt(&mut self, n: usize) {
        self.n_gt += n;
    }

    /// Pre-size the pooled buffer so steady-state folding never grows
    /// it mid-sequence.
    pub fn reserve(&mut self, additional: usize) {
        self.scored.reserve(additional);
    }

    /// Reset to empty, keeping the pooled buffer's capacity.
    pub fn clear(&mut self) {
        self.scored.clear();
        self.n_gt = 0;
    }

    /// The pooled (score, is_tp) pairs, in fold order.
    pub fn scored(&self) -> &[(f32, bool)] {
        &self.scored
    }

    pub fn n_gt(&self) -> usize {
        self.n_gt
    }

    pub fn n_scored(&self) -> usize {
        self.scored.len()
    }

    /// Average precision under the given rule.
    pub fn ap(&self, method: ApMethod) -> f64 {
        average_precision(&self.scored, self.n_gt, method)
    }

    /// The (recall, precision) curve, sorted by ascending recall.
    pub fn curve(&self) -> Vec<(f64, f64)> {
        pr_curve(&self.scored, self.n_gt)
    }
}

/// Precision-recall points from pooled (score, is_tp) pairs.
pub fn pr_curve(scored: &[(f32, bool)], n_gt: usize) -> Vec<(f64, f64)> {
    if n_gt == 0 || scored.is_empty() {
        return Vec::new();
    }
    let mut s: Vec<(f32, bool)> = scored.to_vec();
    // NaN-safe descending sort: one NaN confidence from a broken head
    // must not abort a whole evaluation. NaN carries no confidence, so
    // it ranks last — it cannot outrank any finite-score detection.
    s.sort_by(|a, b| {
        crate::detection::by_score_desc_nan_last(a.0, b.0)
    });
    let mut tp = 0usize;
    let mut fp = 0usize;
    let mut curve = Vec::with_capacity(s.len());
    for (_, is_tp) in s {
        if is_tp {
            tp += 1;
        } else {
            fp += 1;
        }
        let recall = tp as f64 / n_gt as f64;
        let precision = tp as f64 / (tp + fp) as f64;
        curve.push((recall, precision));
    }
    curve
}

/// Average precision from pooled (score, is_tp) pairs.
///
/// Edge cases: no ground truth and no detections → perfect (1.0) by
/// convention; no ground truth but detections → 0.0; detections absent
/// with ground truth present → 0.0.
pub fn average_precision(
    scored: &[(f32, bool)],
    n_gt: usize,
    method: ApMethod,
) -> f64 {
    if n_gt == 0 {
        return if scored.is_empty() { 1.0 } else { 0.0 };
    }
    let curve = pr_curve(scored, n_gt);
    if curve.is_empty() {
        return 0.0;
    }
    match method {
        ApMethod::AllPoint => {
            // monotone envelope, integrate dr * p. The curve is owned
            // here, so the envelope is computed in place — the old
            // `curve.clone()` doubled the allocation for nothing.
            let mut env = curve;
            let mut best = 0.0f64;
            for i in (0..env.len()).rev() {
                best = best.max(env[i].1);
                env[i].1 = best;
            }
            let mut ap = 0.0;
            let mut prev_r = 0.0;
            for (r, p) in env {
                ap += (r - prev_r).max(0.0) * p;
                prev_r = r;
            }
            ap
        }
        ApMethod::ElevenPoint => {
            let mut total = 0.0;
            for k in 0..=10 {
                let r0 = k as f64 / 10.0;
                let pmax = curve
                    .iter()
                    .filter(|(r, _)| *r >= r0 - 1e-12)
                    .map(|(_, p)| *p)
                    .fold(0.0f64, f64::max);
                total += pmax;
            }
            total / 11.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn eval_from(scored: Vec<(f32, bool)>, n_gt: usize) -> SequenceEval {
        let mut e = SequenceEval::new();
        e.push(&FrameMatch { scored, n_gt, n_ignored: 0 });
        e
    }

    #[test]
    fn perfect_detector_ap_is_one() {
        let e = eval_from(vec![(0.9, true), (0.8, true)], 2);
        assert!((e.ap(ApMethod::AllPoint) - 1.0).abs() < 1e-12);
        assert!((e.ap(ApMethod::ElevenPoint) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn all_false_positives_ap_zero() {
        let e = eval_from(vec![(0.9, false), (0.8, false)], 3);
        assert_eq!(e.ap(ApMethod::AllPoint), 0.0);
    }

    #[test]
    fn no_detections_with_gt_is_zero() {
        let e = eval_from(vec![], 5);
        assert_eq!(e.ap(ApMethod::AllPoint), 0.0);
    }

    #[test]
    fn empty_everything_is_one() {
        let e = eval_from(vec![], 0);
        assert_eq!(e.ap(ApMethod::AllPoint), 1.0);
    }

    #[test]
    fn hand_computed_ap() {
        // 3 gt; dets sorted by score: TP, FP, TP
        // points: r=1/3 p=1; r=1/3 p=1/2; r=2/3 p=2/3
        // envelope: p(0..1/3]=1, p(1/3..2/3]=2/3
        // AP = 1/3 * 1 + 1/3 * 2/3 = 0.5555...
        let e = eval_from(vec![(0.9, true), (0.8, false), (0.7, true)], 3);
        let ap = e.ap(ApMethod::AllPoint);
        assert!((ap - (1.0 / 3.0 + 2.0 / 9.0)).abs() < 1e-12, "ap={ap}");
    }

    #[test]
    fn score_order_invariance() {
        // AP depends on score ranking, not on push order
        let e1 = eval_from(vec![(0.9, true), (0.5, false), (0.7, true)], 2);
        let e2 = eval_from(vec![(0.5, false), (0.7, true), (0.9, true)], 2);
        assert_eq!(e1.ap(ApMethod::AllPoint), e2.ap(ApMethod::AllPoint));
    }

    #[test]
    fn better_ranking_scores_higher() {
        // same TP/FP multiset, but TPs ranked above FPs scores higher
        let good = eval_from(
            vec![(0.9, true), (0.8, true), (0.3, false), (0.2, false)],
            2,
        );
        let bad = eval_from(
            vec![(0.9, false), (0.8, false), (0.3, true), (0.2, true)],
            2,
        );
        assert!(
            good.ap(ApMethod::AllPoint) > bad.ap(ApMethod::AllPoint) + 0.3
        );
    }

    #[test]
    fn ap_bounded_zero_one() {
        let e = eval_from(
            vec![(0.9, true), (0.8, false), (0.7, true), (0.1, false)],
            10,
        );
        for m in [ApMethod::AllPoint, ApMethod::ElevenPoint] {
            let ap = e.ap(m);
            assert!((0.0..=1.0).contains(&ap));
        }
    }

    #[test]
    fn eleven_point_close_to_allpoint_on_dense_curve() {
        // a long, well-behaved detector run: both rules should agree
        // within a few points
        let mut scored = Vec::new();
        for i in 0..200 {
            scored.push((1.0 - i as f32 / 200.0, i % 3 != 0));
        }
        let e = eval_from(scored, 140);
        let a = e.ap(ApMethod::AllPoint);
        let b = e.ap(ApMethod::ElevenPoint);
        assert!((a - b).abs() < 0.08, "all={a} eleven={b}");
    }

    #[test]
    fn nan_score_does_not_abort_evaluation() {
        // regression: a single NaN confidence used to panic the sort
        // inside pr_curve; it must now rank deterministically (last,
        // as a no-confidence detection) and leave the AP finite
        let e = eval_from(
            vec![(0.9, true), (f32::NAN, false), (0.7, true)],
            2,
        );
        let ap = e.ap(ApMethod::AllPoint);
        assert!(ap.is_finite());
        assert!((0.0..=1.0).contains(&ap));
        // the NaN FP ranks below both TPs, so full recall is reached
        // at precision 1 before the FP appears: AP = 1
        assert!((ap - 1.0).abs() < 1e-12, "ap={ap}");
    }

    #[test]
    fn streaming_fold_matches_push_and_clear_resets() {
        let m = FrameMatch {
            scored: vec![(0.9, true), (0.4, false)],
            n_gt: 3,
            n_ignored: 1,
        };
        let mut batch = SequenceEval::new();
        batch.push(&m);

        let mut streamed = SequenceEval::new();
        streamed.reserve(2);
        for &(s, tp) in &m.scored {
            streamed.push_scored(s, tp);
        }
        streamed.add_gt(m.n_gt);

        assert_eq!(streamed.scored(), batch.scored());
        assert_eq!(streamed.n_gt(), batch.n_gt());
        assert_eq!(
            streamed.ap(ApMethod::AllPoint),
            batch.ap(ApMethod::AllPoint)
        );

        streamed.clear();
        assert_eq!(streamed.n_scored(), 0);
        assert_eq!(streamed.n_gt(), 0);
        assert_eq!(streamed.ap(ApMethod::AllPoint), 1.0);
    }

    #[test]
    fn accumulates_across_frames() {
        let mut e = SequenceEval::new();
        e.push(&FrameMatch { scored: vec![(0.9, true)], n_gt: 1, n_ignored: 0 });
        e.push(&FrameMatch { scored: vec![(0.8, true)], n_gt: 1, n_ignored: 0 });
        assert_eq!(e.n_gt(), 2);
        assert_eq!(e.n_scored(), 2);
        assert!((e.ap(ApMethod::AllPoint) - 1.0).abs() < 1e-12);
    }
}
