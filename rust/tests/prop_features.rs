//! Property tests for the stream-feature extractor
//! (`features/extract.rs`): the invariants selection correctness rests
//! on, over randomized detection streams (`tod::testing::prop` style).

use tod::detection::{Detection, PERSON_CLASS};
use tod::features::{FeatureConfig, FeatureExtractor, FrameFeatures};
use tod::geometry::BBox;
use tod::testing::prop::{Gen, PropConfig};

const W: f64 = 1280.0;
const H: f64 = 720.0;

fn det(x: f64, y: f64, w: f64, h: f64) -> Detection {
    Detection::new(BBox::new(x, y, w, h), 0.9, PERSON_CLASS)
}

fn random_dets(g: &mut Gen, n: usize) -> Vec<Detection> {
    (0..n)
        .map(|_| {
            det(
                g.f64_in(0.0, W - 80.0),
                g.f64_in(0.0, H - 80.0),
                g.f64_in(1.0, 220.0),
                g.f64_in(1.0, 320.0),
            )
        })
        .collect()
}

/// Fresh extractor with no smoothing, so the property reads the raw
/// per-update speed estimate.
fn raw_extractor() -> FeatureExtractor {
    FeatureExtractor::with_config(
        FeatureConfig { ewma_alpha: 1.0, ..FeatureConfig::default() },
        W,
        H,
    )
}

#[test]
fn speed_is_never_negative_and_always_finite() {
    PropConfig::default().run("speed >= 0 and finite", |g| {
        let mut fx = raw_extractor();
        let mut frame = 0u64;
        for _ in 0..g.usize_in(1, 12) {
            frame += g.usize_in(1, 5) as u64;
            let dets = random_dets(g, g.usize_in(0, 10));
            fx.on_detections(frame, &dets);
            let f = fx.features(&dets);
            if !(f.speed >= 0.0 && f.speed.is_finite()) {
                return false;
            }
            if !(f.mbbs >= 0.0 && f.density >= 0.0) {
                return false;
            }
        }
        true
    });
}

#[test]
fn frame_gap_normalisation_is_invariant_to_schedule_sparsity() {
    // a rigid translation at constant px/frame must read the same
    // per-frame speed whether snapshots arrive every frame or every
    // k-th frame — the property that makes speed comparable between
    // light-DNN (dense) and heavy-DNN (sparse) schedules.
    //
    // The exact-equality form of the property holds only where the
    // matcher is guaranteed to pair every box with its own successor:
    // boxes must be large enough (and spaced widely enough) that the
    // biggest per-snapshot displacement (8 px/frame x gap 6 = 48 px)
    // stays inside the centroid gate and below the inter-box spacing —
    // hence the structured grid generator, not `random_dets`.
    PropConfig::with_cases(64).run("gap-normalised speed", |g| {
        let vx = g.f64_in(0.5, 8.0);
        let vy = g.f64_in(-3.0, 3.0);
        let gap = g.usize_in(1, 6) as u64;
        let n = g.usize_in(1, 5);
        let base: Vec<Detection> = (0..n)
            .map(|i| {
                det(
                    250.0 * i as f64 + g.f64_in(0.0, 30.0),
                    g.f64_in(0.0, H - 200.0),
                    g.f64_in(60.0, 120.0),
                    g.f64_in(80.0, 160.0),
                )
            })
            .collect();
        let diag = (W * W + H * H).sqrt();

        let speed_at_gap = |gap: u64| {
            let mut fx = raw_extractor();
            for k in 0..6u64 {
                let f = 1 + k * gap;
                let t = (f - 1) as f64;
                let moved: Vec<Detection> = base
                    .iter()
                    .map(|d| {
                        det(
                            d.bbox.x + vx * t,
                            d.bbox.y + vy * t,
                            d.bbox.w,
                            d.bbox.h,
                        )
                    })
                    .collect();
                fx.on_detections(f, &moved);
            }
            fx.speed()
        };

        let dense = speed_at_gap(1);
        let sparse = speed_at_gap(gap);
        let expect = (vx * vx + vy * vy).sqrt() / diag;
        (dense - expect).abs() < 1e-9 && (sparse - expect).abs() < 1e-9
    });
}

#[test]
fn mbbs_is_monotone_under_uniform_box_scaling() {
    // scaling every box by s >= 1 must not shrink the MBBS channel —
    // the monotonicity Algorithm 1's thresholds assume
    PropConfig::default().run("mbbs monotone in scale", |g| {
        let dets = random_dets(g, g.usize_in(1, 15));
        let s = g.f64_in(1.0, 3.0);
        let scaled: Vec<Detection> = dets
            .iter()
            .map(|d| det(d.bbox.x, d.bbox.y, d.bbox.w * s, d.bbox.h * s))
            .collect();
        let fx = FeatureExtractor::new(W, H);
        let base = fx.features(&dets);
        let grown = fx.features(&scaled);
        // areas scale by s^2 exactly, so the median does too
        (grown.mbbs - base.mbbs * s * s).abs() < 1e-12
            && grown.mbbs >= base.mbbs - 1e-12
            && (grown.density - base.density * s * s).abs() < 1e-9
    });
}

#[test]
fn empty_and_single_frame_extraction_is_defined() {
    PropConfig::with_cases(64).run("empty/single defined", |g| {
        // no snapshots at all: every channel is at its neutral value
        let fx = FeatureExtractor::new(W, H);
        let none = fx.features(&[]);
        if none
            != (FrameFeatures { mbbs: 0.0, count: 0, density: 0.0, speed: 0.0 })
        {
            return false;
        }

        // exactly one snapshot: features are defined, speed stays 0
        // (two distinct snapshots are needed for motion)
        let mut fx = raw_extractor();
        let dets = random_dets(g, g.usize_in(0, 8));
        fx.on_detections(1, &dets);
        let f = fx.features(&dets);
        f.speed == 0.0
            && f.count == dets.len()
            && f.mbbs.is_finite()
            && f.density.is_finite()
    });
}

#[test]
fn speed_resets_with_the_stream() {
    PropConfig::with_cases(32).run("reset clears speed", |g| {
        let mut fx = raw_extractor();
        // one large box, shifted well inside the IoU gate, so the
        // match (and hence a non-zero speed) is guaranteed
        let a = vec![det(
            g.f64_in(50.0, W - 200.0),
            g.f64_in(50.0, H - 200.0),
            g.f64_in(60.0, 120.0),
            g.f64_in(80.0, 160.0),
        )];
        fx.on_detections(1, &a);
        let shifted: Vec<Detection> = a
            .iter()
            .map(|d| det(d.bbox.x + 6.0, d.bbox.y, d.bbox.w, d.bbox.h))
            .collect();
        fx.on_detections(2, &shifted);
        let moving = fx.speed() > 0.0;
        fx.reset();
        moving && fx.speed() == 0.0
    });
}
