//! The seven MOT17Det-like sequences used throughout the paper.
//!
//! Each spec mirrors the real sequence's resolution, length, frame rate,
//! camera motion class, crowd density and — most importantly for TOD —
//! the object-size and apparent-speed statistics (the knobs the paper's
//! policy responds to). MOT17-02/-04/-10 come from static cameras,
//! -05/-09/-11 from a camera at walking speed, and -13 from a car-mounted
//! camera (§III.B.4 and §IV).

use crate::dataset::synth::{CameraMotion, Sequence, SequenceSpec};

/// Identifier for the seven sequences.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum SequenceId {
    Mot02,
    Mot04,
    Mot05,
    Mot09,
    Mot10,
    Mot11,
    Mot13,
}

impl SequenceId {
    /// The six training sequences of Table I, in the paper's order.
    pub const TRAIN: [SequenceId; 6] = [
        SequenceId::Mot02,
        SequenceId::Mot04,
        SequenceId::Mot09,
        SequenceId::Mot10,
        SequenceId::Mot11,
        SequenceId::Mot13,
    ];

    /// All seven sequences (train + the MOT17-05 test sequence).
    pub const ALL: [SequenceId; 7] = [
        SequenceId::Mot02,
        SequenceId::Mot04,
        SequenceId::Mot05,
        SequenceId::Mot09,
        SequenceId::Mot10,
        SequenceId::Mot11,
        SequenceId::Mot13,
    ];

    pub fn name(self) -> &'static str {
        match self {
            SequenceId::Mot02 => "MOT17-02",
            SequenceId::Mot04 => "MOT17-04",
            SequenceId::Mot05 => "MOT17-05",
            SequenceId::Mot09 => "MOT17-09",
            SequenceId::Mot10 => "MOT17-10",
            SequenceId::Mot11 => "MOT17-11",
            SequenceId::Mot13 => "MOT17-13",
        }
    }

    /// The FPS constraint the paper evaluates under: 30 FPS everywhere
    /// except MOT17-05, whose native rate is 14 FPS (§IV.B.2).
    pub fn eval_fps(self) -> f64 {
        match self {
            SequenceId::Mot05 => 14.0,
            _ => 30.0,
        }
    }
}

impl std::str::FromStr for SequenceId {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let norm = s.to_uppercase();
        for id in SequenceId::ALL {
            if id.name() == norm
                || norm == format!("{:02}", seq_number(id))
                || norm == format!("MOT17-{:02}", seq_number(id))
            {
                return Ok(id);
            }
        }
        Err(format!("unknown sequence: {s}"))
    }
}

fn seq_number(id: SequenceId) -> u32 {
    match id {
        SequenceId::Mot02 => 2,
        SequenceId::Mot04 => 4,
        SequenceId::Mot05 => 5,
        SequenceId::Mot09 => 9,
        SequenceId::Mot10 => 10,
        SequenceId::Mot11 => 11,
        SequenceId::Mot13 => 13,
    }
}

/// Build the spec for a sequence.
///
/// Size/speed calibration (nominal MBBS as fraction of the frame):
/// * static group (02, 04, 10): small-to-medium boxes, MBBS ≲ 0.007 — the
///   region where the paper's TOD "stays with YOLOv4-416";
/// * walking group (09, 11): large boxes, MBBS around 0.03–0.05;
///   MOT17-11 gets a wide depth range for the high variance of Fig. 9;
/// * MOT17-05: close-range 640x480 footage, MBBS > 0.04 (TOD picks
///   YOLOv4-tiny-288 84.5% of the time, Fig. 10/12);
/// * MOT17-13: small fast boxes from a car — heavy nets are selected but
///   drop frames, the regime where TOD concedes accuracy (§V).
pub fn sequence_spec(id: SequenceId) -> SequenceSpec {
    match id {
        SequenceId::Mot02 => SequenceSpec {
            name: "MOT17-02".into(),
            width: 1920,
            height: 1080,
            fps: 30.0,
            frames: 600,
            density: 26,
            ref_height: 380.0,
            depth_range: (1.4, 2.8),
            walk_speed: 1.6,
            camera: CameraMotion::Static,
            seed: 0x1702,
        },
        SequenceId::Mot04 => SequenceSpec {
            name: "MOT17-04".into(),
            width: 1920,
            height: 1080,
            fps: 30.0,
            frames: 1050,
            density: 42,
            ref_height: 340.0,
            depth_range: (2.2, 3.4),
            walk_speed: 1.2,
            camera: CameraMotion::Static,
            seed: 0x1704,
        },
        SequenceId::Mot05 => SequenceSpec {
            name: "MOT17-05".into(),
            width: 640,
            height: 480,
            fps: 14.0,
            frames: 837,
            density: 7,
            ref_height: 330.0,
            depth_range: (1.1, 2.1),
            walk_speed: 1.4,
            camera: CameraMotion::Walking { pan_speed: 32.0 },
            seed: 0x1705,
        },
        SequenceId::Mot09 => SequenceSpec {
            name: "MOT17-09".into(),
            width: 1920,
            height: 1080,
            fps: 30.0,
            frames: 525,
            density: 9,
            ref_height: 755.0,
            depth_range: (1.0, 2.0),
            walk_speed: 1.8,
            camera: CameraMotion::Walking { pan_speed: 30.0 },
            seed: 0x1709,
        },
        SequenceId::Mot10 => SequenceSpec {
            name: "MOT17-10".into(),
            width: 1920,
            height: 1080,
            fps: 30.0,
            frames: 654,
            density: 20,
            ref_height: 330.0,
            depth_range: (1.3, 2.6),
            walk_speed: 2.2,
            camera: CameraMotion::Static,
            seed: 0x170a,
        },
        SequenceId::Mot11 => SequenceSpec {
            name: "MOT17-11".into(),
            width: 1920,
            height: 1080,
            fps: 30.0,
            frames: 900,
            density: 12,
            ref_height: 900.0,
            // wide depth range -> high MBBS variance (Fig. 9)
            depth_range: (1.0, 3.2),
            walk_speed: 2.0,
            camera: CameraMotion::Walking { pan_speed: 22.0 },
            seed: 0x170b,
        },
        SequenceId::Mot13 => SequenceSpec {
            name: "MOT17-13".into(),
            width: 1920,
            height: 1080,
            fps: 30.0,
            frames: 750,
            density: 16,
            ref_height: 280.0,
            depth_range: (1.6, 3.4),
            walk_speed: 2.5,
            camera: CameraMotion::Vehicle { flow_speed: 10.0 },
            seed: 0x170d,
        },
    }
}

/// Generate all seven sequences (deterministic).
pub fn mot17det_catalog() -> Vec<Sequence> {
    SequenceId::ALL
        .iter()
        .map(|&id| Sequence::generate(sequence_spec(id)))
        .collect()
}

/// Generate one sequence by id.
pub fn generate(id: SequenceId) -> Sequence {
    Sequence::generate(sequence_spec(id))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::median;

    #[test]
    fn parse_names() {
        assert_eq!("MOT17-04".parse::<SequenceId>().unwrap(), SequenceId::Mot04);
        assert_eq!("mot17-13".parse::<SequenceId>().unwrap(), SequenceId::Mot13);
        assert_eq!("05".parse::<SequenceId>().unwrap(), SequenceId::Mot05);
        assert!("MOT17-99".parse::<SequenceId>().is_err());
    }

    #[test]
    fn eval_fps_matches_paper() {
        assert_eq!(SequenceId::Mot05.eval_fps(), 14.0);
        assert_eq!(SequenceId::Mot04.eval_fps(), 30.0);
    }

    #[test]
    fn camera_groups_match_paper() {
        use CameraMotion::*;
        assert!(matches!(sequence_spec(SequenceId::Mot02).camera, Static));
        assert!(matches!(sequence_spec(SequenceId::Mot04).camera, Static));
        assert!(matches!(sequence_spec(SequenceId::Mot10).camera, Static));
        assert!(matches!(sequence_spec(SequenceId::Mot05).camera, Walking { .. }));
        assert!(matches!(sequence_spec(SequenceId::Mot09).camera, Walking { .. }));
        assert!(matches!(sequence_spec(SequenceId::Mot11).camera, Walking { .. }));
        assert!(matches!(sequence_spec(SequenceId::Mot13).camera, Vehicle { .. }));
    }

    #[test]
    fn size_regimes_span_the_policy_regions() {
        // static group small, walking group large, MOT17-13 smallest —
        // this is what makes the paper's thresholds meaningful
        let frac = |id| {
            let s = generate(id);
            median(&s.mbbs_series())
        };
        let m04 = frac(SequenceId::Mot04);
        let m09 = frac(SequenceId::Mot09);
        let m05 = frac(SequenceId::Mot05);
        let m13 = frac(SequenceId::Mot13);
        assert!(m04 < 0.007, "MOT17-04 median {m04} should be <= h1");
        assert!(m09 > 0.02, "MOT17-09 median {m09} should be walking-large");
        assert!(m05 > 0.04, "MOT17-05 median {m05} should exceed h3");
        assert!(m13 < 0.007, "MOT17-13 median {m13} should be small");
    }

    #[test]
    fn mot11_variance_exceeds_mot04() {
        // Fig. 9: MOT17-04 (static) has low MBBS variance, MOT17-11
        // (moving camera) high variance
        let var = |id| {
            let series = generate(id).mbbs_series();
            let m = series.iter().sum::<f64>() / series.len() as f64;
            series.iter().map(|v| (v - m).powi(2)).sum::<f64>()
                / series.len() as f64
                / (m * m) // relative variance
        };
        assert!(var(SequenceId::Mot11) > var(SequenceId::Mot04));
    }

    #[test]
    fn sequence_lengths_match_mot17() {
        assert_eq!(sequence_spec(SequenceId::Mot02).frames, 600);
        assert_eq!(sequence_spec(SequenceId::Mot04).frames, 1050);
        assert_eq!(sequence_spec(SequenceId::Mot05).frames, 837);
        assert_eq!(sequence_spec(SequenceId::Mot09).frames, 525);
        assert_eq!(sequence_spec(SequenceId::Mot10).frames, 654);
        assert_eq!(sequence_spec(SequenceId::Mot11).frames, 900);
        assert_eq!(sequence_spec(SequenceId::Mot13).frames, 750);
    }
}
