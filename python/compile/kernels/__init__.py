"""L1 Pallas kernels for the TOD detector hot-spots.

``fused_matmul.fused_matmul_bias_act`` — tiled matmul + bias + activation
(the im2col convolution core); ``pool.maxpool2x2`` — stride-2 max-pool.
``ref`` holds the pure-jnp oracles used by the test suite.
"""

from .fused_matmul import (  # noqa: F401
    fused_matmul_bias_act,
    mxu_utilisation_estimate,
    vmem_footprint_bytes,
)
from .pool import maxpool2x2  # noqa: F401
