//! Deterministic PRNG: xoshiro256++ seeded via SplitMix64.
//!
//! Every stochastic component in the simulator (world generation, oracle
//! detector noise, latency jitter) takes an explicit [`Rng`] so whole
//! experiment campaigns replay bit-identically from a single seed.

/// xoshiro256++ PRNG (Blackman & Vigna), seeded through SplitMix64 so any
/// `u64` — including 0 — is a valid seed.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller output.
    gauss_spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent child stream (for per-sequence / per-frame
    /// sub-streams that must not perturb each other).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)`; `n` must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Lemire-style rejection-free for our (small-n) uses: the modulo
        // bias at n << 2^64 is < 2^-50, far below simulator noise.
        (self.next_u64() % n as u64) as usize
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn gauss(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * th.sin());
        r * th.cos()
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gauss()
    }

    /// Poisson-distributed count (Knuth's method; fine for small λ).
    pub fn poisson(&mut self, lambda: f64) -> usize {
        if lambda <= 0.0 {
            return 0;
        }
        let l = (-lambda).exp();
        let mut k = 0usize;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
            if k > 10_000 {
                return k; // guard against pathological λ
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        let mut c = Rng::new(43);
        let va: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..16).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::new(3);
        let lambda = 2.5;
        let n = 20_000;
        let total: usize = (0..n).map(|_| r.poisson(lambda)).sum();
        let mean = total as f64 / n as f64;
        assert!((mean - lambda).abs() < 0.1, "mean={mean}");
        assert_eq!(r.poisson(0.0), 0);
        assert_eq!(r.poisson(-1.0), 0);
    }

    #[test]
    fn below_covers_range() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(1);
        let mut f1 = root.fork(1);
        let mut f2 = root.fork(2);
        let a: Vec<u64> = (0..8).map(|_| f1.next_u64()).collect();
        let b: Vec<u64> = (0..8).map(|_| f2.next_u64()).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
