//! Figures 11, 13, 14, 15: memory, GPU utilisation and power.

use crate::app::Campaign;
use crate::dataset::catalog::SequenceId;
use crate::sim::profiles::mem_loaded_gb;
use crate::telemetry::tegrastats::TegrastatsSim;
use crate::util::csv::CsvTable;
use crate::util::table::{sparkline, AsciiTable};
use crate::DnnKind;

use super::ExperimentOutput;

/// Fig. 11: memory allocation per DNN configuration.
pub fn fig11_memory() -> ExperimentOutput {
    let mut table = AsciiTable::new(
        "Fig. 11 — Memory Allocation on Jetson Nano (GB)",
        vec!["configuration", "memory_gb", "paper_gb"],
    );
    let mut csv = CsvTable::new(vec!["configuration", "memory_gb", "paper_gb"]);
    let paper = [2.21, 2.21, 2.22, 2.56];
    for (k, p) in DnnKind::ALL.iter().zip(paper) {
        let row = vec![
            k.artifact_name().to_string(),
            format!("{:.2}", mem_loaded_gb(&[*k])),
            format!("{p:.2}"),
        ];
        table.push(row.clone());
        csv.push(row);
    }
    let row = vec![
        "TOD (all four)".to_string(),
        format!("{:.2}", mem_loaded_gb(&DnnKind::ALL)),
        "2.85".to_string(),
    ];
    table.push(row.clone());
    csv.push(row);
    let text = format!(
        "{}\n(1.5 GB allocated before loading any DNN; TOD ≈ +11% over \
         single YOLOv4-416)\n",
        table.render()
    );
    ExperimentOutput {
        id: "fig11",
        title: "Fig. 11: memory allocation".into(),
        text,
        csv: vec![("fig11_memory.csv".into(), csv)],
    }
}

/// Fig. 13: GPU utilisation trace for TOD on MOT17-05.
pub fn fig13_gpu(c: &mut Campaign) -> ExperimentOutput {
    let r = c.tod(SequenceId::Mot05).clone();
    let sim = TegrastatsSim::default();
    let samples = sim.sample(&r.trace);
    let series: Vec<f64> = samples.iter().map(|s| s.gpu_util_pct).collect();
    let mean = series.iter().sum::<f64>() / series.len().max(1) as f64;
    let mut csv = CsvTable::new(vec!["t_s", "gpu_util_pct"]);
    for s in &samples {
        csv.push(vec![format!("{:.0}", s.t), format!("{:.1}", s.gpu_util_pct)]);
    }
    // comparison: saturated single-DNN runs
    let y416 = sim.mean_gpu(&c.realtime_fixed(SequenceId::Mot05, DnnKind::Y416).trace);
    let text = format!(
        "Fig. 13 — GPU Utilisation, TOD on MOT17-05 (1 Hz)\n  {}\n\
         mean {:.1}% (paper: 41.1%); always-Y-416 uses {:.1}%; \
         TOD/Y-416 ratio {:.1}% (paper: 45.1%)\n",
        sparkline(&series),
        mean,
        y416,
        mean / y416 * 100.0
    );
    ExperimentOutput {
        id: "fig13",
        title: "Fig. 13: GPU utilisation".into(),
        text,
        csv: vec![("fig13_gpu.csv".into(), csv)],
    }
}

/// Fig. 14: power of each individual YOLO on MOT17-05.
pub fn fig14_power_single(c: &mut Campaign) -> ExperimentOutput {
    let sim = TegrastatsSim::default();
    let mut table = AsciiTable::new(
        "Fig. 14 — Mean Power, individual YOLOs on MOT17-05 (W)",
        vec!["dnn", "mean_power_w", "paper_w (active)"],
    );
    let mut csv = CsvTable::new(vec!["dnn", "mean_power_w", "paper_w"]);
    let paper = [3.8, 4.8, 7.2, 7.5];
    for (k, p) in DnnKind::ALL.iter().zip(paper) {
        let trace = c.realtime_fixed(SequenceId::Mot05, *k).trace.clone();
        let w = sim.mean_power(&trace);
        let row = vec![
            k.artifact_name().to_string(),
            format!("{w:.1}"),
            format!("{p:.1}"),
        ];
        table.push(row.clone());
        csv.push(row);
    }
    let text = format!(
        "{}\n(means include idle time between inferences; the paper plots \
         active-phase power while the DNN is saturating the GPU)\n",
        table.render()
    );
    ExperimentOutput {
        id: "fig14",
        title: "Fig. 14: single-DNN power".into(),
        text,
        csv: vec![("fig14_power_single.csv".into(), csv)],
    }
}

/// Fig. 15: power trace for TOD on MOT17-05.
pub fn fig15_power_tod(c: &mut Campaign) -> ExperimentOutput {
    let r = c.tod(SequenceId::Mot05).clone();
    let sim = TegrastatsSim::default();
    let samples = sim.sample(&r.trace);
    let series: Vec<f64> = samples.iter().map(|s| s.power_w).collect();
    let mean = series.iter().sum::<f64>() / series.len().max(1) as f64;
    let mut csv = CsvTable::new(vec!["t_s", "power_w"]);
    for s in &samples {
        csv.push(vec![format!("{:.0}", s.t), format!("{:.2}", s.power_w)]);
    }
    let y416 =
        sim.mean_power(&c.realtime_fixed(SequenceId::Mot05, DnnKind::Y416).trace);
    let text = format!(
        "Fig. 15 — Power, TOD on MOT17-05 (1 Hz)\n  {}\n\
         mean {:.1} W (paper: 4.7 W); always-Y-416 {:.1} W; \
         TOD/Y-416 ratio {:.1}% (paper: 62.7%)\n",
        sparkline(&series),
        mean,
        y416,
        mean / y416 * 100.0
    );
    ExperimentOutput {
        id: "fig15",
        title: "Fig. 15: TOD power".into(),
        text,
        csv: vec![("fig15_power_tod.csv".into(), csv)],
    }
}
