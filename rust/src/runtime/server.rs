//! Multi-producer micro-batching inference server.
//!
//! Concurrent streams submit [`InferRequest`]s; the server collects
//! them into per-DNN micro-batches (size- and deadline-bounded, see
//! [`super::batch`]), dispatches each batch as one job, and hands every
//! submitter a [`ResultHandle`] it can block on. Three invariants make
//! the path production-shaped:
//!
//! * **Panic-free**: every request resolves to a `Result`. An engine
//!   error fails its own request; a *panic* inside the backend is
//!   caught per item, so a poisoned batch fails only the requests in
//!   it — the process, the workers and the other streams keep going.
//! * **Admission-controlled**: the pending queue is bounded
//!   ([`crate::runtime::batch::BatchConfig::queue_cap`]); overload
//!   either blocks the submitter (backpressure) or sheds the request
//!   with [`AdmitError::QueueFull`], per
//!   [`crate::runtime::batch::AdmissionPolicy`].
//! * **No silent loss**: a dropped (never-executed) batch job fails its
//!   requests with [`ServeError::Shutdown`] instead of leaving waiters
//!   parked forever.
//!
//! [`ServerCore`] is the engine-agnostic heart (queues + completion
//! plumbing): any thread may pump it via [`ServerCore::next_batch`] and
//! execute batches wherever it likes — the PJRT demo pumps on the
//! thread that owns the engine pool, so compiled executables never
//! cross threads. [`InferenceServer`] is the turnkey threaded front:
//! a dispatcher thread pops due batches and runs them on the crate's
//! [`ThreadPool`] against a shared [`BatchDetector`].

// Serving path: a NaN, a dead engine or a poisoned lock must surface
// as a value, never a panic.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use crate::dataset::mot::GtEntry;
use crate::detection::Detection;
use crate::exec::pool::ThreadPool;
use crate::runtime::batch::{
    AdmissionPolicy, BatchConfig, BatchStats, MicroBatcher,
};
use crate::DnnKind;

/// One inference request from one stream.
#[derive(Debug, Clone)]
pub struct InferRequest {
    /// Caller-chosen stream tag (diagnostics only).
    pub stream: u64,
    /// 1-based frame id within the stream.
    pub frame: u64,
    /// Variant the stream's policy selected.
    pub dnn: DnnKind,
    /// Source frame dimensions (the decode scale).
    pub frame_w: f64,
    pub frame_h: f64,
    /// The frame payload of this reproduction: ground-truth boxes the
    /// backend rasterizes into the input image.
    pub gt: Vec<GtEntry>,
}

/// Why one request failed. Failures are per request by construction.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The backend reported an error (missing variant, PJRT failure,
    /// malformed output).
    Engine(String),
    /// The backend panicked while executing this request's batch; the
    /// panic was caught and confined to the affected items.
    BatchPanicked,
    /// The server shut down (or lost its workers) before the request
    /// ran.
    Shutdown,
    /// The request was never admitted (shed under overload, or the
    /// server closed to new work) — distinct from [`Self::Engine`] so
    /// operators can tell deliberate load shedding from a dying
    /// backend.
    NotAdmitted(AdmitError),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Engine(msg) => write!(f, "engine error: {msg}"),
            ServeError::BatchPanicked => {
                f.write_str("backend panicked while serving this batch")
            }
            ServeError::Shutdown => {
                f.write_str("server shut down before the request ran")
            }
            ServeError::NotAdmitted(e) => {
                write!(f, "not admitted: {e}")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// Per-request inference outcome.
pub type ServeResult = Result<Vec<Detection>, ServeError>;

/// Why a submission was not admitted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitError {
    /// Shed-mode admission control rejected the request (queue full).
    QueueFull,
    /// The server is closed to new work.
    Shutdown,
}

impl std::fmt::Display for AdmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmitError::QueueFull => {
                f.write_str("request shed: pending queue full")
            }
            AdmitError::Shutdown => f.write_str("server closed"),
        }
    }
}

impl std::error::Error for AdmitError {}

/// Batch execution backend for the threaded [`InferenceServer`].
///
/// `infer` must be callable from any worker thread. `on_batch_start`
/// fires once per dispatched batch before its items run — backends
/// model (or perform) per-dispatch setup there, so batching has
/// something to amortise.
pub trait BatchDetector: Send + Sync {
    /// Run one request.
    fn infer(&self, req: &InferRequest) -> ServeResult;

    /// Called once before a batch of `n` same-variant requests runs.
    fn on_batch_start(&self, dnn: DnnKind, n: usize) {
        let _ = (dnn, n);
    }
}

/// Recover the guard from a poisoned lock: the server must keep
/// serving other requests even after a panic somewhere else poisoned a
/// mutex (the panic itself was already confined by `catch_unwind`).
fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// One-shot completion slot shared by a request and its executor.
///
/// Resolution is tracked by a flag separate from the result's
/// presence: taking the result (via `wait`/`try_wait`) must not reopen
/// the slot, or a late drop-guard write could overwrite a delivered
/// success with a spurious shutdown error.
struct Completion {
    slot: Mutex<Slot>,
    ready: Condvar,
}

struct Slot {
    result: Option<ServeResult>,
    resolved: bool,
}

impl Completion {
    fn new() -> Arc<Completion> {
        Arc::new(Completion {
            slot: Mutex::new(Slot { result: None, resolved: false }),
            ready: Condvar::new(),
        })
    }

    /// First write wins; later writes (e.g. the drop guard after a
    /// normal completion) are no-ops — even after the first result has
    /// already been taken by a waiter.
    fn fulfil(&self, result: ServeResult) {
        let mut slot = lock_unpoisoned(&self.slot);
        if !slot.resolved {
            slot.resolved = true;
            slot.result = Some(result);
            self.ready.notify_all();
        }
    }
}

/// Waitable handle for one submitted request.
pub struct ResultHandle {
    done: Arc<Completion>,
}

impl ResultHandle {
    /// Block until the request resolves. Every admitted request
    /// resolves: completed batches fulfil normally, and batches that
    /// are dropped unexecuted fail their requests with
    /// [`ServeError::Shutdown`]. If the result was already consumed by
    /// an earlier [`try_wait`](Self::try_wait), reports `Shutdown`
    /// rather than hanging.
    pub fn wait(self) -> ServeResult {
        let mut slot = lock_unpoisoned(&self.done.slot);
        loop {
            if let Some(result) = slot.result.take() {
                return result;
            }
            if slot.resolved {
                return Err(ServeError::Shutdown);
            }
            slot = self
                .done
                .ready
                .wait(slot)
                .unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Non-blocking probe; `Some` exactly once, when the result is in.
    pub fn try_wait(&self) -> Option<ServeResult> {
        lock_unpoisoned(&self.done.slot).result.take()
    }
}

/// One queued request plus its completion slot.
pub struct BatchJob {
    req: InferRequest,
    done: Arc<Completion>,
}

impl BatchJob {
    pub fn request(&self) -> &InferRequest {
        &self.req
    }

    /// Resolve this request.
    pub fn complete(self, result: ServeResult) {
        self.done.fulfil(result);
    }
}

/// A never-executed job must not strand its waiter.
impl Drop for BatchJob {
    fn drop(&mut self) {
        self.done.fulfil(Err(ServeError::Shutdown));
    }
}

/// One flushed micro-batch: same-variant jobs ready to execute.
///
/// The job buffer is pooled: once the batch is done (executed, or
/// dropped unexecuted on shutdown) the emptied `Vec` returns to its
/// server's spare-buffer pool, so the steady-state dispatch path
/// flushes batches without allocating.
pub struct MicroBatch {
    dnn: DnnKind,
    jobs: Vec<BatchJob>,
    /// Pool to hand the emptied job buffer back to (None only for
    /// batches detached from a core, which never happens today).
    recycle: Option<Arc<SparePool>>,
}

impl MicroBatch {
    pub fn dnn(&self) -> DnnKind {
        self.dnn
    }

    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// Execute every job with `infer`, catching panics **per item**: a
    /// panicking request resolves to [`ServeError::BatchPanicked`] and
    /// the rest of the batch still runs.
    pub fn run_with(
        mut self,
        infer: &mut dyn FnMut(&InferRequest) -> ServeResult,
    ) {
        // drain in place so the buffer (and its capacity) survives for
        // the drop-time recycle
        for job in self.jobs.drain(..) {
            let outcome =
                catch_unwind(AssertUnwindSafe(|| infer(job.request())));
            match outcome {
                Ok(result) => job.complete(result),
                Err(_) => job.complete(Err(ServeError::BatchPanicked)),
            }
        }
    }

    /// Execute against a [`BatchDetector`] (setup hook + per-item run).
    pub fn run(mut self, detector: &dyn BatchDetector) {
        let n = self.len();
        let dnn = self.dnn;
        if catch_unwind(AssertUnwindSafe(|| {
            detector.on_batch_start(dnn, n)
        }))
        .is_err()
        {
            // a panicking setup poisons the whole batch — but only the
            // batch: each request resolves instead of the process dying
            for job in self.jobs.drain(..) {
                job.complete(Err(ServeError::BatchPanicked));
            }
            return;
        }
        self.run_with(&mut |req| detector.infer(req));
    }
}

/// Returns the item buffer to the spare pool. Any still-queued jobs
/// drop first, so their guards fail the waiters with
/// [`ServeError::Shutdown`] — pooling never changes loss semantics.
impl Drop for MicroBatch {
    fn drop(&mut self) {
        if let Some(pool) = self.recycle.take() {
            recycle_buf(&pool, std::mem::take(&mut self.jobs));
        }
    }
}

/// What [`ServerCore::next_batch`] observed.
pub enum BatchPoll {
    /// A due batch, ready to execute.
    Batch(MicroBatch),
    /// Nothing came due within the wait budget.
    Idle,
    /// The server is closed and every pending request has been handed
    /// out: the pump loop can stop.
    Drained,
}

struct CoreState {
    batcher: MicroBatcher<BatchJob>,
    closed: bool,
}

/// Recycled micro-batch item buffers (each retains `max_batch`
/// capacity after its first use). Separate from the state lock so a
/// batch finishing on a worker thread never contends with the
/// dispatcher or submitters.
type SparePool = Mutex<Vec<Vec<BatchJob>>>;

/// Spare buffers beyond this are dropped rather than hoarded; the pool
/// only needs to cover the in-flight batch high water (dispatcher +
/// worker pool), which is far below this.
const SPARE_CAP: usize = 32;

/// Clear a buffer and return it to the pool (bounded: excess drops).
/// Jobs are cleared *before* the pool lock is taken — their drop
/// guards resolve completions, which must not run under the pool lock.
fn recycle_buf(pool: &SparePool, mut buf: Vec<BatchJob>) {
    buf.clear();
    let mut spare = lock_unpoisoned(pool);
    if spare.len() < SPARE_CAP {
        spare.push(buf);
    }
}

struct CoreShared {
    state: Mutex<CoreState>,
    /// Pump wake-up: new work, a newly due batch, or close.
    kick: Condvar,
    /// Submitter wake-up: queue space freed, or close.
    space: Condvar,
    cfg: BatchConfig,
    stats: Mutex<BatchStats>,
    /// Spare item buffers cycling pool → batch → pool; `Arc` so a
    /// [`MicroBatch`] can self-recycle without keeping the whole core
    /// (condvars included) alive.
    spare: Arc<SparePool>,
}

/// Engine-agnostic server core: bounded admission, per-DNN
/// micro-batching, completion handles. Clone handles freely — all
/// clones share one queue.
#[derive(Clone)]
pub struct ServerCore {
    shared: Arc<CoreShared>,
}

impl ServerCore {
    /// Panics only on an invalid config (see
    /// [`BatchConfig::validate`]); prefer validating CLI input first.
    pub fn new(cfg: BatchConfig) -> ServerCore {
        if let Err(e) = cfg.validate() {
            // tod-lint: allow(srv-panic) reason="documented construction-time contract; CLI validates first, no request exists yet"
            panic!("invalid batch config: {e}");
        }
        // reserve the admission bound up front: once every variant has
        // warmed to its peak occupancy the queues never reallocate
        let batcher = MicroBatcher::with_queue_capacity(
            cfg.max_batch,
            cfg.max_wait,
            cfg.queue_cap,
        );
        ServerCore {
            shared: Arc::new(CoreShared {
                state: Mutex::new(CoreState { batcher, closed: false }),
                kick: Condvar::new(),
                space: Condvar::new(),
                cfg,
                stats: Mutex::new(BatchStats::default()),
                spare: Arc::new(Mutex::new(Vec::new())),
            }),
        }
    }

    /// Submit one request; returns a handle the caller can block on.
    ///
    /// At capacity, [`AdmissionPolicy::Block`] waits for space while
    /// [`AdmissionPolicy::Shed`] fails fast with
    /// [`AdmitError::QueueFull`].
    pub fn submit(
        &self,
        req: InferRequest,
    ) -> Result<ResultHandle, AdmitError> {
        let sh = &self.shared;
        let mut st = lock_unpoisoned(&sh.state);
        loop {
            if st.closed {
                return Err(AdmitError::Shutdown);
            }
            if st.batcher.len() < sh.cfg.queue_cap {
                break;
            }
            match sh.cfg.admission {
                AdmissionPolicy::Shed => {
                    drop(st);
                    lock_unpoisoned(&sh.stats).shed += 1;
                    return Err(AdmitError::QueueFull);
                }
                AdmissionPolicy::Block => {
                    st = sh
                        .space
                        .wait(st)
                        .unwrap_or_else(PoisonError::into_inner);
                }
            }
        }
        let done = Completion::new();
        let dnn = req.dnn;
        st.batcher.push(
            dnn,
            BatchJob { req, done: done.clone() },
            Instant::now(),
        );
        drop(st);
        // wake the pump: the push may have completed a batch or armed
        // the first deadline
        sh.kick.notify_all();
        Ok(ResultHandle { done })
    }

    /// Stop admitting work. Pending requests still flush: keep pumping
    /// [`next_batch`](Self::next_batch) until it returns
    /// [`BatchPoll::Drained`] (blocked submitters are woken and fail
    /// with [`AdmitError::Shutdown`]).
    pub fn close(&self) {
        lock_unpoisoned(&self.shared.state).closed = true;
        self.shared.kick.notify_all();
        self.shared.space.notify_all();
    }

    /// Pending (admitted, undispatched) requests.
    pub fn pending(&self) -> usize {
        lock_unpoisoned(&self.shared.state).batcher.len()
    }

    /// Peak simultaneous queue occupancy since start (all variants) —
    /// feed to [`crate::obs::MetricsRegistry::observe_queue_depth`].
    pub fn queue_high_water(&self) -> usize {
        lock_unpoisoned(&self.shared.state).batcher.high_water()
    }

    /// Snapshot of the batch/admission statistics.
    pub fn stats(&self) -> BatchStats {
        lock_unpoisoned(&self.shared.stats).clone()
    }

    /// Wait up to `idle_timeout` for a batch to come due and pop it.
    ///
    /// Size-complete queues pop immediately; otherwise the call parks
    /// until the earliest deadline (or a kick) and re-checks. After
    /// [`close`](Self::close), every remaining request flushes
    /// immediately regardless of deadlines, then the poll reports
    /// [`BatchPoll::Drained`].
    pub fn next_batch(&self, idle_timeout: Duration) -> BatchPoll {
        let sh = &self.shared;
        let started = Instant::now();
        // take a recycled item buffer up front (the pop fills a
        // caller-owned Vec in place): early calls pay one allocation
        // each, then buffers cycle pool → batch → pool and the flush
        // path allocates nothing — pinned by the alloc-free test below
        let mut buf = lock_unpoisoned(&sh.spare)
            .pop()
            .unwrap_or_else(|| Vec::with_capacity(sh.cfg.max_batch));
        let mut st = lock_unpoisoned(&sh.state);
        loop {
            let now = Instant::now();
            let popped = if st.closed {
                st.batcher.pop_any_into(&mut buf)
            } else {
                st.batcher.pop_due_into(now, &mut buf)
            };
            if let Some(dnn) = popped {
                drop(st);
                sh.space.notify_all();
                lock_unpoisoned(&sh.stats).record(dnn, buf.len());
                return BatchPoll::Batch(MicroBatch {
                    dnn,
                    jobs: buf,
                    // tod-lint: allow(hot-clone) reason="Arc refcount bump handing the recycle pool to the batch, not a deep copy"
                    recycle: Some(sh.spare.clone()),
                });
            }
            if st.closed && st.batcher.is_empty() {
                drop(st);
                recycle_buf(&sh.spare, buf);
                return BatchPoll::Drained;
            }
            let elapsed = started.elapsed();
            if elapsed >= idle_timeout {
                drop(st);
                recycle_buf(&sh.spare, buf);
                return BatchPoll::Idle;
            }
            let mut wait = idle_timeout - elapsed;
            if let Some(deadline) = st.batcher.next_deadline() {
                wait = wait.min(deadline.saturating_duration_since(now));
            }
            // zero-duration waits still yield the lock; clamp to a
            // minimal park so a due-at-now race cannot spin hot
            wait = wait.max(Duration::from_micros(50));
            let (guard, _timeout) = sh
                .kick
                .wait_timeout(st, wait)
                .unwrap_or_else(PoisonError::into_inner);
            st = guard;
        }
    }
}

/// Turnkey threaded server: a dispatcher thread pops due batches off a
/// [`ServerCore`] and executes them on the crate's [`ThreadPool`]
/// against a shared [`BatchDetector`].
pub struct InferenceServer {
    core: ServerCore,
    pool: Arc<ThreadPool>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
}

impl InferenceServer {
    /// Start the dispatcher and `workers` pool workers.
    pub fn start(
        detector: Arc<dyn BatchDetector>,
        cfg: BatchConfig,
        workers: usize,
    ) -> InferenceServer {
        let core = ServerCore::new(cfg);
        let pool =
            Arc::new(ThreadPool::new(workers.max(1), workers.max(1) * 2));
        let pump_core = core.clone();
        let pump_pool = pool.clone();
        let dispatcher = std::thread::Builder::new()
            .name("tod-batch-dispatch".into())
            .spawn(move || loop {
                match pump_core.next_batch(Duration::from_millis(20)) {
                    BatchPoll::Batch(batch) => {
                        let det = detector.clone();
                        // a failed submit (all workers dead) drops the
                        // closure; BatchJob's drop guard then fails the
                        // batch's requests with Shutdown instead of
                        // stranding their waiters
                        let _ = pump_pool.submit(move || batch.run(&*det));
                    }
                    BatchPoll::Idle => continue,
                    BatchPoll::Drained => break,
                }
            })
            .ok();
        InferenceServer { core, pool, dispatcher }
    }

    /// Submit one request (see [`ServerCore::submit`]).
    pub fn submit(
        &self,
        req: InferRequest,
    ) -> Result<ResultHandle, AdmitError> {
        // a dispatcher that failed to spawn would strand every waiter:
        // refuse admission instead
        if self.dispatcher.is_none() {
            return Err(AdmitError::Shutdown);
        }
        self.core.submit(req)
    }

    /// Batch/admission statistics so far.
    pub fn stats(&self) -> BatchStats {
        self.core.stats()
    }

    /// Pending (admitted, undispatched) requests.
    pub fn pending(&self) -> usize {
        self.core.pending()
    }

    /// Peak simultaneous queue occupancy since start (all variants).
    pub fn queue_high_water(&self) -> usize {
        self.core.queue_high_water()
    }

    /// Graceful shutdown: stop intake, flush pending batches, wait for
    /// in-flight work, return the final statistics.
    pub fn shutdown(mut self) -> BatchStats {
        self.finish();
        self.core.stats()
    }

    fn finish(&mut self) {
        self.core.close();
        if let Some(d) = self.dispatcher.take() {
            d.join().ok();
        }
        // all batches are submitted by now; wait for the workers
        self.pool.wait_idle();
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        self.finish();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::BBox;

    fn req(stream: u64, frame: u64, dnn: DnnKind) -> InferRequest {
        InferRequest {
            stream,
            frame,
            dnn,
            frame_w: 640.0,
            frame_h: 480.0,
            gt: Vec::new(),
        }
    }

    /// Deterministic synthetic backend: one box derived from the
    /// request identity, so batched results are comparable bit for bit.
    struct Synth;

    fn synth_infer(r: &InferRequest) -> ServeResult {
        Ok(vec![Detection::new(
            BBox::new(r.frame as f64, r.stream as f64, 10.0, 20.0),
            0.9,
            crate::detection::PERSON_CLASS,
        )])
    }

    impl BatchDetector for Synth {
        fn infer(&self, r: &InferRequest) -> ServeResult {
            synth_infer(r)
        }
    }

    #[test]
    fn core_serves_a_full_batch_inline() {
        let core = ServerCore::new(BatchConfig {
            max_batch: 2,
            max_wait: Duration::from_secs(3600),
            ..BatchConfig::default()
        });
        let h1 = core.submit(req(0, 1, DnnKind::Y416)).unwrap();
        let h2 = core.submit(req(1, 1, DnnKind::Y416)).unwrap();
        match core.next_batch(Duration::from_millis(200)) {
            BatchPoll::Batch(b) => {
                assert_eq!(b.dnn(), DnnKind::Y416);
                assert_eq!(b.len(), 2);
                b.run_with(&mut synth_infer);
            }
            _ => panic!("expected a due batch"),
        }
        let d1 = h1.wait().unwrap();
        let d2 = h2.wait().unwrap();
        assert_eq!(d1[0].bbox.y, 0.0);
        assert_eq!(d2[0].bbox.y, 1.0);
        let stats = core.stats();
        assert_eq!(stats.total_batches(), 1);
        assert_eq!(stats.total_items(), 2);
    }

    #[test]
    fn deadline_flushes_a_lone_request() {
        let core = ServerCore::new(BatchConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            ..BatchConfig::default()
        });
        let h = core.submit(req(0, 3, DnnKind::TinyY288)).unwrap();
        match core.next_batch(Duration::from_secs(5)) {
            BatchPoll::Batch(b) => {
                assert_eq!(b.len(), 1);
                b.run_with(&mut synth_infer);
            }
            _ => panic!("deadline flush did not fire"),
        }
        assert!(h.wait().is_ok());
    }

    #[test]
    fn shed_admission_rejects_at_capacity() {
        let core = ServerCore::new(BatchConfig {
            max_batch: 2,
            max_wait: Duration::from_secs(3600),
            queue_cap: 2,
            admission: AdmissionPolicy::Shed,
        });
        let _h1 = core.submit(req(0, 1, DnnKind::Y288)).unwrap();
        let _h2 = core.submit(req(1, 1, DnnKind::Y288)).unwrap();
        assert_eq!(
            core.submit(req(2, 1, DnnKind::Y288)).err(),
            Some(AdmitError::QueueFull)
        );
        assert_eq!(core.stats().shed, 1);
        assert_eq!(core.pending(), 2);
    }

    #[test]
    fn closed_core_drains_and_rejects() {
        let core = ServerCore::new(BatchConfig {
            max_batch: 8,
            max_wait: Duration::from_secs(3600),
            ..BatchConfig::default()
        });
        let h = core.submit(req(0, 1, DnnKind::Y416)).unwrap();
        core.close();
        assert_eq!(
            core.submit(req(1, 1, DnnKind::Y416)).err(),
            Some(AdmitError::Shutdown)
        );
        // pending work still flushes (regardless of its far deadline)...
        let BatchPoll::Batch(b) = core.next_batch(Duration::from_secs(5))
        else {
            panic!("closed core must flush pending work")
        };
        // ...and a batch dropped unexecuted fails its requests instead
        // of stranding them
        drop(b);
        assert_eq!(h.wait(), Err(ServeError::Shutdown));
        assert!(matches!(
            core.next_batch(Duration::from_millis(10)),
            BatchPoll::Drained
        ));
    }

    #[test]
    fn try_wait_delivers_exactly_once() {
        // regression: the job's drop guard must not re-open a slot
        // whose result was already taken (a second poll used to see a
        // spurious Shutdown error)
        let core = ServerCore::new(BatchConfig {
            max_batch: 1,
            max_wait: Duration::ZERO,
            ..BatchConfig::default()
        });
        let h = core.submit(req(0, 5, DnnKind::Y288)).unwrap();
        let BatchPoll::Batch(b) = core.next_batch(Duration::from_secs(5))
        else {
            panic!("batch due immediately at max_wait zero")
        };
        b.run_with(&mut synth_infer); // complete() then drop guard
        let first = h.try_wait().expect("result is in");
        assert!(first.is_ok());
        assert!(
            h.try_wait().is_none(),
            "second poll must not resurrect a result"
        );
    }

    #[test]
    fn steady_state_batch_flush_is_alloc_free() {
        // server-path extension of the batcher's alloc-free test: once
        // the spare pool is warm, pump → execute → recycle allocates
        // nothing per batch
        let core = ServerCore::new(BatchConfig {
            max_batch: 4,
            max_wait: Duration::ZERO,
            ..BatchConfig::default()
        });
        let mut handles = Vec::with_capacity(40);
        let mut pump = |core: &ServerCore| {
            let BatchPoll::Batch(b) =
                core.next_batch(Duration::from_secs(1))
            else {
                panic!("batch due immediately at max_wait zero")
            };
            let n = b.len();
            b.run_with(&mut |_| Ok(Vec::new()));
            n
        };
        // warm-up round: allocates the one pooled buffer + stats
        for i in 0..4 {
            handles.push(core.submit(req(i, 1, DnnKind::Y288)).unwrap());
        }
        assert_eq!(pump(&core), 4);
        for round in 0..8u64 {
            for i in 0..4 {
                handles.push(
                    core.submit(req(i, round + 2, DnnKind::Y288)).unwrap(),
                );
            }
            // submits allocate (completion slots); the flush must not
            let (delta, n) = crate::perf::count_allocs(|| pump(&core));
            assert_eq!(n, 4);
            assert_eq!(
                delta.allocs, 0,
                "round {round}: steady-state next_batch/run/recycle \
                 allocated ({} allocs, {} bytes)",
                delta.allocs, delta.bytes
            );
        }
        for h in handles {
            assert!(h.wait().is_ok());
        }
    }

    #[test]
    fn dropped_batch_recycles_its_buffer() {
        let core = ServerCore::new(BatchConfig {
            max_batch: 1,
            max_wait: Duration::ZERO,
            ..BatchConfig::default()
        });
        let h = core.submit(req(0, 1, DnnKind::Y416)).unwrap();
        let BatchPoll::Batch(b) = core.next_batch(Duration::from_secs(1))
        else {
            panic!("batch due immediately")
        };
        drop(b); // unexecuted: drop guard fails the request...
        assert_eq!(h.wait(), Err(ServeError::Shutdown));
        // ...and the buffer still made it back to the pool
        assert_eq!(lock_unpoisoned(&core.shared.spare).len(), 1);
    }

    #[test]
    fn threaded_server_round_trips() {
        let server = InferenceServer::start(
            Arc::new(Synth),
            BatchConfig::default(),
            2,
        );
        let handles: Vec<ResultHandle> = (0..16)
            .map(|i| {
                server
                    .submit(req(i % 4, i, DnnKind::ALL[(i % 4) as usize]))
                    .unwrap()
            })
            .collect();
        for (i, h) in handles.into_iter().enumerate() {
            let dets = h.wait().unwrap();
            assert_eq!(dets[0].bbox.x, i as f64);
        }
        let stats = server.shutdown();
        assert_eq!(stats.total_items(), 16);
        assert!(stats.total_batches() >= 4, "one batch per variant min");
    }
}
