//! Waiver fixture: honoured, reason-less, and stale waivers.

pub fn guarded() {
    // tod-lint: allow(srv-panic) reason="fixture: documented contract"
    panic!("guarded");
}

pub fn reasonless(x: Option<u32>) -> u32 {
    // tod-lint: allow(srv-unwrap)
    x.unwrap()
}

pub fn stale() {
    // tod-lint: allow(srv-expect) reason="fixture: nothing to waive"
    let _ = 1 + 1;
}
