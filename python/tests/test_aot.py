"""AOT path: HLO text artifacts + manifest consumed by the Rust runtime."""

import json
import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def tiny_hlo():
    return aot.lower_variant(model.VARIANTS["yolov4-tiny-288"])


def test_hlo_text_parseable_header(tiny_hlo):
    assert tiny_hlo.startswith("HloModule")


def test_hlo_constants_not_elided(tiny_hlo):
    """print_large_constants must be in effect — `{...}` elision would
    silently drop the baked weights on the Rust side."""
    assert "constant({...})" not in tiny_hlo


def test_hlo_has_single_image_parameter(tiny_hlo):
    # The ENTRY computation takes exactly one runtime parameter — the
    # image (weights are baked constants). Inner fusion computations may
    # have their own parameter(N) lines, so inspect only ENTRY's body.
    entry = tiny_hlo[tiny_hlo.index("ENTRY "):]
    body = entry[: entry.index("\n}")]
    param_lines = [
        ln for ln in body.splitlines() if "= f32" in ln and "parameter(" in ln
    ]
    assert len(param_lines) == 1, param_lines
    assert "parameter(0)" in param_lines[0]
    assert "f32[1,288,288,3]" in param_lines[0]


def test_manifest_structure(tmp_path):
    man = aot.build_all(str(tmp_path), variants=["yolov4-tiny-288"])
    assert man["format"] == "hlo-text"
    v = man["variants"][0]
    assert v["name"] == "yolov4-tiny-288"
    assert v["input_shape"] == [1, 288, 288, 3]
    assert v["heads"][0]["grid"] == 9
    assert v["heads"][0]["stride"] == 32
    assert v["heads"][0]["channels"] == 18
    assert len(v["heads"][0]["anchors"]) == 3
    # artifact file exists and matches recorded size
    path = os.path.join(str(tmp_path), v["artifact"])
    assert os.path.getsize(path) == v["hlo_bytes"]
    # manifest json round-trips
    with open(os.path.join(str(tmp_path), "manifest.json")) as f:
        man2 = json.load(f)
    assert man2 == man


def test_checked_in_artifacts_fresh_if_present():
    """If `make artifacts` has run, the manifest must list all four
    variants with consistent grids (guards stale artifacts)."""
    mpath = os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json"
    )
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built")
    with open(mpath) as f:
        man = json.load(f)
    names = {v["name"] for v in man["variants"]}
    assert names == set(model.VARIANTS)
    for v in man["variants"]:
        cfg = model.VARIANTS[v["name"]]
        for head, stride in zip(v["heads"], cfg.head_strides):
            assert head["grid"] == cfg.input_size // stride
