//! `tod` — the TOD coordinator CLI.
//!
//! Subcommands:
//! * `figures [--id <id>|--all] [--out results]` — regenerate the paper's
//!   tables and figures (DESIGN.md §5).
//! * `search` — run the Table I hyperparameter grid search.
//! * `run --seq <name> [--policy tod|fixed:<dnn>|chameleon] [--fps N]
//!   [--watts-budget W] [--gpu-budget PCT]` — schedule one sequence and
//!   print the run summary (budget flags enable the power governor).
//! * `power [--seq <name>] [--watts W] [--gpu PCT] [--rate-cap S]` —
//!   the resource-saving study: fixed Y-416 vs TOD vs budgeted TOD.
//! * `dataset --out <dir>` — export the synthetic MOT17Det-like catalog
//!   as MOT gt.txt files.
//! * `scenario {list,run,record,check}` — the scenario matrix and its
//!   golden-trace conformance harness (DESIGN.md §12).
//! * `serve [--frames N] [--artifacts dir]` — end-to-end PJRT serving
//!   demo on the request path (requires `make artifacts`).
//! * `trace {summarize,grep,explain-drop} --in out.jsonl` — inspect a
//!   structured trace written by `run --trace` (DESIGN.md §14).
//! * `metrics [--prom|--json]` — run the canonical workload with the
//!   metrics registry attached and print the exposition.
//! * `lint [--check]` — zone-aware static analysis of the crate's own
//!   sources against `rust/lint-policy.json` (DESIGN.md §16).
//! * `bench-report` — one-line summary of key performance counters.

use std::cell::RefCell;
use std::path::PathBuf;
use std::rc::Rc;

use tod::app::Campaign;
use tod::cli::Args;
use tod::coordinator::baselines::{run_chameleon_lite, ChameleonConfig};
use tod::coordinator::multistream::{
    BatchingSim, DispatchPolicy, MultiStreamScheduler,
};
use tod::coordinator::policy::{
    FixedPolicy, MbbsPolicy, SelectionPolicy, Thresholds,
};
use tod::coordinator::projected::ProjectedAccuracyPolicy;
use tod::coordinator::scheduler::{
    run_realtime, run_realtime_observed, OracleBackend, RunResult,
};
use tod::coordinator::session::StreamSession;
use tod::dataset::catalog::{generate, SequenceId};
use tod::obs::{JsonlSink, MetricsRegistry, SharedRecorder};
use tod::perf::{run_suite, BenchReport, SuiteOptions, DEFAULT_TOLERANCE};
use tod::power::{
    BudgetConfig, BudgetedPolicy, EnergyMeter, PowerBudget, RateCap,
};
use tod::predictor::{calibrate, store, CalibrationConfig, CalibrationTable};
use tod::runtime::batch::{AdmissionPolicy, BatchConfig};
use tod::sim::latency::{BatchLatencyModel, ContentionModel, LatencyModel};
use tod::sim::oracle::OracleDetector;
use tod::telemetry::tegrastats::TegrastatsSim;
use tod::DnnKind;

fn main() {
    let args = Args::from_env();
    let code = match args.command.as_deref() {
        Some("figures") => cmd_figures(&args),
        Some("search") => cmd_search(),
        Some("run") => cmd_run(&args),
        Some("calibrate") => cmd_calibrate(&args),
        Some("multistream") => cmd_multistream(&args),
        Some("power") => cmd_power(&args),
        Some("dataset") => cmd_dataset(&args),
        Some("scenario") => cmd_scenario(&args),
        Some("serve") => cmd_serve(&args),
        Some("trace") => cmd_trace(&args),
        Some("slo") => cmd_slo(&args),
        Some("metrics") => cmd_metrics(&args),
        Some("bench") => cmd_bench(&args),
        Some("bench-report") => cmd_bench_report(),
        Some("lint") => cmd_lint(&args),
        Some(other) => {
            eprintln!("unknown subcommand: {other}");
            usage();
            2
        }
        None => {
            usage();
            0
        }
    };
    std::process::exit(code);
}

fn usage() {
    eprintln!(
        "tod — Transprecise Object Detection (ICFEC 2021 reproduction)\n\
         usage: tod <figures|search|run|calibrate|multistream|power|\
         dataset|scenario|serve|trace|slo|metrics|bench|bench-report|\
         lint> [flags]\n\
         \n\
         figures --all | --id <table1|fig4..fig15|multistream|predictor|\
         power|scenario> [--out results]\n\
         search\n\
         run --seq MOT17-05 [--policy <spec>] [--fps 14] \
         [--watts-budget W]\n  \
         [--gpu-budget PCT] [--budget-window 1.0] [--trace out.jsonl]\n  \
         policy specs: tod (Algorithm 1 with H_opt), tod:<h1,h2,h3> \
         (custom\n  \
         ascending thresholds), fixed:<dnn> (e.g. fixed:yolov4-416), \
         chameleon\n  \
         (periodic re-profiling), projected (projected-accuracy \
         selection from a\n  \
         calibration table; [--table calibration.json] [--budget-ms N])\n  \
         --watts-budget/--gpu-budget cap the sliding-window board power \
         / GPU\n  \
         utilisation by masking infeasible DNNs (projected policies \
         switch to\n  \
         the energy-aware argmax); --trace writes the structured \
         observability\n  \
         event log (deterministic JSON lines, DESIGN.md s14)\n\
         calibrate [--out calibration.json] [--fps 30] [--frames 180] \
         [--quick]\n  \
         fits the per-DNN size x speed projected-accuracy table on \
         synthetic\n  \
         operating points (oracle ground truth) and writes it as \
         versioned JSON\n\
         multistream [--streams 4] [--dispatch rr|edf] [--alpha 0.12]\n  \
         [--batch [--max-batch 4] [--setup-frac 0.35]]  --batch compares \
         the\n  \
         same schedule with cross-stream micro-batching (setup cost \
         amortised\n  \
         across back-to-back same-DNN dispatches) against per-request \
         dispatch\n\
         multistream --scaling [--scale 1,2,4,8] [--dispatch rr|edf]\n\
         power [--seq MOT17-05] [--watts 6.5] [--gpu PCT] \
         [--window 1.0]\n  \
         [--rate-cap SCALE]  compares fixed Y-416, TOD and budgeted TOD \
         on\n  \
         metered AP/power/GPU (the paper's 45.1%-GPU / 62.7%-power \
         claim);\n  \
         --rate-cap adds a DVFS-style frequency-capped TOD run\n\
         dataset --out <dir>\n\
         scenario list | run --name <scenario> [--spec file.json]\n  \
         [--config tod|projected|budgeted|fixed:<dnn>] [--dispatch rr|edf]\n  \
         [--watts W] [--max-batch N] [--json]  replays one scenario of \
         the\n  \
         matrix (or a tod-scenario JSON document) end to end and prints \
         the\n  \
         canonical run record\n\
         scenario record [--goldens DIR]  re-runs the 8-scenario matrix \
         and\n  \
         writes the golden reports (default DIR: rust/tests/goldens)\n\
         scenario check [--goldens DIR] [--bootstrap] [--dump-dir DIR]  \
         re-runs\n  \
         the matrix and byte-compares against the committed goldens; \
         --bootstrap\n  \
         records them first when the directory holds none; --dump-dir \
         re-runs\n  \
         each failing scenario with the flight recorder + metrics \
         registry\n  \
         attached and writes <scenario>.flight.jsonl / \
         <scenario>.metrics.json\n  \
         there for post-mortem\n\
         serve [--frames 60] [--artifacts artifacts] [--policy tod]\n  \
         [--batch [--streams 4] [--max-batch 4] [--max-wait-ms 2] \
         [--shed]]\n  \
         --batch serves N concurrent synthetic streams through the \
         micro-\n  \
         batching server (per-DNN batches, bounded queue, panic-free \
         per-request\n  \
         results); --shed rejects on overload instead of blocking\n\
         trace summarize --in out.jsonl  per-type / per-stream digest of \
         a trace\n\
         trace grep --in out.jsonl [--type TAG] [--stream N] \
         [--frame N]\n  \
         prints the matching raw event lines (byte-exact)\n\
         trace explain-drop --in out.jsonl  reconstructs the cause chain \
         of\n  \
         every dropped frame: busy accelerator, busy-after-budget-clamp, \
         or shed\n\
         trace export --chrome --in out.jsonl [--out trace.json]  \
         renders the\n  \
         span trace as Chrome trace-event JSON (chrome://tracing / \
         Perfetto);\n  \
         byte-identical for the same seed\n\
         trace flame --in out.jsonl [--out folded.txt]  collapsed \
         flamegraph\n  \
         stacks weighted by span self-time microseconds\n\
         trace profile --in out.jsonl  per-stage self-time attribution \
         (the\n  \
         versioned tod-profile JSON report)\n\
         slo check --scenario <name> [--expect-breach] \
         [--chrome-out PATH]\n  \
         replays the scenario's canonical ladder run and evaluates the\n  \
         rolling-window SLOs (p99 latency, drop rate, AP proxy, watts \
         cap);\n  \
         exits 1 on breach (--expect-breach inverts: exits 1 when \
         nothing\n  \
         breaches); --chrome-out writes the Chrome trace with SLO \
         instants\n\
         metrics [--seq MOT17-05] [--policy <spec>] [--prom|--json]  \
         runs one\n  \
         sequence with the metrics registry attached and prints the \
         Prometheus\n  \
         text exposition (default) or the versioned JSON snapshot\n\
         bench [--json] [--out BENCH_6.json] [--quick] [--filter SUBSTR]\n  \
         [--comment TEXT] [--check [--baseline ../BENCH_6.json] \
         [--tolerance 0.15]]\n  \
         runs the hot-path micro-bench suite (see DESIGN.md s13); \
         --check diffs\n  \
         against the committed baseline and exits 1 on a pinned-metric \
         regression;\n  \
         --comment overrides the report's stamped provenance line\n\
         lint [--src DIR] [--policy FILE] [--json] [--out report.json] \
         [--check]\n  \
         zone-aware static analysis of the crate sources (DESIGN.md s16): \
         the\n  \
         determinism / serving / hot-path rule zones come from \
         rust/lint-policy.json\n  \
         and findings are waivable inline with `tod-lint: allow(<rule>) \
         reason=..`;\n  \
         --json prints the versioned tod-lint report, --check exits 1 on \
         any\n  \
         unwaived deny finding (the CI gate)\n\
         bench-report"
    );
}

fn cmd_figures(args: &Args) -> i32 {
    let out_dir = PathBuf::from(args.get("out").unwrap_or("results"));
    let ids: Vec<String> = if args.has("all") || args.get("id").is_none() {
        tod::experiments::ALL_IDS.iter().map(|s| s.to_string()).collect()
    } else {
        vec![args.get("id").unwrap().to_string()]
    };
    let mut campaign = Campaign::new();
    for id in ids {
        match tod::experiments::run(&id, &mut campaign) {
            Some(out) => {
                println!("{}", out.text);
                if let Err(e) = out.save(&out_dir) {
                    eprintln!("warning: could not save CSVs: {e}");
                }
            }
            None => {
                eprintln!("unknown experiment id: {id}");
                return 2;
            }
        }
    }
    println!("CSV series written to {}", out_dir.display());
    0
}

fn cmd_search() -> i32 {
    let out = tod::experiments::table1::run();
    println!("{}", out.text);
    0
}

fn parse_policy(spec: &str) -> Result<Box<dyn SelectionPolicy>, String> {
    if spec == "tod" {
        return Ok(Box::new(MbbsPolicy::tod_default()));
    }
    if let Some(h) = spec.strip_prefix("tod:") {
        // user-supplied thresholds: validation errors come back as
        // messages, not panics
        let vals: Vec<f64> = h
            .split(',')
            .map(|t| {
                t.trim()
                    .parse::<f64>()
                    .map_err(|_| format!("invalid threshold: {t:?}"))
            })
            .collect::<Result<_, String>>()?;
        let th = Thresholds::new(vals).map_err(|e| e.to_string())?;
        if th.n_dnn() != DnnKind::ALL.len() {
            return Err(format!(
                "need {} thresholds for the {}-DNN ladder, got {}",
                DnnKind::ALL.len() - 1,
                DnnKind::ALL.len(),
                th.values().len()
            ));
        }
        return Ok(Box::new(MbbsPolicy::new(th)));
    }
    if let Some(d) = spec.strip_prefix("fixed:") {
        return Ok(Box::new(FixedPolicy(d.parse()?)));
    }
    Err(format!(
        "unknown policy: {spec} \
         (want tod|tod:<h1,h2,h3>|fixed:<dnn>|chameleon|projected)"
    ))
}

/// Load (or, with a note, fit in-memory) the calibration table for
/// `--policy projected`. The in-memory fallback applies only to the
/// implicit default path — an explicitly passed `--table` that does not
/// exist is an error (a typo must not silently swap the table).
fn projected_table(args: &Args, fps: f64) -> Result<CalibrationTable, String> {
    let explicit = args.get("table").filter(|v| !v.is_empty());
    let path = PathBuf::from(explicit.unwrap_or("calibration.json"));
    let table = if path.exists() {
        store::load(&path)?
    } else if explicit.is_some() {
        return Err(format!(
            "--table {}: no such file (run `tod calibrate --out {0}` \
             first)",
            path.display()
        ));
    } else {
        eprintln!(
            "note: {} not found; calibrating in-memory at {fps} FPS \
             (run `tod calibrate` once to persist the table)",
            path.display()
        );
        calibrate(&CalibrationConfig::default_for_fps(fps))
    };
    if (table.fps - fps).abs() > 1e-9 {
        eprintln!(
            "note: table calibrated at {} FPS but the stream runs at \
             {fps} FPS; projected APs will be approximate (re-run \
             `tod calibrate --fps {fps}` for an exact match)",
            table.fps
        );
    }
    Ok(table)
}

fn print_run(r: &RunResult) {
    let sim = TegrastatsSim::default();
    println!(
        "sequence {} policy {} @{} fps\n  AP {:.3} | frames {} inferred {} \
         dropped {} ({:.1}%) | switches {}",
        r.sequence,
        r.policy,
        r.fps,
        r.ap,
        r.n_frames,
        r.n_inferred,
        r.n_dropped,
        r.drop_rate() * 100.0,
        r.switches
    );
    if r.n_failed > 0 {
        println!(
            "  {} inferences failed (detections carried forward)",
            r.n_failed
        );
    }
    let freq = r.deploy_freq();
    println!(
        "  deploy: YT-288 {:.1}% YT-416 {:.1}% Y-288 {:.1}% Y-416 {:.1}%",
        freq[0] * 100.0,
        freq[1] * 100.0,
        freq[2] * 100.0,
        freq[3] * 100.0
    );
    println!(
        "  telemetry: mean power {:.1} W, mean GPU {:.1}%",
        sim.mean_power(&r.trace),
        sim.mean_gpu(&r.trace)
    );
    println!(
        "  metered: {:.1} J over {:.1}s | avg {:.2} W | GPU busy {:.1}% \
         (util {:.1}%)",
        r.power.energy_j,
        r.power.duration_s,
        r.power.avg_power_w,
        r.power.gpu_busy_frac * 100.0,
        r.power.avg_gpu_pct
    );
}

/// Parse a positive, finite f64 flag (`default` when absent). Keeps
/// every budget-ish flag on the eprintln-and-exit path instead of
/// tripping the governor's constructor asserts.
fn parse_positive_finite(
    args: &Args,
    name: &str,
    default: f64,
) -> Result<f64, String> {
    let v = args.get_parse(name, default)?;
    if v > 0.0 && v.is_finite() {
        Ok(v)
    } else {
        Err(format!("--{name} must be positive and finite, got {v}"))
    }
}

/// Build the optional power governor from `--watts-budget`,
/// `--gpu-budget` and `--budget-window`. `Ok(None)` when neither cap
/// flag is present.
fn budget_from_args(
    args: &Args,
    lat: &LatencyModel,
) -> Result<Option<PowerBudget>, String> {
    let watts = if args.has("watts-budget") {
        Some(parse_positive_finite(args, "watts-budget", 0.0)?)
    } else {
        None
    };
    let gpu = if args.has("gpu-budget") {
        Some(parse_positive_finite(args, "gpu-budget", 0.0)?)
    } else {
        None
    };
    if watts.is_none() && gpu.is_none() {
        if args.has("budget-window") {
            return Err(
                "--budget-window needs --watts-budget or --gpu-budget \
                 (a window without a cap governs nothing)"
                    .into(),
            );
        }
        return Ok(None);
    }
    let window = parse_positive_finite(args, "budget-window", 1.0)?;
    PowerBudget::try_new(
        BudgetConfig {
            watts_cap: watts,
            gpu_cap_pct: gpu,
            window_s: window,
            rate_cap: None,
        },
        lat,
    )
    .map(Some)
}

fn cmd_run(args: &Args) -> i32 {
    let seq_name = args.get("seq").unwrap_or("MOT17-05");
    let id: SequenceId = match seq_name.parse() {
        Ok(id) => id,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let seq = generate(id);
    let fps = match args.get_parse("fps", id.eval_fps()) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let mut det = OracleBackend(OracleDetector::new(
        seq.spec.seed,
        seq.spec.width as f64,
        seq.spec.height as f64,
    ));
    let mut lat = LatencyModel::deterministic();
    let policy_spec = args.get("policy").unwrap_or("tod");
    let power_budget = match budget_from_args(args, &lat) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    // --trace: attach the JSON-lines event sink to the session (and,
    // when a budget governor runs, to the governor so clamps land in
    // the same stream). Same seed + flags => byte-identical file.
    let trace_path = args.get("trace").map(PathBuf::from);
    if trace_path.is_some() && policy_spec == "chameleon" {
        eprintln!(
            "--trace is not supported with the chameleon baseline (its \
             loop bypasses the session event spine)"
        );
        return 2;
    }
    let sink = trace_path.as_ref().map(|_| {
        Rc::new(RefCell::new(JsonlSink::new(&format!(
            "run seq={seq_name} policy={policy_spec} fps={fps}"
        ))))
    });
    let obs_rec: Option<SharedRecorder> =
        sink.as_ref().map(|s| -> SharedRecorder { s.clone() });
    let r = if policy_spec == "chameleon" {
        if power_budget.is_some() {
            eprintln!(
                "--watts-budget/--gpu-budget are not supported with the \
                 chameleon baseline (its loop bypasses the governor \
                 hooks)"
            );
            return 2;
        }
        run_chameleon_lite(&seq, &mut det, &mut lat, fps,
                           &ChameleonConfig::default())
    } else if policy_spec == "projected" {
        let table = match projected_table(args, fps) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        let budget_s = match args.get_parse("budget-ms", f64::INFINITY) {
            Ok(ms) if ms > 0.0 => ms / 1e3,
            Ok(ms) => {
                eprintln!("--budget-ms must be positive, got {ms}");
                return 2;
            }
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        if let Some(budget) = power_budget {
            // projected + power budget = the energy-aware argmax
            if budget_s.is_finite() {
                eprintln!(
                    "--budget-ms does not compose with a power budget \
                     (the energy-aware argmax already prices demand); \
                     drop one of the two"
                );
                return 2;
            }
            let mut policy = BudgetedPolicy::argmax(table, budget);
            if let Some(rec) = &obs_rec {
                policy = policy.with_recorder(rec.clone(), 0);
            }
            run_realtime_observed(
                &seq,
                &mut policy,
                &mut det,
                &mut lat,
                fps,
                obs_rec.clone().map(|r| (r, 0)),
            )
        } else {
            let mut policy =
                ProjectedAccuracyPolicy::with_budget(table, &lat, budget_s);
            run_realtime_observed(
                &seq,
                &mut policy,
                &mut det,
                &mut lat,
                fps,
                obs_rec.clone().map(|r| (r, 0)),
            )
        }
    } else {
        let mut policy = match parse_policy(policy_spec) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        match power_budget {
            Some(budget) => {
                let mut policy = BudgetedPolicy::masking(policy, budget);
                if let Some(rec) = &obs_rec {
                    policy = policy.with_recorder(rec.clone(), 0);
                }
                run_realtime_observed(
                    &seq,
                    &mut policy,
                    &mut det,
                    &mut lat,
                    fps,
                    obs_rec.clone().map(|r| (r, 0)),
                )
            }
            None => run_realtime_observed(
                &seq,
                policy.as_mut(),
                &mut det,
                &mut lat,
                fps,
                obs_rec.clone().map(|r| (r, 0)),
            ),
        }
    };
    print_run(&r);
    if let (Some(path), Some(s)) = (&trace_path, &sink) {
        let s = s.borrow();
        if let Err(e) = s.save(path) {
            eprintln!("{e}");
            return 1;
        }
        eprintln!("trace: {} events -> {}", s.events(), path.display());
    }
    0
}

/// `tod power` — the resource-saving reproduction: fixed Y-416 vs TOD
/// vs budgeted TOD (and optionally DVFS-rate-capped TOD) on one
/// sequence, with metered AP / board power / GPU-busy figures.
fn cmd_power(args: &Args) -> i32 {
    let seq_name = args.get("seq").unwrap_or("MOT17-05");
    let id: SequenceId = match seq_name.parse() {
        Ok(id) => id,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let seq = generate(id);
    let fps = id.eval_fps();
    let watts = match parse_positive_finite(
        args,
        "watts",
        tod::app::DEFAULT_WATTS_BUDGET,
    ) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let gpu_cap = if args.has("gpu") {
        match parse_positive_finite(args, "gpu", 0.0) {
            Ok(v) => Some(v),
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    } else {
        None
    };
    let window = match parse_positive_finite(args, "window", 1.0) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let rate_cap = if args.has("rate-cap") {
        match args.get_parse("rate-cap", 1.0f64) {
            Ok(v) if v > 0.0 && v <= 1.0 => Some(RateCap::new(v)),
            Ok(v) => {
                eprintln!("--rate-cap must be in (0, 1], got {v}");
                return 2;
            }
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    } else {
        None
    };

    let fresh_det = || {
        OracleBackend(OracleDetector::new(
            seq.spec.seed,
            seq.spec.width as f64,
            seq.spec.height as f64,
        ))
    };
    let run_with = |policy: &mut dyn SelectionPolicy,
                    lat: &mut LatencyModel| {
        run_realtime(&seq, policy, &mut fresh_det(), lat, fps)
    };

    let mut lat = LatencyModel::deterministic();
    let mut y416 = FixedPolicy(DnnKind::Y416);
    let r_y416 = run_with(&mut y416, &mut lat);
    let mut tod_pol = MbbsPolicy::tod_default();
    let r_tod = run_with(&mut tod_pol, &mut lat);
    let cfg = BudgetConfig {
        watts_cap: Some(watts),
        gpu_cap_pct: gpu_cap,
        window_s: window,
        rate_cap: None,
    };
    let budget = match PowerBudget::try_new(cfg, &lat) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let mut budgeted = BudgetedPolicy::masking(
        Box::new(MbbsPolicy::tod_default()),
        budget,
    );
    let r_budgeted = run_with(&mut budgeted, &mut lat);

    println!(
        "power study on {} @ {fps} FPS (budget {watts} W{} over {window} s \
         windows):",
        id.name(),
        gpu_cap.map_or(String::new(), |g| format!(" / {g}% GPU")),
    );
    println!(
        "  {:<34} {:>6} {:>8} {:>9} {:>8}",
        "policy", "AP", "power W", "GPU busy%", "drop%"
    );

    // optional DVFS run: stretched latencies, scale² dynamic power
    let r_capped = rate_cap.map(|rc| {
        let mut lat_capped = rc.stretch(&LatencyModel::deterministic());
        let mut pol = MbbsPolicy::tod_default();
        let mut r = run_realtime(
            &seq,
            &mut pol,
            &mut fresh_det(),
            &mut lat_capped,
            fps,
        );
        // re-meter at capped clocks: same schedule, scaled active power
        let mut m = EnergyMeter::with_active_scale(rc.power_factor());
        m.fold_trace(&r.trace);
        r.power = m.summary();
        r.policy = format!("{} rate-cap={:.2}", r.policy, rc.scale());
        r
    });
    let mut rows = vec![&r_y416, &r_tod, &r_budgeted];
    if let Some(r) = &r_capped {
        rows.push(r);
    }
    for r in &rows {
        println!(
            "  {:<34} {:>6.3} {:>8.2} {:>9.1} {:>8.1}",
            r.policy,
            r.ap,
            r.power.avg_power_w,
            r.power.gpu_busy_frac * 100.0,
            r.drop_rate() * 100.0
        );
    }
    println!(
        "  budgeted vs always-Y-416: power {:.1}% | GPU {:.1}% \
         (paper §IV.D: 62.7% / 45.1%)",
        r_budgeted.power.avg_power_w / r_y416.power.avg_power_w * 100.0,
        r_budgeted.power.gpu_busy_frac / r_y416.power.gpu_busy_frac
            * 100.0
    );
    0
}

fn cmd_calibrate(args: &Args) -> i32 {
    let out = PathBuf::from(args.get("out").unwrap_or("calibration.json"));
    let fps = match args.get_parse("fps", 30.0) {
        Ok(v) if v > 0.0 => v,
        Ok(v) => {
            eprintln!("--fps must be positive, got {v}");
            return 2;
        }
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let mut cfg = if args.has("quick") {
        CalibrationConfig::quick(fps)
    } else {
        CalibrationConfig::default_for_fps(fps)
    };
    cfg.frames = match args.get_parse("frames", cfg.frames) {
        Ok(v) if v > 0 => v,
        Ok(v) => {
            eprintln!("--frames must be positive, got {v}");
            return 2;
        }
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    println!(
        "calibrating {}x{} (size x speed) cells, {} frames each, 4 DNNs, \
         at {fps} FPS...",
        cfg.size_targets.len(),
        cfg.speed_targets.len(),
        cfg.frames
    );
    let table = calibrate(&cfg);
    // the selection map: which DNN wins each cell (rows = size, cols =
    // speed) — the calibrated replacement for the paper's Table I
    println!("selection map (rows: MBBS; cols: speed in frame-diag/frame):");
    print!("{:>9}", "");
    for v in &table.speed_axis {
        print!(" {v:>8.4}");
    }
    println!();
    for (si, s) in table.size_axis.iter().enumerate() {
        print!("{s:>9.4}");
        for vi in 0..table.speed_axis.len() {
            // same tie-break as ProjectedAccuracyPolicy::select_pure:
            // strictly-greater over lightest -> heaviest keeps the
            // lighter net, so the map shows what would actually deploy
            let mut best = DnnKind::TinyY288;
            let mut best_v = f64::NEG_INFINITY;
            for k in DnnKind::ALL {
                let v = table.ap[k.index()][si][vi];
                if v > best_v {
                    best_v = v;
                    best = k;
                }
            }
            print!(" {:>8}", best.short_label());
        }
        println!();
    }
    match store::save(&table, &out) {
        Ok(()) => {
            println!(
                "calibration table ({} cells, version {}) -> {}",
                table.n_cells(),
                tod::predictor::TABLE_VERSION,
                out.display()
            );
            0
        }
        Err(e) => {
            eprintln!("error writing {}: {e}", out.display());
            1
        }
    }
}

fn cmd_multistream(args: &Args) -> i32 {
    let dispatch = match args.get_parse("dispatch", DispatchPolicy::RoundRobin)
    {
        Ok(d) => d,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if args.has("scaling") {
        // the scaling sweep is campaign-memoized under the fixed Jetson
        // contention default; refuse flags it would silently ignore
        if args.has("alpha") || args.has("streams") {
            eprintln!(
                "--scaling ignores --alpha/--streams (it sweeps --scale \
                 under the Jetson contention default); drop them or run \
                 without --scaling"
            );
            return 2;
        }
        let scale = match args.get_list("scale", &tod::app::MULTISTREAM_SCALE)
        {
            Ok(s) => s,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        let mut campaign = Campaign::new();
        println!(
            "multi-stream scaling ({dispatch} dispatch, Jetson contention):\n\
             streams  mean AP  drop%   util%   inf/s"
        );
        for n in scale {
            let r = campaign.multistream(n, dispatch);
            println!(
                "{n:>7}  {:>7.3}  {:>5.1}  {:>6.1}  {:>6.1}",
                r.mean_ap(),
                r.drop_rate() * 100.0,
                r.utilisation.utilisation() * 100.0,
                r.utilisation.throughput_ips(),
            );
        }
        return 0;
    }

    let n = match args.get_parse("streams", 4usize) {
        Ok(v) => v.max(1),
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let alpha = match args.get_parse("alpha", ContentionModel::default().alpha)
    {
        Ok(v) if v >= 0.0 => v,
        Ok(v) => {
            eprintln!("--alpha must be non-negative, got {v}");
            return 2;
        }
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let ids: Vec<SequenceId> = (0..n)
        .map(|i| SequenceId::ALL[i % SequenceId::ALL.len()])
        .collect();
    let seqs: Vec<_> = ids.iter().map(|&id| generate(id)).collect();
    let build = |batching: Option<BatchingSim>| {
        let mut sched = MultiStreamScheduler::new(
            dispatch,
            ContentionModel::new(alpha),
            LatencyModel::deterministic(),
        );
        if let Some(b) = batching {
            sched = sched.with_batching(b);
        }
        for (id, seq) in ids.iter().zip(&seqs) {
            let det = OracleBackend(OracleDetector::new(
                seq.spec.seed,
                seq.spec.width as f64,
                seq.spec.height as f64,
            ));
            sched.add_stream(
                StreamSession::new(
                    seq,
                    MbbsPolicy::tod_default(),
                    id.eval_fps(),
                ),
                Box::new(det),
            );
        }
        sched.run()
    };
    if args.has("batch") {
        let max_batch = match args.get_parse("max-batch", 4usize) {
            Ok(v) if v >= 1 => v,
            Ok(v) => {
                eprintln!("--max-batch must be >= 1, got {v}");
                return 2;
            }
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        let setup_frac = match args.get_parse(
            "setup-frac",
            BatchLatencyModel::DEFAULT_SETUP_FRAC,
        ) {
            Ok(v) if (0.0..1.0).contains(&v) => v,
            Ok(v) => {
                eprintln!("--setup-frac must be in [0, 1), got {v}");
                return 2;
            }
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        let plain = build(None);
        let batched =
            build(Some(BatchingSim::new(setup_frac, max_batch)));
        println!(
            "{n} streams, {dispatch} dispatch, alpha {alpha}: \
             per-request vs micro-batched (max_batch {max_batch}, \
             setup share {setup_frac}):"
        );
        println!(
            "  {:<14} {:>8} {:>7} {:>7} {:>7}",
            "mode", "inf/s", "util%", "drop%", "mean AP"
        );
        for (label, r) in
            [("per-request", &plain), ("micro-batched", &batched)]
        {
            println!(
                "  {label:<14} {:>8.1} {:>7.1} {:>7.1} {:>7.3}",
                r.utilisation.throughput_ips(),
                r.utilisation.utilisation() * 100.0,
                r.drop_rate() * 100.0,
                r.mean_ap(),
            );
        }
        println!(
            "  throughput x{:.2}",
            batched.utilisation.throughput_ips()
                / plain.utilisation.throughput_ips().max(1e-12)
        );
        if let Some(stats) = &batched.batching {
            println!("  batching: {stats}");
        }
        return 0;
    }
    let result = build(None);
    println!(
        "{n} streams over one accelerator ({dispatch} dispatch, \
         contention alpha {alpha}):"
    );
    for (i, r) in result.per_stream.iter().enumerate() {
        println!(
            "  stream {i}: {} AP {:.3} | inferred {} dropped {} ({:.1}%)",
            r.sequence,
            r.ap,
            r.n_inferred,
            r.n_dropped,
            r.drop_rate() * 100.0
        );
    }
    println!("  aggregate: {}", result.utilisation.report());
    let sim = TegrastatsSim::default();
    println!(
        "  telemetry: mean power {:.1} W, mean GPU {:.1}%",
        sim.mean_power(&result.utilisation.merged),
        sim.mean_gpu(&result.utilisation.merged)
    );
    0
}

fn cmd_dataset(args: &Args) -> i32 {
    let out = PathBuf::from(args.get("out").unwrap_or("data/mot17det-synth"));
    for id in SequenceId::ALL {
        let seq = generate(id);
        let dir = out.join(id.name()).join("gt");
        let path = dir.join("gt.txt");
        if let Err(e) = tod::dataset::mot::write_file(&path, &seq.all_entries())
        {
            eprintln!("error writing {}: {e}", path.display());
            return 1;
        }
        println!(
            "{}: {} frames, {} gt rows -> {}",
            id.name(),
            seq.n_frames(),
            seq.all_entries().len(),
            path.display()
        );
    }
    0
}

/// Goldens directory: `rust/tests/goldens` from the repository root,
/// `tests/goldens` when already inside `rust/` (the CI working dir).
/// Errors when neither exists — resolving relative to an arbitrary
/// CWD would silently scatter goldens into an unrelated directory;
/// pass `--goldens DIR` explicitly from outside the repo.
fn default_goldens_dir() -> Result<PathBuf, String> {
    for candidate in ["rust/tests/goldens", "tests/goldens"] {
        let p = PathBuf::from(candidate);
        if p.is_dir() {
            return Ok(p);
        }
    }
    Err("no goldens directory found relative to the current directory \
         (expected rust/tests/goldens or tests/goldens); run from the \
         repository root or pass --goldens DIR"
        .into())
}

fn cmd_scenario(args: &Args) -> i32 {
    use tod::scenario::{conformance, harness, matrix, record, store};

    let verb = args.positional.first().map(String::as_str);
    match verb {
        Some("list") => {
            println!("scenario matrix ({} scenarios):", matrix::ScenarioId::ALL.len());
            for id in matrix::ScenarioId::ALL {
                let spec = matrix::scenario_spec(id);
                let phases: Vec<String> = spec
                    .streams
                    .iter()
                    .map(|s| {
                        let ph: Vec<&str> = s
                            .phases
                            .iter()
                            .map(|p| p.label.as_str())
                            .collect();
                        format!("{}[{}]", s.label, ph.join(">"))
                    })
                    .collect();
                println!(
                    "  {:<16} {} frames, {} stream(s): {}\n    {}",
                    spec.name,
                    spec.n_frames(),
                    spec.streams.len(),
                    phases.join(" "),
                    spec.description
                );
            }
            0
        }
        Some("run") => {
            let spec = if let Some(path) = args.get("spec") {
                match store::load(&PathBuf::from(path)) {
                    Ok(s) => s,
                    Err(e) => {
                        eprintln!("{e}");
                        return 2;
                    }
                }
            } else {
                let name = args.get("name").unwrap_or("rush-hour-surge");
                match name.parse::<matrix::ScenarioId>() {
                    Ok(id) => matrix::scenario_spec(id),
                    Err(e) => {
                        eprintln!("{e}");
                        return 2;
                    }
                }
            };
            let config_spec = args.get("config").unwrap_or("tod");
            let needs_table =
                matches!(config_spec, "projected" | "budgeted");
            if needs_table {
                // same guard as conformance::run_report: the table's
                // drop pricing is per-FPS, so projecting a non-matrix
                // spec through it would be silently wrong
                let fps = tod::scenario::conformance::MATRIX_FPS;
                if (spec.base_fps - fps).abs() > 1e-9 {
                    eprintln!(
                        "scenario {:?} runs at {} FPS but --config \
                         {config_spec} projects from the {fps} FPS \
                         calibration table; re-author the scenario at \
                         {fps} FPS (or use --config tod|fixed:<dnn>)",
                        spec.name, spec.base_fps
                    );
                    return 2;
                }
                eprintln!(
                    "note: fitting the calibration table (one-off per \
                     invocation; persisted tables are not used here so \
                     runs stay conformance-identical)"
                );
            }
            let mut cfg = match config_spec {
                "tod" => harness::HarnessConfig::tod(),
                "projected" => harness::HarnessConfig::projected(
                    conformance::calibration_table().clone(),
                ),
                "budgeted" => harness::HarnessConfig::projected(
                    conformance::calibration_table().clone(),
                )
                .with_watts(spec.watts_budget),
                other => {
                    if let Some(d) = other.strip_prefix("fixed:") {
                        match d.parse() {
                            Ok(k) => harness::HarnessConfig::fixed(k),
                            Err(e) => {
                                eprintln!("{e}");
                                return 2;
                            }
                        }
                    } else {
                        eprintln!(
                            "unknown --config: {other} (want tod|projected|\
                             budgeted|fixed:<dnn>)"
                        );
                        return 2;
                    }
                }
            };
            match args.get_parse("dispatch", DispatchPolicy::RoundRobin) {
                Ok(d) => cfg = cfg.with_dispatch(d),
                Err(e) => {
                    eprintln!("{e}");
                    return 2;
                }
            }
            if args.has("watts") {
                match args.get_parse("watts", spec.watts_budget) {
                    Ok(w) if w > 0.0 && w.is_finite() => {
                        cfg = cfg.with_watts(w)
                    }
                    Ok(w) => {
                        eprintln!("--watts must be positive, got {w}");
                        return 2;
                    }
                    Err(e) => {
                        eprintln!("{e}");
                        return 2;
                    }
                }
            }
            if args.has("max-batch") {
                match args.get_parse("max-batch", 4usize) {
                    Ok(n) if n >= 1 => {
                        cfg = cfg.with_batching(BatchingSim::jetson_nano(n))
                    }
                    Ok(n) => {
                        eprintln!("--max-batch must be >= 1, got {n}");
                        return 2;
                    }
                    Err(e) => {
                        eprintln!("{e}");
                        return 2;
                    }
                }
            }
            let streams = match spec.compile() {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("{e}");
                    return 2;
                }
            };
            let run =
                match harness::run_scenario(&spec.name, &streams, &cfg) {
                    Ok(r) => r,
                    Err(e) => {
                        eprintln!("{e}");
                        return 2;
                    }
                };
            let rec = record::RunRecord::from_run(&run, spec.seed);
            if args.has("json") {
                print!("{}", rec.canonical_text());
                return 0;
            }
            println!(
                "scenario {} config {} (seed {}):",
                rec.scenario, rec.config, rec.seed
            );
            for s in &rec.streams {
                println!(
                    "  {:<10} join {:>4.1}s | AP {:.3} | frames {} \
                     inferred {} dropped {} ({:.1}%) | switches {} | \
                     {:.2} W",
                    s.label,
                    s.join_s,
                    s.ap,
                    s.frames,
                    s.inferred,
                    s.dropped,
                    if s.frames == 0 {
                        0.0
                    } else {
                        s.dropped as f64 / s.frames as f64 * 100.0
                    },
                    s.switches,
                    s.avg_power_w,
                );
                for p in &s.phases {
                    let freq: Vec<String> = DnnKind::ALL
                        .iter()
                        .map(|d| {
                            format!(
                                "{} {}",
                                d.short_label(),
                                p.deploy[d.index()]
                            )
                        })
                        .collect();
                    println!(
                        "    phase {:<10} {} frames, {} inferred, mean \
                         MBBS {:.4} | {}",
                        p.label,
                        p.frames,
                        p.inferred,
                        p.mean_mbbs,
                        freq.join(" ")
                    );
                }
            }
            let a = &rec.aggregate;
            println!(
                "  aggregate: mean AP {:.3} | drop {:.1}% | makespan \
                 {:.1}s | util {:.1}% | board {:.2} W",
                a.mean_ap,
                if a.frames == 0 {
                    0.0
                } else {
                    a.dropped as f64 / a.frames as f64 * 100.0
                },
                a.makespan_s,
                a.utilisation * 100.0,
                a.avg_power_w,
            );
            0
        }
        Some("record") => {
            let dir = match args.get("goldens").map(PathBuf::from) {
                Some(d) => d,
                None => match default_goldens_dir() {
                    Ok(d) => d,
                    Err(e) => {
                        eprintln!("{e}");
                        return 2;
                    }
                },
            };
            eprintln!(
                "recording the scenario matrix (8 scenarios x 7 configs; \
                 includes the one-off calibration campaign)..."
            );
            match tod::scenario::conformance::write_goldens(&dir) {
                Ok(paths) => {
                    for p in &paths {
                        println!("recorded {}", p.display());
                    }
                    0
                }
                Err(e) => {
                    eprintln!("{e}");
                    1
                }
            }
        }
        Some("check") => {
            let dir = match args.get("goldens").map(PathBuf::from) {
                Some(d) => d,
                None => match default_goldens_dir() {
                    Ok(d) => d,
                    Err(e) => {
                        eprintln!("{e}");
                        return 2;
                    }
                },
            };
            if args.has("bootstrap") {
                match conformance::bootstrap_goldens_if_missing(&dir) {
                    Ok(true) => eprintln!(
                        "no goldens under {} — recorded the matrix first \
                         (commit the files to pin them)",
                        dir.display()
                    ),
                    Ok(false) => {}
                    Err(e) => {
                        eprintln!("{e}");
                        return 1;
                    }
                }
            }
            let results = match conformance::check_goldens(&dir) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("{e}");
                    return 1;
                }
            };
            let mut failed = 0;
            for (name, verdict) in &results {
                match verdict {
                    conformance::CheckVerdict::Match => {
                        println!("  {name:<16} OK (bit-identical)");
                    }
                    conformance::CheckVerdict::Missing => {
                        failed += 1;
                        println!(
                            "  {name:<16} MISSING (run `tod scenario \
                             record`)"
                        );
                    }
                    conformance::CheckVerdict::Mismatch {
                        line,
                        golden,
                        observed,
                    } => {
                        failed += 1;
                        println!(
                            "  {name:<16} MISMATCH at line {line}\n    \
                             golden:   {golden}\n    observed: {observed}"
                        );
                    }
                }
            }
            if failed > 0 {
                // post-mortem: re-run each failing scenario with the
                // flight recorder + metrics registry attached and keep
                // the dumps (CI uploads them as artifacts)
                if let Some(dump) = args.get("dump-dir") {
                    let dump_dir = PathBuf::from(dump);
                    for (name, verdict) in &results {
                        if matches!(
                            verdict,
                            conformance::CheckVerdict::Match
                        ) {
                            continue;
                        }
                        let dumped = name
                            .parse::<matrix::ScenarioId>()
                            .map_err(|e| e.to_string())
                            .and_then(|id| {
                                conformance::dump_failure_artifacts(
                                    &matrix::scenario_spec(id),
                                    &dump_dir,
                                )
                            });
                        match dumped {
                            Ok(paths) => {
                                for p in paths {
                                    eprintln!("dumped {}", p.display());
                                }
                            }
                            Err(e) => eprintln!("dump {name}: {e}"),
                        }
                    }
                }
                eprintln!(
                    "{failed}/{} scenarios failed conformance",
                    results.len()
                );
                1
            } else {
                println!(
                    "all {} scenarios bit-identical to {}",
                    results.len(),
                    dir.display()
                );
                0
            }
        }
        other => {
            eprintln!(
                "scenario needs a verb: list|run|record|check (got {:?})",
                other.unwrap_or("none")
            );
            2
        }
    }
}

fn cmd_serve(args: &Args) -> i32 {
    let artifacts = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    let frames = match args.get_parse("frames", 60u64) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let served = if args.has("batch") {
        let streams = match args.get_parse("streams", 4usize) {
            Ok(v) => v.max(1),
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        let max_batch = match args.get_parse("max-batch", 4usize) {
            Ok(v) => v,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        let max_wait_ms = match args.get_parse("max-wait-ms", 2.0f64) {
            Ok(v) if v >= 0.0 && v.is_finite() => v,
            Ok(v) => {
                eprintln!("--max-wait-ms must be non-negative, got {v}");
                return 2;
            }
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        let default_cfg = BatchConfig::default();
        let cfg = BatchConfig {
            max_batch,
            max_wait: std::time::Duration::from_micros(
                (max_wait_ms * 1e3) as u64,
            ),
            admission: if args.has("shed") {
                AdmissionPolicy::Shed
            } else {
                AdmissionPolicy::Block
            },
            // a full batch must be admissible: grow the default queue
            // bound with --max-batch instead of failing validation
            queue_cap: default_cfg.queue_cap.max(max_batch),
        };
        if let Err(e) = cfg.validate() {
            eprintln!("invalid batch config: {e}");
            return 2;
        }
        tod::runtime::serve::serve_batched_demo(
            &artifacts, frames, streams, cfg,
        )
    } else {
        tod::runtime::serve::serve_demo(&artifacts, frames)
    };
    match served {
        Ok(report) => {
            println!("{report}");
            0
        }
        Err(e) => {
            eprintln!("serve failed: {e:#}");
            eprintln!("hint: run `make artifacts` first");
            1
        }
    }
}

/// `tod trace` — inspect a structured observability trace written by
/// `tod run --trace` (DESIGN.md §14).
fn cmd_trace(args: &Args) -> i32 {
    use tod::obs::{explain_drops, parse_trace, DropCause};

    let verb = args.positional.first().map(String::as_str);
    let Some(path) = args.get("in") else {
        eprintln!(
            "trace needs --in <file.jsonl> (write one with \
             `tod run --trace out.jsonl`)"
        );
        return 2;
    };
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("read {path}: {e}");
            return 1;
        }
    };
    let (header, events) = match parse_trace(&text) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{path}: {e}");
            return 1;
        }
    };
    match verb {
        Some("summarize") => {
            if let Some(label) = header
                .as_ref()
                .and_then(|h| h.get("label"))
                .and_then(|l| l.as_str())
            {
                println!("label: {label}");
            }
            print!("{}", tod::obs::replay::summarize(&events));
            0
        }
        Some("grep") => {
            let want_type = args.get("type");
            let want_stream: Option<u32> = if args.has("stream") {
                match args.get_parse("stream", 0u32) {
                    Ok(v) => Some(v),
                    Err(e) => {
                        eprintln!("{e}");
                        return 2;
                    }
                }
            } else {
                None
            };
            let want_frame: Option<u64> = if args.has("frame") {
                match args.get_parse("frame", 0u64) {
                    Ok(v) => Some(v),
                    Err(e) => {
                        eprintln!("{e}");
                        return 2;
                    }
                }
            } else {
                None
            };
            let mut shown = 0usize;
            for ev in &events {
                if let Some(t) = want_type {
                    if t != ev.type_tag() {
                        continue;
                    }
                }
                if let Some(s) = want_stream {
                    if ev.stream() != Some(s) {
                        continue;
                    }
                }
                if let Some(f) = want_frame {
                    if ev.frame() != Some(f) {
                        continue;
                    }
                }
                // re-serialization is byte-identical to the sink line
                // (sorted keys, shortest-roundtrip floats)
                println!("{}", ev.to_json().to_string());
                shown += 1;
            }
            eprintln!("{shown}/{} events matched", events.len());
            0
        }
        Some("explain-drop") => {
            let explanations = explain_drops(&events);
            if explanations.is_empty() {
                println!("no dropped frames in this trace");
                return 0;
            }
            let (mut busy, mut clamped, mut shed, mut unknown) =
                (0u64, 0u64, 0u64, 0u64);
            for ex in &explanations {
                println!("{ex}");
                match ex.cause {
                    DropCause::BusyAccelerator => busy += 1,
                    DropCause::BusyAfterClamp { .. } => clamped += 1,
                    DropCause::Shed => shed += 1,
                    DropCause::Unknown => unknown += 1,
                }
            }
            println!(
                "{} drops: {busy} busy accelerator | {clamped} busy \
                 after budget clamp | {shed} shed | {unknown} unexplained",
                explanations.len()
            );
            // a drop the trace cannot explain is itself a finding
            if unknown > 0 {
                1
            } else {
                0
            }
        }
        Some("export") => {
            if !args.has("chrome") {
                eprintln!(
                    "trace export needs a format: --chrome (Chrome \
                     trace-event JSON)"
                );
                return 2;
            }
            let rendered = tod::obs::chrome_trace(&events).to_string();
            write_or_print(args.get("out"), &rendered, "chrome trace")
        }
        Some("flame") => {
            let rendered = tod::obs::flamegraph(&events);
            if rendered.is_empty() {
                eprintln!(
                    "no spans in this trace (span events need a \
                     recorder-attached run)"
                );
                return 1;
            }
            write_or_print(args.get("out"), &rendered, "folded stacks")
        }
        Some("profile") => {
            if let Err(e) = tod::obs::validate_spans(&events) {
                eprintln!("{path}: invalid span structure: {e}");
                return 1;
            }
            let report = tod::obs::profile::profile(&events);
            println!("{}", report.to_json().to_pretty());
            0
        }
        other => {
            eprintln!(
                "trace needs a verb: summarize|grep|explain-drop|\
                 export|flame|profile (got {:?})",
                other.unwrap_or("none")
            );
            2
        }
    }
}

/// Write `text` to `--out` when given, else print it to stdout.
fn write_or_print(out: Option<&str>, text: &str, what: &str) -> i32 {
    match out {
        Some(path) => match std::fs::write(path, text) {
            Ok(()) => {
                eprintln!("{what} written to {path}");
                0
            }
            Err(e) => {
                eprintln!("write {path}: {e}");
                1
            }
        },
        None => {
            print!("{text}");
            if !text.ends_with('\n') {
                println!();
            }
            0
        }
    }
}

/// `tod slo check` — replay one matrix scenario's canonical ladder run
/// and evaluate the rolling-window SLO watchdog over its trace
/// (DESIGN.md §15). Exit code 1 signals an unexpected health state:
/// any breach normally, *no* breach under `--expect-breach` (the CI
/// spelling for scenarios that exist to trip the watchdog).
fn cmd_slo(args: &Args) -> i32 {
    use tod::scenario::{conformance, matrix};

    let verb = args.positional.first().map(String::as_str);
    if verb != Some("check") {
        eprintln!(
            "slo needs a verb: check (got {:?})",
            verb.unwrap_or("none")
        );
        return 2;
    }
    let Some(name) = args.get("scenario") else {
        eprintln!("slo check needs --scenario <name> (see `tod scenario list`)");
        return 2;
    };
    let id: matrix::ScenarioId = match name.parse() {
        Ok(id) => id,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let spec = matrix::scenario_spec(id);
    let events = match conformance::scenario_slo_events(&spec) {
        Ok(evs) => evs,
        Err(e) => {
            eprintln!("{name}: {e}");
            return 1;
        }
    };
    let slo_spec = conformance::scenario_slo_spec(&spec);
    let report = tod::obs::slo::check_events(&events, &slo_spec);
    for ev in &report.events {
        println!("{}", ev.to_json().to_string());
    }
    println!(
        "{name}: {} breach(es) over {} checks (window {} s)",
        report.breaches, report.checks, slo_spec.window_s
    );
    if let Some(path) = args.get("chrome-out") {
        // the exported trace carries the SLO transitions as instants
        let mut all = events;
        all.extend(report.events.iter().copied());
        let rendered = tod::obs::chrome_trace(&all).to_string();
        if let Err(e) = std::fs::write(path, rendered) {
            eprintln!("write {path}: {e}");
            return 1;
        }
        eprintln!("chrome trace written to {path}");
    }
    match (report.breached(), args.has("expect-breach")) {
        (true, true) => {
            println!("{name}: breach expected and observed — ok");
            0
        }
        (false, false) => {
            println!("{name}: all SLOs held");
            0
        }
        (true, false) => {
            eprintln!("{name}: SLO breach");
            1
        }
        (false, true) => {
            eprintln!("{name}: expected an SLO breach but none fired");
            1
        }
    }
}

/// `tod metrics` — run one sequence with the metrics registry attached
/// to the observability spine and print the exposition.
fn cmd_metrics(args: &Args) -> i32 {
    let seq_name = args.get("seq").unwrap_or("MOT17-05");
    let id: SequenceId = match seq_name.parse() {
        Ok(id) => id,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let seq = generate(id);
    let fps = id.eval_fps();
    let mut det = OracleBackend(OracleDetector::new(
        seq.spec.seed,
        seq.spec.width as f64,
        seq.spec.height as f64,
    ));
    let mut lat = LatencyModel::deterministic();
    let policy_spec = args.get("policy").unwrap_or("tod");
    if matches!(policy_spec, "chameleon" | "projected") {
        eprintln!(
            "tod metrics supports tod|tod:<h..>|fixed:<dnn> (drive the \
             {policy_spec} path through `tod run`)"
        );
        return 2;
    }
    let power_budget = match budget_from_args(args, &lat) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let registry = Rc::new(RefCell::new(MetricsRegistry::new()));
    let rec: SharedRecorder = registry.clone();
    let mut policy = match parse_policy(policy_spec) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let r = match power_budget {
        Some(budget) => {
            let mut policy = BudgetedPolicy::masking(policy, budget)
                .with_recorder(rec.clone(), 0);
            run_realtime_observed(
                &seq,
                &mut policy,
                &mut det,
                &mut lat,
                fps,
                Some((rec.clone(), 0)),
            )
        }
        None => run_realtime_observed(
            &seq,
            policy.as_mut(),
            &mut det,
            &mut lat,
            fps,
            Some((rec.clone(), 0)),
        ),
    };
    {
        // switches and the metered power summary are not on the event
        // stream; fold them in before rendering
        let mut reg = registry.borrow_mut();
        reg.switches += r.switches;
        reg.observe_power(&r.power);
    }
    let reg = registry.borrow();
    if args.has("json") {
        print!("{}", reg.to_json().to_pretty());
    } else {
        print!("{}", reg.to_prometheus());
    }
    0
}

fn cmd_bench(args: &Args) -> i32 {
    use std::path::Path;
    let opts = SuiteOptions {
        quick: args.has("quick"),
        filter: args.get("filter").map(String::from),
    };
    let tolerance = match args.get_parse("tolerance", DEFAULT_TOLERANCE) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let check = args.has("check");
    if check && opts.filter.is_some() {
        eprintln!(
            "--filter cannot be combined with --check: skipped cases would \
             count as missing from the baseline"
        );
        return 2;
    }

    let mut report = run_suite(&opts);
    if let Some(c) = args.get("comment") {
        // e.g. name the reference machine when pinning a baseline
        report.comment = Some(c.to_string());
    }

    if args.has("json") {
        println!("{}", report.to_json().to_pretty());
    } else {
        for c in &report.cases {
            match (c.min_ns, c.mean_ns, c.allocs_per_op) {
                (Some(min), Some(mean), Some(allocs)) => println!(
                    "{:<34} min {:>12.1} ns  mean {:>12.1} ns  \
                     {:>8.2} allocs/op  ({} iters)",
                    c.name, min, mean, allocs, c.iters
                ),
                _ => println!("{:<34} (no samples)", c.name),
            }
        }
    }

    if let Some(out) = args.get("out") {
        if let Err(e) = report.save(Path::new(out)) {
            eprintln!("write {out}: {e}");
            return 1;
        }
        eprintln!("wrote {out}");
    }

    if check {
        let path = args.get("baseline").unwrap_or("../BENCH_6.json");
        let baseline = match BenchReport::load(Path::new(path)) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("{e}");
                return 1;
            }
        };
        let diff = report.diff(&baseline, tolerance);
        print!("{}", diff.render());
        if diff.is_regression() {
            eprintln!(
                "bench regression against {path} (tolerance {:.0}%)",
                tolerance * 100.0
            );
            return 1;
        }
        println!("no regression against {path}");
    }
    0
}

/// Resolve a lint input path: an explicit flag must exist; otherwise
/// try repo-root-relative then `rust/`-relative candidates (the same
/// two working directories `default_goldens_dir` serves).
fn resolve_lint_path(
    explicit: Option<&str>,
    flag: &str,
    candidates: &[&str],
) -> Result<PathBuf, String> {
    if let Some(p) = explicit {
        let pb = PathBuf::from(p);
        if pb.exists() {
            return Ok(pb);
        }
        return Err(format!("--{flag} {p}: no such path"));
    }
    for c in candidates {
        let pb = PathBuf::from(c);
        if pb.exists() {
            return Ok(pb);
        }
    }
    Err(format!(
        "no default for --{flag} found relative to the current \
         directory (tried {}); run from the repository root or pass \
         --{flag} explicitly",
        candidates.join(", ")
    ))
}

/// `tod lint` — zone-aware static analysis of the crate's own sources
/// (DESIGN.md §16). `--check` is the CI gate: exit 1 on any unwaived
/// deny finding.
fn cmd_lint(args: &Args) -> i32 {
    use tod::analysis::{run_lint, Policy};

    let src = match resolve_lint_path(
        args.get("src"),
        "src",
        &["rust/src", "src"],
    ) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let policy_path = match resolve_lint_path(
        args.get("policy"),
        "policy",
        &["rust/lint-policy.json", "lint-policy.json"],
    ) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let policy = match Policy::load(&policy_path) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let report = match run_lint(&src, &policy) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    if args.has("json") {
        println!("{}", report.to_json().to_pretty());
    } else {
        print!("{}", report.render_text());
    }
    if let Some(out) = args.get("out") {
        let text = report.to_json().to_pretty();
        if let Err(e) = std::fs::write(out, text) {
            eprintln!("write {out}: {e}");
            return 1;
        }
        eprintln!("lint report written to {out}");
    }
    if args.has("check") && !report.clean() {
        eprintln!(
            "tod lint --check: {} unwaived deny finding(s) under policy \
             {} v{}",
            report.findings.len(),
            policy_path.display(),
            policy.version
        );
        return 1;
    }
    0
}

fn cmd_bench_report() -> i32 {
    // quick single-process counters: policy decision cost
    use std::time::Instant;
    let policy = MbbsPolicy::tod_default();
    let n = 10_000_000u64;
    let t0 = Instant::now();
    let mut acc = 0usize;
    for i in 0..n {
        let m = (i % 1000) as f64 / 5000.0;
        acc += policy.select_pure(m).index();
    }
    let per = t0.elapsed().as_nanos() as f64 / n as f64;
    println!(
        "policy decision: {per:.2} ns/frame (checksum {acc}) — vs 27-153 ms \
         inference: negligible (the paper's overhead claim)"
    );
    0
}
