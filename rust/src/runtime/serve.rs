//! End-to-end serving on the PJRT request path: rasterize -> infer ->
//! decode -> policy, with all four engines preloaded. Python never runs
//! here — the binary is self-contained once `make artifacts` has built
//! the HLO text.

use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::policy::{MbbsPolicy, SelectionPolicy};
use crate::coordinator::scheduler::Detector;
use crate::dataset::mot::GtEntry;
use crate::dataset::synth::{CameraMotion, Sequence, SequenceSpec};
use crate::detection::{Detection, FrameDetections};
use crate::features::FeatureExtractor;
use crate::runtime::decode::decode;
use crate::runtime::pool::EnginePool;
use crate::runtime::raster::rasterize;
use crate::util::stats::percentile;
use crate::DnnKind;

/// A [`Detector`] backend that runs real PJRT inference (used by the
/// integration tests and the serving examples).
pub struct PjrtBackend<'a> {
    pub pool: &'a EnginePool,
    pub frame_w: f64,
    pub frame_h: f64,
    /// Wall-clock seconds spent per inference, appended per call.
    pub latencies: Vec<(DnnKind, f64)>,
}

impl<'a> PjrtBackend<'a> {
    pub fn new(pool: &'a EnginePool, frame_w: f64, frame_h: f64) -> Self {
        PjrtBackend { pool, frame_w, frame_h, latencies: Vec::new() }
    }
}

impl<'a> Detector for PjrtBackend<'a> {
    fn detect(
        &mut self,
        frame: u64,
        gt: &[GtEntry],
        dnn: DnnKind,
    ) -> Vec<Detection> {
        let engine = self.pool.engine(dnn).expect("variant not loaded");
        let spec = engine.spec().clone();
        let img =
            rasterize(gt, self.frame_w, self.frame_h, spec.input_size, frame);
        let t0 = Instant::now();
        let heads = engine.infer(&img).expect("inference failed");
        self.latencies.push((dnn, t0.elapsed().as_secs_f64()));
        decode(&heads, &spec, self.frame_w, self.frame_h)
    }
}

/// Latency/throughput report for one serving run.
pub struct ServeReport {
    pub frames: u64,
    pub wall_s: f64,
    /// (p50_ms, p95_ms, n) per DNN.
    pub per_dnn: Vec<(DnnKind, f64, f64, usize)>,
    pub deploy: [u64; DnnKind::COUNT],
    pub switches: u64,
}

impl std::fmt::Display for ServeReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "served {} frames in {:.2}s ({:.2} frames/s, real CPU-PJRT \
             inference on the request path)",
            self.frames,
            self.wall_s,
            self.frames as f64 / self.wall_s
        )?;
        for (k, p50, p95, n) in &self.per_dnn {
            writeln!(
                f,
                "  {:16} p50 {:7.1} ms  p95 {:7.1} ms  ({} runs)",
                k.artifact_name(),
                p50,
                p95,
                n
            )?;
        }
        writeln!(
            f,
            "  deploy counts (YT-288/YT-416/Y-288/Y-416): {:?}, switches {}",
            self.deploy, self.switches
        )
    }
}

/// The `tod serve` demo: a TOD loop over a synthetic stream with real
/// inference. Every frame is inferred (no virtual drop-clock here — the
/// point is to exercise the full stack and measure actual latencies; the
/// drop-frame accounting is exercised by the simulation campaign).
pub fn serve_demo(artifacts: &Path, frames: u64) -> Result<String> {
    let pool = EnginePool::load(artifacts)?;
    let spec = SequenceSpec {
        name: "SERVE-DEMO".into(),
        width: 640,
        height: 480,
        fps: 30.0,
        frames,
        density: 6,
        ref_height: 240.0,
        depth_range: (1.0, 2.5),
        walk_speed: 1.5,
        camera: CameraMotion::Walking { pan_speed: 6.0 },
        seed: 2021,
    };
    let seq = Sequence::generate(spec);
    let report = serve_sequence(&pool, &seq, &mut MbbsPolicy::tod_default())?;
    Ok(report.to_string())
}

/// Run a policy over a sequence with real PJRT inference on every frame.
pub fn serve_sequence(
    pool: &EnginePool,
    seq: &Sequence,
    policy: &mut dyn SelectionPolicy,
) -> Result<ServeReport> {
    let (fw, fh) = (seq.spec.width as f64, seq.spec.height as f64);
    let mut backend = PjrtBackend::new(pool, fw, fh);
    let mut features = FeatureExtractor::new(fw, fh);
    let mut carried: Vec<Detection> = Vec::new();
    let mut deploy = [0u64; DnnKind::COUNT];
    let mut switches = 0u64;
    let mut last: Option<DnnKind> = None;
    let t0 = Instant::now();
    for f in 1..=seq.n_frames() {
        let feats = features.features(&carried);
        let dnn = policy.select(&feats);
        let raw = backend.detect(f, seq.gt(f), dnn);
        carried = FrameDetections { frame: f, detections: raw }
            .filtered()
            .detections;
        features.on_detections(f, &carried);
        deploy[dnn.index()] += 1;
        if let Some(prev) = last {
            if prev != dnn {
                switches += 1;
            }
        }
        last = Some(dnn);
    }
    let wall = t0.elapsed().as_secs_f64();
    let mut per_dnn = Vec::new();
    for k in DnnKind::ALL {
        let ms: Vec<f64> = backend
            .latencies
            .iter()
            .filter(|(d, _)| *d == k)
            .map(|(_, s)| s * 1e3)
            .collect();
        if !ms.is_empty() {
            per_dnn.push((
                k,
                percentile(&ms, 50.0),
                percentile(&ms, 95.0),
                ms.len(),
            ));
        }
    }
    Ok(ServeReport {
        frames: seq.n_frames(),
        wall_s: wall,
        per_dnn,
        deploy,
        switches,
    })
}
