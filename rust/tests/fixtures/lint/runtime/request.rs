//! Serving-zone fixture: panic sites outside tests, one exempt inside.

pub fn live(x: Option<u32>) -> u32 {
    x.unwrap()
}

pub fn looked(x: Option<u32>) -> u32 {
    x.expect("present")
}

pub fn boom() {
    panic!("no");
}

#[cfg(test)]
mod tests {
    #[test]
    fn exempt() {
        let v: Option<u32> = Some(1);
        assert_eq!(v.unwrap(), 1);
    }
}
