//! Shared test fixtures: the sequence/oracle/policy builders the
//! integration suites converged on, promoted out of per-file copies.
//!
//! Every suite used to re-declare the same helpers (a seeded oracle for
//! a sequence, a small 960×540 synthetic world, random thresholds for
//! property tests, a bit-identity comparator for [`RunResult`]s). They
//! live here once so a change to the canonical test world — or to what
//! "bit-identical" means — edits one place.

use crate::coordinator::policy::Thresholds;
use crate::coordinator::scheduler::{OracleBackend, RunResult};
use crate::dataset::synth::{CameraMotion, Sequence, SequenceSpec};
use crate::sim::oracle::OracleDetector;
use crate::testing::prop::Gen;

/// The oracle backend seeded for a sequence — the one way every suite
/// builds its detector.
pub fn oracle_for(seq: &Sequence) -> OracleBackend {
    OracleBackend(OracleDetector::new(
        seq.spec.seed,
        seq.spec.width as f64,
        seq.spec.height as f64,
    ))
}

/// Builder over [`SequenceSpec`] with the canonical small test world:
/// 960×540 @ 30 FPS, density 6, `ref_height` 220, depth [1, 2], walk
/// speed 1.5, static camera. Override what the test cares about.
#[derive(Debug, Clone)]
pub struct SeqBuilder {
    spec: SequenceSpec,
}

impl SeqBuilder {
    /// Canonical world named `{prefix}-{seed}`.
    pub fn new(prefix: &str, seed: u64) -> Self {
        SeqBuilder {
            spec: SequenceSpec {
                name: format!("{prefix}-{seed}"),
                width: 960,
                height: 540,
                fps: 30.0,
                frames: 120,
                density: 6,
                ref_height: 220.0,
                depth_range: (1.0, 2.0),
                walk_speed: 1.5,
                camera: CameraMotion::Static,
                seed,
            },
        }
    }

    pub fn frames(mut self, frames: u64) -> Self {
        self.spec.frames = frames;
        self
    }

    pub fn density(mut self, density: usize) -> Self {
        self.spec.density = density;
        self
    }

    pub fn ref_height(mut self, ref_height: f64) -> Self {
        self.spec.ref_height = ref_height;
        self
    }

    pub fn depth_range(mut self, near: f64, far: f64) -> Self {
        self.spec.depth_range = (near, far);
        self
    }

    pub fn walk_speed(mut self, walk_speed: f64) -> Self {
        self.spec.walk_speed = walk_speed;
        self
    }

    pub fn camera(mut self, camera: CameraMotion) -> Self {
        self.spec.camera = camera;
        self
    }

    pub fn geometry(mut self, width: u32, height: u32) -> Self {
        self.spec.width = width;
        self.spec.height = height;
        self
    }

    pub fn build(self) -> Sequence {
        Sequence::generate(self.spec)
    }
}

/// The canonical small test stream (`SeqBuilder` defaults).
pub fn synth_stream(prefix: &str, seed: u64, frames: u64) -> Sequence {
    SeqBuilder::new(prefix, seed).frames(frames).build()
}

/// Small-object variant (`ref_height` 120): selection leans on the
/// heavy networks, so power caps and capacity effects actually bind.
pub fn small_object_stream(prefix: &str, seed: u64, frames: u64) -> Sequence {
    SeqBuilder::new(prefix, seed)
        .frames(frames)
        .ref_height(120.0)
        .build()
}

/// Random 800×600 world for property suites: 20–150 frames, density
/// 1–12, static or walking camera.
pub fn random_seq(g: &mut Gen) -> Sequence {
    SeqBuilder::new("PROP", g.usize_in(0, 1_000_000) as u64)
        .geometry(800, 600)
        .frames(g.usize_in(20, 150) as u64)
        .density(g.usize_in(1, 12))
        .ref_height(g.f64_in(60.0, 420.0))
        .depth_range(1.0, 2.4)
        .walk_speed(g.f64_in(0.5, 3.0))
        .camera(if g.bool() {
            CameraMotion::Static
        } else {
            CameraMotion::Walking { pan_speed: g.f64_in(1.0, 25.0) }
        })
        .build()
}

/// Random strictly ascending three-rung thresholds for the full ladder.
pub fn random_thresholds(g: &mut Gen) -> Thresholds {
    let h1 = g.f64_in(1e-4, 0.01);
    let h2 = h1 + g.f64_in(1e-4, 0.05);
    let h3 = h2 + g.f64_in(1e-4, 0.1);
    Thresholds::new(vec![h1, h2, h3]).expect("generated ascending")
}

/// Bit-identity over everything a scheduled run produces (series,
/// schedule and summary counters — the equivalence the session/
/// scheduler golden tests pin).
pub fn results_identical(a: &RunResult, b: &RunResult) -> bool {
    a.ap == b.ap
        && a.n_frames == b.n_frames
        && a.n_inferred == b.n_inferred
        && a.n_dropped == b.n_dropped
        && a.deploy_counts == b.deploy_counts
        && a.switches == b.switches
        && a.mbbs_series == b.mbbs_series
        && a.dnn_series == b.dnn_series
        && a.trace.busy == b.trace.busy
        && a.trace.duration == b.trace.duration
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::MbbsPolicy;
    use crate::coordinator::scheduler::run_realtime;
    use crate::sim::latency::LatencyModel;
    use crate::testing::prop::PropConfig;

    #[test]
    fn builder_defaults_are_the_canonical_world() {
        let seq = synth_stream("FIX", 7, 30);
        assert_eq!(seq.spec.name, "FIX-7");
        assert_eq!((seq.spec.width, seq.spec.height), (960, 540));
        assert_eq!(seq.spec.fps, 30.0);
        assert_eq!(seq.n_frames(), 30);
        // deterministic per seed, distinct across seeds
        let again = synth_stream("FIX", 7, 30);
        assert_eq!(seq.all_entries(), again.all_entries());
        let other = synth_stream("FIX", 8, 30);
        assert_ne!(seq.all_entries(), other.all_entries());
    }

    #[test]
    fn small_object_stream_reads_small() {
        let small = small_object_stream("FIX", 7, 60);
        let big = synth_stream("FIX", 7, 60);
        let med = |s: &Sequence| {
            crate::util::stats::median(&s.mbbs_series())
        };
        assert!(med(&small) < med(&big));
    }

    #[test]
    fn random_thresholds_are_always_valid() {
        PropConfig::with_cases(64).run("thresholds ascend", |g| {
            let t = random_thresholds(g);
            t.values().windows(2).all(|w| w[0] < w[1]) && t.n_dnn() == 4
        });
    }

    #[test]
    fn results_identical_detects_equality_and_difference() {
        let seq = synth_stream("FIX", 9, 60);
        let run = || {
            let mut det = oracle_for(&seq);
            let mut pol = MbbsPolicy::tod_default();
            let mut lat = LatencyModel::deterministic();
            run_realtime(&seq, &mut pol, &mut det, &mut lat, 30.0)
        };
        let a = run();
        let b = run();
        assert!(results_identical(&a, &b));
        let mut c = run();
        c.switches += 1;
        assert!(!results_identical(&a, &c));
    }
}
