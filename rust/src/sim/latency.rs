//! Virtual-clock inference latency model, calibrated to the paper's
//! Fig. 5 Jetson Nano measurements.
//!
//! Algorithm 2's drop-frame behaviour depends only on the *ratio* of
//! inference latency to the frame period; replaying the paper's measured
//! latencies on a virtual clock reproduces its real-time regime exactly
//! and deterministically, independent of this machine's CPU (DESIGN.md
//! §3). Real CPU-PJRT latencies are measured separately by the
//! `runtime_infer` bench and `tod figures --id fig5`.

use crate::sim::profiles::DnnProfile;
use crate::util::rng::Rng;
use crate::DnnKind;

/// Latency source for the scheduler's virtual clock.
#[derive(Debug, Clone)]
pub struct LatencyModel {
    profiles: [DnnProfile; DnnKind::COUNT],
    /// When false, jitter is disabled and `sample` returns the mean.
    jitter: bool,
    rng: Rng,
}

impl LatencyModel {
    /// Jetson-Nano-calibrated model with multiplicative jitter.
    pub fn jetson_nano(seed: u64) -> Self {
        LatencyModel {
            profiles: DnnKind::ALL.map(DnnProfile::of),
            jitter: true,
            rng: Rng::new(seed ^ 0x1a7e_0c10),
        }
    }

    /// Deterministic model (mean latency, no jitter) — used by tests and
    /// by the paired policy comparisons of Table I.
    pub fn deterministic() -> Self {
        let mut m = Self::jetson_nano(0);
        m.jitter = false;
        m
    }

    /// Mean latency of a variant, seconds.
    pub fn mean(&self, dnn: DnnKind) -> f64 {
        self.profiles[dnn.index()].latency_mean_s
    }

    /// Mean latencies of all four variants, lightest first — the
    /// feasibility vector budget-constrained policies check per frame.
    pub fn means(&self) -> [f64; DnnKind::COUNT] {
        DnnKind::ALL.map(|d| self.mean(d))
    }

    /// A copy with every latency mean multiplied by `factor` — the
    /// execution half of a DVFS-style frequency cap
    /// ([`crate::power::RateCap`] stretches by `1/scale`). Jitter, as a
    /// fraction of the mean, is unchanged.
    pub fn stretched(mut self, factor: f64) -> Self {
        assert!(
            factor > 0.0 && factor.is_finite(),
            "latency stretch factor must be positive and finite"
        );
        for p in self.profiles.iter_mut() {
            p.latency_mean_s *= factor;
        }
        self
    }

    /// Sample one inference latency, seconds.
    pub fn sample(&mut self, dnn: DnnKind) -> f64 {
        let p = &self.profiles[dnn.index()];
        if !self.jitter {
            return p.latency_mean_s;
        }
        // lognormal-ish multiplicative jitter, clamped to ±4σ
        let f = (1.0
            + self
                .rng
                .normal(0.0, p.latency_jitter)
                .clamp(-4.0 * p.latency_jitter, 4.0 * p.latency_jitter))
        .max(0.5);
        p.latency_mean_s * f
    }

    /// Does the variant meet a frame budget of `1/fps` on average?
    pub fn meets_realtime(&self, dnn: DnnKind, fps: f64) -> bool {
        self.mean(dnn) <= 1.0 / fps
    }
}

/// Batched inference latency: fixed per-dispatch setup plus a marginal
/// per-item cost.
///
/// Micro-batching same-variant requests amortises the per-dispatch
/// overhead an edge accelerator pays on every engine invocation —
/// weight/engine (re)binding, host-side launch, pre/post-processing
/// setup (the throughput lever studied by the parallel-detection edge
/// work in PAPERS.md). The model is affine in the batch size `n`:
///
/// `latency(dnn, n) = first(dnn) + (n - 1) * marginal(dnn)`,  n >= 1
///
/// anchored so a batch of one costs *exactly* the unbatched mean
/// ([`LatencyModel::mean`]) — a batched schedule with `max_batch == 1`
/// is therefore bit-identical to an unbatched one. The per-item cost
/// `latency / n` strictly decreases with `n` whenever the setup share
/// is positive.
#[derive(Debug, Clone)]
pub struct BatchLatencyModel {
    /// Cost of a batch of one (== the unbatched mean), seconds.
    first_s: [f64; DnnKind::COUNT],
    /// Marginal cost of each additional item, seconds.
    marginal_s: [f64; DnnKind::COUNT],
}

impl BatchLatencyModel {
    /// Fraction of the unbatched mean attributed to per-dispatch setup
    /// on the Jetson-Nano profile (engine bind + host launch overhead —
    /// a modelling assumption, held fixed across variants).
    pub const DEFAULT_SETUP_FRAC: f64 = 0.35;

    /// Build from per-variant unbatched means; `setup_frac` in [0, 1)
    /// is the share of the mean amortised away inside a batch.
    pub fn from_means(
        means: [f64; DnnKind::COUNT],
        setup_frac: f64,
    ) -> Self {
        assert!(
            (0.0..1.0).contains(&setup_frac),
            "setup fraction must be in [0, 1), got {setup_frac}"
        );
        let mut marginal = [0.0; DnnKind::COUNT];
        for (mean, out) in means.iter().zip(marginal.iter_mut()) {
            assert!(
                *mean > 0.0 && mean.is_finite(),
                "latency means must be positive and finite"
            );
            *out = mean * (1.0 - setup_frac);
        }
        BatchLatencyModel { first_s: means, marginal_s: marginal }
    }

    /// Derive from a [`LatencyModel`]'s means.
    pub fn from_model(model: &LatencyModel, setup_frac: f64) -> Self {
        Self::from_means(model.means(), setup_frac)
    }

    /// Jetson-Nano-calibrated default (deterministic means,
    /// [`Self::DEFAULT_SETUP_FRAC`] setup share).
    pub fn jetson_nano() -> Self {
        Self::from_model(
            &LatencyModel::deterministic(),
            Self::DEFAULT_SETUP_FRAC,
        )
    }

    /// Cost of a batch of one — exactly the unbatched mean.
    pub fn first(&self, dnn: DnnKind) -> f64 {
        self.first_s[dnn.index()]
    }

    /// Marginal cost of each item after the first.
    pub fn marginal(&self, dnn: DnnKind) -> f64 {
        self.marginal_s[dnn.index()]
    }

    /// The amortisable setup share of a dispatch, seconds.
    pub fn setup(&self, dnn: DnnKind) -> f64 {
        self.first(dnn) - self.marginal(dnn)
    }

    /// Total latency of a batch of `n` items (0.0 for an empty batch).
    pub fn batch_latency(&self, dnn: DnnKind, n: usize) -> f64 {
        if n == 0 {
            return 0.0;
        }
        self.first(dnn) + (n - 1) as f64 * self.marginal(dnn)
    }

    /// Effective per-item latency inside a batch of `n`.
    pub fn per_item(&self, dnn: DnnKind, n: usize) -> f64 {
        if n == 0 {
            return 0.0;
        }
        self.batch_latency(dnn, n) / n as f64
    }

    /// Throughput multiplier of batching `n` items vs `n` singleton
    /// dispatches (>= 1.0, and exactly 1.0 at `n <= 1`).
    pub fn speedup(&self, dnn: DnnKind, n: usize) -> f64 {
        if n <= 1 {
            return 1.0;
        }
        n as f64 * self.first(dnn) / self.batch_latency(dnn, n)
    }
}

/// Contention-aware latency inflation for a shared accelerator.
///
/// The multi-stream scheduler serialises inferences on the virtual GPU,
/// but co-resident streams still slow each other down: engine/weight
/// cache evictions between different models, shared memory bandwidth,
/// and host-side pre/post-processing overlap (the regime studied by
/// ROMA and the parallel-detection edge work in PAPERS.md). This model
/// inflates each inference latency linearly in the number of streams
/// *waiting* for the accelerator at dispatch time:
///
/// `effective = base * (1 + alpha * (occupancy - 1))`
///
/// so a single stream (`occupancy == 1`) is exactly uninflated and the
/// single-stream reproduction stays bit-identical.
#[derive(Debug, Clone)]
pub struct ContentionModel {
    /// Fractional latency inflation per additional contending stream.
    pub alpha: f64,
}

impl ContentionModel {
    pub fn new(alpha: f64) -> Self {
        assert!(alpha >= 0.0, "contention alpha must be non-negative");
        ContentionModel { alpha }
    }

    /// No contention effect (pure serialisation).
    pub fn none() -> Self {
        ContentionModel { alpha: 0.0 }
    }

    /// Jetson-Nano-flavoured default: ~12% per co-resident stream,
    /// dominated by engine swaps between per-stream model selections.
    pub fn jetson_nano() -> Self {
        ContentionModel { alpha: 0.12 }
    }

    /// Multiplicative latency factor for `occupancy` streams contending
    /// (the dispatched one included). Always 1.0 for `occupancy <= 1`.
    pub fn factor(&self, occupancy: usize) -> f64 {
        1.0 + self.alpha * occupancy.saturating_sub(1) as f64
    }
}

impl Default for ContentionModel {
    fn default() -> Self {
        ContentionModel::jetson_nano()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_returns_mean() {
        let mut m = LatencyModel::deterministic();
        for d in DnnKind::ALL {
            assert_eq!(m.sample(d), m.mean(d));
        }
        let means = m.means();
        for d in DnnKind::ALL {
            assert_eq!(means[d.index()], m.mean(d));
        }
    }

    #[test]
    fn jitter_centres_on_mean() {
        let mut m = LatencyModel::jetson_nano(42);
        let n = 5000;
        let mean_sample: f64 =
            (0..n).map(|_| m.sample(DnnKind::Y416)).sum::<f64>() / n as f64;
        let mean = m.mean(DnnKind::Y416);
        assert!((mean_sample / mean - 1.0).abs() < 0.02);
    }

    #[test]
    fn samples_are_positive_and_bounded() {
        let mut m = LatencyModel::jetson_nano(7);
        for _ in 0..2000 {
            let v = m.sample(DnnKind::TinyY288);
            assert!(v > 0.0);
            assert!(v < m.mean(DnnKind::TinyY288) * 2.0);
        }
    }

    #[test]
    fn contention_factor_is_identity_for_one_stream() {
        for m in [
            ContentionModel::none(),
            ContentionModel::jetson_nano(),
            ContentionModel::new(0.5),
        ] {
            assert_eq!(m.factor(0), 1.0);
            assert_eq!(m.factor(1), 1.0);
        }
    }

    #[test]
    fn contention_factor_grows_linearly() {
        let m = ContentionModel::new(0.1);
        assert!((m.factor(2) - 1.1).abs() < 1e-12);
        assert!((m.factor(5) - 1.4).abs() < 1e-12);
        let none = ContentionModel::none();
        assert_eq!(none.factor(8), 1.0);
    }

    #[test]
    #[should_panic(expected = "non-negative")]
    fn negative_alpha_rejected() {
        ContentionModel::new(-0.1);
    }

    #[test]
    fn stretched_scales_means_only() {
        let m = LatencyModel::deterministic().stretched(2.0);
        let base = LatencyModel::deterministic();
        for d in DnnKind::ALL {
            assert!((m.mean(d) - 2.0 * base.mean(d)).abs() < 1e-15);
        }
        // half-frequency Y-416: 306 ms — even 14 FPS is out of reach
        assert!(!m.meets_realtime(DnnKind::TinyY416, 14.0));
    }

    #[test]
    #[should_panic(expected = "stretch factor")]
    fn stretched_rejects_zero() {
        let _ = LatencyModel::deterministic().stretched(0.0);
    }

    #[test]
    fn batch_of_one_costs_exactly_the_unbatched_mean() {
        let m = LatencyModel::deterministic();
        let b = BatchLatencyModel::jetson_nano();
        for d in DnnKind::ALL {
            // bit-exact anchor: max_batch == 1 schedules reproduce the
            // unbatched schedule bit for bit
            assert_eq!(b.batch_latency(d, 1), m.mean(d));
            assert_eq!(b.first(d), m.mean(d));
            assert_eq!(b.batch_latency(d, 0), 0.0);
        }
    }

    #[test]
    fn per_item_cost_decreases_and_speedup_grows() {
        let b = BatchLatencyModel::jetson_nano();
        for d in DnnKind::ALL {
            let mut prev = f64::INFINITY;
            for n in 1..=8usize {
                let item = b.per_item(d, n);
                assert!(item < prev, "{d}: per-item not decreasing at {n}");
                prev = item;
                assert!(b.speedup(d, n) >= 1.0);
                // affine structure: total = first + (n-1) * marginal
                let expect =
                    b.first(d) + (n - 1) as f64 * b.marginal(d);
                assert!((b.batch_latency(d, n) - expect).abs() < 1e-15);
            }
            assert_eq!(b.speedup(d, 1), 1.0);
            assert!(b.speedup(d, 4) > 1.2, "{d}: no batching win");
            assert!(b.setup(d) > 0.0);
        }
    }

    #[test]
    #[should_panic(expected = "setup fraction")]
    fn batch_model_rejects_full_setup_fraction() {
        let _ = BatchLatencyModel::from_model(
            &LatencyModel::deterministic(),
            1.0,
        );
    }

    #[test]
    fn realtime_budget_matches_paper() {
        let m = LatencyModel::deterministic();
        // 30 FPS: only tiny-288 (Fig. 5)
        assert!(m.meets_realtime(DnnKind::TinyY288, 30.0));
        assert!(!m.meets_realtime(DnnKind::TinyY416, 30.0));
        assert!(!m.meets_realtime(DnnKind::Y288, 30.0));
        assert!(!m.meets_realtime(DnnKind::Y416, 30.0));
        // 14 FPS (MOT17-05): both tiny variants fit
        assert!(m.meets_realtime(DnnKind::TinyY416, 14.0));
        assert!(!m.meets_realtime(DnnKind::Y288, 14.0));
    }
}
