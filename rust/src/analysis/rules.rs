//! The rule set: what each zone bans, and how a banned construct is
//! recognised on a masked source line.
//!
//! Matching runs over [`scanner`](crate::analysis::scanner) output, so
//! comments and string literals are already blanked — a rule needle
//! only ever matches *code*. Needles are deliberately token-literal
//! (`.unwrap()`, `Instant::now`, `HashMap`) rather than syntactic:
//! every needle is the textual fingerprint of exactly the construct
//! the corresponding dynamic test would catch at run time, and a
//! false positive is waivable inline with a reason
//! ([`crate::analysis::waivers`]).

use crate::analysis::zones::{Severity, Zone};

/// How a needle matches within a masked line.
#[derive(Debug, Clone, Copy)]
pub enum Needle {
    /// Literal substring (used for patterns that carry their own
    /// delimiters, e.g. `.unwrap()`).
    Exact(&'static str),
    /// Identifier: substring bounded by non-identifier characters on
    /// both sides (e.g. `HashMap`, but not `MyHashMapLike`).
    Ident(&'static str),
    /// Both substrings on the same line, in order (e.g.
    /// `partial_cmp` ... `.unwrap()`).
    Pair(&'static str, &'static str),
}

/// One lint rule.
#[derive(Debug, Clone, Copy)]
pub struct Rule {
    /// Stable id, `<zone-prefix>-<name>` (carried in findings,
    /// waivers and the policy's severity table).
    pub id: &'static str,
    /// Zone the rule runs in.
    pub zone: Zone,
    /// Default severity (the policy may override per id).
    pub default_severity: Severity,
    /// Patterns, any of which constitutes a finding.
    pub needles: &'static [Needle],
    /// One-line rationale shown with each finding.
    pub message: &'static str,
}

/// The shipped rule set, grouped by zone.
pub const RULES: &[Rule] = &[
    // -- determinism zone ---------------------------------------------
    Rule {
        id: "det-wall-clock",
        zone: Zone::Determinism,
        default_severity: Severity::Deny,
        needles: &[
            Needle::Exact("Instant::now"),
            Needle::Ident("SystemTime"),
        ],
        message: "wall-clock read in a byte-stable module: traces and \
                  goldens must replay identically (use the virtual \
                  stream clock)",
    },
    Rule {
        id: "det-unordered-iter",
        zone: Zone::Determinism,
        default_severity: Severity::Deny,
        needles: &[Needle::Ident("HashMap"), Needle::Ident("HashSet")],
        message: "unordered map/set in a serialising module: iteration \
                  order leaks into pinned output (use BTreeMap/BTreeSet)",
    },
    Rule {
        id: "det-ambient-rng",
        zone: Zone::Determinism,
        default_severity: Severity::Deny,
        needles: &[
            Needle::Ident("thread_rng"),
            Needle::Ident("RandomState"),
            Needle::Exact("rand::random"),
        ],
        message: "ambient randomness in a byte-stable module: all \
                  entropy must flow from the seeded util::rng",
    },
    Rule {
        id: "det-float-cmp-unwrap",
        zone: Zone::Determinism,
        default_severity: Severity::Deny,
        needles: &[Needle::Pair("partial_cmp", ".unwrap()")],
        message: "partial_cmp().unwrap() panics on NaN and orders \
                  nothing deterministically (use total_cmp)",
    },
    // -- serving zone -------------------------------------------------
    Rule {
        id: "srv-unwrap",
        zone: Zone::Serving,
        default_severity: Severity::Deny,
        needles: &[
            Needle::Exact(".unwrap()"),
            Needle::Exact(".unwrap_err()"),
        ],
        message: "unwrap on the serving path: a failed request must \
                  fail itself, not the process (return a Result or \
                  carry forward)",
    },
    Rule {
        id: "srv-expect",
        zone: Zone::Serving,
        default_severity: Severity::Deny,
        needles: &[
            Needle::Exact(".expect("),
            Needle::Exact(".expect_err("),
        ],
        message: "expect on the serving path: same failure mode as \
                  unwrap, with a nicer epitaph",
    },
    Rule {
        id: "srv-panic",
        zone: Zone::Serving,
        default_severity: Severity::Deny,
        needles: &[
            Needle::Exact("panic!"),
            Needle::Exact("unreachable!"),
            Needle::Exact("todo!"),
            Needle::Exact("unimplemented!"),
        ],
        message: "explicit panic on the serving path (encode the \
                  invariant in types, or waive a documented \
                  construction-time contract)",
    },
    Rule {
        id: "srv-slice-index",
        zone: Zone::Serving,
        default_severity: Severity::Deny,
        needles: &[], // structural: see index_sites()
        message: "raw slice/array indexing can panic on the serving \
                  path (prefer get()/iterators; COUNT-bounded DnnKind \
                  tables are the tolerated idiom)",
    },
    // -- hot-path zone ------------------------------------------------
    Rule {
        id: "hot-alloc",
        zone: Zone::HotPath,
        default_severity: Severity::Deny,
        needles: &[
            Needle::Exact("Vec::new"),
            Needle::Exact("VecDeque::new"),
            Needle::Exact("String::new"),
            Needle::Exact("Box::new"),
            Needle::Exact("vec!"),
        ],
        message: "fresh container/box in a steady-state-alloc-free \
                  function (reuse caller scratch; the counting \
                  allocator pins this dynamically)",
    },
    Rule {
        id: "hot-collect",
        zone: Zone::HotPath,
        default_severity: Severity::Deny,
        needles: &[
            Needle::Exact(".collect()"),
            Needle::Exact(".collect::"),
        ],
        message: "collect() allocates a fresh container per call in an \
                  alloc-free function (extend into reused scratch)",
    },
    Rule {
        id: "hot-clone",
        zone: Zone::HotPath,
        default_severity: Severity::Deny,
        needles: &[Needle::Exact(".clone()")],
        message: "clone in an alloc-free function (borrow, or waive \
                  refcount bumps like Arc::clone with a reason)",
    },
    Rule {
        id: "hot-format",
        zone: Zone::HotPath,
        default_severity: Severity::Deny,
        needles: &[
            Needle::Exact("format!"),
            Needle::Exact(".to_string()"),
            Needle::Exact(".to_owned()"),
            Needle::Exact(".to_vec()"),
        ],
        message: "string/buffer materialisation in an alloc-free \
                  function (defer rendering to the reporting layer)",
    },
];

/// Look up a rule by id.
pub fn rule_by_id(id: &str) -> Option<&'static Rule> {
    RULES.iter().find(|r| r.id == id)
}

/// Does `line` (masked code text) contain the needle?
pub fn needle_matches(line: &str, needle: &Needle) -> bool {
    match needle {
        Needle::Exact(s) => line.contains(s),
        Needle::Ident(s) => ident_matches(line, s),
        Needle::Pair(a, b) => line
            .find(a)
            .map(|i| line[i + a.len()..].contains(b))
            .unwrap_or(false),
    }
}

fn is_ident_char(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

fn ident_matches(line: &str, ident: &str) -> bool {
    let lb = line.as_bytes();
    let mut from = 0;
    while let Some(pos) = line[from..].find(ident) {
        let start = from + pos;
        let end = start + ident.len();
        let left_ok = start == 0 || !is_ident_char(lb[start - 1]);
        let right_ok = end == lb.len() || !is_ident_char(lb[end]);
        if left_ok && right_ok {
            return true;
        }
        from = start + 1;
    }
    false
}

/// Column offsets (0-based) of raw index expressions `expr[...]` on a
/// masked line: a `[` directly preceded by an identifier character,
/// `)` or `]`. Attribute brackets (`#[...]`), slice types (`&[T]`,
/// `: [f64; 4]`) and array literals (`= [a, b]`) all have a
/// non-postfix character before the bracket and never match.
pub fn index_sites(line: &str) -> Vec<usize> {
    let b = line.as_bytes();
    let mut out = Vec::new();
    for i in 1..b.len() {
        if b[i] == b'['
            && (is_ident_char(b[i - 1]) || b[i - 1] == b')' || b[i - 1] == b']')
        {
            out.push(i);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_and_ident_needles() {
        assert!(needle_matches("x.unwrap();", &Needle::Exact(".unwrap()")));
        assert!(!needle_matches(
            "x.unwrap_or(0);",
            &Needle::Exact(".unwrap()")
        ));
        assert!(needle_matches(
            "use std::collections::HashMap;",
            &Needle::Ident("HashMap")
        ));
        assert!(!needle_matches(
            "struct MyHashMapLike;",
            &Needle::Ident("HashMap")
        ));
        assert!(needle_matches(
            "a.partial_cmp(&b).unwrap()",
            &Needle::Pair("partial_cmp", ".unwrap()")
        ));
        assert!(!needle_matches(
            "a.unwrap(); b.partial_cmp(&c)",
            &Needle::Pair("partial_cmp", ".unwrap()")
        ));
    }

    #[test]
    fn index_sites_hit_indexing_only() {
        assert_eq!(index_sites("let x = arr[i];").len(), 1);
        assert_eq!(index_sites("m[k.index()][si][vi]").len(), 3);
        assert!(index_sites("#[cfg(test)]").is_empty());
        assert!(index_sites("let a: [f64; 4] = [0.0; 4];").is_empty());
        assert!(index_sites("fn f(x: &[u8]) {}").is_empty());
        assert_eq!(index_sites("(a + b)[0]").len(), 1);
    }

    #[test]
    fn every_rule_id_is_unique_and_prefixed() {
        for (i, r) in RULES.iter().enumerate() {
            let prefix = match r.zone {
                Zone::Determinism => "det-",
                Zone::Serving => "srv-",
                Zone::HotPath => "hot-",
            };
            assert!(r.id.starts_with(prefix), "{} prefix", r.id);
            assert!(
                RULES[i + 1..].iter().all(|o| o.id != r.id),
                "duplicate rule id {}",
                r.id
            );
        }
        assert!(rule_by_id("srv-unwrap").is_some());
        assert!(rule_by_id("nope").is_none());
    }
}
