//! Inline waiver protocol: `// tod-lint: allow(<rule>) reason="..."`.
//!
//! A waiver suppresses a finding without hiding it — every honoured
//! waiver is enumerated in the report with its reason, and a waiver
//! that stops matching anything becomes an `unused-waiver` advisory so
//! stale exemptions surface instead of rotting.
//!
//! Placement: a **trailing** waiver (sharing its line with code)
//! covers that line; a **standalone** comment line covers the next
//! line that carries code. The marker must *start* the comment body
//! and sit in a plain `//` comment — doc comments and prose mentions
//! of the syntax are never waivers (the scanner filters them). The `reason="..."` clause is mandatory —
//! a reason-less waiver is itself a deny finding
//! (`waiver-missing-reason`), because an unexplained exemption is
//! exactly the convention-rot this pass exists to stop.

use crate::analysis::scanner::ScannedFile;

/// A successfully parsed waiver, resolved to the line it covers.
#[derive(Debug, Clone)]
pub struct Waiver {
    /// 1-based line of the comment itself.
    pub decl_line: usize,
    /// 1-based line findings must sit on to be waived.
    pub target_line: usize,
    /// Rule ids the waiver allows.
    pub rules: Vec<String>,
    /// Mandatory justification.
    pub reason: String,
}

/// A malformed waiver (reported as a finding by the driver).
#[derive(Debug, Clone)]
pub struct WaiverProblem {
    /// 1-based line of the offending comment.
    pub line: usize,
    /// What is wrong with it.
    pub message: String,
}

/// Parsed `allow(...)` clause of a waiver comment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParsedWaiver {
    /// Rule ids listed in `allow(...)`.
    pub rules: Vec<String>,
    /// Text of `reason="..."`, when present and non-empty.
    pub reason: Option<String>,
}

/// Parse the text of a `tod-lint:` comment (everything after `//`).
pub fn parse_comment(text: &str) -> Result<ParsedWaiver, String> {
    let after = text
        .split("tod-lint:")
        .nth(1)
        .ok_or("missing tod-lint: marker")?;
    let rest = after.trim_start();
    let rest = rest
        .strip_prefix("allow")
        .ok_or("expected allow(<rule>[, <rule>]) after tod-lint:")?;
    let rest = rest.trim_start();
    let rest = rest
        .strip_prefix('(')
        .ok_or("expected '(' after allow")?;
    let close = rest.find(')').ok_or("unclosed allow( list")?;
    let rules: Vec<String> = rest[..close]
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if rules.is_empty() {
        return Err("empty allow() list".to_string());
    }
    let tail = &rest[close + 1..];
    let reason = tail.find("reason=").and_then(|at| {
        let q = &tail[at + "reason=".len()..];
        let q = q.strip_prefix('"')?;
        let end = q.find('"')?;
        let r = q[..end].trim();
        if r.is_empty() {
            None
        } else {
            Some(r.to_string())
        }
    });
    Ok(ParsedWaiver { rules, reason })
}

/// Resolve every waiver comment in a scanned file: well-formed waivers
/// come back with their covered line; malformed or reason-less ones
/// come back as problems.
pub fn collect(scanned: &ScannedFile) -> (Vec<Waiver>, Vec<WaiverProblem>) {
    let mut waivers = Vec::new();
    let mut problems = Vec::new();
    for c in &scanned.waivers {
        let parsed = match parse_comment(&c.text) {
            Ok(p) => p,
            Err(e) => {
                problems.push(WaiverProblem {
                    line: c.line,
                    message: format!("malformed waiver: {e}"),
                });
                continue;
            }
        };
        let reason = match parsed.reason {
            Some(r) => r,
            None => {
                problems.push(WaiverProblem {
                    line: c.line,
                    message: format!(
                        "waiver for {} has no reason=\"...\" — every \
                         exemption must say why",
                        parsed.rules.join(", ")
                    ),
                });
                continue;
            }
        };
        let target_line = if c.trailing {
            c.line
        } else {
            // first subsequent line with code on it (comments and
            // blanks are already masked to whitespace)
            scanned
                .lines
                .iter()
                .enumerate()
                .skip(c.line) // 0-based index c.line == 1-based line+1
                .find(|(_, l)| !l.masked.trim().is_empty())
                .map(|(idx, _)| idx + 1)
                .unwrap_or(c.line)
        };
        waivers.push(Waiver {
            decl_line: c.line,
            target_line,
            rules: parsed.rules,
            reason,
        });
    }
    (waivers, problems)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::scanner::scan_source;

    #[test]
    fn parses_single_and_multi_rule() {
        let p = parse_comment(
            " tod-lint: allow(srv-unwrap) reason=\"lock can't poison\"",
        )
        .unwrap();
        assert_eq!(p.rules, vec!["srv-unwrap"]);
        assert_eq!(p.reason.as_deref(), Some("lock can't poison"));

        let p = parse_comment(
            " tod-lint: allow(hot-clone, hot-alloc) reason=\"Arc bump\"",
        )
        .unwrap();
        assert_eq!(p.rules, vec!["hot-clone", "hot-alloc"]);
    }

    #[test]
    fn missing_reason_is_an_error_downstream() {
        let p = parse_comment(" tod-lint: allow(srv-unwrap)").unwrap();
        assert!(p.reason.is_none());
        let p =
            parse_comment(" tod-lint: allow(srv-unwrap) reason=\"  \"")
                .unwrap();
        assert!(p.reason.is_none());
        assert!(parse_comment(" tod-lint: allow()").is_err());
        assert!(parse_comment(" tod-lint: deny(x)").is_err());
    }

    #[test]
    fn trailing_and_standalone_targets() {
        let src = concat!(
            "// tod-lint: allow(srv-panic) reason=\"ctor contract\"\n",
            "\n",
            "panic!();\n",
            "x.unwrap(); // tod-lint: allow(srv-unwrap) reason=\"r\"\n",
        );
        let (ws, probs) = collect(&scan_source("t.rs", src));
        assert!(probs.is_empty());
        assert_eq!(ws.len(), 2);
        assert_eq!(ws[0].decl_line, 1);
        assert_eq!(ws[0].target_line, 3); // skips the blank line
        assert_eq!(ws[1].target_line, 4);
    }

    #[test]
    fn reasonless_waiver_becomes_problem() {
        let src = "x.unwrap(); // tod-lint: allow(srv-unwrap)\n";
        let (ws, probs) = collect(&scan_source("t.rs", src));
        assert!(ws.is_empty());
        assert_eq!(probs.len(), 1);
        assert_eq!(probs[0].line, 1);
        assert!(probs[0].message.contains("no reason"));
    }
}
