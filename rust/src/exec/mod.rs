//! Minimal threaded executor: a fixed worker pool and bounded channels
//! with backpressure (the offline stand-in for tokio; DESIGN.md §3).
//!
//! The serving example uses this to decouple the frame producer from the
//! PJRT inference worker while preserving the paper's single-inference-
//! in-flight discipline.

// Serving zone (lint-policy.json): the pool and channels carry every
// batched request; poisoning recovery replaces unwrap on lock results.
// Tests are exempt via clippy.toml.
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod channel;
pub mod pool;

pub use channel::{bounded, Receiver, SendError, Sender};
pub use pool::{SubmitError, ThreadPool};
