//! Feature-driven DNN selection: pick the network with the highest
//! *projected* accuracy, subject to a per-frame latency budget.
//!
//! This is the runtime counterpart of the paper's second claim — "TOD
//! leverages characteristics of the video stream such as object size
//! and speed of movement [and] selects the best-performing network
//! based on projected accuracy and computational demand". Where
//! [`super::policy::MbbsPolicy`] hard-codes the size→DNN mapping as
//! three thresholds, [`ProjectedAccuracyPolicy`] reads it from a
//! calibrated [`CalibrationTable`] (see [`crate::predictor`]) indexed
//! by the full [`FrameFeatures`] vector, so speed-sensitive regimes
//! (vehicle cameras, fast pans) route to lighter networks even when
//! object sizes alone would demand a heavy one.
//!
//! Selection is O(|DNNs|) table lookups per frame — the same
//! "negligible computational overhead" envelope as Algorithm 1 (see
//! `benches/selection.rs`).

use crate::features::FrameFeatures;
use crate::predictor::CalibrationTable;
use crate::sim::latency::LatencyModel;
use crate::DnnKind;

use super::policy::SelectionPolicy;

/// Selects the feasible DNN maximising projected AP.
///
/// Feasibility is a mean-latency budget per frame (seconds), taken from
/// the [`LatencyModel`] at construction: networks whose mean inference
/// latency exceeds the budget are excluded before the argmax. With
/// [`UNBOUNDED`](Self::UNBOUNDED) (the default), the budget is
/// inactive and computational demand is priced only through the
/// calibration table itself (cells are measured under real-time drop
/// accounting, so a slow network already scores poorly wherever its
/// drops hurt). Ties break towards the lighter network, mirroring the
/// paper's grid-search tie-break.
#[derive(Debug, Clone)]
pub struct ProjectedAccuracyPolicy {
    table: CalibrationTable,
    /// Mean latency per DNN, seconds (from the latency model).
    latency_means: [f64; DnnKind::COUNT],
    budget_s: f64,
}

impl ProjectedAccuracyPolicy {
    /// "No latency budget" sentinel.
    pub const UNBOUNDED: f64 = f64::INFINITY;

    /// Policy over a calibrated table with no latency budget.
    pub fn new(table: CalibrationTable, latency: &LatencyModel) -> Self {
        Self::with_budget(table, latency, Self::UNBOUNDED)
    }

    /// Policy with a hard per-frame latency budget (seconds). If no
    /// network fits the budget, the lightest one is used — degrading
    /// accuracy is recoverable, blowing the deadline is not.
    pub fn with_budget(
        table: CalibrationTable,
        latency: &LatencyModel,
        budget_s: f64,
    ) -> Self {
        assert!(budget_s > 0.0, "latency budget must be positive");
        ProjectedAccuracyPolicy {
            table,
            latency_means: latency.means(),
            budget_s,
        }
    }

    /// The table this policy projects from.
    pub fn table(&self) -> &CalibrationTable {
        &self.table
    }

    /// The active latency budget, seconds.
    pub fn budget_s(&self) -> f64 {
        self.budget_s
    }

    /// Pure selection function (exposed for tests and benches).
    #[inline]
    pub fn select_pure(&self, features: &FrameFeatures) -> DnnKind {
        let mut best: Option<(DnnKind, f64)> = None;
        for k in DnnKind::ALL {
            if self.latency_means[k.index()] > self.budget_s {
                continue;
            }
            let projected = self.table.project_features(k, features);
            // strictly-greater keeps the lighter DNN on exact ties
            // (ALL iterates lightest -> heaviest)
            if best.map(|(_, b)| projected > b).unwrap_or(true) {
                best = Some((k, projected));
            }
        }
        best.map(|(k, _)| k).unwrap_or(DnnKind::TinyY288)
    }
}

impl SelectionPolicy for ProjectedAccuracyPolicy {
    fn select(&mut self, features: &FrameFeatures) -> DnnKind {
        self.select_pure(features)
    }

    fn label(&self) -> String {
        if self.budget_s.is_finite() {
            format!(
                "projected{{fps={},budget={:.0}ms}}",
                self.table.fps,
                self.budget_s * 1e3
            )
        } else {
            format!("projected{{fps={}}}", self.table.fps)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::Thresholds;

    fn flat_table(values: [f64; 4]) -> CalibrationTable {
        let ap = values.iter().map(|&v| vec![vec![v; 2]; 2]).collect();
        CalibrationTable::new(30.0, vec![0.01, 0.05], vec![0.0, 0.01], ap)
    }

    #[test]
    fn picks_global_argmax_without_budget() {
        let p = ProjectedAccuracyPolicy::new(
            flat_table([0.2, 0.9, 0.4, 0.3]),
            &LatencyModel::deterministic(),
        );
        assert_eq!(
            p.select_pure(&FrameFeatures::mbbs_only(0.02)),
            DnnKind::TinyY416
        );
    }

    #[test]
    fn budget_excludes_slow_networks() {
        // 60 ms budget: Y-288 (92 ms) and Y-416 (153 ms) are out even
        // though Y-416 projects best
        let lat = LatencyModel::deterministic();
        let p = ProjectedAccuracyPolicy::with_budget(
            flat_table([0.2, 0.5, 0.8, 0.9]),
            &lat,
            0.060,
        );
        assert_eq!(
            p.select_pure(&FrameFeatures::mbbs_only(0.02)),
            DnnKind::TinyY416
        );
    }

    #[test]
    fn impossible_budget_falls_back_to_lightest() {
        let lat = LatencyModel::deterministic();
        let p = ProjectedAccuracyPolicy::with_budget(
            flat_table([0.1, 0.5, 0.8, 0.9]),
            &lat,
            0.001,
        );
        assert_eq!(
            p.select_pure(&FrameFeatures::mbbs_only(0.02)),
            DnnKind::TinyY288
        );
    }

    #[test]
    fn ties_break_towards_lighter() {
        let p = ProjectedAccuracyPolicy::new(
            flat_table([0.5, 0.5, 0.5, 0.5]),
            &LatencyModel::deterministic(),
        );
        assert_eq!(
            p.select_pure(&FrameFeatures::mbbs_only(0.02)),
            DnnKind::TinyY288
        );
    }

    #[test]
    fn speed_channel_can_flip_the_choice() {
        // heavy net best at low speed, tiny best at high speed, same size
        let mut ap = vec![vec![vec![0.5; 2]; 1]; 4];
        ap[DnnKind::Y416.index()] = vec![vec![0.9, 0.2]];
        ap[DnnKind::TinyY288.index()] = vec![vec![0.3, 0.6]];
        let t = CalibrationTable::new(30.0, vec![0.01], vec![0.0, 0.02], ap);
        let p = ProjectedAccuracyPolicy::new(
            t,
            &LatencyModel::deterministic(),
        );
        let slow = FrameFeatures { speed: 0.0, ..FrameFeatures::mbbs_only(0.01) };
        let fast = FrameFeatures { speed: 0.02, ..FrameFeatures::mbbs_only(0.01) };
        assert_eq!(p.select_pure(&slow), DnnKind::Y416);
        assert_eq!(p.select_pure(&fast), DnnKind::TinyY288);
    }

    #[test]
    fn ladder_table_reproduces_mbbs_policy_pointwise() {
        use crate::coordinator::policy::MbbsPolicy;
        let th = Thresholds::h_opt();
        let mbbs_pol = MbbsPolicy::new(th.clone());
        let proj = ProjectedAccuracyPolicy::new(
            CalibrationTable::from_ladder(&th, &DnnKind::ALL),
            &LatencyModel::deterministic(),
        );
        // half-step offset keeps samples off the exact threshold values,
        // where the paper's `<=` boundary and the table's vanishing
        // interpolation band legitimately differ
        for i in 0..5000 {
            let m = (i as f64 + 0.5) * 0.1 / 5000.0;
            let f = FrameFeatures::mbbs_only(m);
            assert_eq!(
                proj.select_pure(&f),
                mbbs_pol.select_pure(m),
                "diverged at mbbs={m}"
            );
        }
    }

    #[test]
    fn label_identifies_config() {
        let lat = LatencyModel::deterministic();
        let p = ProjectedAccuracyPolicy::new(flat_table([0.1; 4]), &lat);
        assert_eq!(p.label(), "projected{fps=30}");
        let b = ProjectedAccuracyPolicy::with_budget(
            flat_table([0.1; 4]),
            &lat,
            0.060,
        );
        assert_eq!(b.label(), "projected{fps=30,budget=60ms}");
    }
}
