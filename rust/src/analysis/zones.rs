//! Rule zones and the versioned lint policy (`rust/lint-policy.json`,
//! schema `tod-lint-policy` v1).
//!
//! A *zone* names an invariant the crate's tests enforce dynamically
//! and maps it onto the source regions where the static pass enforces
//! it at authoring time (DESIGN.md §16):
//!
//! * **determinism** — modules whose output is pinned byte for byte
//!   (traces, goldens, reports): no wall-clock reads, no unordered-map
//!   iteration, no ambient RNG, no panicking float compares.
//! * **serving** — the request path that must never die: no
//!   `unwrap`/`expect`/`panic!`/`unreachable!` (and, advisorily, no
//!   raw slice indexing) outside `#[cfg(test)]`.
//! * **hot-path** — functions the counting-allocator tests pin as
//!   allocation-free in steady state: no `Vec::new`/`collect`/
//!   `clone`/`format!`/`to_string`/`Box::new` in their bodies.
//!
//! The policy file is data, not code, so a new module enters a zone by
//! editing JSON — the analyser itself never hardcodes a path.

use std::path::Path;

use crate::util::json::Json;

/// Schema tag of the policy document.
pub const POLICY_SCHEMA: &str = "tod-lint-policy";
/// Current policy schema version.
pub const POLICY_VERSION: u64 = 1;

/// The three rule zones.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Zone {
    /// Byte-stable serialisation/trace modules.
    Determinism,
    /// The panic-free request path.
    Serving,
    /// Enumerated allocation-free functions.
    HotPath,
}

impl Zone {
    /// Stable tag used in reports and the policy file.
    pub fn tag(self) -> &'static str {
        match self {
            Zone::Determinism => "determinism",
            Zone::Serving => "serving",
            Zone::HotPath => "hot-path",
        }
    }
}

/// Finding severity, per rule, policy-overridable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    /// Fails `tod lint --check` unless waived.
    Deny,
    /// Reported as an advisory; never fails the gate.
    Warn,
    /// Rule disabled.
    Off,
}

impl Severity {
    /// Stable tag used in reports and the policy file.
    pub fn tag(self) -> &'static str {
        match self {
            Severity::Deny => "deny",
            Severity::Warn => "warn",
            Severity::Off => "off",
        }
    }

    fn parse(s: &str) -> Result<Severity, String> {
        match s {
            "deny" => Ok(Severity::Deny),
            "warn" => Ok(Severity::Warn),
            "off" => Ok(Severity::Off),
            other => {
                Err(format!("unknown severity {other:?} (deny|warn|off)"))
            }
        }
    }
}

/// Parsed lint policy.
#[derive(Debug, Clone)]
pub struct Policy {
    /// Policy document version (distinct from the schema version —
    /// bumped when the zone contents change).
    pub version: u64,
    /// Path prefixes (or exact files) in the determinism zone,
    /// relative to the scan root, `/`-separated.
    pub determinism_paths: Vec<String>,
    /// Path prefixes (or exact files) in the serving zone.
    pub serving_paths: Vec<String>,
    /// Qualified (`Type::method`) or bare function names in the
    /// hot-path zone.
    pub hot_path_functions: Vec<String>,
    /// Per-rule severity overrides (rule id -> severity).
    pub severity: Vec<(String, Severity)>,
}

impl Policy {
    /// Effective severity for a rule (the rule's default unless the
    /// policy overrides it).
    pub fn severity_for(&self, rule_id: &str, default: Severity) -> Severity {
        self.severity
            .iter()
            .find(|(id, _)| id == rule_id)
            .map(|(_, s)| *s)
            .unwrap_or(default)
    }

    /// Zone of a source file, by longest matching path prefix. A file
    /// can sit in at most one *path* zone; hot-path membership is per
    /// function, not per file.
    pub fn path_zone(&self, rel_path: &str) -> Option<Zone> {
        let hit = |paths: &[String]| {
            paths.iter().any(|p| {
                rel_path == p
                    || (p.ends_with('/') && rel_path.starts_with(p.as_str()))
            })
        };
        if hit(&self.determinism_paths) {
            Some(Zone::Determinism)
        } else if hit(&self.serving_paths) {
            Some(Zone::Serving)
        } else {
            None
        }
    }

    /// Whether a function-name stack entry is in the hot-path zone.
    /// Policy entries match the qualified name exactly, or the bare
    /// name when the entry carries no `::` (free functions).
    pub fn is_hot_function(&self, qualified: &str) -> bool {
        self.hot_path_functions.iter().any(|f| {
            f == qualified
                || (!f.contains("::")
                    && qualified.rsplit("::").next() == Some(f.as_str()))
        })
    }

    /// Parse a policy document.
    pub fn parse(text: &str) -> Result<Policy, String> {
        let v = Json::parse(text).map_err(|e| format!("policy: {e}"))?;
        let schema = v
            .get("schema")
            .and_then(Json::as_str)
            .ok_or("policy: missing \"schema\"")?;
        if schema != POLICY_SCHEMA {
            return Err(format!(
                "policy: schema {schema:?}, want {POLICY_SCHEMA:?}"
            ));
        }
        let schema_version = v
            .get("schema_version")
            .and_then(Json::as_usize)
            .ok_or("policy: missing \"schema_version\"")?;
        if schema_version as u64 != POLICY_VERSION {
            return Err(format!(
                "policy: schema_version {schema_version}, this binary \
                 reads v{POLICY_VERSION}"
            ));
        }
        let version = v
            .get("version")
            .and_then(Json::as_usize)
            .ok_or("policy: missing \"version\"")? as u64;
        let strings = |path: &[&str]| -> Result<Vec<String>, String> {
            let arr = v
                .at(path)
                .and_then(Json::as_arr)
                .ok_or_else(|| {
                    format!("policy: missing array {}", path.join("."))
                })?;
            arr.iter()
                .map(|e| {
                    e.as_str().map(String::from).ok_or_else(|| {
                        format!(
                            "policy: non-string entry in {}",
                            path.join(".")
                        )
                    })
                })
                .collect()
        };
        let determinism_paths =
            strings(&["zones", "determinism", "paths"])?;
        let serving_paths = strings(&["zones", "serving", "paths"])?;
        let hot_path_functions =
            strings(&["zones", "hot_path", "functions"])?;
        let mut severity = Vec::new();
        if let Some(Json::Obj(m)) = v.get("severity") {
            for (rule, val) in m {
                let s = val.as_str().ok_or_else(|| {
                    format!("policy: severity.{rule} must be a string")
                })?;
                severity.push((rule.clone(), Severity::parse(s)?));
            }
        }
        Ok(Policy {
            version,
            determinism_paths,
            serving_paths,
            hot_path_functions,
            severity,
        })
    }

    /// Load a policy file.
    pub fn load(path: &Path) -> Result<Policy, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        Policy::parse(&text)
            .map_err(|e| format!("{}: {e}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const POLICY: &str = r#"{
      "schema": "tod-lint-policy",
      "schema_version": 1,
      "version": 3,
      "zones": {
        "determinism": {"paths": ["obs/", "util/json.rs"]},
        "serving": {"paths": ["runtime/", "exec/"]},
        "hot_path": {"functions": ["Foo::bar", "free_fn"]}
      },
      "severity": {"srv-slice-index": "warn"}
    }"#;

    #[test]
    fn parses_and_maps_zones() {
        let p = Policy::parse(POLICY).unwrap();
        assert_eq!(p.version, 3);
        assert_eq!(p.path_zone("obs/span.rs"), Some(Zone::Determinism));
        assert_eq!(p.path_zone("util/json.rs"), Some(Zone::Determinism));
        assert_eq!(p.path_zone("util/csv.rs"), None);
        assert_eq!(p.path_zone("runtime/server.rs"), Some(Zone::Serving));
        assert_eq!(p.path_zone("main.rs"), None);
        // exact-file entries do not match as prefixes
        assert_eq!(p.path_zone("util/json.rs.bak"), None);
    }

    #[test]
    fn hot_function_matching() {
        let p = Policy::parse(POLICY).unwrap();
        assert!(p.is_hot_function("Foo::bar"));
        assert!(!p.is_hot_function("Baz::bar"));
        assert!(p.is_hot_function("free_fn"));
        // bare policy entries also match methods of any impl
        assert!(p.is_hot_function("Any::free_fn"));
    }

    #[test]
    fn severity_overrides() {
        let p = Policy::parse(POLICY).unwrap();
        assert_eq!(
            p.severity_for("srv-slice-index", Severity::Deny),
            Severity::Warn
        );
        assert_eq!(
            p.severity_for("srv-unwrap", Severity::Deny),
            Severity::Deny
        );
    }

    #[test]
    fn rejects_wrong_schema() {
        assert!(Policy::parse("{\"schema\":\"x\"}").is_err());
        assert!(Policy::parse("not json").is_err());
        let wrong_ver = POLICY.replace(
            "\"schema_version\": 1",
            "\"schema_version\": 2",
        );
        assert!(Policy::parse(&wrong_ver).is_err());
    }
}
