//! 1 Hz sampling of power and GPU utilisation from a schedule's busy
//! intervals (the NVidia tegrastats default resolution the paper uses).

use crate::sim::profiles::{DnnProfile, GPU_IDLE_PCT, POWER_IDLE_W};
use crate::DnnKind;

/// The DNN-busy intervals produced by one scheduled run.
#[derive(Debug, Clone, Default)]
pub struct ScheduleTrace {
    /// (start, end, dnn) in stream seconds; non-overlapping, ordered.
    pub busy: Vec<(f64, f64, DnnKind)>,
    /// Total stream duration, seconds.
    pub duration: f64,
}

impl ScheduleTrace {
    pub fn push(&mut self, start: f64, end: f64, dnn: DnnKind) {
        debug_assert!(end >= start);
        self.busy.push((start, end, dnn));
        self.duration = self.duration.max(end);
    }

    /// Busy fraction per DNN over the whole run.
    pub fn duty_cycle(&self) -> [f64; 4] {
        let mut out = [0.0; 4];
        if self.duration <= 0.0 {
            return out;
        }
        for &(s, e, d) in &self.busy {
            out[d.index()] += (e - s) / self.duration;
        }
        out
    }
}

/// One tegrastats sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TelemetrySample {
    /// Window start, seconds.
    pub t: f64,
    /// Mean board power over the window, watts.
    pub power_w: f64,
    /// Mean GPU utilisation over the window, percent.
    pub gpu_util_pct: f64,
}

/// The sampler.
#[derive(Debug, Clone)]
pub struct TegrastatsSim {
    profiles: [DnnProfile; 4],
    /// Sampling resolution, seconds (tegrastats default: 1.0).
    pub resolution: f64,
}

impl Default for TegrastatsSim {
    fn default() -> Self {
        TegrastatsSim {
            profiles: [
                DnnProfile::of(DnnKind::TinyY288),
                DnnProfile::of(DnnKind::TinyY416),
                DnnProfile::of(DnnKind::Y288),
                DnnProfile::of(DnnKind::Y416),
            ],
            resolution: 1.0,
        }
    }
}

impl TegrastatsSim {
    /// Sample a schedule trace at the configured resolution.
    pub fn sample(&self, trace: &ScheduleTrace) -> Vec<TelemetrySample> {
        let n = (trace.duration / self.resolution).ceil() as usize;
        let mut samples = Vec::with_capacity(n);
        for i in 0..n {
            let w0 = i as f64 * self.resolution;
            let w1 = w0 + self.resolution;
            let mut busy_frac = [0.0f64; 4];
            for &(s, e, d) in &trace.busy {
                let overlap = (e.min(w1) - s.max(w0)).max(0.0);
                busy_frac[d.index()] += overlap / self.resolution;
            }
            let mut power = POWER_IDLE_W;
            let mut gpu = GPU_IDLE_PCT;
            for (k, frac) in busy_frac.iter().enumerate() {
                let p = &self.profiles[k];
                power += frac * (p.power_active_w - POWER_IDLE_W);
                gpu += frac * (p.gpu_util_pct - GPU_IDLE_PCT);
            }
            samples.push(TelemetrySample {
                t: w0,
                power_w: power,
                gpu_util_pct: gpu.min(100.0),
            });
        }
        samples
    }

    /// Mean power over a trace, watts.
    pub fn mean_power(&self, trace: &ScheduleTrace) -> f64 {
        let s = self.sample(trace);
        if s.is_empty() {
            return POWER_IDLE_W;
        }
        s.iter().map(|x| x.power_w).sum::<f64>() / s.len() as f64
    }

    /// Mean GPU utilisation over a trace, percent.
    pub fn mean_gpu(&self, trace: &ScheduleTrace) -> f64 {
        let s = self.sample(trace);
        if s.is_empty() {
            return GPU_IDLE_PCT;
        }
        s.iter().map(|x| x.gpu_util_pct).sum::<f64>() / s.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::profiles::mem_loaded_gb;

    fn saturated_trace(dnn: DnnKind, secs: f64) -> ScheduleTrace {
        let mut t = ScheduleTrace::default();
        // back-to-back inferences with no idle gaps
        let lat = DnnProfile::of(dnn).latency_mean_s;
        let mut now = 0.0;
        while now < secs {
            t.push(now, (now + lat).min(secs), dnn);
            now += lat;
        }
        t.duration = secs;
        t
    }

    #[test]
    fn saturated_y416_hits_active_power() {
        let sim = TegrastatsSim::default();
        let t = saturated_trace(DnnKind::Y416, 30.0);
        let p = sim.mean_power(&t);
        assert!((p - 7.5).abs() < 0.05, "power {p}");
        let g = sim.mean_gpu(&t);
        assert!((g - 91.0).abs() < 0.5, "gpu {g}");
    }

    #[test]
    fn idle_trace_is_idle() {
        let sim = TegrastatsSim::default();
        let t = ScheduleTrace { busy: vec![], duration: 10.0 };
        assert!((sim.mean_power(&t) - POWER_IDLE_W).abs() < 1e-9);
        assert!((sim.mean_gpu(&t) - GPU_IDLE_PCT).abs() < 1e-9);
    }

    #[test]
    fn duty_cycle_scales_power() {
        // tiny-288 at 30 FPS: busy 27/33.3 ms = 81% of the time
        let sim = TegrastatsSim::default();
        let mut t = ScheduleTrace::default();
        let mut now = 0.0f64;
        for _ in 0..300 {
            t.push(now, now + 0.027, DnnKind::TinyY288);
            now += 1.0 / 30.0;
        }
        t.duration = now;
        let duty = t.duty_cycle()[0];
        assert!((duty - 0.81).abs() < 0.01, "duty {duty}");
        let p = sim.mean_power(&t);
        let expect = POWER_IDLE_W + duty * (3.8 - POWER_IDLE_W);
        assert!((p - expect).abs() < 0.05, "power {p} vs {expect}");
    }

    #[test]
    fn samples_cover_duration_at_1hz() {
        let sim = TegrastatsSim::default();
        let t = saturated_trace(DnnKind::Y288, 12.5);
        let s = sim.sample(&t);
        assert_eq!(s.len(), 13);
        assert_eq!(s[0].t, 0.0);
        assert_eq!(s[12].t, 12.0);
    }

    #[test]
    fn mixed_schedule_power_between_extremes() {
        let sim = TegrastatsSim::default();
        let mut t = ScheduleTrace::default();
        // half the time tiny-288, half Y-416, saturated
        let mut now = 0.0;
        while now < 10.0 {
            t.push(now, now + 0.027, DnnKind::TinyY288);
            now += 0.027;
        }
        while now < 20.0 {
            t.push(now, now + 0.153, DnnKind::Y416);
            now += 0.153;
        }
        t.duration = 20.0;
        let p = sim.mean_power(&t);
        assert!(p > 3.8 && p < 7.5, "power {p}");
    }

    #[test]
    fn gpu_never_exceeds_100() {
        let sim = TegrastatsSim::default();
        let mut t = ScheduleTrace::default();
        // pathological overlapping intervals
        t.push(0.0, 1.0, DnnKind::Y416);
        t.push(0.0, 1.0, DnnKind::Y288);
        t.duration = 1.0;
        for s in sim.sample(&t) {
            assert!(s.gpu_util_pct <= 100.0);
        }
    }

    #[test]
    fn memory_model_fig11_consistency() {
        // singles below all-loaded; TOD (all four) comparable to Y-416
        let all = mem_loaded_gb(&DnnKind::ALL);
        for k in DnnKind::ALL {
            assert!(mem_loaded_gb(&[k]) < all);
        }
    }
}
