//! Cross-stream micro-batching server demo (no artifacts needed).
//!
//! Four concurrent synthetic camera streams run their own TOD policy
//! loops and submit inference requests to one `InferenceServer`. The
//! server collects per-DNN micro-batches (flush at `max_batch` or
//! `max_wait`), executes them on the crate's thread pool against a
//! synthetic backend with a real per-dispatch setup cost, and resolves
//! every request through its own completion handle. The backend is
//! deliberately flaky for stream 3 (every 10th frame errors): those
//! requests fail individually — carried forward by their own stream —
//! without touching the other streams or the process.
//!
//! ```bash
//! cargo run --release --example batched_server -- [frames_per_stream]
//! ```
//!
//! With real PJRT artifacts, the same shape runs on actual engines:
//! `tod serve --batch` (see `runtime::serve::serve_batched`).

use std::sync::Arc;
use std::time::Duration;

use tod::coordinator::policy::{MbbsPolicy, SelectionPolicy};
use tod::dataset::synth::{CameraMotion, Sequence, SequenceSpec};
use tod::detection::{Detection, FrameDetections, PERSON_CLASS};
use tod::features::FeatureExtractor;
use tod::geometry::BBox;
use tod::runtime::batch::BatchConfig;
use tod::runtime::server::{
    BatchDetector, InferRequest, InferenceServer, ServeResult,
};
use tod::DnnKind;

/// Synthetic backend: detections derived from the request's ground
/// truth, plus a wall-clock setup cost per dispatched batch (the cost
/// micro-batching amortises on real hardware).
struct DemoEngine;

fn spin_for(d: Duration) {
    let t0 = std::time::Instant::now();
    while t0.elapsed() < d {
        std::hint::spin_loop();
    }
}

impl BatchDetector for DemoEngine {
    fn infer(&self, req: &InferRequest) -> ServeResult {
        // injected flakiness: stream 3 loses every 10th frame — the
        // error resolves that request alone, the batch and the other
        // streams are untouched
        if req.stream == 3 && req.frame % 10 == 0 {
            return Err(tod::runtime::server::ServeError::Engine(
                format!("transient engine fault at frame {}", req.frame),
            ));
        }
        spin_for(Duration::from_micros(80)); // marginal per-item cost
        Ok(req
            .gt
            .iter()
            .map(|g| {
                Detection::new(
                    BBox::new(g.bbox.x, g.bbox.y, g.bbox.w, g.bbox.h),
                    0.9,
                    PERSON_CLASS,
                )
            })
            .collect())
    }

    fn on_batch_start(&self, dnn: DnnKind, n: usize) {
        let _ = (dnn, n);
        spin_for(Duration::from_micros(250)); // per-dispatch setup
    }
}

fn stream_seq(stream: u64, frames: u64) -> Sequence {
    Sequence::generate(SequenceSpec {
        name: format!("CAM-{stream}"),
        width: 960,
        height: 540,
        fps: 30.0,
        frames,
        density: 6,
        ref_height: 200.0 + 30.0 * stream as f64,
        depth_range: (1.0, 2.2),
        walk_speed: 1.5,
        camera: CameraMotion::Walking { pan_speed: 4.0 + stream as f64 },
        seed: 900 + stream,
    })
}

fn main() {
    let frames: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(120);
    let streams = 4u64;

    let server = Arc::new(InferenceServer::start(
        Arc::new(DemoEngine),
        BatchConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            ..BatchConfig::default()
        },
        2,
    ));
    println!(
        "{streams} TOD streams x {frames} frames through one \
         micro-batching server (max_batch 4, max_wait 1 ms)...\n"
    );

    let t0 = std::time::Instant::now();
    let clients: Vec<_> = (0..streams)
        .map(|s| {
            let server = server.clone();
            std::thread::spawn(move || {
                let seq = stream_seq(s, frames);
                let (fw, fh) =
                    (seq.spec.width as f64, seq.spec.height as f64);
                let mut policy = MbbsPolicy::tod_default();
                let mut features = FeatureExtractor::new(fw, fh);
                let mut carried: Vec<Detection> = Vec::new();
                let mut failed = 0u64;
                for f in 1..=seq.n_frames() {
                    let feats = features.features(&carried);
                    let dnn = policy.select(&feats);
                    let handle = server.submit(InferRequest {
                        stream: s,
                        frame: f,
                        dnn,
                        frame_w: fw,
                        frame_h: fh,
                        gt: seq.gt(f).to_vec(),
                    });
                    match handle.map(|h| h.wait()) {
                        Ok(Ok(raw)) => {
                            carried = FrameDetections {
                                frame: f,
                                detections: raw,
                            }
                            .filtered()
                            .detections;
                            features.on_detections(f, &carried);
                        }
                        // failed request: carry the previous detections
                        _ => failed += 1,
                    }
                }
                (s, seq.n_frames(), failed)
            })
        })
        .collect();

    for c in clients {
        let (s, n, failed) = c.join().expect("client thread");
        println!("  stream {s}: {n} frames served, {failed} failed");
    }
    let wall = t0.elapsed().as_secs_f64();
    let total = streams * frames;
    println!(
        "\n{total} frames in {wall:.2}s ({:.0} frames/s aggregate)",
        total as f64 / wall
    );
    let stats = match Arc::try_unwrap(server) {
        Ok(srv) => srv.shutdown(),
        Err(arc) => arc.stats(),
    };
    println!("batching: {stats}");
    println!(
        "\nEvery request resolved through its own handle — an engine \
         error or panic fails one request, never the process (see \
         rust/tests/batching.rs for the failure-injection proofs)."
    );
}
