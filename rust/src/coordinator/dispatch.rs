//! Dispatch-order bookkeeping for the multi-stream scheduler.
//!
//! [`super::multistream::MultiStreamScheduler::run`] used to rebuild a
//! `Vec` of `(idx, ready, deadline)` candidates from scratch every
//! dispatch epoch — an allocation plus two O(N) scans per inference,
//! even though only the stream that was just stepped can have changed.
//! [`DispatchQueue`] keeps that information incrementally:
//!
//! * earliest-deadline-first selection through a lazily-invalidated
//!   binary min-heap (per-stream version stamps; stale entries are
//!   skipped on pop),
//! * round-robin selection through an ordered set of live stream
//!   indices,
//! * contention occupancy by an exact allocation-free scan. The scan is
//!   deliberate: a chosen stream can run out of frames while its doomed
//!   frames drain, without dispatching, so the occupancy threshold is
//!   *not* monotone across epochs and a drained-counter shortcut would
//!   over-count.
//!
//! Selection semantics are pinned to the naive per-epoch scan by
//! `queue_matches_naive_scan_model` below, and end to end by the
//! scheduler's bit-identity tests.

use std::cmp::{Ordering, Reverse};
use std::collections::{BTreeSet, BinaryHeap};

/// `f64` heap key under the IEEE total order (NaN-safe, `Ord`).
#[derive(Debug, Clone, Copy, PartialEq)]
struct F64Ord(f64);

impl Eq for F64Ord {}

impl PartialOrd for F64Ord {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for F64Ord {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Incremental candidate set for N streams sharing one accelerator.
///
/// Each stream is either *live* — it has a next inferable frame, with a
/// `(ready, deadline)` pair — or absent. [`update`](Self::update) after
/// every step of a stream; query via [`peek_edf`](Self::peek_edf),
/// [`next_round_robin`](Self::next_round_robin) and
/// [`occupancy`](Self::occupancy).
#[derive(Debug)]
pub struct DispatchQueue {
    /// Live candidate per stream: `(ready, deadline)` in stream seconds.
    state: Vec<Option<(f64, f64)>>,
    /// Bumped on every update; heap entries carrying an older stamp are
    /// stale and skipped on pop.
    version: Vec<u64>,
    /// Min-heap on `(deadline, idx, version)`.
    edf: BinaryHeap<Reverse<(F64Ord, usize, u64)>>,
    /// Live stream indices in ascending order (round-robin order).
    live: BTreeSet<usize>,
}

impl DispatchQueue {
    pub fn new(n_streams: usize) -> Self {
        DispatchQueue {
            state: vec![None; n_streams],
            version: vec![0; n_streams],
            edf: BinaryHeap::with_capacity(n_streams + 1),
            live: BTreeSet::new(),
        }
    }

    /// Number of live candidates.
    pub fn len(&self) -> usize {
        self.live.len()
    }

    pub fn is_empty(&self) -> bool {
        self.live.is_empty()
    }

    /// Record stream `idx`'s next dispatch candidate (`None` once
    /// nothing inferable remains). Must be called after every sequence
    /// of steps applied to the stream.
    pub fn update(&mut self, idx: usize, cand: Option<(f64, f64)>) {
        self.state[idx] = cand;
        self.version[idx] = self.version[idx].wrapping_add(1);
        match cand {
            Some((_, deadline)) => {
                self.live.insert(idx);
                self.edf.push(Reverse((
                    F64Ord(deadline),
                    idx,
                    self.version[idx],
                )));
            }
            None => {
                self.live.remove(&idx);
            }
        }
    }

    /// The live candidate whose deadline is earliest, ties broken by
    /// lowest stream index — `(idx, ready, deadline)`. Pops stale heap
    /// entries lazily; amortised O(log N).
    pub fn peek_edf(&mut self) -> Option<(usize, f64, f64)> {
        while let Some(&Reverse((F64Ord(deadline), idx, ver))) =
            self.edf.peek()
        {
            if ver != self.version[idx] {
                self.edf.pop();
                continue;
            }
            // a current-version entry implies a live state
            let ready = match self.state[idx] {
                Some((r, _)) => r,
                None => {
                    self.edf.pop();
                    continue;
                }
            };
            return Some((idx, ready, deadline));
        }
        None
    }

    /// The first live candidate with index >= `cursor`, wrapping to the
    /// lowest live index — `(idx, ready, deadline)`.
    pub fn next_round_robin(
        &self,
        cursor: usize,
    ) -> Option<(usize, f64, f64)> {
        let idx = self
            .live
            .range(cursor..)
            .next()
            .or_else(|| self.live.iter().next())
            .copied()?;
        let (ready, deadline) = self.state[idx]?;
        Some((idx, ready, deadline))
    }

    /// Number of live candidates whose pending frame is already waiting
    /// when an inference starts at `start_est` (the contention
    /// occupancy). Exact and allocation-free.
    pub fn occupancy(&self, start_est: f64) -> usize {
        self.live
            .iter()
            .filter(|&&i| {
                self.state[i]
                    .map(|(r, _)| r <= start_est + 1e-12)
                    .unwrap_or(false)
            })
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::{Gen, PropConfig};

    /// The per-epoch scan `MultiStreamScheduler::run` performed before
    /// the queue existed: the oracle the queue is pinned against.
    struct NaiveModel {
        state: Vec<Option<(f64, f64)>>,
    }

    impl NaiveModel {
        fn candidates(&self) -> Vec<(usize, f64, f64)> {
            self.state
                .iter()
                .enumerate()
                .filter_map(|(i, c)| c.map(|(r, d)| (i, r, d)))
                .collect()
        }

        fn edf(&self) -> Option<(usize, f64, f64)> {
            self.candidates()
                .into_iter()
                .min_by(|a, b| a.2.total_cmp(&b.2).then(a.0.cmp(&b.0)))
        }

        fn round_robin(&self, cursor: usize) -> Option<(usize, f64, f64)> {
            let c = self.candidates();
            c.iter()
                .find(|(i, _, _)| *i >= cursor)
                .or_else(|| c.first())
                .copied()
        }

        fn occupancy(&self, start_est: f64) -> usize {
            self.candidates()
                .iter()
                .filter(|(_, r, _)| *r <= start_est + 1e-12)
                .count()
        }
    }

    #[test]
    fn queue_matches_naive_scan_model() {
        PropConfig::default().run("queue_matches_naive_scan_model", |g| {
            let n = g.usize_in(1, 8);
            let mut q = DispatchQueue::new(n);
            let mut model = NaiveModel { state: vec![None; n] };
            for _ in 0..g.usize_in(1, 50) {
                if g.bool() {
                    let idx = g.usize_in(0, n - 1);
                    // quantised deadlines force ties, exercising the
                    // lowest-index tie-break
                    let cand = if g.bool() {
                        Some((
                            g.f64_in(0.0, 10.0),
                            g.usize_in(0, 4) as f64,
                        ))
                    } else {
                        None
                    };
                    q.update(idx, cand);
                    model.state[idx] = cand;
                } else {
                    if q.peek_edf() != model.edf() {
                        return false;
                    }
                    let cursor = g.usize_in(0, n);
                    if q.next_round_robin(cursor) != model.round_robin(cursor)
                    {
                        return false;
                    }
                    let x = g.f64_in(0.0, 10.0);
                    if q.occupancy(x) != model.occupancy(x) {
                        return false;
                    }
                    if q.len() != model.candidates().len()
                        || q.is_empty() != model.candidates().is_empty()
                    {
                        return false;
                    }
                }
            }
            true
        });
    }

    #[test]
    fn empty_queue_yields_nothing() {
        let mut q = DispatchQueue::new(3);
        assert!(q.is_empty());
        assert_eq!(q.peek_edf(), None);
        assert_eq!(q.next_round_robin(0), None);
        assert_eq!(q.occupancy(5.0), 0);
    }

    #[test]
    fn stale_entries_are_skipped() {
        let mut q = DispatchQueue::new(2);
        q.update(0, Some((0.0, 1.0)));
        q.update(1, Some((0.0, 2.0)));
        // stream 0 re-updates to a later deadline; its old heap entry
        // (deadline 1.0) must not win
        q.update(0, Some((0.0, 3.0)));
        assert_eq!(q.peek_edf(), Some((1, 0.0, 2.0)));
        // stream 1 leaves entirely
        q.update(1, None);
        assert_eq!(q.peek_edf(), Some((0, 0.0, 3.0)));
        assert_eq!(q.len(), 1);
    }
}
