//! Loader for `artifacts/manifest.json`, the AOT handshake with
//! `python/compile/aot.py`.

use std::path::Path;

use crate::ext::anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;
use crate::DnnKind;

/// One detection head of a variant.
#[derive(Debug, Clone, PartialEq)]
pub struct HeadSpec {
    pub stride: usize,
    pub grid: usize,
    pub channels: usize,
    /// (w, h) anchor sizes in input pixels.
    pub anchors: Vec<(f64, f64)>,
}

/// One AOT-compiled detector variant.
#[derive(Debug, Clone, PartialEq)]
pub struct VariantSpec {
    pub kind: DnnKind,
    /// HLO text file name relative to the manifest directory.
    pub artifact: String,
    pub input_size: usize,
    pub param_count: usize,
    pub heads: Vec<HeadSpec>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub variants: Vec<VariantSpec>,
    pub pallas: bool,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Manifest::parse(&text)
    }

    /// Parse manifest JSON text.
    pub fn parse(text: &str) -> Result<Manifest> {
        let root = Json::parse(text).map_err(|e| anyhow!("{e}"))?;
        if root.get("format").and_then(Json::as_str) != Some("hlo-text") {
            bail!("manifest format must be hlo-text");
        }
        let pallas =
            root.get("pallas").and_then(Json::as_bool).unwrap_or(true);
        let vs = root
            .get("variants")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing variants[]"))?;
        let mut variants = Vec::new();
        for v in vs {
            variants.push(parse_variant(v)?);
        }
        if variants.is_empty() {
            bail!("manifest has no variants");
        }
        Ok(Manifest { variants, pallas })
    }

    /// Spec for one DNN kind.
    pub fn variant(&self, kind: DnnKind) -> Option<&VariantSpec> {
        self.variants.iter().find(|v| v.kind == kind)
    }

    /// True when all four paper variants are present.
    pub fn is_complete(&self) -> bool {
        DnnKind::ALL.iter().all(|&k| self.variant(k).is_some())
    }
}

fn field_usize(v: &Json, name: &str) -> Result<usize> {
    v.get(name)
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("variant missing integer field {name}"))
}

fn parse_variant(v: &Json) -> Result<VariantSpec> {
    let name = v
        .get("name")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("variant missing name"))?;
    let kind: DnnKind = name.parse().map_err(|e: String| anyhow!(e))?;
    let artifact = v
        .get("artifact")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("variant {name} missing artifact"))?
        .to_string();
    let input_size = field_usize(v, "input_size")?;
    let param_count = field_usize(v, "param_count")?;
    let heads_json = v
        .get("heads")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("variant {name} missing heads[]"))?;
    let mut heads = Vec::new();
    for h in heads_json {
        let stride = field_usize(h, "stride")?;
        let grid = field_usize(h, "grid")?;
        let channels = field_usize(h, "channels")?;
        if grid * stride != input_size {
            bail!(
                "variant {name}: grid {grid} x stride {stride} != input \
                 {input_size}"
            );
        }
        let anchors_json = h
            .get("anchors")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("head missing anchors"))?;
        let mut anchors = Vec::new();
        for a in anchors_json {
            let pair = a.as_arr().ok_or_else(|| anyhow!("bad anchor"))?;
            if pair.len() != 2 {
                bail!("anchor must be [w, h]");
            }
            anchors.push((
                pair[0].as_f64().ok_or_else(|| anyhow!("bad anchor w"))?,
                pair[1].as_f64().ok_or_else(|| anyhow!("bad anchor h"))?,
            ));
        }
        if channels % (5 + 1) != 0 || anchors.len() * 6 != channels {
            bail!(
                "variant {name}: {channels} channels inconsistent with \
                 {} anchors x (5 + 1 class)",
                anchors.len()
            );
        }
        heads.push(HeadSpec { stride, grid, channels, anchors });
    }
    Ok(VariantSpec { kind, artifact, input_size, param_count, heads })
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = r#"{
      "format": "hlo-text",
      "pallas": true,
      "variants": [
        {"name": "yolov4-tiny-288", "artifact": "yolov4-tiny-288.hlo.txt",
         "input_size": 288, "param_count": 100,
         "heads": [{"stride": 32, "grid": 9, "channels": 18,
                    "anchors": [[23,56],[52,128],[110,245]]}]}
      ]
    }"#;

    #[test]
    fn parses_good_manifest() {
        let m = Manifest::parse(GOOD).unwrap();
        assert_eq!(m.variants.len(), 1);
        let v = m.variant(DnnKind::TinyY288).unwrap();
        assert_eq!(v.input_size, 288);
        assert_eq!(v.heads[0].grid, 9);
        assert_eq!(v.heads[0].anchors.len(), 3);
        assert!(!m.is_complete());
        assert!(m.pallas);
    }

    #[test]
    fn rejects_bad_format() {
        assert!(Manifest::parse(r#"{"format": "proto", "variants": []}"#)
            .is_err());
        assert!(Manifest::parse("{").is_err());
        assert!(
            Manifest::parse(r#"{"format": "hlo-text", "variants": []}"#)
                .is_err()
        );
    }

    #[test]
    fn rejects_inconsistent_grid() {
        let bad = GOOD.replace("\"grid\": 9", "\"grid\": 10");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_channel_anchor_mismatch() {
        let bad = GOOD.replace("\"channels\": 18", "\"channels\": 24");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts");
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this checkout
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.is_complete());
        for v in &m.variants {
            assert!(dir.join(&v.artifact).exists());
            assert_eq!(v.kind.input_size(), v.input_size);
        }
    }
}
