"""L1 Pallas kernel: fused tiled matmul + bias + activation.

This is the compute hot-spot of the YOLO-style detector backbone: every
convolution is lowered to an im2col patch extraction followed by this
kernel, which computes

    out = act(x @ w + b)

in (bm, bn) output tiles with a bk-step contraction loop, accumulating in
a float32 VMEM scratch accumulator.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the paper's TensorRT
FP16 tensor-core path becomes an MXU-shaped tiled matmul. Block shapes
default to multiples of (8, 128) so the systolic array is fed full tiles;
the accumulator lives in VMEM scratch; the HBM→VMEM schedule is expressed
with BlockSpec index maps over a (M/bm, N/bn, K/bk) grid.

CPU note: kernels are lowered with ``interpret=True`` so they emit plain
HLO (a grid loop with dynamic slices) executable by the CPU PJRT client —
real-TPU lowering emits a Mosaic custom-call the CPU plugin cannot run.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Activation = Literal["linear", "relu", "leaky_relu"]

# Default MXU-shaped tile sizes (multiples of the 8x128 register tile /
# 128x128 systolic array). bk is kept modest so x-tile + w-tile + acc fit
# VMEM with double-buffering headroom; see DESIGN.md §Perf for the budget.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128

LEAKY_SLOPE = 0.1  # YOLO / Darknet convention


def _apply_act(x, activation: Activation):
    if activation == "linear":
        return x
    if activation == "relu":
        return jnp.maximum(x, 0.0)
    if activation == "leaky_relu":
        return jnp.where(x >= 0.0, x, LEAKY_SLOPE * x)
    raise ValueError(f"unknown activation: {activation}")


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int,
                   activation: Activation):
    """Grid = (M/bm, N/bn, K/bk); k is the innermost (fastest) dimension.

    The output tile doubles as the accumulator (float32), persisting
    across the k steps of one (i, j) tile; the bias-add + activation are
    fused into the final k step so no separate epilogue pass over HBM is
    needed.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)

    @pl.when(k == nk - 1)
    def _finish():
        out = o_ref[...] + b_ref[...]
        o_ref[...] = _apply_act(out, activation).astype(o_ref.dtype)


def _pad_to(x, multiple: int, axis: int):
    size = x.shape[axis]
    rem = size % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, multiple - rem)
    return jnp.pad(x, pad)


@functools.partial(
    jax.jit,
    static_argnames=("activation", "bm", "bn", "bk", "interpret"),
)
def fused_matmul_bias_act(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    activation: Activation = "leaky_relu",
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
) -> jax.Array:
    """Compute ``act(x @ w + b)`` with a tiled Pallas kernel.

    Args:
      x: (M, K) float array.
      w: (K, N) float array.
      b: (N,) float array.
      activation: "linear" | "relu" | "leaky_relu".
      bm/bn/bk: tile sizes; inputs are zero-padded up to tile multiples
        and the result is sliced back, so arbitrary shapes are accepted.
      interpret: must stay True for CPU PJRT execution (see module doc).

    Returns:
      (M, N) array with x's dtype.
    """
    if x.ndim != 2 or w.ndim != 2 or b.ndim != 1:
        raise ValueError(
            f"bad ranks: x{x.shape} w{w.shape} b{b.shape}"
        )
    m, kx = x.shape
    kw, n = w.shape
    if kx != kw or b.shape[0] != n:
        raise ValueError(
            f"shape mismatch: x{x.shape} w{w.shape} b{b.shape}"
        )

    # Clamp tiles to (padded) problem size so tiny problems stay tiny.
    bm_ = min(bm, _ceil_mult(m, 8))
    bn_ = min(bn, _ceil_mult(n, 128))
    bk_ = min(bk, _ceil_mult(kx, 128))

    xp = _pad_to(_pad_to(x, bm_, 0), bk_, 1)
    wp = _pad_to(_pad_to(w, bk_, 0), bn_, 1)
    bp = _pad_to(b, bn_, 0).reshape(1, -1)

    mp, kp = xp.shape
    _, np_ = wp.shape
    nk = kp // bk_
    grid = (mp // bm_, np_ // bn_, nk)

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn_), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=interpret,
    )(xp, wp, bp)
    return out[:m, :n]


def _ceil_mult(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def vmem_footprint_bytes(bm: int, bn: int, bk: int,
                         dtype_bytes: int = 4) -> int:
    """Analytic VMEM budget for one grid step (double-buffered inputs).

    x-tile + w-tile are double-buffered by the pipeline; the accumulator
    and output tile are single instances. Used by DESIGN.md §Perf and the
    kernel structure tests — interpret mode gives no TPU wallclock, so
    structure is what we optimise.
    """
    x_tile = bm * bk * dtype_bytes * 2
    w_tile = bk * bn * dtype_bytes * 2
    b_tile = bn * dtype_bytes * 2
    acc = bm * bn * 4
    out = bm * bn * dtype_bytes
    return x_tile + w_tile + b_tile + acc + out


def mxu_utilisation_estimate(m: int, n: int, k: int,
                             bm: int, bn: int, bk: int) -> float:
    """Fraction of MXU-issued MACs that are useful (non-padding) work."""
    mp, np_, kp = (_ceil_mult(m, bm), _ceil_mult(n, bn), _ceil_mult(k, bk))
    useful = m * n * k
    issued = mp * np_ * kp
    return useful / issued
