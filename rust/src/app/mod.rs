//! High-level drivers shared by the CLI, the examples and the figure
//! harness.

pub mod campaign;

pub use campaign::{
    Campaign, MultiStreamScalingRow, DEFAULT_WATTS_BUDGET,
    MULTISTREAM_SCALE,
};
