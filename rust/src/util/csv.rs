//! Tiny CSV writer/reader for experiment outputs (`results/*.csv`).
//!
//! Quoting rules follow RFC 4180 for the subset we emit: fields containing
//! a comma, quote or newline are quoted, quotes doubled.

use std::io::Write;
use std::path::Path;

/// In-memory CSV table with a header row.
#[derive(Debug, Clone, Default)]
pub struct CsvTable {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl CsvTable {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        CsvTable {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    /// Push a row; must match the header width.
    pub fn push<S: Into<String>>(&mut self, row: Vec<S>) {
        let row: Vec<String> = row.into_iter().map(Into::into).collect();
        assert_eq!(
            row.len(),
            self.header.len(),
            "csv row width {} != header width {}",
            row.len(),
            self.header.len()
        );
        self.rows.push(row);
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        write_row(&mut out, &self.header);
        for r in &self.rows {
            write_row(&mut out, r);
        }
        out
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_string().as_bytes())
    }

    /// Parse CSV text (header + rows).
    pub fn parse(text: &str) -> Result<CsvTable, String> {
        let mut records = parse_records(text)?;
        if records.is_empty() {
            return Err("empty csv".into());
        }
        let header = records.remove(0);
        for (i, r) in records.iter().enumerate() {
            if r.len() != header.len() {
                return Err(format!(
                    "row {} width {} != header width {}",
                    i + 1,
                    r.len(),
                    header.len()
                ));
            }
        }
        Ok(CsvTable { header, rows: records })
    }

    pub fn col_index(&self, name: &str) -> Option<usize> {
        self.header.iter().position(|h| h == name)
    }
}

fn needs_quoting(s: &str) -> bool {
    s.contains(',') || s.contains('"') || s.contains('\n') || s.contains('\r')
}

fn write_row(out: &mut String, row: &[String]) {
    for (i, field) in row.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if needs_quoting(field) {
            out.push('"');
            out.push_str(&field.replace('"', "\"\""));
            out.push('"');
        } else {
            out.push_str(field);
        }
    }
    out.push('\n');
}

fn parse_records(text: &str) -> Result<Vec<Vec<String>>, String> {
    let mut records = Vec::new();
    let mut row = Vec::new();
    let mut field = String::new();
    let mut chars = text.chars().peekable();
    let mut in_quotes = false;
    let mut any = false;
    while let Some(c) = chars.next() {
        any = true;
        if in_quotes {
            match c {
                '"' => {
                    if chars.peek() == Some(&'"') {
                        chars.next();
                        field.push('"');
                    } else {
                        in_quotes = false;
                    }
                }
                c => field.push(c),
            }
        } else {
            match c {
                '"' if field.is_empty() => in_quotes = true,
                ',' => {
                    row.push(std::mem::take(&mut field));
                }
                '\r' => {}
                '\n' => {
                    row.push(std::mem::take(&mut field));
                    records.push(std::mem::take(&mut row));
                }
                c => field.push(c),
            }
        }
    }
    if in_quotes {
        return Err("unterminated quoted field".into());
    }
    if any && (!field.is_empty() || !row.is_empty()) {
        row.push(field);
        records.push(row);
    }
    Ok(records)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_plain() {
        let mut t = CsvTable::new(vec!["a", "b"]);
        t.push(vec!["1", "2"]);
        t.push(vec!["x", "y"]);
        let s = t.to_string();
        let back = CsvTable::parse(&s).unwrap();
        assert_eq!(back.header, t.header);
        assert_eq!(back.rows, t.rows);
    }

    #[test]
    fn roundtrip_quoted() {
        let mut t = CsvTable::new(vec!["name", "note"]);
        t.push(vec!["a,b", "say \"hi\""]);
        t.push(vec!["line\nbreak", "plain"]);
        let back = CsvTable::parse(&t.to_string()).unwrap();
        assert_eq!(back.rows, t.rows);
    }

    #[test]
    #[should_panic(expected = "csv row width")]
    fn width_mismatch_panics() {
        let mut t = CsvTable::new(vec!["a", "b"]);
        t.push(vec!["only-one"]);
    }

    #[test]
    fn parse_rejects_ragged() {
        assert!(CsvTable::parse("a,b\n1\n").is_err());
        assert!(CsvTable::parse("").is_err());
        assert!(CsvTable::parse("a,\"b\n").is_err());
    }

    #[test]
    fn col_index() {
        let t = CsvTable::parse("x,y,z\n1,2,3\n").unwrap();
        assert_eq!(t.col_index("y"), Some(1));
        assert_eq!(t.col_index("w"), None);
    }
}
