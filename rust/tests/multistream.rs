//! StreamSession / MultiStreamScheduler invariants: the resumable
//! session must reproduce the legacy single-stream loop bit for bit,
//! and the multi-stream scheduler must never double-book the shared
//! accelerator (`tod::testing::prop` style; see DESIGN.md §8).

use tod::coordinator::multistream::{
    DispatchPolicy, MultiStreamResult, MultiStreamScheduler,
};
use tod::coordinator::policy::MbbsPolicy;
use tod::coordinator::scheduler::run_realtime;
use tod::coordinator::session::{SessionEvent, StreamSession};
use tod::dataset::catalog::{generate, SequenceId};
use tod::dataset::synth::Sequence;
use tod::sim::latency::{ContentionModel, LatencyModel};
use tod::testing::fixtures::{
    oracle_for as oracle, random_seq, random_thresholds, results_identical,
};
use tod::testing::prop::PropConfig;

#[test]
fn session_stepwise_matches_legacy_loop() {
    // driving a session step by step is bit-identical to run_realtime
    PropConfig::with_cases(12).run("session == legacy loop", |g| {
        let seq = random_seq(g);
        let th = random_thresholds(g);
        let fps = g.f64_in(10.0, 40.0);

        let mut pol = MbbsPolicy::new(th.clone());
        let mut det = oracle(&seq);
        let mut lat = LatencyModel::deterministic();
        let legacy = run_realtime(&seq, &mut pol, &mut det, &mut lat, fps);

        let mut det2 = oracle(&seq);
        let mut lat2 = LatencyModel::deterministic();
        let mut session =
            StreamSession::new(&seq, MbbsPolicy::new(th), fps);
        let mut steps = 0u64;
        while session.step(&mut det2, &mut lat2) != SessionEvent::Finished {
            steps += 1;
        }
        let stepped = session.finish();
        steps == seq.n_frames() && results_identical(&legacy, &stepped)
    });
}

#[test]
fn one_stream_scheduler_matches_legacy_loop() {
    // the multi-stream code path with N=1 (shared-floor accounting,
    // occupancy-1 contention) reproduces run_realtime exactly
    PropConfig::with_cases(12).run("1-stream scheduler == legacy", |g| {
        let seq = random_seq(g);
        let th = random_thresholds(g);
        let fps = g.f64_in(10.0, 40.0);

        let mut pol = MbbsPolicy::new(th.clone());
        let mut det = oracle(&seq);
        let mut lat = LatencyModel::deterministic();
        let legacy = run_realtime(&seq, &mut pol, &mut det, &mut lat, fps);

        let mut sched = MultiStreamScheduler::new(
            if g.bool() {
                DispatchPolicy::RoundRobin
            } else {
                DispatchPolicy::EarliestDeadlineFirst
            },
            ContentionModel::jetson_nano(),
            LatencyModel::deterministic(),
        );
        sched.add_stream(
            StreamSession::new(&seq, MbbsPolicy::new(th), fps),
            Box::new(oracle(&seq)),
        );
        let multi = sched.run();
        multi.per_stream.len() == 1
            && results_identical(&legacy, &multi.per_stream[0])
    });
}

fn run_catalog_streams(n: usize, dispatch: DispatchPolicy) -> MultiStreamResult {
    let seqs: Vec<(SequenceId, Sequence)> = (0..n)
        .map(|i| {
            let id = SequenceId::ALL[i % SequenceId::ALL.len()];
            (id, generate(id))
        })
        .collect();
    let mut sched = MultiStreamScheduler::new(
        dispatch,
        ContentionModel::jetson_nano(),
        LatencyModel::deterministic(),
    );
    for (id, seq) in &seqs {
        sched.add_stream(
            StreamSession::new(seq, MbbsPolicy::tod_default(), id.eval_fps()),
            Box::new(oracle(seq)),
        );
    }
    sched.run()
}

#[test]
fn eight_catalog_streams_share_without_double_booking() {
    for dispatch in [
        DispatchPolicy::RoundRobin,
        DispatchPolicy::EarliestDeadlineFirst,
    ] {
        let r = run_catalog_streams(8, dispatch);
        assert_eq!(r.per_stream.len(), 8);
        // every stream ran to completion with conserving accounting
        for s in &r.per_stream {
            assert_eq!(s.n_inferred + s.n_dropped, s.n_frames);
            assert!(s.n_inferred >= 1);
            assert!((0.0..=1.0).contains(&s.ap));
            // per-stream busy intervals are ordered and disjoint
            assert!(s.trace.busy.windows(2).all(|w| w[1].0 >= w[0].1 - 1e-9));
        }
        // the shared accelerator is never double-booked across streams
        assert!(
            r.utilisation.overlap_seconds() < 1e-9,
            "overlap {} under {dispatch}",
            r.utilisation.overlap_seconds()
        );
        // 8 concurrent streams oversubscribe one Jetson. The bound is
        // not ~1.0 because MOT17-05 (14 FPS, ~60 s) outlives the 30-FPS
        // streams and runs the tail of the makespan alone at low duty.
        assert!(
            r.utilisation.utilisation() > 0.6,
            "utilisation {} under {dispatch}",
            r.utilisation.utilisation()
        );
    }
}

#[test]
fn drop_rate_grows_with_stream_count() {
    // note: different stream counts mix different catalog sequences, so
    // only the comfortably separated comparisons are asserted
    let one = run_catalog_streams(1, DispatchPolicy::RoundRobin);
    let four = run_catalog_streams(4, DispatchPolicy::RoundRobin);
    let eight = run_catalog_streams(8, DispatchPolicy::RoundRobin);
    assert!(
        eight.drop_rate() > one.drop_rate(),
        "8-stream {} vs 1-stream {}",
        eight.drop_rate(),
        one.drop_rate()
    );
    assert!(
        eight.drop_rate() >= four.drop_rate(),
        "8-stream {} vs 4-stream {}",
        eight.drop_rate(),
        four.drop_rate()
    );
}
