//! Online stream-feature extraction: the runtime counterpart of the
//! paper's "characteristics of the video stream such as object size and
//! speed of movement".
//!
//! [`extract`] computes a per-frame [`FrameFeatures`] vector (MBBS,
//! object count, density, apparent speed) incrementally from the
//! detections the scheduler already carries; [`ewma`] provides the
//! smoothing primitive. The feature vector is what every
//! [`crate::coordinator::policy::SelectionPolicy`] now consumes —
//! MBBS-threshold policies read only the size channel, the
//! projected-accuracy policy ([`crate::coordinator::projected`]) reads
//! size and speed against a calibrated [`crate::predictor`] table.

pub mod ewma;
pub mod extract;

pub use ewma::Ewma;
pub use extract::{FeatureConfig, FeatureExtractor, FrameFeatures};
