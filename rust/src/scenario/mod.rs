//! Scenario matrix + deterministic conformance harness.
//!
//! The paper's claim is that TOD adapts to *changing* stream
//! characteristics, yet its evaluation replays seven static sequences.
//! This subsystem makes scenario diversity a first-class, regression-
//! pinned artifact:
//!
//! * [`spec`] — composable scenario descriptions: typed builders for
//!   phased workloads (crowd density, object-size geometry, camera
//!   motion, FPS sag/burst, day/night detection noise) across one or
//!   more churning streams, compiled deterministically onto
//!   [`crate::dataset::synth`] sequences.
//! * [`store`] — versioned JSON persistence for scenario documents
//!   (schema `tod-scenario`), so deployments can describe their own
//!   workloads and replay them through the same harness.
//! * [`matrix`] — the eight curated scenarios (`rush-hour-surge`,
//!   `night-drift`, `fps-sag`, `camera-handoff`, `stream-churn`,
//!   `budget-squeeze`, `bursty-crowd`, `steady-sparse`).
//! * [`harness`] — the deterministic replay loop: any policy ×
//!   dispatch × watts-budget × batching configuration, end to end from
//!   a single seed, over the production [`crate::coordinator::session::
//!   StreamSession`] state machine.
//! * [`record`] — the canonical, byte-stable [`record::RunRecord`]
//!   (schema `tod-scenario-run`).
//! * [`conformance`] — golden-trace conformance: per-scenario reports
//!   with adaptive-vs-fixed differential margins, written by
//!   `tod scenario record` into `rust/tests/goldens/` and byte-checked
//!   by `tod scenario check` and CI.
//!
//! See DESIGN.md §12 for the harness semantics (churn epochs, the
//! fps-scale transform, noise pairing) and how to re-record goldens.

pub mod conformance;
pub mod harness;
pub mod matrix;
pub mod record;
pub mod spec;
pub mod store;

pub use conformance::{check_goldens, run_report, ScenarioReport};
pub use harness::{run_scenario, HarnessConfig, PolicyKind, ScenarioRun};
pub use matrix::{matrix, scenario_spec, ScenarioId};
pub use record::RunRecord;
pub use spec::{NoiseProfile, PhaseSpec, ScenarioSpec, StreamSpec};
