//! Bench: per-frame cost of energy metering and budget governance.
//!
//! The governor sits on the same per-frame decision path as selection,
//! so metering + feasibility + budgeted select must stay inside the
//! sub-50 µs envelope `benches/selection.rs` pins for the unbudgeted
//! path (3+ orders of magnitude below the 27–153 ms inferences). The
//! governor's window scan is O(window / lightest-latency) ≈ 40
//! intervals worst case — read `budget/feasible_loaded` for that cost.

use tod::bench::{black_box, Bench};
use tod::coordinator::policy::{MbbsPolicy, SelectionPolicy};
use tod::detection::{Detection, PERSON_CLASS};
use tod::features::FeatureExtractor;
use tod::geometry::BBox;
use tod::power::{BudgetedPolicy, EnergyMeter, PowerBudget};
use tod::sim::latency::LatencyModel;
use tod::util::rng::Rng;
use tod::DnnKind;

fn synth_dets(n: usize, seed: u64) -> Vec<Detection> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            Detection::new(
                BBox::new(
                    rng.uniform(0.0, 1800.0),
                    rng.uniform(0.0, 1000.0),
                    rng.uniform(10.0, 120.0),
                    rng.uniform(20.0, 280.0),
                ),
                rng.uniform(0.4, 1.0) as f32,
                PERSON_CLASS,
            )
        })
        .collect()
}

/// A governor whose 1 s window is saturated with back-to-back tiny-288
/// inferences — the worst-case number of retained intervals.
fn loaded_budget() -> PowerBudget {
    let mut b = PowerBudget::watts(6.5, &LatencyModel::deterministic());
    let lat = 0.027;
    let mut t = 0.0;
    while t < 2.0 {
        b.record(t, t + lat, DnnKind::TinyY288);
        t += lat;
    }
    b
}

fn main() {
    let mut b = Bench::new();

    // per-inference metering: one interval fold + horizon advance
    {
        let mut meter = EnergyMeter::new();
        let mut t = 0.0f64;
        b.case("meter/on_interval", || {
            meter.on_interval(t, t + 0.027, DnnKind::TinyY288);
            t += 1.0 / 30.0;
            meter.advance_to(black_box(t));
        });
        b.case("meter/summary", || {
            black_box(meter.summary());
        });
    }

    // feasibility projection against a saturated window
    {
        let budget = loaded_budget();
        let now = budget.now();
        b.case("budget/feasible_loaded", || {
            black_box(budget.feasible(black_box(now)));
        });
    }

    // interval recording incl. eviction
    {
        let mut budget = loaded_budget();
        let mut t = budget.now();
        b.case("budget/record", || {
            budget.record(t, t + 0.027, DnnKind::TinyY288);
            t += 0.027;
        });
    }

    // the full budgeted per-frame decision: features from the carried
    // set, governor mask, masked selection (MOT17 max density)
    for n in [10usize, 42] {
        let dets = synth_dets(n, n as u64);
        let fx = FeatureExtractor::new(1920.0, 1080.0);
        let mut policy = BudgetedPolicy::masking(
            Box::new(MbbsPolicy::tod_default()),
            loaded_budget(),
        );
        let mut t = 2.0f64;
        b.case(&format!("budgeted/frame_decision/n={n}"), || {
            t += 1.0 / 30.0;
            policy.on_frame(black_box(t));
            let f = fx.features(black_box(&dets));
            let d = black_box(policy.select(&f));
            // keep the governor's window realistically loaded
            policy.on_inferred(t, t + 0.027, d);
        });
    }

    b.save_csv("power.csv").ok();
}
