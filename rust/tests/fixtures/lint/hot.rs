//! Hot-path fixture: Core::step is policy-enumerated, Core::cold is not.

pub struct Core;

impl Core {
    pub fn step(&self) -> usize {
        let v: Vec<usize> = (0..4).collect();
        let w = v.clone();
        w.len()
    }

    pub fn cold(&self) -> Vec<usize> {
        (0..4).collect()
    }
}
