//! Feature-driven selection end to end: calibrate a projected-accuracy
//! table, persist it, load it back, and schedule a fast-moving stream
//! with it — the `tod calibrate` → `tod run --policy projected` flow as
//! a library user sees it.
//!
//! ```bash
//! cargo run --release --example projected_policy
//! ```

use tod::coordinator::policy::MbbsPolicy;
use tod::coordinator::projected::ProjectedAccuracyPolicy;
use tod::coordinator::scheduler::{run_realtime, OracleBackend};
use tod::dataset::catalog::{generate, SequenceId};
use tod::predictor::{calibrate, store, CalibrationConfig};
use tod::sim::latency::LatencyModel;
use tod::sim::oracle::OracleDetector;

fn main() {
    // 1. Offline: fit the per-DNN size x speed projected-accuracy table
    //    on the synthetic catalog (oracle detector as ground truth).
    println!("calibrating (this is the offline, run-once part)...");
    let table = calibrate(&CalibrationConfig::default_for_fps(30.0));

    // 2. Persist and reload — deployments ship the JSON, not the
    //    calibration campaign.
    let path = std::env::temp_dir().join("tod_example_calibration.json");
    store::save(&table, &path).expect("write calibration table");
    let table = store::load(&path).expect("read calibration table");
    println!(
        "calibration table: {} cells -> {}",
        table.n_cells(),
        path.display()
    );

    // 3. Online: schedule the fast-pan MOT17-09-like stream with the
    //    projected policy vs the paper's threshold ladder.
    let id = SequenceId::Mot09;
    let seq = generate(id);
    let make_detector = || {
        OracleBackend(OracleDetector::new(
            seq.spec.seed,
            seq.spec.width as f64,
            seq.spec.height as f64,
        ))
    };
    println!("\nsequence {} @ {} FPS", id.name(), id.eval_fps());

    let mut ladder = MbbsPolicy::tod_default();
    let mut latency = LatencyModel::deterministic();
    let r_ladder = run_realtime(
        &seq,
        &mut ladder,
        &mut make_detector(),
        &mut latency,
        id.eval_fps(),
    );

    let mut projected = ProjectedAccuracyPolicy::new(
        table,
        &LatencyModel::deterministic(),
    );
    let mut latency = LatencyModel::deterministic();
    let r_proj = run_realtime(
        &seq,
        &mut projected,
        &mut make_detector(),
        &mut latency,
        id.eval_fps(),
    );

    for r in [&r_ladder, &r_proj] {
        let freq = r.deploy_freq();
        println!(
            "  {:28} AP {:.3}  deploy YT-288 {:.0}% YT-416 {:.0}% \
             Y-288 {:.0}% Y-416 {:.0}%",
            r.policy,
            r.ap,
            freq[0] * 100.0,
            freq[1] * 100.0,
            freq[2] * 100.0,
            freq[3] * 100.0
        );
    }
    println!(
        "\n(the projected policy reads object size AND apparent speed: on \
         a fast pan it\n routes to lighter nets before stale carried boxes \
         cost accuracy)"
    );
    std::fs::remove_file(&path).ok();
}
