//! Algorithm 2: dropped-frame accounting under a fixed FPS constraint.
//!
//! The paper measures *real-time* accuracy by replaying each sequence
//! against a virtual real-time clock: while a DNN is busy with frame `f`,
//! frames arriving in the meantime are dropped and inherit frame `f`'s
//! detections (the GStreamer appsink `drop=true` behaviour). This module
//! is a faithful transcription of the paper's Algorithm 2 pseudocode:
//!
//! ```text
//! if FrameID > Frame#:        # DNN still busy -> dropped frame
//!     use previous inference
//! else:
//!     acc_inf_time += dnn_time
//!     FrameID = int(acc_inf_time × FPS) + 1
//! if acc_inf_time < Frame#/FPS:   # DNN faster than the stream
//!     acc_inf_time = Frame#/FPS
//! ```

use crate::video::clock::FrameClock;

/// What happened to a frame under the FPS constraint.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameOutcome {
    /// The DNN ran on this frame.
    Inferred,
    /// The DNN was busy; the previous inference is reused.
    Dropped,
}

/// Algorithm 2 state machine.
#[derive(Debug, Clone)]
pub struct DropFrameAccounting {
    clock: FrameClock,
    /// `acc_inf_time` in the paper: the virtual time at which the DNN
    /// becomes free.
    acc_inf_time: f64,
    /// `FrameID` in the paper: the next frame eligible for inference.
    frame_id: u64,
    n_inferred: u64,
    n_dropped: u64,
    /// Total time spent inside DNN inference (for telemetry duty cycle).
    busy_time: f64,
}

impl DropFrameAccounting {
    pub fn new(fps: f64) -> Self {
        DropFrameAccounting {
            clock: FrameClock::new(fps),
            acc_inf_time: 0.0,
            frame_id: 1,
            n_inferred: 0,
            n_dropped: 0,
            busy_time: 0.0,
        }
    }

    /// Present frame `frame` (1-based, in order). If the DNN is free the
    /// caller must supply the inference latency via `dnn_time()`; returns
    /// the outcome and, for inferred frames, the busy interval
    /// `(start, end)` in stream time (used by the telemetry sampler).
    pub fn on_frame(
        &mut self,
        frame: u64,
        dnn_time: impl FnMut() -> f64,
    ) -> (FrameOutcome, Option<(f64, f64)>) {
        // a dedicated accelerator is the shared case with no foreign
        // busy time (for in-order presentation the inference start then
        // equals acc_inf_time, the paper's plain `acc_inf_time += t`)
        self.on_frame_shared(frame, 0.0, dnn_time)
    }

    /// Algorithm 2 on a *shared* accelerator: like
    /// [`on_frame`](Self::on_frame), but the inference additionally may
    /// not start before `resource_free` — the virtual timestamp at which
    /// the accelerator finishes other streams' work (multi-stream
    /// scheduling). Frames arriving while the accelerator is
    /// foreign-busy are dropped on subsequent calls, exactly as frames
    /// arriving during our own inference are.
    ///
    /// With frames presented in order and `resource_free <= now()`,
    /// this is bit-identical to `on_frame`: the inference start then
    /// equals `acc_inf_time`, so `acc_inf_time` advances by exactly the
    /// sampled latency.
    pub fn on_frame_shared(
        &mut self,
        frame: u64,
        resource_free: f64,
        mut dnn_time: impl FnMut() -> f64,
    ) -> (FrameOutcome, Option<(f64, f64)>) {
        if self.frame_id > frame {
            self.n_dropped += 1;
            return (FrameOutcome::Dropped, None);
        }
        let t = dnn_time();
        assert!(t >= 0.0, "negative inference latency");
        let start = self
            .acc_inf_time
            // inference cannot start before the frame exists
            .max(self.clock.arrival(frame) - self.clock.period())
            // ...nor before the shared accelerator is free
            .max(resource_free);
        self.acc_inf_time = start + t;
        self.frame_id =
            (self.acc_inf_time * self.clock.fps()) as u64 + 1;
        // DNN faster than the stream: wait for the next frame arrival
        if self.acc_inf_time < self.clock.arrival(frame) {
            self.acc_inf_time = self.clock.arrival(frame);
        }
        self.n_inferred += 1;
        self.busy_time += t;
        (FrameOutcome::Inferred, Some((start, start + t)))
    }

    /// The next frame eligible for inference (`FrameID` in the paper);
    /// every earlier frame presented from now on will be dropped.
    pub fn next_eligible(&self) -> u64 {
        self.frame_id
    }

    pub fn n_inferred(&self) -> u64 {
        self.n_inferred
    }

    pub fn n_dropped(&self) -> u64 {
        self.n_dropped
    }

    /// Fraction of frames dropped so far.
    pub fn drop_rate(&self) -> f64 {
        let total = self.n_inferred + self.n_dropped;
        if total == 0 {
            0.0
        } else {
            self.n_dropped as f64 / total as f64
        }
    }

    /// Total DNN-busy seconds (duty-cycle numerator for telemetry).
    pub fn busy_time(&self) -> f64 {
        self.busy_time
    }

    /// Current virtual time (the DNN-free timestamp).
    pub fn now(&self) -> f64 {
        self.acc_inf_time
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Run n frames with a constant per-inference latency; return the
    /// outcome sequence.
    fn run(n: u64, fps: f64, latency: f64) -> Vec<FrameOutcome> {
        let mut acc = DropFrameAccounting::new(fps);
        (1..=n).map(|f| acc.on_frame(f, || latency).0).collect()
    }

    #[test]
    fn fast_dnn_never_drops() {
        // tiny-288 at 30 FPS: 27 ms < 33.3 ms -> every frame inferred
        let outcomes = run(100, 30.0, 0.027);
        assert!(outcomes.iter().all(|o| *o == FrameOutcome::Inferred));
    }

    #[test]
    fn slow_dnn_drop_ratio_matches_latency_ratio() {
        // Y-416 at 30 FPS: 153 ms ≈ 4.6 frame periods -> keep roughly
        // one frame in 5
        let outcomes = run(1000, 30.0, 0.153);
        let inferred =
            outcomes.iter().filter(|o| **o == FrameOutcome::Inferred).count();
        let expect = (1000.0f64 / (0.153 * 30.0)).round() as usize;
        assert!(
            (inferred as i64 - expect as i64).abs() <= 2,
            "inferred {inferred} expected ≈{expect}"
        );
    }

    #[test]
    fn paper_fig3_pattern() {
        // Fig. 3: Y-416 (153 ms) first -> frames 2..5 dropped; with
        // 30 FPS, inference of frame 1 ends at 0.153 s = frame 4.59 ->
        // FrameID 5 -> frames 2,3,4 dropped, frame 5 inferred.
        let mut acc = DropFrameAccounting::new(30.0);
        assert_eq!(acc.on_frame(1, || 0.153).0, FrameOutcome::Inferred);
        assert_eq!(acc.on_frame(2, || unreachable!()).0, FrameOutcome::Dropped);
        assert_eq!(acc.on_frame(3, || unreachable!()).0, FrameOutcome::Dropped);
        assert_eq!(acc.on_frame(4, || unreachable!()).0, FrameOutcome::Dropped);
        assert_eq!(acc.on_frame(5, || 0.050).0, FrameOutcome::Inferred);
    }

    #[test]
    fn first_frame_always_inferred() {
        let mut acc = DropFrameAccounting::new(30.0);
        let (o, iv) = acc.on_frame(1, || 10.0);
        assert_eq!(o, FrameOutcome::Inferred);
        assert!(iv.is_some());
    }

    #[test]
    fn conservation_inferred_plus_dropped() {
        let mut acc = DropFrameAccounting::new(30.0);
        for f in 1..=500 {
            acc.on_frame(f, || 0.09);
        }
        assert_eq!(acc.n_inferred() + acc.n_dropped(), 500);
        assert!(acc.drop_rate() > 0.5);
    }

    #[test]
    fn clamp_waits_for_stream() {
        // very fast DNN: virtual time advances with the stream, so the
        // busy fraction is latency/period, not 100%
        let mut acc = DropFrameAccounting::new(30.0);
        for f in 1..=300 {
            acc.on_frame(f, || 0.005);
        }
        let stream_time = 300.0 / 30.0;
        assert!((acc.now() - stream_time).abs() < 1e-9);
        let duty = acc.busy_time() / stream_time;
        assert!((duty - 0.005 * 30.0).abs() < 0.01, "duty {duty}");
    }

    #[test]
    fn busy_intervals_are_ordered_and_disjoint() {
        let mut acc = DropFrameAccounting::new(30.0);
        let mut prev_end = 0.0;
        for f in 1..=200 {
            if let (_, Some((s, e))) = acc.on_frame(f, || 0.06) {
                assert!(s >= prev_end - 1e-9, "overlap: {s} < {prev_end}");
                assert!(e > s);
                prev_end = e;
            }
        }
    }

    #[test]
    fn accounting_matches_paper_recurrence_bit_for_bit() {
        // on_frame (now the shared form with a 0.0 floor) must reproduce
        // the paper's literal Algorithm 2 recurrence `acc_inf_time += t`
        // exactly for in-order presentation: the inference start equals
        // the running acc_inf_time, so `start + t` and `acc += t` agree
        let lats = [0.153, 0.027, 0.09, 0.005, 0.2, 0.051, 0.027, 0.027];
        let fps = 30.0;
        let mut acc = DropFrameAccounting::new(fps);
        let mut acc_paper = 0.0f64;
        let mut frame_id = 1u64;
        for f in 1..=200u64 {
            let lat = lats[(f % lats.len() as u64) as usize];
            let (o, iv) = acc.on_frame(f, || lat);
            if frame_id > f {
                assert_eq!(o, FrameOutcome::Dropped, "frame {f}");
            } else {
                assert_eq!(o, FrameOutcome::Inferred, "frame {f}");
                let (start, end) = iv.unwrap();
                assert_eq!(start, acc_paper, "start at frame {f}");
                assert_eq!(end, acc_paper + lat, "end at frame {f}");
                acc_paper += lat;
                frame_id = (acc_paper * fps) as u64 + 1;
                if acc_paper < f as f64 / fps {
                    acc_paper = f as f64 / fps;
                }
                assert_eq!(acc.now(), acc_paper, "acc at frame {f}");
            }
        }
    }

    #[test]
    fn shared_floor_defers_start() {
        let mut acc = DropFrameAccounting::new(30.0);
        let (o, iv) = acc.on_frame_shared(1, 0.4, || 0.05);
        assert_eq!(o, FrameOutcome::Inferred);
        let (s, e) = iv.unwrap();
        assert!((s - 0.4).abs() < 1e-12);
        assert!((e - 0.45).abs() < 1e-12);
        // frames captured while the accelerator was foreign-busy drop
        assert_eq!(acc.on_frame_shared(2, 0.0, || 0.05).0, FrameOutcome::Dropped);
        assert_eq!(acc.next_eligible(), 14); // floor(0.45*30)+1
    }

    #[test]
    #[should_panic(expected = "fps must be positive")]
    fn zero_fps_rejected() {
        // the zero-FPS guard lives in FrameClock; the accounting must
        // refuse to construct rather than divide by zero later
        DropFrameAccounting::new(0.0);
    }

    #[test]
    fn exact_deadline_boundary_is_deterministic() {
        // power-of-two fps (32) makes arrivals exact binary floats.
        // An inference ending exactly ON frame 2's arrival (2 periods)
        // supersedes frame 2 and keeps frame 3 — the paper's
        // `int(acc*FPS)+1` recurrence, with no epsilon ambiguity.
        let fps = 32.0;
        let period = 1.0 / fps;
        let mut acc = DropFrameAccounting::new(fps);
        assert_eq!(acc.on_frame(1, || 2.0 * period).0, FrameOutcome::Inferred);
        assert_eq!(acc.next_eligible(), 3);
        assert_eq!(
            acc.on_frame(2, || unreachable!()).0,
            FrameOutcome::Dropped
        );
        assert_eq!(acc.on_frame(3, || period).0, FrameOutcome::Inferred);

        // ending strictly INSIDE frame 2's capture window keeps frame 2
        let mut acc = DropFrameAccounting::new(fps);
        assert_eq!(
            acc.on_frame(1, || 1.5 * period).0,
            FrameOutcome::Inferred
        );
        assert_eq!(acc.next_eligible(), 2);
        assert_eq!(acc.on_frame(2, || period).0, FrameOutcome::Inferred);
    }

    #[test]
    fn accounting_sums_to_frames_issued() {
        // inferred + dropped == frames presented, for constant, mixed
        // and degenerate (zero-latency) schedules — the conservation
        // every RunResult relies on
        let schedules: [fn(u64) -> f64; 4] = [
            |_| 0.0,
            |_| 0.027,
            |_| 0.153,
            |f| if f % 7 == 0 { 0.2 } else { 0.01 },
        ];
        for (si, latency_of) in schedules.iter().enumerate() {
            for n in [1u64, 2, 9, 250] {
                let mut acc = DropFrameAccounting::new(30.0);
                for f in 1..=n {
                    acc.on_frame(f, || latency_of(f));
                }
                assert_eq!(
                    acc.n_inferred() + acc.n_dropped(),
                    n,
                    "schedule {si}, {n} frames"
                );
                assert!(acc.n_inferred() >= 1, "schedule {si}");
                assert!((0.0..=1.0).contains(&acc.drop_rate()));
            }
        }
    }

    #[test]
    fn zero_latency_never_drops_and_tracks_stream_time() {
        let mut acc = DropFrameAccounting::new(30.0);
        for f in 1..=90 {
            let (o, iv) = acc.on_frame(f, || 0.0);
            assert_eq!(o, FrameOutcome::Inferred);
            let (s, e) = iv.unwrap();
            assert_eq!(s, e, "zero-latency interval is a point");
        }
        assert_eq!(acc.n_dropped(), 0);
        assert_eq!(acc.busy_time(), 0.0);
        // the clamp keeps virtual time pinned to the stream clock
        assert!((acc.now() - 90.0 / 30.0).abs() < 1e-9);
    }

    #[test]
    fn mixed_latency_recovers() {
        // a slow inference followed by fast ones: drops happen only in
        // the slow shadow
        let mut acc = DropFrameAccounting::new(30.0);
        let lat = [0.2, 0.01, 0.01, 0.01, 0.01, 0.01, 0.01, 0.01];
        let mut li = 0;
        let mut outcomes = Vec::new();
        for f in 1..=8 {
            let (o, _) = acc.on_frame(f, || {
                let v = lat[li];
                li += 1;
                v
            });
            outcomes.push(o);
        }
        use FrameOutcome::*;
        // 0.2 s = 6 frame periods: frames 2..6 dropped, 7+ inferred
        assert_eq!(
            outcomes,
            vec![Inferred, Dropped, Dropped, Dropped, Dropped, Dropped,
                 Inferred, Inferred]
        );
    }
}
