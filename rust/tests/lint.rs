//! Integration tests for `tod lint` (analysis/, DESIGN.md §16).
//!
//! Three layers: a fixture tree with known-bad snippets asserting that
//! each rule fires with the right id and file:line; the waiver
//! round-trip (honoured, reason-less, stale); and the self-run gate —
//! the crate's own `src/` under the shipped `lint-policy.json` must be
//! clean, which is exactly what `tod lint --check` enforces in CI.

use std::path::Path;

use tod::analysis::report::{REPORT_SCHEMA, REPORT_VERSION};
use tod::analysis::{run_lint, Policy, Zone};
use tod::util::json::Json;

/// Policy mapping the fixture tree's paths onto the three zones.
const FIXTURE_POLICY: &str = r#"{
  "schema": "tod-lint-policy",
  "schema_version": 1,
  "version": 7,
  "zones": {
    "determinism": {"paths": ["obs/"]},
    "serving": {"paths": ["runtime/"]},
    "hot_path": {"functions": ["Core::step"]}
  },
  "severity": {"srv-slice-index": "warn"}
}"#;

fn fixture_root() -> &'static Path {
    Path::new(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/lint"
    ))
}

#[test]
fn fixtures_flag_every_rule_with_id_and_line() {
    let policy = Policy::parse(FIXTURE_POLICY).unwrap();
    let rep = run_lint(fixture_root(), &policy).unwrap();
    assert_eq!(rep.files_scanned, 4);
    assert_eq!(rep.policy_version, 7);

    let got: Vec<(&str, usize, &str)> = rep
        .findings
        .iter()
        .map(|f| (f.file.as_str(), f.line, f.rule.as_str()))
        .collect();
    // sorted by (file, line, rule) — the report pins this order
    let want = [
        ("hot.rs", 7, "hot-collect"),
        ("hot.rs", 8, "hot-clone"),
        ("obs/clocky.rs", 4, "det-wall-clock"),
        ("obs/clocky.rs", 5, "det-unordered-iter"),
        ("obs/clocky.rs", 7, "det-float-cmp-unwrap"),
        ("runtime/request.rs", 4, "srv-unwrap"),
        ("runtime/request.rs", 8, "srv-expect"),
        ("runtime/request.rs", 12, "srv-panic"),
        ("runtime/waived.rs", 9, "waiver-missing-reason"),
        ("runtime/waived.rs", 10, "srv-unwrap"),
    ];
    assert_eq!(got, want, "deny findings (file, line, rule)");

    // the unwrap inside request.rs's #[cfg(test)] module is exempt:
    // no finding points past line 12 of that file
    assert!(rep
        .findings
        .iter()
        .all(|f| f.file != "runtime/request.rs" || f.line <= 12));
    // Core::cold's collect (hot.rs:13) is outside the hot zone
    assert!(!got.contains(&("hot.rs", 13, "hot-collect")));
}

#[test]
fn waiver_round_trip_honoured_and_enumerated() {
    let policy = Policy::parse(FIXTURE_POLICY).unwrap();
    let rep = run_lint(fixture_root(), &policy).unwrap();

    // honoured: the panic under the reasoned waiver is suppressed but
    // enumerated with its reason
    assert_eq!(rep.waived.len(), 1);
    let w = &rep.waived[0];
    assert_eq!(w.finding.file, "runtime/waived.rs");
    assert_eq!(w.finding.line, 5);
    assert_eq!(w.finding.rule, "srv-panic");
    assert_eq!(w.reason, "fixture: documented contract");

    // stale: the srv-expect waiver covering a clean line surfaces as
    // an unused-waiver advisory at its declaration line
    assert_eq!(rep.advisories.len(), 1);
    assert_eq!(rep.advisories[0].rule, "unused-waiver");
    assert_eq!(rep.advisories[0].file, "runtime/waived.rs");
    assert_eq!(rep.advisories[0].line, 14);
}

#[test]
fn report_json_is_versioned_and_complete() {
    let policy = Policy::parse(FIXTURE_POLICY).unwrap();
    let rep = run_lint(fixture_root(), &policy).unwrap();
    let j = rep.to_json();
    assert_eq!(j.get("schema").and_then(Json::as_str), Some(REPORT_SCHEMA));
    assert_eq!(
        j.get("schema_version").and_then(Json::as_usize),
        Some(REPORT_VERSION as usize)
    );
    assert_eq!(j.get("policy_version").and_then(Json::as_usize), Some(7));
    assert_eq!(j.get("files_scanned").and_then(Json::as_usize), Some(4));
    let findings = j.get("findings").and_then(Json::as_arr).unwrap();
    assert_eq!(findings.len(), rep.findings.len());
    let waived = j.get("waived").and_then(Json::as_arr).unwrap();
    assert_eq!(
        waived[0].get("reason").and_then(Json::as_str),
        Some("fixture: documented contract")
    );
    // byte-determinism: re-running the scan renders identical JSON
    let rep2 = run_lint(fixture_root(), &policy).unwrap();
    assert_eq!(rep.to_json().to_pretty(), rep2.to_json().to_pretty());
}

#[test]
fn shipped_policy_parses_and_maps_the_real_zones() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let policy = Policy::load(&root.join("lint-policy.json")).unwrap();
    assert_eq!(
        policy.path_zone("obs/trace.rs"),
        Some(Zone::Determinism)
    );
    assert_eq!(
        policy.path_zone("runtime/server.rs"),
        Some(Zone::Serving)
    );
    assert_eq!(policy.path_zone("analysis/mod.rs"), None);
    assert!(policy.is_hot_function("StreamSession::step"));
    assert!(policy.is_hot_function("nms"));
    assert!(!policy.is_hot_function("StreamSession::summary"));
}

#[test]
fn self_run_is_clean_and_every_waiver_has_a_reason() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let policy = Policy::load(&root.join("lint-policy.json")).unwrap();
    let rep = run_lint(&root.join("src"), &policy).unwrap();
    assert!(rep.files_scanned > 50, "scanned {}", rep.files_scanned);

    let details: Vec<String> = rep
        .findings
        .iter()
        .map(|f| format!("{}:{} [{}] {}", f.file, f.line, f.rule, f.message))
        .collect();
    assert!(
        rep.clean(),
        "unwaived deny findings in src/:\n{}",
        details.join("\n")
    );
    // the waiver protocol's own guarantee, end to end: everything
    // waived in the real tree carries a non-empty reason
    assert!(!rep.waived.is_empty(), "expected the documented waivers");
    for w in &rep.waived {
        assert!(
            !w.reason.trim().is_empty(),
            "{}:{} waived without reason",
            w.finding.file,
            w.finding.line
        );
    }
    // and none of them is stale
    let stale: Vec<String> = rep
        .advisories
        .iter()
        .filter(|a| a.rule == "unused-waiver")
        .map(|a| format!("{}:{}", a.file, a.line))
        .collect();
    assert!(stale.is_empty(), "stale waivers: {}", stale.join(", "));
}
