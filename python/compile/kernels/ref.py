"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

Every Pallas kernel in this package has a reference implementation here
written with plain jax.numpy / lax ops only. pytest (and the hypothesis
sweeps) assert allclose between kernel and reference across shapes and
dtypes — this is the core L1 correctness signal.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

LEAKY_SLOPE = 0.1


def ref_matmul_bias_act(x, w, b, activation: str = "leaky_relu"):
    """act(x @ w + b) with float32 accumulation, matching the kernel."""
    out = jnp.dot(x, w, preferred_element_type=jnp.float32) + b
    if activation == "linear":
        pass
    elif activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation == "leaky_relu":
        out = jnp.where(out >= 0.0, out, LEAKY_SLOPE * out)
    else:
        raise ValueError(f"unknown activation: {activation}")
    return out.astype(x.dtype)


def ref_maxpool2x2(x):
    """2x2 stride-2 max pool on NHWC."""
    n, h, w, c = x.shape
    x = x.reshape(n, h // 2, 2, w // 2, 2, c)
    return jnp.max(x, axis=(2, 4))


def ref_conv2d_bias_act(x, w, b, stride: int = 1,
                        activation: str = "leaky_relu"):
    """Direct NHWC conv + bias + activation via lax.conv (SAME padding).

    w layout: (kh, kw, cin, cout). This is the oracle for the im2col +
    fused-matmul convolution path in ``compile.conv``.
    """
    out = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    out = out + b
    if activation == "linear":
        pass
    elif activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation == "leaky_relu":
        out = jnp.where(out >= 0.0, out, LEAKY_SLOPE * out)
    else:
        raise ValueError(f"unknown activation: {activation}")
    return out.astype(x.dtype)
