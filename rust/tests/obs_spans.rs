//! End-to-end span / profile / export checks (ISSUE 8 acceptance).
//!
//! The unit tests inside `obs/` exercise synthetic event streams; these
//! cover the properties only a real traced run can break:
//!
//! 1. `StreamSession::step` emits **balanced, properly nested,
//!    time-monotone** spans over a whole budget-clamped run, with one
//!    frame span per presented frame;
//! 2. for every closed frame span, stage self-times sum exactly to the
//!    frame total (attribution loses nothing and invents nothing);
//! 3. the same seed renders a **byte-identical** Chrome trace — the
//!    `tod trace export --chrome` determinism contract;
//! 4. the flamegraph fold roots every stack at the stream span and
//!    keeps the inference path;
//! 5. a multi-stream scheduler run interleaves streams without
//!    breaking per-stream span nesting.

use std::cell::RefCell;
use std::rc::Rc;

use tod::app::DEFAULT_WATTS_BUDGET;
use tod::coordinator::multistream::{DispatchPolicy, MultiStreamScheduler};
use tod::coordinator::{
    run_realtime_observed, FixedPolicy, MbbsPolicy, OracleBackend,
    RunResult, StreamSession,
};
use tod::dataset::catalog::{generate, SequenceId};
use tod::obs::{
    chrome_trace, flamegraph, validate_spans, Event, EventLog,
    SharedRecorder, SpanKind,
};
use tod::power::{BudgetConfig, BudgetedPolicy, PowerBudget};
use tod::sim::latency::{ContentionModel, LatencyModel};
use tod::sim::oracle::OracleDetector;
use tod::DnnKind;

fn oracle_backend(seq: &tod::dataset::Sequence) -> OracleBackend {
    OracleBackend(OracleDetector::new(
        seq.spec.seed,
        seq.spec.width as f64,
        seq.spec.height as f64,
    ))
}

/// Fixed-Y416 under the default watts cap: the governor clamps and the
/// accelerator saturates, so the trace mixes inferred and dropped
/// frames — the interesting case for attribution.
fn traced_run() -> (Vec<Event>, RunResult) {
    let id = SequenceId::Mot05;
    let seq = generate(id);
    let mut det = oracle_backend(&seq);
    let mut lat = LatencyModel::deterministic();
    let budget = PowerBudget::try_new(
        BudgetConfig {
            watts_cap: Some(DEFAULT_WATTS_BUDGET),
            gpu_cap_pct: None,
            window_s: 1.0,
            rate_cap: None,
        },
        &lat,
    )
    .expect("default watts cap is a valid budget");
    let log = Rc::new(RefCell::new(EventLog::new()));
    let rec: SharedRecorder = log.clone();
    let mut policy =
        BudgetedPolicy::masking(Box::new(FixedPolicy(DnnKind::Y416)), budget)
            .with_recorder(rec.clone(), 0);
    let r = run_realtime_observed(
        &seq,
        &mut policy,
        &mut det,
        &mut lat,
        id.eval_fps(),
        Some((rec.clone(), 0)),
    );
    let events = log.borrow().events().to_vec();
    (events, r)
}

#[test]
fn traced_run_has_balanced_nested_monotone_spans() {
    let (events, r) = traced_run();
    validate_spans(&events).expect("real trace must validate");
    let opens = events
        .iter()
        .filter(|e| matches!(e, Event::SpanOpen { .. }))
        .count();
    let closes = events
        .iter()
        .filter(|e| matches!(e, Event::SpanClose { .. }))
        .count();
    assert_eq!(opens, closes, "every opened span closes");
    assert!(opens > 0, "traced run emitted no spans");
    let frame_spans = events
        .iter()
        .filter(|e| {
            matches!(e, Event::SpanOpen { kind: SpanKind::Frame, .. })
        })
        .count();
    assert_eq!(
        frame_spans as u64, r.n_frames,
        "one frame span per presented frame"
    );
    let infer_spans = events
        .iter()
        .filter(|e| {
            matches!(e, Event::SpanOpen { kind: SpanKind::Inference, .. })
        })
        .count();
    assert_eq!(
        infer_spans as u64,
        r.n_inferred + r.n_failed,
        "one inference span per dispatched frame"
    );
}

#[test]
fn stage_self_times_sum_to_each_frame_span() {
    let (events, r) = traced_run();
    assert!(
        r.n_inferred > 0 && r.n_dropped > 0,
        "fixture must mix inferred and dropped frames"
    );
    let frames = tod::obs::profile::per_frame(&events);
    assert_eq!(frames.len() as u64, r.n_frames);
    for f in &frames {
        let sum: f64 = f.stage_self_s.iter().sum();
        assert!(
            (sum - f.total_s).abs() < 1e-9,
            "frame {}: stage self-times {} != frame span {}",
            f.frame,
            sum,
            f.total_s
        );
    }
    let report = tod::obs::profile::profile(&events);
    assert_eq!(report.unclosed, 0, "a clean run leaves nothing open");
    assert_eq!(report.frames, r.n_frames);
    // inference is the only stage with real width in virtual time
    assert!(report.stage(SpanKind::Inference).self_s > 0.0);
}

#[test]
fn same_seed_chrome_export_is_byte_identical() {
    let (a, ra) = traced_run();
    let (b, rb) = traced_run();
    assert_eq!(ra.n_inferred, rb.n_inferred);
    let ja = chrome_trace(&a).to_string();
    assert_eq!(ja, chrome_trace(&b).to_string(), "same-seed exports differ");
    assert!(ja.starts_with("{\"traceEvents\":["));
    assert!(ja.contains("\"name\":\"inference\""));
    assert!(
        ja.contains("\"budget_clamp\""),
        "clamped run must export clamp instants"
    );
}

#[test]
fn flamegraph_folds_the_real_span_stack() {
    let (events, _) = traced_run();
    let fg = flamegraph(&events);
    assert_eq!(fg, flamegraph(&events), "flamegraph must be deterministic");
    let lines: Vec<&str> = fg.lines().collect();
    assert!(!lines.is_empty());
    for l in &lines {
        assert!(
            l.starts_with("stream_0;stream"),
            "stack not rooted at the stream span: {l}"
        );
    }
    assert!(
        lines
            .iter()
            .any(|l| l.starts_with("stream_0;stream;frame;inference ")),
        "inference path missing:\n{fg}"
    );
}

#[test]
fn multi_stream_spans_stay_nested_per_stream() {
    let log = Rc::new(RefCell::new(EventLog::new()));
    let rec: SharedRecorder = log.clone();
    let mut sched = MultiStreamScheduler::new(
        DispatchPolicy::EarliestDeadlineFirst,
        ContentionModel::jetson_nano(),
        LatencyModel::deterministic(),
    )
    .with_recorder(rec);
    for id in [SequenceId::Mot02, SequenceId::Mot05] {
        let seq = generate(id);
        let det = oracle_backend(&seq);
        sched.add_stream(
            StreamSession::new(&seq, MbbsPolicy::tod_default(), 30.0),
            Box::new(det),
        );
    }
    let result = sched.run();
    assert_eq!(result.per_stream.len(), 2);
    let events = log.borrow().events().to_vec();
    validate_spans(&events).expect("interleaved trace must validate");
    let streams: std::collections::BTreeSet<u32> = events
        .iter()
        .filter_map(|e| match e {
            Event::SpanOpen { stream, .. } => Some(*stream),
            _ => None,
        })
        .collect();
    assert_eq!(
        streams.into_iter().collect::<Vec<_>>(),
        vec![0, 1],
        "both streams must emit spans"
    );
}
