//! Composable scenario descriptions: phased edge workloads.
//!
//! A [`ScenarioSpec`] describes a *workload*, not a single sequence: one
//! or more camera streams, each sequencing [`PhaseSpec`]s that shift the
//! regime mid-run — crowd density, object-size distribution (via the
//! perspective `ref_height`/depth geometry of [`crate::dataset::synth`]),
//! camera-motion class, capture-clock scale (FPS sag/burst), and
//! detection noise (day/night) — plus stream churn (staggered joins and
//! early leaves). Everything is deterministic in the scenario seed:
//! [`ScenarioSpec::compile`] lowers each stream onto a concrete
//! [`Sequence`] (phases concatenated, frames renumbered, ids kept
//! unique) together with the per-phase harness annotations the replay
//! loop ([`super::harness`]) needs.

use crate::dataset::synth::{CameraMotion, Sequence, SequenceSpec};

/// Detection-noise profile of a phase (the day/night axis).
///
/// Night footage is harder for every detector: a fraction of the
/// would-be detections is missed outright and confidences sag. The
/// harness applies this as a deterministic post-filter on the oracle's
/// output ([`super::harness::NoisyDetector`]) — a pure function of
/// `(frame, dnn)`, so policy comparisons stay paired.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NoiseProfile {
    /// Probability that a detection is dropped, in [0, 1).
    pub miss: f64,
    /// Multiplicative confidence attenuation, in [0, 1) (0 = none).
    pub conf_loss: f64,
}

impl NoiseProfile {
    /// Clean daylight footage: the oracle's output untouched.
    pub const DAY: NoiseProfile = NoiseProfile { miss: 0.0, conf_loss: 0.0 };

    /// Night-time attenuation: roughly a quarter of the detections
    /// vanish and confidences drop by a fifth.
    pub const NIGHT: NoiseProfile =
        NoiseProfile { miss: 0.25, conf_loss: 0.2 };

    pub fn is_clean(&self) -> bool {
        self.miss == 0.0 && self.conf_loss == 0.0
    }

    pub fn validate(&self) -> Result<(), String> {
        if !(0.0..1.0).contains(&self.miss) {
            return Err(format!("noise miss must be in [0,1): {}", self.miss));
        }
        if !(0.0..1.0).contains(&self.conf_loss) {
            return Err(format!(
                "noise conf_loss must be in [0,1): {}",
                self.conf_loss
            ));
        }
        Ok(())
    }
}

/// One regime segment of a stream.
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseSpec {
    /// Short label ("rush", "night", ...) used in per-phase series.
    pub label: String,
    /// Frames in the phase (> 0).
    pub frames: u64,
    /// Target simultaneously visible pedestrians.
    pub density: usize,
    /// Reference box height at depth 1.0 (controls the MBBS regime).
    pub ref_height: f64,
    /// Depth range [near, far] — spread of the size distribution.
    pub depth_range: (f64, f64),
    /// Pedestrian world speed, px/frame at depth 1.0.
    pub walk_speed: f64,
    /// Camera-motion class during the phase.
    pub camera: CameraMotion,
    /// Capture-clock scale relative to the scenario base FPS (1.0 =
    /// nominal). Compiled as the period-relative transform: the frame
    /// clock stays fixed and every inference in the phase is priced at
    /// `sample × fps_scale`, which reproduces the drop-regime of a
    /// camera running at `fps_scale × base_fps` against an unchanged
    /// accelerator. `< 1` = sagging camera (load lightens), `> 1` =
    /// backlog burst (budgets tighten).
    pub fps_scale: f64,
    /// Detection-noise profile (day/night).
    pub noise: NoiseProfile,
}

impl PhaseSpec {
    /// A daylight static-camera phase with mid-crowd defaults; chain
    /// the builder methods to shape the regime.
    pub fn new(label: &str, frames: u64) -> Self {
        PhaseSpec {
            label: label.to_string(),
            frames,
            density: 10,
            ref_height: 240.0,
            depth_range: (1.0, 2.2),
            walk_speed: 1.5,
            camera: CameraMotion::Static,
            fps_scale: 1.0,
            noise: NoiseProfile::DAY,
        }
    }

    pub fn density(mut self, density: usize) -> Self {
        self.density = density;
        self
    }

    pub fn ref_height(mut self, ref_height: f64) -> Self {
        self.ref_height = ref_height;
        self
    }

    pub fn depth_range(mut self, near: f64, far: f64) -> Self {
        self.depth_range = (near, far);
        self
    }

    pub fn walk_speed(mut self, walk_speed: f64) -> Self {
        self.walk_speed = walk_speed;
        self
    }

    pub fn camera(mut self, camera: CameraMotion) -> Self {
        self.camera = camera;
        self
    }

    pub fn fps_scale(mut self, fps_scale: f64) -> Self {
        self.fps_scale = fps_scale;
        self
    }

    pub fn noise(mut self, noise: NoiseProfile) -> Self {
        self.noise = noise;
        self
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.label.is_empty() {
            return Err("phase label must not be empty".into());
        }
        if self.frames == 0 {
            return Err(format!("phase {:?}: frames must be > 0", self.label));
        }
        if !(self.ref_height > 0.0 && self.ref_height.is_finite()) {
            return Err(format!(
                "phase {:?}: ref_height must be positive and finite",
                self.label
            ));
        }
        if !(self.depth_range.0 > 0.0 && self.depth_range.1 >= self.depth_range.0)
        {
            return Err(format!(
                "phase {:?}: depth range must be 0 < near <= far",
                self.label
            ));
        }
        if !(self.fps_scale > 0.0 && self.fps_scale.is_finite()) {
            return Err(format!(
                "phase {:?}: fps_scale must be positive and finite",
                self.label
            ));
        }
        self.noise
            .validate()
            .map_err(|e| format!("phase {:?}: {e}", self.label))
    }
}

/// One camera stream of the scenario: a phase sequence plus churn
/// coordinates.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamSpec {
    /// Stream label ("cam0", ...).
    pub label: String,
    /// Board time (seconds) at which the stream joins. Its frame clock
    /// starts at the join, so frame 1 arrives `1/fps` later; a stream
    /// *leaves* when its phases run out of frames.
    pub join_s: f64,
    /// The stream's regime phases, replayed in order.
    pub phases: Vec<PhaseSpec>,
}

impl StreamSpec {
    pub fn new(label: &str, phases: Vec<PhaseSpec>) -> Self {
        StreamSpec { label: label.to_string(), join_s: 0.0, phases }
    }

    pub fn join_at(mut self, join_s: f64) -> Self {
        self.join_s = join_s;
        self
    }

    /// Total frames across all phases.
    pub fn n_frames(&self) -> u64 {
        self.phases.iter().map(|p| p.frames).sum()
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.label.is_empty() {
            return Err("stream label must not be empty".into());
        }
        if self.phases.is_empty() {
            return Err(format!(
                "stream {:?}: needs at least one phase",
                self.label
            ));
        }
        if !(self.join_s >= 0.0 && self.join_s.is_finite()) {
            return Err(format!(
                "stream {:?}: join_s must be finite and >= 0",
                self.label
            ));
        }
        for p in &self.phases {
            p.validate().map_err(|e| format!("stream {:?}: {e}", self.label))?;
        }
        Ok(())
    }
}

/// A complete scenario: named, seeded, versioned (see [`super::store`]).
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    /// Unique name ("rush-hour-surge", ...).
    pub name: String,
    /// One-line description for `tod scenario list`.
    pub description: String,
    /// Master seed; every stream/phase world derives from it.
    pub seed: u64,
    /// Frame geometry shared by all streams.
    pub width: u32,
    pub height: u32,
    /// Base evaluation FPS (phases scale it via `fps_scale`).
    pub base_fps: f64,
    /// Watts budget the canonical "budgeted" configuration runs under.
    pub watts_budget: f64,
    pub streams: Vec<StreamSpec>,
}

impl ScenarioSpec {
    pub fn new(name: &str, description: &str, streams: Vec<StreamSpec>) -> Self {
        ScenarioSpec {
            name: name.to_string(),
            description: description.to_string(),
            seed: 0x5ce0,
            width: 960,
            height: 540,
            base_fps: 30.0,
            watts_budget: crate::app::DEFAULT_WATTS_BUDGET,
            streams,
        }
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn geometry(mut self, width: u32, height: u32) -> Self {
        self.width = width;
        self.height = height;
        self
    }

    pub fn base_fps(mut self, fps: f64) -> Self {
        self.base_fps = fps;
        self
    }

    pub fn watts_budget(mut self, watts: f64) -> Self {
        self.watts_budget = watts;
        self
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.name.is_empty() {
            return Err("scenario name must not be empty".into());
        }
        if self.streams.is_empty() {
            return Err(format!(
                "scenario {:?}: needs at least one stream",
                self.name
            ));
        }
        if self.width == 0 || self.height == 0 {
            return Err(format!(
                "scenario {:?}: frame geometry must be non-empty",
                self.name
            ));
        }
        if !(self.base_fps > 0.0 && self.base_fps.is_finite()) {
            return Err(format!(
                "scenario {:?}: base_fps must be positive and finite",
                self.name
            ));
        }
        if !(self.watts_budget > 0.0 && self.watts_budget.is_finite()) {
            return Err(format!(
                "scenario {:?}: watts_budget must be positive and finite",
                self.name
            ));
        }
        let mut labels = std::collections::BTreeSet::new();
        for s in &self.streams {
            s.validate().map_err(|e| format!("scenario {:?}: {e}", self.name))?;
            if !labels.insert(s.label.clone()) {
                return Err(format!(
                    "scenario {:?}: duplicate stream label {:?}",
                    self.name, s.label
                ));
            }
        }
        Ok(())
    }

    /// Total frames across all streams.
    pub fn n_frames(&self) -> u64 {
        self.streams.iter().map(StreamSpec::n_frames).sum()
    }

    /// Lower every stream onto a concrete synthetic sequence plus the
    /// per-phase harness annotations. Deterministic in `self.seed`.
    pub fn compile(&self) -> Result<Vec<CompiledStream>, String> {
        self.validate()?;
        self.streams
            .iter()
            .enumerate()
            .map(|(si, stream)| self.compile_stream(si, stream))
            .collect()
    }

    fn compile_stream(
        &self,
        stream_idx: usize,
        stream: &StreamSpec,
    ) -> Result<CompiledStream, String> {
        // one sub-world per phase, seeded from (scenario, stream, phase)
        let stream_seed = self
            .seed
            .wrapping_mul(0x9e3779b97f4a7c15)
            .wrapping_add(stream_idx as u64 + 1);
        let mut frames = Vec::with_capacity(stream.n_frames() as usize);
        let mut phase_starts = Vec::with_capacity(stream.phases.len());
        let mut next_frame: u64 = 1;
        for (pi, phase) in stream.phases.iter().enumerate() {
            phase_starts.push(next_frame);
            let spec = SequenceSpec {
                name: format!("{}/{}/{}", self.name, stream.label, phase.label),
                width: self.width,
                height: self.height,
                fps: self.base_fps,
                frames: phase.frames,
                density: phase.density,
                ref_height: phase.ref_height,
                depth_range: phase.depth_range,
                walk_speed: phase.walk_speed,
                camera: phase.camera,
                seed: stream_seed
                    .wrapping_mul(0x9e3779b97f4a7c15)
                    .wrapping_add(pi as u64 + 1),
            };
            let sub = Sequence::generate(spec);
            // renumber frames to the stream timeline; offset ids so they
            // stay unique across phase worlds
            let id_offset = (pi as i64 + 1) << 20;
            for rows in &sub.frames {
                let mut out = Vec::with_capacity(rows.len());
                for r in rows {
                    let mut r = r.clone();
                    r.frame = next_frame;
                    r.id += id_offset;
                    out.push(r);
                }
                frames.push(out);
                next_frame += 1;
            }
        }
        let spec = SequenceSpec {
            name: format!("{}/{}", self.name, stream.label),
            width: self.width,
            height: self.height,
            fps: self.base_fps,
            frames: stream.n_frames(),
            // spec-level world stats describe the first phase (the
            // per-phase truth lives in `phases`)
            density: stream.phases[0].density,
            ref_height: stream.phases[0].ref_height,
            depth_range: stream.phases[0].depth_range,
            walk_speed: stream.phases[0].walk_speed,
            camera: stream.phases[0].camera,
            seed: stream_seed,
        };
        Ok(CompiledStream {
            label: stream.label.clone(),
            seq: Sequence { spec, frames },
            phase_starts,
            phases: stream.phases.clone(),
            join_s: stream.join_s,
            eval_fps: self.base_fps,
        })
    }
}

/// One stream lowered onto a concrete sequence plus per-phase
/// annotations for the replay harness.
#[derive(Debug, Clone)]
pub struct CompiledStream {
    pub label: String,
    /// All phases concatenated, frames renumbered 1..=n.
    pub seq: Sequence,
    /// First frame (1-based) of each phase.
    pub phase_starts: Vec<u64>,
    /// The phase specs (same order as `phase_starts`).
    pub phases: Vec<PhaseSpec>,
    /// Board time at which the stream joins.
    pub join_s: f64,
    /// Evaluation FPS of the stream's frame clock.
    pub eval_fps: f64,
}

impl CompiledStream {
    /// Index of the phase a 1-based frame belongs to.
    pub fn phase_of(&self, frame: u64) -> usize {
        match self.phase_starts.binary_search(&frame) {
            Ok(i) => i,
            Err(i) => i.saturating_sub(1),
        }
    }

    /// 1-based frame range `[start, end]` of a phase.
    pub fn phase_frames(&self, phase: usize) -> (u64, u64) {
        let start = self.phase_starts[phase];
        (start, start + self.phases[phase].frames - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_phase_scenario() -> ScenarioSpec {
        ScenarioSpec::new(
            "unit",
            "two-phase unit scenario",
            vec![StreamSpec::new(
                "cam0",
                vec![
                    PhaseSpec::new("sparse", 30).density(4).ref_height(320.0),
                    PhaseSpec::new("surge", 40)
                        .density(18)
                        .ref_height(120.0)
                        .noise(NoiseProfile::NIGHT),
                ],
            )],
        )
        .seed(7)
    }

    #[test]
    fn compile_concatenates_and_renumbers() {
        let s = two_phase_scenario();
        let streams = s.compile().unwrap();
        assert_eq!(streams.len(), 1);
        let c = &streams[0];
        assert_eq!(c.seq.n_frames(), 70);
        assert_eq!(c.phase_starts, vec![1, 31]);
        for (i, rows) in c.seq.frames.iter().enumerate() {
            for r in rows {
                assert_eq!(r.frame, i as u64 + 1);
            }
        }
        // distinct id spaces per phase
        let ids_a: std::collections::BTreeSet<i64> =
            c.seq.frames[0].iter().map(|r| r.id).collect();
        let ids_b: std::collections::BTreeSet<i64> =
            c.seq.frames[69].iter().map(|r| r.id).collect();
        assert!(ids_a.is_disjoint(&ids_b));
    }

    #[test]
    fn compile_is_deterministic_in_seed() {
        let a = two_phase_scenario().compile().unwrap();
        let b = two_phase_scenario().compile().unwrap();
        assert_eq!(a[0].seq.all_entries(), b[0].seq.all_entries());
        let c = two_phase_scenario().seed(8).compile().unwrap();
        assert_ne!(a[0].seq.all_entries(), c[0].seq.all_entries());
    }

    #[test]
    fn phase_lookup_matches_boundaries() {
        let c = &two_phase_scenario().compile().unwrap()[0];
        assert_eq!(c.phase_of(1), 0);
        assert_eq!(c.phase_of(30), 0);
        assert_eq!(c.phase_of(31), 1);
        assert_eq!(c.phase_of(70), 1);
        assert_eq!(c.phase_frames(0), (1, 30));
        assert_eq!(c.phase_frames(1), (31, 70));
    }

    #[test]
    fn phase_shift_changes_the_size_regime() {
        // the surge phase's close-up crowd must read much larger/denser
        let c = &two_phase_scenario().compile().unwrap()[0];
        let count_a = c.seq.frames[..30].iter().map(Vec::len).sum::<usize>();
        let count_b = c.seq.frames[40..].iter().map(Vec::len).sum::<usize>();
        assert!(count_b > count_a * 2, "surge {count_b} vs sparse {count_a}");
    }

    #[test]
    fn validation_rejects_bad_specs() {
        let mut s = two_phase_scenario();
        s.streams[0].phases[0].frames = 0;
        assert!(s.validate().is_err());

        let mut s = two_phase_scenario();
        s.streams[0].phases[1].fps_scale = 0.0;
        assert!(s.validate().is_err());

        let mut s = two_phase_scenario();
        s.streams[0].phases[1].noise.miss = 1.5;
        assert!(s.validate().is_err());

        let mut s = two_phase_scenario();
        s.streams.push(s.streams[0].clone());
        assert!(s.validate().unwrap_err().contains("duplicate"));

        let mut s = two_phase_scenario();
        s.streams[0].join_s = -1.0;
        assert!(s.validate().is_err());

        assert!(two_phase_scenario().validate().is_ok());
    }

    #[test]
    fn noise_profiles_validate() {
        assert!(NoiseProfile::DAY.is_clean());
        assert!(!NoiseProfile::NIGHT.is_clean());
        assert!(NoiseProfile::NIGHT.validate().is_ok());
        assert!(NoiseProfile { miss: -0.1, conf_loss: 0.0 }
            .validate()
            .is_err());
        assert!(NoiseProfile { miss: 0.0, conf_loss: 1.0 }
            .validate()
            .is_err());
    }
}
