//! Hierarchical spans over the per-frame pipeline (DESIGN.md §15).
//!
//! A span is an interval of virtual time with a parent: the stream span
//! (opened when a session joins a recorder, closed by `finish`) holds
//! one frame span per presented frame, and each frame span holds the
//! pipeline stages — `feature_extract`, `predict_select` (with a nested
//! `budget_govern` when the policy is a governor), `dispatch_wait`,
//! `inference` and `postprocess`. Spans ride the existing
//! [`crate::obs::Recorder`] plumbing as two `Copy` events
//! ([`crate::obs::Event::SpanOpen`] / [`crate::obs::Event::SpanClose`])
//! stamped with ids from a per-stream [`SpanArena`], so:
//!
//! * with a [`crate::obs::NullRecorder`] (or no recorder) the span path
//!   is a single branch — steady-state stepping stays allocation-free
//!   (asserted in `tests/perf_alloc.rs`);
//! * all timestamps come from the deterministic sim clock, so the same
//!   seed produces byte-identical traces, Chrome exports and profiles.
//!
//! Stage spans that model pure selector work (feature extraction, the
//! policy decision, postprocess/eval) are *zero-width instants* in
//! virtual time: the paper's "negligible computational overhead" claim
//! means the simulation charges them no latency, and keeping them
//! zero-width makes per-frame self-times sum exactly to the frame span
//! (`dispatch_wait + inference` carry all the width). [`validate_spans`]
//! checks the structural invariants offline; `obs/profile.rs` folds
//! self-times out of a validated trace.

use std::collections::BTreeMap;

use crate::obs::Event;

/// What a span measures. Order is the per-frame pipeline order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SpanKind {
    /// Whole-stream envelope (join → leave).
    Stream,
    /// One presented frame, capture to pipeline exit.
    Frame,
    /// Previous-frame feature extraction (MBBS, density, speed).
    FeatureExtract,
    /// Policy decision (threshold walk / projected argmax).
    PredictSelect,
    /// Budget governor pass inside the decision (governors only).
    BudgetGovern,
    /// Capture → accelerator start (queueing / contention wait).
    DispatchWait,
    /// Accelerator-busy interval.
    Inference,
    /// Detection filtering + eval bookkeeping after inference.
    Postprocess,
}

impl SpanKind {
    /// Number of span kinds.
    pub const COUNT: usize = 8;

    /// All kinds, pipeline order.
    pub const ALL: [SpanKind; SpanKind::COUNT] = [
        SpanKind::Stream,
        SpanKind::Frame,
        SpanKind::FeatureExtract,
        SpanKind::PredictSelect,
        SpanKind::BudgetGovern,
        SpanKind::DispatchWait,
        SpanKind::Inference,
        SpanKind::Postprocess,
    ];

    /// Dense index (array keying for per-stage aggregates).
    #[inline]
    pub fn index(self) -> usize {
        match self {
            SpanKind::Stream => 0,
            SpanKind::Frame => 1,
            SpanKind::FeatureExtract => 2,
            SpanKind::PredictSelect => 3,
            SpanKind::BudgetGovern => 4,
            SpanKind::DispatchWait => 5,
            SpanKind::Inference => 6,
            SpanKind::Postprocess => 7,
        }
    }

    /// Inverse of [`SpanKind::index`].
    pub fn from_index(i: usize) -> Option<SpanKind> {
        SpanKind::ALL.get(i).copied()
    }

    /// Stable label used in traces, exports and metrics names.
    pub fn label(self) -> &'static str {
        match self {
            SpanKind::Stream => "stream",
            SpanKind::Frame => "frame",
            SpanKind::FeatureExtract => "feature_extract",
            SpanKind::PredictSelect => "predict_select",
            SpanKind::BudgetGovern => "budget_govern",
            SpanKind::DispatchWait => "dispatch_wait",
            SpanKind::Inference => "inference",
            SpanKind::Postprocess => "postprocess",
        }
    }

    /// Inverse of [`SpanKind::label`] (trace parsing).
    pub fn from_label(s: &str) -> Option<SpanKind> {
        SpanKind::ALL.iter().copied().find(|k| k.label() == s)
    }
}

impl std::fmt::Display for SpanKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// Per-stream span id allocator and open-span stack.
///
/// Ids are dense (1, 2, 3...) per stream — id 0 is reserved for "no
/// parent" (the root). The stack is pre-sized to the maximum nesting
/// depth (stream ▸ frame ▸ stage ▸ nested stage), so steady-state
/// `open`/`close` never allocates.
#[derive(Debug, Clone)]
pub struct SpanArena {
    next_id: u32,
    stack: Vec<u32>,
}

impl Default for SpanArena {
    fn default() -> Self {
        Self::new()
    }
}

impl SpanArena {
    pub fn new() -> Self {
        SpanArena { next_id: 1, stack: Vec::with_capacity(8) }
    }

    /// Open a span: returns `(id, parent)` where `parent` is the
    /// innermost open span (0 at the root) and pushes the new span.
    #[inline]
    pub fn open(&mut self) -> (u32, u32) {
        let id = self.next_id;
        self.next_id += 1;
        let parent = self.stack.last().copied().unwrap_or(0);
        self.stack.push(id);
        (id, parent)
    }

    /// Allocate a span id without pushing it — for zero-width stage
    /// instants whose open and close are emitted back to back.
    #[inline]
    pub fn instant(&mut self) -> (u32, u32) {
        let id = self.next_id;
        self.next_id += 1;
        let parent = self.stack.last().copied().unwrap_or(0);
        (id, parent)
    }

    /// Close the innermost open span, returning its id (0 if the stack
    /// is empty, which indicates an emitter bug and is caught by
    /// [`validate_spans`] in tests rather than panicking on the hot
    /// path).
    #[inline]
    pub fn close(&mut self) -> u32 {
        self.stack.pop().unwrap_or(0)
    }

    /// Current nesting depth.
    pub fn depth(&self) -> usize {
        self.stack.len()
    }
}

/// Timestamp slack for span-ordering checks: virtual-clock arithmetic
/// is deterministic, but derived times may differ by float rounding.
const SPAN_T_EPS: f64 = 1e-9;

/// Check the structural span invariants over a recorded event stream:
/// every open has a matching close (per stream, LIFO), every open's
/// `parent` is the innermost open span at that point, timestamps are
/// monotone non-decreasing per stream, and children close before (and
/// open after) their parents. Non-span events are ignored.
pub fn validate_spans(events: &[Event]) -> Result<(), String> {
    // per-stream: stack of (span id, open time), plus last event time
    let mut stacks: BTreeMap<u32, (Vec<(u32, f64)>, f64)> = BTreeMap::new();
    for ev in events {
        match *ev {
            Event::SpanOpen { stream, span, parent, t, kind, .. } => {
                let (stack, last_t) = stacks
                    .entry(stream)
                    .or_insert_with(|| (Vec::new(), f64::NEG_INFINITY));
                if t + SPAN_T_EPS < *last_t {
                    return Err(format!(
                        "stream {stream}: span {span} ({kind}) opens at \
                         {t} after a later event at {last_t}"
                    ));
                }
                let top = stack.last().map(|&(id, _)| id).unwrap_or(0);
                if parent != top {
                    return Err(format!(
                        "stream {stream}: span {span} ({kind}) claims \
                         parent {parent} but innermost open span is {top}"
                    ));
                }
                stack.push((span, t));
                *last_t = last_t.max(t);
            }
            Event::SpanClose { stream, span, t } => {
                let (stack, last_t) = stacks
                    .entry(stream)
                    .or_insert_with(|| (Vec::new(), f64::NEG_INFINITY));
                let Some((open_id, open_t)) = stack.pop() else {
                    return Err(format!(
                        "stream {stream}: close of span {span} with no \
                         open span"
                    ));
                };
                if open_id != span {
                    return Err(format!(
                        "stream {stream}: close of span {span} but \
                         innermost open span is {open_id}"
                    ));
                }
                if t + SPAN_T_EPS < open_t {
                    return Err(format!(
                        "stream {stream}: span {span} closes at {t} \
                         before it opened at {open_t}"
                    ));
                }
                if t + SPAN_T_EPS < *last_t {
                    return Err(format!(
                        "stream {stream}: span {span} closes at {t} \
                         after a later event at {last_t}"
                    ));
                }
                *last_t = last_t.max(t);
            }
            _ => {}
        }
    }
    for (stream, (stack, _)) in &stacks {
        if let Some(&(id, t)) = stack.last() {
            return Err(format!(
                "stream {stream}: span {id} opened at {t} never closed"
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_index_roundtrips_and_labels_are_unique() {
        for (i, k) in SpanKind::ALL.iter().enumerate() {
            assert_eq!(k.index(), i);
            assert_eq!(SpanKind::from_index(i), Some(*k));
            assert_eq!(SpanKind::from_label(k.label()), Some(*k));
        }
        assert_eq!(SpanKind::from_index(SpanKind::COUNT), None);
        assert_eq!(SpanKind::from_label("bogus"), None);
        let mut labels: Vec<&str> =
            SpanKind::ALL.iter().map(|k| k.label()).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), SpanKind::COUNT);
    }

    #[test]
    fn arena_ids_are_dense_and_parents_track_the_stack() {
        let mut a = SpanArena::new();
        let (s1, p1) = a.open();
        assert_eq!((s1, p1), (1, 0));
        let (s2, p2) = a.open();
        assert_eq!((s2, p2), (2, 1));
        let (i3, ip) = a.instant();
        assert_eq!((i3, ip), (3, 2));
        assert_eq!(a.depth(), 2);
        assert_eq!(a.close(), 2);
        assert_eq!(a.close(), 1);
        assert_eq!(a.depth(), 0);
        // underflow reports the reserved root id instead of panicking
        assert_eq!(a.close(), 0);
    }

    fn open(stream: u32, span: u32, parent: u32, t: f64) -> Event {
        Event::SpanOpen {
            stream,
            frame: 0,
            span,
            parent,
            kind: SpanKind::Frame,
            t,
        }
    }

    fn close(stream: u32, span: u32, t: f64) -> Event {
        Event::SpanClose { stream, span, t }
    }

    #[test]
    fn validate_accepts_nested_balanced_spans() {
        let evs = [
            open(0, 1, 0, 0.0),
            open(0, 2, 1, 0.0),
            close(0, 2, 0.5),
            open(0, 3, 1, 0.5),
            close(0, 3, 0.5),
            close(0, 1, 1.0),
            // interleaved second stream has its own id space
            open(1, 1, 0, 0.2),
            close(1, 1, 0.3),
        ];
        assert!(validate_spans(&evs).is_ok());
    }

    #[test]
    fn validate_rejects_structural_violations() {
        // unbalanced: open without close
        let e = validate_spans(&[open(0, 1, 0, 0.0)]).unwrap_err();
        assert!(e.contains("never closed"), "{e}");
        // close without open
        let e = validate_spans(&[close(0, 7, 0.0)]).unwrap_err();
        assert!(e.contains("no open span"), "{e}");
        // wrong parent
        let e = validate_spans(&[open(0, 1, 0, 0.0), open(0, 2, 9, 0.1)])
            .unwrap_err();
        assert!(e.contains("parent"), "{e}");
        // non-LIFO close
        let e = validate_spans(&[
            open(0, 1, 0, 0.0),
            open(0, 2, 1, 0.0),
            close(0, 1, 0.5),
        ])
        .unwrap_err();
        assert!(e.contains("innermost"), "{e}");
        // time reversal
        let e = validate_spans(&[
            open(0, 1, 0, 1.0),
            close(0, 1, 0.5),
        ])
        .unwrap_err();
        assert!(e.contains("before it opened"), "{e}");
    }
}
