//! Determinism-zone fixture: deliberately violates every det rule.

pub fn stamp() -> f64 {
    let t = std::time::Instant::now();
    let mut m = std::collections::HashMap::new();
    m.insert("k", 1);
    let _ord = 0.1_f64.partial_cmp(&0.2).unwrap();
    t.elapsed().as_secs_f64()
}
