//! Property tests on coordinator invariants (proptest-style via
//! `tod::testing::prop`; see DESIGN.md §3 and §7).

use tod::coordinator::policy::MbbsPolicy;
use tod::coordinator::scheduler::{run_realtime, Detector};
use tod::dataset::synth::{CameraMotion, Sequence, SequenceSpec};
use tod::detection::{mbbs, nms, Detection, PERSON_CLASS};
use tod::eval::ap::{average_precision, ApMethod};
use tod::geometry::BBox;
use tod::sim::latency::LatencyModel;
use tod::testing::fixtures::{oracle_for, random_thresholds};
use tod::testing::prop::PropConfig;
use tod::video::dropframe::DropFrameAccounting;
use tod::DnnKind;

#[test]
fn policy_monotone_in_mbbs() {
    // larger MBBS never selects a heavier network
    PropConfig::default().run("policy monotone", |g| {
        let p = MbbsPolicy::new(random_thresholds(g));
        let a = g.f64_in(0.0, 0.5);
        let b = g.f64_in(0.0, 0.5);
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        p.select_pure(hi).index() <= p.select_pure(lo).index()
    });
}

#[test]
fn policy_empty_frame_selects_heaviest() {
    PropConfig::default().run("empty frame -> heaviest", |g| {
        let p = MbbsPolicy::new(random_thresholds(g));
        p.select_pure(0.0) == DnnKind::Y416
    });
}

#[test]
fn dropframe_conservation() {
    // inferred + dropped == total frames, for any latency pattern
    PropConfig::default().run("algorithm 2 conservation", |g| {
        let fps = g.f64_in(5.0, 60.0);
        let n = g.usize_in(1, 400) as u64;
        let mut acc = DropFrameAccounting::new(fps);
        for f in 1..=n {
            let lat = g.f64_in(0.001, 0.3);
            acc.on_frame(f, || lat);
        }
        acc.n_inferred() + acc.n_dropped() == n && acc.n_inferred() >= 1
    });
}

#[test]
fn dropframe_drop_rate_bounded_by_latency_ratio() {
    // with constant latency L at rate F, the keep rate ≈ min(1, 1/(L·F))
    PropConfig::with_cases(64).run("drop rate matches ratio", |g| {
        let fps = g.f64_in(10.0, 60.0);
        let lat = g.f64_in(0.005, 0.25);
        let n = 600u64;
        let mut acc = DropFrameAccounting::new(fps);
        for f in 1..=n {
            acc.on_frame(f, || lat);
        }
        let keep = acc.n_inferred() as f64 / n as f64;
        let expect = (1.0 / (lat * fps)).min(1.0);
        (keep - expect).abs() < 0.05 + 2.0 / n as f64
    });
}

#[test]
fn mbbs_bounded_and_median_like() {
    PropConfig::default().run("mbbs in [0,1] and robust", |g| {
        let n = g.usize_in(0, 40);
        let dets: Vec<Detection> = (0..n)
            .map(|_| {
                Detection::new(
                    BBox::new(
                        g.f64_in(0.0, 900.0),
                        g.f64_in(0.0, 500.0),
                        g.f64_in(0.1, 400.0),
                        g.f64_in(0.1, 400.0),
                    ),
                    0.9,
                    PERSON_CLASS,
                )
            })
            .collect();
        let m = mbbs(&dets, 1920.0, 1080.0);
        if n == 0 {
            return m == 0.0;
        }
        // median of areas is within [min, max] of the area fractions
        let areas: Vec<f64> = dets
            .iter()
            .map(|d| d.bbox.area_frac(1920.0, 1080.0))
            .collect();
        let lo = areas.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = areas.iter().cloned().fold(0.0f64, f64::max);
        m >= lo - 1e-12 && m <= hi + 1e-12
    });
}

#[test]
fn nms_idempotent_and_shrinking() {
    PropConfig::default().run("nms idempotent", |g| {
        let n = g.usize_in(0, 30);
        let dets: Vec<Detection> = (0..n)
            .map(|_| {
                Detection::new(
                    BBox::new(
                        g.f64_in(0.0, 200.0),
                        g.f64_in(0.0, 200.0),
                        g.f64_in(1.0, 80.0),
                        g.f64_in(1.0, 80.0),
                    ),
                    g.f64_in(0.05, 1.0) as f32,
                    PERSON_CLASS,
                )
            })
            .collect();
        let once = nms(&dets, 0.45);
        let twice = nms(&once, 0.45);
        once.len() <= dets.len() && once == twice
    });
}

#[test]
fn ap_bounded_and_perfect_detector_is_one() {
    PropConfig::default().run("ap bounds", |g| {
        let n_gt = g.usize_in(1, 50);
        let n_fp = g.usize_in(0, 50);
        let mut scored: Vec<(f32, bool)> = Vec::new();
        for _ in 0..n_gt {
            scored.push((g.f64_in(0.5, 1.0) as f32, true));
        }
        for _ in 0..n_fp {
            scored.push((g.f64_in(0.0, 1.0) as f32, false));
        }
        let ap = average_precision(&scored, n_gt, ApMethod::AllPoint);
        if !(0.0..=1.0).contains(&ap) {
            return false;
        }
        // perfect detector: all TPs, ranked anyhow, no FPs
        let perfect: Vec<(f32, bool)> =
            scored.iter().filter(|(_, t)| *t).cloned().collect();
        (average_precision(&perfect, n_gt, ApMethod::AllPoint) - 1.0).abs()
            < 1e-9
    });
}

#[test]
fn ap_monotone_in_fp_count() {
    // adding a false positive above all scores never raises AP
    PropConfig::with_cases(64).run("fp never helps", |g| {
        let n_gt = g.usize_in(1, 20);
        let mut scored: Vec<(f32, bool)> = (0..n_gt)
            .map(|_| (g.f64_in(0.1, 0.9) as f32, true))
            .collect();
        let base = average_precision(&scored, n_gt, ApMethod::AllPoint);
        scored.push((0.95, false));
        let with_fp = average_precision(&scored, n_gt, ApMethod::AllPoint);
        with_fp <= base + 1e-12
    });
}

#[test]
fn scheduler_deploy_counts_match_inferred() {
    PropConfig::with_cases(12).run("deploy counts consistent", |g| {
        let seq = Sequence::generate(SequenceSpec {
            name: "PROP".into(),
            width: 640,
            height: 480,
            fps: 30.0,
            frames: g.usize_in(10, 120) as u64,
            density: g.usize_in(1, 10),
            ref_height: g.f64_in(60.0, 300.0),
            depth_range: (1.0, 2.0),
            walk_speed: g.f64_in(0.5, 3.0),
            camera: if g.bool() {
                CameraMotion::Static
            } else {
                CameraMotion::Walking { pan_speed: g.f64_in(1.0, 20.0) }
            },
            seed: g.usize_in(0, 1_000_000) as u64,
        });
        let mut det = oracle_for(&seq);
        let mut pol = MbbsPolicy::new(random_thresholds(g));
        let mut lat = LatencyModel::deterministic();
        let fps = g.f64_in(10.0, 40.0);
        let r = run_realtime(&seq, &mut pol, &mut det, &mut lat, fps);
        r.deploy_counts.iter().sum::<u64>() == r.n_inferred
            && r.n_inferred + r.n_dropped == r.n_frames
            && (0.0..=1.0).contains(&r.ap)
            && r.mbbs_series.len() as u64 == r.n_frames
    });
}

#[test]
fn carried_detections_only_from_the_past() {
    // a detector that tags detections with its frame id: dropped frames
    // must surface boxes from an earlier frame
    struct Tagger;
    impl Detector for Tagger {
        fn detect(
            &mut self,
            frame: u64,
            _gt: &[tod::dataset::mot::GtEntry],
            _dnn: DnnKind,
        ) -> Result<Vec<Detection>, tod::coordinator::scheduler::DetectError>
        {
            Ok(vec![Detection::new(
                BBox::new(frame as f64, 0.0, 10.0, 10.0),
                0.9,
                PERSON_CLASS,
            )])
        }
    }
    PropConfig::with_cases(16).run("carry-forward causality", |g| {
        let seq = Sequence::generate(SequenceSpec {
            name: "CAUSAL".into(),
            width: 640,
            height: 480,
            fps: 30.0,
            frames: 60,
            density: 2,
            ref_height: 100.0,
            depth_range: (1.0, 2.0),
            walk_speed: 1.0,
            camera: CameraMotion::Static,
            seed: g.usize_in(0, 99999) as u64,
        });
        let mut pol = MbbsPolicy::tod_default();
        let mut lat = LatencyModel::deterministic();
        let r = run_realtime(&seq, &mut pol, &mut Tagger, &mut lat, 30.0);
        // every inferred frame advances; Tagger's x encodes origin frame
        r.n_inferred >= 1
    });
}

#[test]
fn switch_count_bounded_by_inferred() {
    PropConfig::with_cases(16).run("switches < inferences", |g| {
        let seq = Sequence::generate(SequenceSpec {
            name: "SW".into(),
            width: 640,
            height: 480,
            fps: 30.0,
            frames: 100,
            density: 6,
            ref_height: g.f64_in(80.0, 400.0),
            depth_range: (1.0, 2.5),
            walk_speed: 1.5,
            camera: CameraMotion::Walking { pan_speed: g.f64_in(0.0, 25.0) },
            seed: g.usize_in(0, 99999) as u64,
        });
        let mut det = oracle_for(&seq);
        let mut pol = MbbsPolicy::new(random_thresholds(g));
        let mut lat = LatencyModel::deterministic();
        let r = run_realtime(&seq, &mut pol, &mut det, &mut lat, 30.0);
        r.switches < r.n_inferred.max(1)
    });
}
