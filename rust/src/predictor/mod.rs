//! Projected-accuracy prediction: the calibrated model behind
//! feature-driven DNN selection.
//!
//! The paper's Algorithm 1 encodes "which DNN wins at which object
//! size" as three hand-tuned thresholds. This module replaces the
//! hand-tuning with measurement: [`calibrate`] runs an offline campaign
//! over synthetic operating points (object size × apparent speed) with
//! the oracle detector as ground truth, [`model::CalibrationTable`]
//! stores the per-DNN real-time AP surface, and [`store`] persists it
//! as a versioned JSON document. At runtime
//! [`crate::coordinator::projected::ProjectedAccuracyPolicy`] picks the
//! feasible DNN with the highest projected AP — a lookup, not a search.

pub mod calibrate;
pub mod model;
pub mod store;

pub use calibrate::{calibrate, CalibrationConfig};
pub use model::{CalibrationTable, TABLE_VERSION};
