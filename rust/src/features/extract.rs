//! Online per-frame stream-feature extraction.
//!
//! The paper's second contribution claims selection should react to
//! "characteristics of the video stream such as object size and speed of
//! movement". [`FrameFeatures`] is that characteristic vector, computed
//! incrementally from the detections the application already has (the
//! previous frame's carried boxes) — no extra inference, no pixel access:
//!
//! * `mbbs` — the paper's Median of Bounding-Box Sizes (area fraction);
//! * `count` / `density` — how many objects and how much of the frame
//!   they cover;
//! * `speed` — apparent object speed, estimated by greedy IoU/centroid
//!   matching of consecutive detection snapshots and smoothed by a
//!   configurable EWMA ([`super::ewma::Ewma`]).
//!
//! Speed is the magnitude of the *median* matched displacement vector
//! (median over dx and dy separately). The median of signed components
//! makes the estimate a coherent-flow statistic: per-box localisation
//! jitter and opposing pedestrian motion cancel, while camera pan/flow —
//! the dominant regime signal the paper's camera groups differ by —
//! passes through undamped. It is reported in *frame diagonals per
//! frame* so it is comparable across resolutions (a 20 px/frame pan
//! means something very different at 640x480 than at 1920x1080).
//! Matching is O(|prev| · |cur|) per update — microseconds at MOT
//! densities, comfortably inside the paper's "negligible overhead"
//! envelope (see `benches/selection.rs`).

use std::cell::RefCell;

use crate::detection::{mbbs_with_scratch, Detection};
use crate::util::stats::median_mut;

use super::ewma::Ewma;

/// The per-frame feature vector handed to
/// [`crate::coordinator::policy::SelectionPolicy::select`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FrameFeatures {
    /// Median bounding-box size, fraction of frame area (the paper's
    /// Algorithm 1 signal). 0.0 when there are no detections.
    pub mbbs: f64,
    /// Number of carried detections.
    pub count: usize,
    /// Total box area as a fraction of the frame (scene coverage).
    pub density: f64,
    /// EWMA-smoothed apparent object speed, frame diagonals per frame.
    /// 0.0 until two distinct detection snapshots have been observed.
    pub speed: f64,
}

impl FrameFeatures {
    /// A size-only feature vector (count/density/speed zero) — the
    /// degenerate view legacy MBBS-threshold policies consume, used by
    /// tests and callers that have no extractor state.
    pub fn mbbs_only(mbbs: f64) -> Self {
        FrameFeatures { mbbs, count: 0, density: 0.0, speed: 0.0 }
    }
}

/// Tunables for the extractor.
#[derive(Debug, Clone)]
pub struct FeatureConfig {
    /// EWMA smoothing factor for the speed estimate, in (0, 1].
    pub ewma_alpha: f64,
    /// Minimum IoU for an IoU-based match between snapshots.
    pub iou_gate: f64,
    /// Fallback centroid-distance gate, in multiples of the mean box
    /// diagonal of the candidate pair.
    pub centroid_gate: f64,
}

impl Default for FeatureConfig {
    fn default() -> Self {
        FeatureConfig { ewma_alpha: 0.25, iou_gate: 0.05, centroid_gate: 2.0 }
    }
}

/// Incremental feature extractor for one stream.
///
/// Call [`features`](Self::features) with the detections visible at the
/// current frame (typically the carried set) to read the feature vector,
/// and [`on_detections`](Self::on_detections) whenever an inference
/// produces a *fresh* snapshot, so the speed estimate advances. Dropped
/// frames (carried boxes unchanged) must not call `on_detections` — a
/// carried set matched against itself would report zero motion and drag
/// the speed estimate down during exactly the heavy-DNN schedules where
/// motion matters most.
#[derive(Debug, Clone)]
pub struct FeatureExtractor {
    cfg: FeatureConfig,
    frame_w: f64,
    frame_h: f64,
    /// Frame diagonal, px — the speed normaliser.
    diag: f64,
    speed: Ewma,
    /// Last distinct detection snapshot and the frame it came from.
    prev: Vec<Detection>,
    prev_frame: Option<u64>,
    /// Reusable matching/median scratch for the speed update — per-frame
    /// extraction allocates nothing once these buffers are warm.
    scratch: MatchScratch,
    /// Area scratch for the MBBS median; interior-mutable because
    /// [`features`](Self::features) reads through `&self`.
    areas: RefCell<Vec<f64>>,
}

/// Working buffers for [`match_displacements_into`], reused across
/// frames by the extractor.
#[derive(Debug, Clone, Default)]
struct MatchScratch {
    iou_pairs: Vec<(f64, usize, usize)>,
    dist_pairs: Vec<(f64, usize, usize)>,
    prev_used: Vec<bool>,
    cur_used: Vec<bool>,
    disp: Vec<(f64, f64)>,
    dxs: Vec<f64>,
    dys: Vec<f64>,
}

impl FeatureExtractor {
    pub fn new(frame_w: f64, frame_h: f64) -> Self {
        FeatureExtractor::with_config(FeatureConfig::default(), frame_w, frame_h)
    }

    pub fn with_config(cfg: FeatureConfig, frame_w: f64, frame_h: f64) -> Self {
        assert!(frame_w > 0.0 && frame_h > 0.0, "frame must be non-empty");
        let alpha = cfg.ewma_alpha;
        FeatureExtractor {
            cfg,
            frame_w,
            frame_h,
            diag: (frame_w * frame_w + frame_h * frame_h).sqrt(),
            speed: Ewma::new(alpha),
            prev: Vec::new(),
            prev_frame: None,
            scratch: MatchScratch::default(),
            areas: RefCell::new(Vec::new()),
        }
    }

    /// Feature vector for a frame whose visible detections are `dets`.
    /// `mbbs` is bit-identical to [`crate::detection::mbbs`] on the same
    /// set, so MBBS-threshold policies behave exactly as before.
    pub fn features(&self, dets: &[Detection]) -> FrameFeatures {
        let density = dets
            .iter()
            .map(|d| d.bbox.area_frac(self.frame_w, self.frame_h))
            .sum();
        let mbbs = {
            let mut areas = self.areas.borrow_mut();
            mbbs_with_scratch(dets, self.frame_w, self.frame_h, &mut areas)
        };
        FrameFeatures {
            mbbs,
            count: dets.len(),
            density,
            speed: self.speed.value(),
        }
    }

    /// Current smoothed speed estimate (frame diagonals per frame).
    pub fn speed(&self) -> f64 {
        self.speed.value()
    }

    /// Fold a fresh detection snapshot (from an inference at `frame`)
    /// into the speed estimate. Displacements are divided by the frame
    /// gap since the previous snapshot, so sparse heavy-DNN schedules
    /// and dense light-DNN schedules estimate the same physical speed.
    pub fn on_detections(&mut self, frame: u64, dets: &[Detection]) {
        if let Some(prev_frame) = self.prev_frame {
            let gap = frame.saturating_sub(prev_frame);
            if gap > 0 {
                match_displacements_into(
                    &self.prev,
                    dets,
                    self.cfg.iou_gate,
                    self.cfg.centroid_gate,
                    &mut self.scratch,
                );
                if !self.scratch.disp.is_empty() {
                    let s = &mut self.scratch;
                    s.dxs.clear();
                    s.dxs.extend(s.disp.iter().map(|&(dx, _)| dx));
                    s.dys.clear();
                    s.dys.extend(s.disp.iter().map(|&(_, dy)| dy));
                    let (mx, my) =
                        (median_mut(&mut s.dxs), median_mut(&mut s.dys));
                    let px_per_frame =
                        (mx * mx + my * my).sqrt() / gap as f64;
                    self.speed.update(px_per_frame / self.diag);
                }
            }
        }
        self.prev.clear();
        self.prev.extend_from_slice(dets);
        self.prev_frame = Some(frame);
    }

    /// Forget all history (stream restart).
    pub fn reset(&mut self) {
        self.speed.reset();
        self.prev.clear();
        self.prev_frame = None;
    }
}

/// Greedy two-stage matching of consecutive detection snapshots,
/// returning the signed centroid displacement `(dx, dy)` in px
/// (current minus previous) of each matched pair.
///
/// Stage 1 pairs by descending IoU (above `iou_gate`); stage 2 pairs the
/// leftovers by ascending centroid distance, gated at `centroid_gate`
/// mean box diagonals (fast objects can fully leave their old box
/// between sparse inferences, where IoU is zero but the track is
/// unambiguous). O(|prev| · |cur|) candidate pairs.
fn match_displacements(
    prev: &[Detection],
    cur: &[Detection],
    iou_gate: f64,
    centroid_gate: f64,
) -> Vec<(f64, f64)> {
    let mut scratch = MatchScratch::default();
    match_displacements_into(prev, cur, iou_gate, centroid_gate, &mut scratch);
    scratch.disp
}

/// Scratch-buffer core of [`match_displacements`]: fills `s.disp` with
/// the matched displacements, reusing every working buffer. Pinned
/// bit-identical to the per-call reference implementation by
/// `scratch_matching_matches_reference_on_random_snapshots`.
fn match_displacements_into(
    prev: &[Detection],
    cur: &[Detection],
    iou_gate: f64,
    centroid_gate: f64,
    s: &mut MatchScratch,
) {
    s.disp.clear();
    if prev.is_empty() || cur.is_empty() {
        return;
    }
    s.prev_used.clear();
    s.prev_used.resize(prev.len(), false);
    s.cur_used.clear();
    s.cur_used.resize(cur.len(), false);

    // stage 1: IoU pairs, best overlap first
    s.iou_pairs.clear();
    for (i, p) in prev.iter().enumerate() {
        for (j, c) in cur.iter().enumerate() {
            let iou = p.bbox.iou(&c.bbox);
            if iou >= iou_gate {
                s.iou_pairs.push((iou, i, j));
            }
        }
    }
    // NaN-safe: a degenerate box can yield a NaN IoU; it must sort
    // deterministically, not panic the per-frame feature update.
    // Unstable sort keeps the hot path allocation-free; the (i, j)
    // tie-break reproduces stable push order bit for bit.
    s.iou_pairs.sort_unstable_by(|a, b| {
        b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2))
    });
    for k in 0..s.iou_pairs.len() {
        let (_, i, j) = s.iou_pairs[k];
        if s.prev_used[i] || s.cur_used[j] {
            continue;
        }
        s.prev_used[i] = true;
        s.cur_used[j] = true;
        s.disp.push(displacement(&prev[i], &cur[j]));
    }

    // stage 2: nearest-centroid pairs among the unmatched
    s.dist_pairs.clear();
    for (i, p) in prev.iter().enumerate() {
        if s.prev_used[i] {
            continue;
        }
        for (j, c) in cur.iter().enumerate() {
            if s.cur_used[j] {
                continue;
            }
            let (dx, dy) = displacement(p, c);
            let dist = (dx * dx + dy * dy).sqrt();
            let gate = centroid_gate * 0.5 * (diagonal(p) + diagonal(c));
            if dist <= gate {
                s.dist_pairs.push((dist, i, j));
            }
        }
    }
    s.dist_pairs.sort_unstable_by(|a, b| {
        a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2))
    });
    for k in 0..s.dist_pairs.len() {
        let (_, i, j) = s.dist_pairs[k];
        if s.prev_used[i] || s.cur_used[j] {
            continue;
        }
        s.prev_used[i] = true;
        s.cur_used[j] = true;
        s.disp.push(displacement(&prev[i], &cur[j]));
    }
}

/// Signed centroid displacement `cur - prev`, px.
fn displacement(prev: &Detection, cur: &Detection) -> (f64, f64) {
    let (px, py) = prev.bbox.center();
    let (cx, cy) = cur.bbox.center();
    (cx - px, cy - py)
}

fn diagonal(d: &Detection) -> f64 {
    (d.bbox.w * d.bbox.w + d.bbox.h * d.bbox.h).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::detection::PERSON_CLASS;
    use crate::geometry::BBox;

    fn det(x: f64, y: f64, w: f64, h: f64) -> Detection {
        Detection::new(BBox::new(x, y, w, h), 0.9, PERSON_CLASS)
    }

    #[test]
    fn mbbs_only_is_neutral_elsewhere() {
        let f = FrameFeatures::mbbs_only(0.03);
        assert_eq!(f.mbbs, 0.03);
        assert_eq!(f.count, 0);
        assert_eq!(f.density, 0.0);
        assert_eq!(f.speed, 0.0);
    }

    #[test]
    fn features_match_detection_mbbs() {
        let fx = FeatureExtractor::new(960.0, 540.0);
        let dets =
            vec![det(10.0, 10.0, 40.0, 90.0), det(200.0, 50.0, 60.0, 120.0)];
        let f = fx.features(&dets);
        assert_eq!(f.mbbs, mbbs(&dets, 960.0, 540.0));
        assert_eq!(f.count, 2);
        let cover = (40.0 * 90.0 + 60.0 * 120.0) / (960.0 * 540.0);
        assert!((f.density - cover).abs() < 1e-12);
        assert_eq!(f.speed, 0.0);
    }

    #[test]
    fn constant_translation_recovers_speed() {
        let mut fx = FeatureExtractor::with_config(
            FeatureConfig { ewma_alpha: 1.0, ..FeatureConfig::default() },
            1000.0,
            1000.0,
        );
        let diag = (2.0f64).sqrt() * 1000.0;
        // three objects all moving +5 px/frame in x
        for f in 1..=20u64 {
            let x0 = 5.0 * f as f64;
            let dets = vec![
                det(x0, 100.0, 50.0, 100.0),
                det(x0 + 200.0, 300.0, 50.0, 100.0),
                det(x0 + 400.0, 500.0, 50.0, 100.0),
            ];
            fx.on_detections(f, &dets);
        }
        assert!((fx.speed() * diag - 5.0).abs() < 1e-9);
    }

    #[test]
    fn frame_gap_normalises_sparse_schedules() {
        // snapshots every 4 frames, objects at +5 px/frame -> 20 px per
        // snapshot, but the per-frame estimate must still be 5
        let mut fx = FeatureExtractor::with_config(
            FeatureConfig { ewma_alpha: 1.0, ..FeatureConfig::default() },
            1000.0,
            1000.0,
        );
        let diag = (2.0f64).sqrt() * 1000.0;
        for k in 0..6u64 {
            let f = 1 + 4 * k;
            let x0 = 5.0 * f as f64;
            fx.on_detections(f, &[det(x0, 100.0, 60.0, 120.0)]);
        }
        assert!((fx.speed() * diag - 5.0).abs() < 1e-9);
    }

    #[test]
    fn static_scene_speed_is_zero() {
        let mut fx = FeatureExtractor::new(1000.0, 1000.0);
        for f in 1..=10u64 {
            fx.on_detections(
                f,
                &[det(100.0, 100.0, 50.0, 100.0), det(400.0, 200.0, 50.0, 100.0)],
            );
        }
        assert_eq!(fx.speed(), 0.0);
    }

    #[test]
    fn centroid_fallback_catches_fast_objects() {
        // 80 px jump with a 50x100 box: IoU is 0, centroid matching
        // (gate 2 diagonals ≈ 224 px) must still pair them
        let d = match_displacements(
            &[det(0.0, 0.0, 50.0, 100.0)],
            &[det(80.0, 0.0, 50.0, 100.0)],
            0.05,
            2.0,
        );
        assert_eq!(d.len(), 1);
        assert!((d[0].0 - 80.0).abs() < 1e-9);
        assert!(d[0].1.abs() < 1e-9);
    }

    #[test]
    fn far_objects_stay_unmatched() {
        let d = match_displacements(
            &[det(0.0, 0.0, 20.0, 40.0)],
            &[det(900.0, 900.0, 20.0, 40.0)],
            0.05,
            2.0,
        );
        assert!(d.is_empty());
    }

    #[test]
    fn greedy_iou_prefers_best_overlap() {
        // one prev box, two cur candidates: the higher-IoU one wins and
        // the other goes unmatched (too far for the centroid gate too)
        let prev = vec![det(0.0, 0.0, 100.0, 100.0)];
        let cur = vec![
            det(5.0, 0.0, 100.0, 100.0),   // near-perfect overlap
            det(70.0, 0.0, 100.0, 100.0),  // partial overlap
        ];
        let d = match_displacements(&prev, &cur, 0.05, 0.1);
        assert_eq!(d.len(), 1);
        assert!((d[0].0 - 5.0).abs() < 1e-9);
    }

    #[test]
    fn opposing_motion_cancels_in_median_flow() {
        // two pedestrians walking towards each other: coherent flow is
        // zero, so the speed estimate stays near zero even though each
        // box moved 6 px (the regime signal is camera flow, not gait)
        let mut fx = FeatureExtractor::with_config(
            FeatureConfig { ewma_alpha: 1.0, ..FeatureConfig::default() },
            1000.0,
            1000.0,
        );
        for f in 1..=10u64 {
            let t = f as f64;
            let dets = vec![
                det(100.0 + 6.0 * t, 100.0, 50.0, 100.0),
                det(700.0 - 6.0 * t, 100.0, 50.0, 100.0),
            ];
            fx.on_detections(f, &dets);
        }
        // median of {+6, -6} per axis is the midpoint 0
        assert!(fx.speed() < 1e-9, "speed {}", fx.speed());
    }

    #[test]
    fn empty_snapshots_do_not_update() {
        let mut fx = FeatureExtractor::new(1000.0, 1000.0);
        fx.on_detections(1, &[det(0.0, 0.0, 50.0, 100.0)]);
        fx.on_detections(2, &[]); // objects lost
        fx.on_detections(3, &[det(10.0, 0.0, 50.0, 100.0)]);
        // no pairs were ever matched -> speed stays at its neutral 0
        assert_eq!(fx.speed(), 0.0);
    }

    /// The straightforward per-call matcher `match_displacements`
    /// delegated through before the scratch-reusing form existed; the
    /// oracle for the equivalence property test below.
    fn match_displacements_reference(
        prev: &[Detection],
        cur: &[Detection],
        iou_gate: f64,
        centroid_gate: f64,
    ) -> Vec<(f64, f64)> {
        if prev.is_empty() || cur.is_empty() {
            return Vec::new();
        }
        let mut prev_used = vec![false; prev.len()];
        let mut cur_used = vec![false; cur.len()];
        let mut out = Vec::new();

        let mut iou_pairs: Vec<(f64, usize, usize)> = Vec::new();
        for (i, p) in prev.iter().enumerate() {
            for (j, c) in cur.iter().enumerate() {
                let iou = p.bbox.iou(&c.bbox);
                if iou >= iou_gate {
                    iou_pairs.push((iou, i, j));
                }
            }
        }
        iou_pairs.sort_by(|a, b| b.0.total_cmp(&a.0));
        for &(_, i, j) in &iou_pairs {
            if prev_used[i] || cur_used[j] {
                continue;
            }
            prev_used[i] = true;
            cur_used[j] = true;
            out.push(displacement(&prev[i], &cur[j]));
        }

        let mut dist_pairs: Vec<(f64, usize, usize)> = Vec::new();
        for (i, p) in prev.iter().enumerate() {
            if prev_used[i] {
                continue;
            }
            for (j, c) in cur.iter().enumerate() {
                if cur_used[j] {
                    continue;
                }
                let (dx, dy) = displacement(p, c);
                let dist = (dx * dx + dy * dy).sqrt();
                let gate =
                    centroid_gate * 0.5 * (diagonal(p) + diagonal(c));
                if dist <= gate {
                    dist_pairs.push((dist, i, j));
                }
            }
        }
        dist_pairs.sort_by(|a, b| a.0.total_cmp(&b.0));
        for &(_, i, j) in &dist_pairs {
            if prev_used[i] || cur_used[j] {
                continue;
            }
            prev_used[i] = true;
            cur_used[j] = true;
            out.push(displacement(&prev[i], &cur[j]));
        }
        out
    }

    #[test]
    fn scratch_matching_matches_reference_on_random_snapshots() {
        use crate::testing::prop::{Gen, PropConfig};
        // one scratch reused across cases: stale pair lists from a
        // previous (larger) snapshot must not leak into the next
        let mut scratch = MatchScratch::default();
        let gen_snap = |g: &mut Gen, n: usize| -> Vec<Detection> {
            (0..n)
                .map(|_| {
                    // degenerate (zero/negative-extent) boxes included:
                    // they exercise the NaN-IoU sort path
                    det(
                        g.f64_in(-10.0, 60.0),
                        g.f64_in(-10.0, 60.0),
                        g.f64_in(-2.0, 30.0),
                        g.f64_in(-2.0, 30.0),
                    )
                })
                .collect()
        };
        PropConfig::default().run(
            "scratch_matching_matches_reference_on_random_snapshots",
            |g: &mut Gen| {
                let prev = gen_snap(g, g.usize_in(0, 12));
                let cur = gen_snap(g, g.usize_in(0, 12));
                let iou_gate = g.f64_in(0.0, 0.6);
                let centroid_gate = g.f64_in(0.0, 3.0);
                let reference = match_displacements_reference(
                    &prev, &cur, iou_gate, centroid_gate,
                );
                match_displacements_into(
                    &prev,
                    &cur,
                    iou_gate,
                    centroid_gate,
                    &mut scratch,
                );
                scratch.disp.len() == reference.len()
                    && scratch.disp.iter().zip(&reference).all(
                        |((ax, ay), (bx, by))| {
                            ax.to_bits() == bx.to_bits()
                                && ay.to_bits() == by.to_bits()
                        },
                    )
            },
        );
    }

    #[test]
    fn reset_clears_state() {
        let mut fx = FeatureExtractor::new(1000.0, 1000.0);
        fx.on_detections(1, &[det(0.0, 0.0, 50.0, 100.0)]);
        fx.on_detections(2, &[det(30.0, 0.0, 50.0, 100.0)]);
        assert!(fx.speed() > 0.0);
        fx.reset();
        assert_eq!(fx.speed(), 0.0);
    }
}
