//! Cross-module integration tests: campaign-level behaviour that must
//! hold for the paper's figures to be meaningful.

use tod::app::Campaign;
use tod::coordinator::policy::{MbbsPolicy, Thresholds};
use tod::coordinator::scheduler::run_realtime;
use tod::dataset::catalog::{generate, SequenceId};
use tod::sim::latency::LatencyModel;
use tod::telemetry::tegrastats::TegrastatsSim;
use tod::testing::fixtures::oracle_for;
use tod::DnnKind;

#[test]
fn fig4_offline_ordering_holds_everywhere() {
    // Y-416 best and tiny-288 worst on every sequence (paper Fig. 4)
    let mut c = Campaign::new();
    for id in SequenceId::ALL {
        let aps: Vec<f64> = DnnKind::ALL
            .iter()
            .map(|&k| c.offline(id, k).ap)
            .collect();
        assert!(
            aps[3] >= aps.iter().cloned().fold(0.0, f64::max) - 1e-12,
            "{}: Y-416 must be best offline: {aps:?}",
            id.name()
        );
        assert!(
            aps[0] <= aps.iter().cloned().fold(1.0, f64::min) + 1e-12,
            "{}: tiny-288 must be worst offline: {aps:?}",
            id.name()
        );
    }
}

#[test]
fn fig6_realtime_group_structure() {
    let mut c = Campaign::new();
    // static group: Y-416 still best in real-time mode
    for id in [SequenceId::Mot02, SequenceId::Mot04, SequenceId::Mot10] {
        let (best, _) = c.best_fixed_realtime(id);
        assert_eq!(best, DnnKind::Y416, "{}: static group", id.name());
    }
    // walking group: a tiny variant wins in real-time mode
    for id in [SequenceId::Mot05, SequenceId::Mot09, SequenceId::Mot11] {
        let (best, _) = c.best_fixed_realtime(id);
        assert!(
            best.is_tiny(),
            "{}: walking group should favour tiny, got {best}",
            id.name()
        );
    }
    // vehicle sequence: a full-YOLO 288 wins but Y-416 collapses
    let (best13, _) = c.best_fixed_realtime(SequenceId::Mot13);
    assert_eq!(best13, DnnKind::Y288, "MOT17-13 regime");
}

#[test]
fn fig7_drop_concentrates_on_heavy_nets_and_fast_motion() {
    let mut c = Campaign::new();
    // tiny-288 never drops frames -> zero offline->realtime drop
    for id in SequenceId::ALL {
        let off = c.offline(id, DnnKind::TinyY288).ap;
        let rt = c.realtime_fixed(id, DnnKind::TinyY288).ap;
        assert!((off - rt).abs() < 1e-9, "{}", id.name());
    }
    // the vehicle sequence shows the largest Y-416 drop
    let drop = |c: &mut Campaign, id: SequenceId| {
        c.offline(id, DnnKind::Y416).ap
            - c.realtime_fixed(id, DnnKind::Y416).ap
    };
    let d13 = drop(&mut c, SequenceId::Mot13);
    for id in [SequenceId::Mot02, SequenceId::Mot04, SequenceId::Mot10] {
        assert!(
            d13 > drop(&mut c, id),
            "MOT17-13 must have the largest Y-416 drop"
        );
    }
}

#[test]
fn fig8_tod_tracks_best_and_beats_lightest_clearly() {
    let mut c = Campaign::new();
    let mut tod_mean = 0.0;
    let mut t288_mean = 0.0;
    for id in SequenceId::ALL {
        let tod = c.tod(id).ap;
        let (_, best) = c.best_fixed_realtime(id);
        // the paper concedes up to ~0.2 AP on MOT17-13 and ~0.1 on
        // -05/-11; everywhere else TOD ≈ best
        let allowance = match id {
            SequenceId::Mot13 => 0.26,
            _ => 0.12,
        };
        assert!(
            tod > best - allowance,
            "{}: TOD {tod} vs best {best}",
            id.name()
        );
        tod_mean += tod / 7.0;
        t288_mean += c.realtime_fixed(id, DnnKind::TinyY288).ap / 7.0;
    }
    // headline: the big win is against tiny-288 (paper: +34.7%)
    assert!(
        tod_mean > t288_mean * 1.15,
        "TOD {tod_mean} must clearly beat tiny-288 {t288_mean}"
    );
}

#[test]
fn table1_selects_paper_hopt() {
    let out = tod::experiments::table1::run();
    assert!(
        out.text.contains("Selected H_opt = {0.007, 0.03, 0.04}"),
        "grid search must land on the paper's H_opt; got:\n{}",
        out.text
    );
}

#[test]
fn tod_uses_less_power_and_gpu_than_y416_on_mot05() {
    // §IV.D: TOD uses a fraction of Y-416's GPU and power on MOT17-05
    let mut c = Campaign::new();
    let sim = TegrastatsSim::default();
    let tod = c.tod(SequenceId::Mot05).trace.clone();
    let y416 = c.realtime_fixed(SequenceId::Mot05, DnnKind::Y416).trace.clone();
    let gpu_ratio = sim.mean_gpu(&tod) / sim.mean_gpu(&y416);
    let pow_ratio = sim.mean_power(&tod) / sim.mean_power(&y416);
    assert!(
        gpu_ratio < 0.65,
        "GPU ratio {gpu_ratio} (paper: 0.451)"
    );
    assert!(
        pow_ratio < 0.80,
        "power ratio {pow_ratio} (paper: 0.627)"
    );
    // and accuracy does not suffer vs Y-416 (paper: "without losing
    // accuracy")
    assert!(c.tod(SequenceId::Mot05).ap >=
            c.realtime_fixed(SequenceId::Mot05, DnnKind::Y416).ap - 0.01);
}

#[test]
fn tod_on_mot04_stays_with_y416() {
    // Fig. 9/10: the static far-field camera keeps MBBS under h1
    let mut c = Campaign::new();
    let freq = c.tod(SequenceId::Mot04).deploy_freq();
    assert!(
        freq[DnnKind::Y416.index()] > 0.95,
        "MOT17-04 should stay with Y-416: {freq:?}"
    );
}

#[test]
fn tod_on_mot05_mostly_tiny288() {
    let mut c = Campaign::new();
    let freq = c.tod(SequenceId::Mot05).deploy_freq();
    assert!(
        freq[DnnKind::TinyY288.index()] > 0.45,
        "MOT17-05 should be tiny-288-dominant: {freq:?}"
    );
    assert!(
        freq[DnnKind::TinyY288.index()] + freq[DnnKind::TinyY416.index()]
            > 0.8,
        "MOT17-05 should be tiny-dominant overall: {freq:?}"
    );
}

#[test]
fn custom_thresholds_change_deployment() {
    // pushing h3 up starves tiny-288 (sanity of the knob the search turns)
    let seq = generate(SequenceId::Mot05);
    let run = |th: Thresholds| {
        let mut pol = MbbsPolicy::new(th);
        let mut lat = LatencyModel::deterministic();
        run_realtime(&seq, &mut pol, &mut oracle_for(&seq), &mut lat, 14.0)
            .deploy_freq()
    };
    let low = run(Thresholds::new(vec![0.007, 0.03, 0.04]).unwrap());
    let high = run(Thresholds::new(vec![0.007, 0.03, 0.4]).unwrap());
    assert!(low[0] > high[0] + 0.3, "low h3 {low:?} vs high h3 {high:?}");
}

#[test]
fn latency_jitter_does_not_flip_conclusions() {
    // run TOD with jittered latencies; the MOT17-05 structure holds
    let seq = generate(SequenceId::Mot05);
    let mut det = oracle_for(&seq);
    let mut pol = MbbsPolicy::tod_default();
    let mut lat = LatencyModel::jetson_nano(123);
    let r = run_realtime(&seq, &mut pol, &mut det, &mut lat, 14.0);
    let freq = r.deploy_freq();
    assert!(freq[0] + freq[1] > 0.7, "tiny-dominant under jitter: {freq:?}");
    assert!(r.ap > 0.5);
}
