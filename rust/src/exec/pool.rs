//! Fixed-size worker thread pool over the bounded channel.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::exec::channel::{bounded, Sender};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Error returned by [`ThreadPool::submit`] when the job queue is closed
/// (every worker has exited, e.g. after panicking).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SubmitError;

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("thread pool queue closed: all workers have exited")
    }
}

impl std::error::Error for SubmitError {}

/// A fixed pool of worker threads executing submitted closures.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    in_flight: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `n` workers with a task queue of `queue_cap` (backpressure:
    /// `submit` blocks when the queue is full).
    ///
    /// Panics when the OS refuses to spawn a thread — a construction-
    /// time resource failure, not a serving-path state (the pool is
    /// built once at server start-up, before any request exists).
    #[allow(clippy::expect_used)]
    pub fn new(n: usize, queue_cap: usize) -> Self {
        assert!(n > 0);
        let (tx, rx) = bounded::<Job>(queue_cap.max(1));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|i| {
                let rx = rx.clone();
                let in_flight = in_flight.clone();
                std::thread::Builder::new()
                    .name(format!("tod-worker-{i}"))
                    .spawn(move || {
                        while let Some(job) = rx.recv() {
                            // decrement on drop so a panicking job still
                            // releases its in-flight slot (wait_idle must
                            // not hang on poisoned work)
                            struct Slot<'a>(&'a AtomicUsize);
                            impl Drop for Slot<'_> {
                                fn drop(&mut self) {
                                    self.0.fetch_sub(1, Ordering::SeqCst);
                                }
                            }
                            let _slot = Slot(&in_flight);
                            job();
                        }
                    })
                    // tod-lint: allow(srv-expect) reason="construction-time OS spawn failure, before any request exists"
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, in_flight }
    }

    /// Submit a job; blocks when the queue is full. Fails when every
    /// worker has exited (the queue has no receivers left), in which
    /// case the in-flight count is rolled back so `wait_idle` callers
    /// don't hang on a job that never ran.
    pub fn submit<F: FnOnce() + Send + 'static>(
        &self,
        f: F,
    ) -> Result<(), SubmitError> {
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        // tx is None only after Drop runs, so this arm is unreachable
        // from safe code — but a closed pool is exactly what
        // SubmitError describes, so report it instead of panicking
        let Some(tx) = self.tx.as_ref() else {
            self.in_flight.fetch_sub(1, Ordering::SeqCst);
            return Err(SubmitError);
        };
        match tx.send(Box::new(f)) {
            Ok(()) => Ok(()),
            Err(_) => {
                self.in_flight.fetch_sub(1, Ordering::SeqCst);
                Err(SubmitError)
            }
        }
    }

    /// Jobs submitted but not yet finished.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// Busy-wait (with yield) until all submitted jobs finished. Returns
    /// early if every worker has died (panicked jobs): work still queued
    /// at that point will never run, so waiting on it would spin forever.
    pub fn wait_idle(&self) {
        while self.in_flight() > 0 {
            if self.workers.iter().all(|w| w.is_finished()) {
                return;
            }
            std::thread::yield_now();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the queue
        for w in self.workers.drain(..) {
            w.join().ok();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4, 16);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            })
            .unwrap();
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn drop_joins_workers() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(2, 4);
            for _ in 0..10 {
                let c = counter.clone();
                pool.submit(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                })
                .unwrap();
            }
        } // drop waits for queue drain
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn parallelism_actually_happens() {
        let pool = ThreadPool::new(4, 8);
        let t0 = std::time::Instant::now();
        for _ in 0..8 {
            pool.submit(|| {
                std::thread::sleep(std::time::Duration::from_millis(25))
            })
            .unwrap();
        }
        pool.wait_idle();
        let elapsed = t0.elapsed();
        // serial would be 200 ms; 4 workers should finish in ~50 ms
        assert!(elapsed.as_millis() < 150, "elapsed {elapsed:?}");
    }

    #[test]
    fn failed_send_rolls_back_in_flight() {
        use std::time::{Duration, Instant};
        // a panicking job kills the sole worker; its receiver handle
        // drops, so later sends must fail instead of queueing forever
        let pool = ThreadPool::new(1, 4);
        pool.submit(|| panic!("worker down (expected in this test)"))
            .unwrap();
        // poll until the dead worker's receiver is gone; sends that race
        // the shutdown may still be accepted (and will never run)
        let t0 = Instant::now();
        let mut raced = 0usize;
        loop {
            match pool.submit(|| {}) {
                Err(SubmitError) => break,
                Ok(()) => {
                    raced += 1;
                    assert!(
                        t0.elapsed() < Duration::from_secs(5),
                        "submit kept succeeding after worker death"
                    );
                    std::thread::sleep(Duration::from_millis(2));
                }
            }
        }
        // the panicked job released its slot (guard) and every *failed*
        // submit rolled back its increment: only raced sends remain
        assert_eq!(pool.in_flight(), raced);
        assert_eq!(pool.submit(|| {}), Err(SubmitError));
        assert_eq!(pool.in_flight(), raced);
        // raced jobs will never run, but wait_idle must not hang on
        // them: it detects the dead pool and returns
        pool.wait_idle();
    }
}
