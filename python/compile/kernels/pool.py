"""L1 Pallas kernel: 2x2/stride-2 max-pool over NHWC feature maps.

YOLOv4-tiny downsamples with max-pool between conv stages; this kernel
tiles the feature map over (rows, channel) blocks so each grid step holds
one input row-pair strip in VMEM and emits one output row strip.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _maxpool_kernel(x_ref, o_ref):
    # x_ref: (1, 2*bh, W, bc) input strip; o_ref: (1, bh, W//2, bc).
    x = x_ref[...]
    _, h2, w, c = x.shape
    x = x.reshape(1, h2 // 2, 2, w // 2, 2, c)
    o_ref[...] = jnp.max(x, axis=(2, 4))


@functools.partial(jax.jit, static_argnames=("bh", "bc", "interpret"))
def maxpool2x2(
    x: jax.Array,
    *,
    bh: int = 8,
    bc: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """2x2 stride-2 max pool on an NHWC tensor via Pallas.

    H and W must be even (the detector keeps all spatial dims powers of
    two times the stem size, so this always holds in-model).
    """
    n, h, w, c = x.shape
    if h % 2 or w % 2:
        raise ValueError(f"maxpool2x2 needs even H, W; got {x.shape}")
    oh, ow = h // 2, w // 2
    bh_ = min(bh, oh)
    while oh % bh_:
        bh_ -= 1
    bc_ = min(bc, c)
    while c % bc_:
        bc_ -= 1
    grid = (n, oh // bh_, c // bc_)
    return pl.pallas_call(
        _maxpool_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 2 * bh_, w, bc_), lambda i, j, k: (i, j, 0, k)),
        ],
        out_specs=pl.BlockSpec((1, bh_, ow, bc_), lambda i, j, k: (i, j, 0, k)),
        out_shape=jax.ShapeDtypeStruct((n, oh, ow, c), x.dtype),
        interpret=interpret,
    )(x)
