//! The Discussion-section use case: detecting cars on a highway.
//!
//! "If a user is interested in detecting cars on a highway, the
//! hyperparameter search will return the most suitable model ... a
//! greater deployment frequency of DNN usage can be assigned to
//! YOLO-tiny DNNs since cars move faster than pedestrians." (§V)
//!
//! This example builds highway-like sequences (fast lateral flow,
//! mid-size boxes), re-runs the hyperparameter search, and shows the
//! returned H_opt shifting deployment towards the tiny variants
//! compared to the pedestrian H_opt.
//!
//! ```bash
//! cargo run --release --example highway
//! ```

use tod::coordinator::policy::MbbsPolicy;
use tod::coordinator::scheduler::{run_realtime, OracleBackend};
use tod::coordinator::search::{grid_search_oracle, SearchSpace};
use tod::dataset::synth::{CameraMotion, Sequence, SequenceSpec};
use tod::sim::latency::LatencyModel;
use tod::sim::oracle::OracleDetector;

fn highway_seq(seed: u64, flow: f64, ref_height: f64) -> Sequence {
    Sequence::generate(SequenceSpec {
        name: format!("HIGHWAY-{seed:02}"),
        width: 1920,
        height: 1080,
        fps: 30.0,
        frames: 600,
        density: 10,
        ref_height,
        depth_range: (1.2, 3.0),
        // cars: much faster world speed than pedestrians
        walk_speed: 8.0,
        camera: CameraMotion::Vehicle { flow_speed: flow },
        seed,
    })
}

fn main() {
    // three highway conditions: overtaking traffic, dense flow, far lane
    let seqs = vec![
        highway_seq(1, 26.0, 620.0),
        highway_seq(2, 34.0, 540.0),
        highway_seq(3, 20.0, 700.0),
    ];
    let train: Vec<(&_, f64)> = seqs.iter().map(|s| (s, 30.0)).collect();

    // a wider grid than the paper's 2x2x2: the highway regime benefits
    // from lower h3 (more tiny-288), so offer the search smaller values
    let space = SearchSpace {
        h1: vec![0.0007, 0.007],
        h2: vec![0.008, 0.03],
        h3: vec![0.035, 0.04, 0.1],
    };
    let result = grid_search_oracle(&space, &train);
    let hv = result.best_thresholds().values().to_vec();
    println!(
        "highway H_opt = {{{}, {}, {}}} (pedestrian H_opt = {{0.007, 0.03, \
         0.04}})",
        hv[0], hv[1], hv[2]
    );

    // deployment comparison: highway H_opt vs pedestrian H_opt
    for (label, th) in [
        ("pedestrian H_opt", tod::coordinator::policy::Thresholds::h_opt()),
        ("highway    H_opt", result.best_thresholds().clone()),
    ] {
        let mut tiny_share = 0.0;
        let mut mean_ap = 0.0;
        for seq in &seqs {
            let mut det = OracleBackend(OracleDetector::new(
                seq.spec.seed,
                1920.0,
                1080.0,
            ));
            let mut pol = MbbsPolicy::new(th.clone());
            let mut lat = LatencyModel::deterministic();
            let r = run_realtime(seq, &mut pol, &mut det, &mut lat, 30.0);
            let f = r.deploy_freq();
            tiny_share += (f[0] + f[1]) / seqs.len() as f64;
            mean_ap += r.ap / seqs.len() as f64;
        }
        println!(
            "  {label}: mean AP {mean_ap:.3}, tiny-DNN share {:.1}%",
            tiny_share * 100.0
        );
    }
}
