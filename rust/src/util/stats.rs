//! Streaming and batch statistics used across the evaluator, telemetry
//! simulator and bench harness.

/// Streaming mean/variance via Welford's algorithm, plus min/max.
#[derive(Debug, Clone)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

/// `Default` must be the same empty state as [`Welford::new`] — a
/// derived all-zeros default would corrupt `min` for every later push.
impl Default for Welford {
    fn default() -> Self {
        Welford::new()
    }
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.m2 / self.n as f64 }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Smallest sample, or NaN before any sample arrives (the raw
    /// ±INFINITY sentinels must never leak into reports).
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.min
        }
    }

    /// Largest sample, or NaN before any sample arrives.
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.max
        }
    }

    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2
            + other.m2
            + d * d * self.n as f64 * other.n as f64 / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Exact median by partial sort under the IEEE total order (NaN-safe:
/// NaN samples sort above +inf instead of panicking the comparator).
///
/// Edge cases are defined, not inherited from `select_nth_unstable_by`
/// preconditions: empty input returns NaN, a single element returns
/// that element for any value (including NaN).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    median_mut(&mut v)
}

/// In-place form of [`median`] for hot paths that own a reusable scratch
/// buffer: same selection arithmetic, no clone. The slice is partially
/// reordered.
pub fn median_mut(v: &mut [f64]) -> f64 {
    if v.is_empty() {
        return f64::NAN;
    }
    if v.len() == 1 {
        return v[0];
    }
    let mid = v.len() / 2;
    let (_, m, _) = v.select_nth_unstable_by(mid, |a, b| a.total_cmp(b));
    let hi = *m;
    if v.len() % 2 == 1 {
        hi
    } else {
        // lower neighbour = max of the left partition
        let lo = v[..mid]
            .iter()
            .copied()
            .fold(f64::NEG_INFINITY, f64::max);
        (lo + hi) / 2.0
    }
}

/// Linear-interpolated percentile (p in [0, 100]) of unsorted data.
///
/// Defined edge cases: empty input or NaN `p` return NaN; a single
/// element is returned unchanged for every `p`; NaN samples sort above
/// +inf (IEEE total order) instead of panicking.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    percentile_sorted(&v, p)
}

/// Percentile of already-sorted data (same edge cases as
/// [`percentile`]).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() || p.is_nan() {
        return f64::NAN;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let p = p.clamp(0.0, 100.0);
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let f = rank - lo as f64;
        sorted[lo] * (1.0 - f) + sorted[hi] * f
    }
}

/// Fixed-bin histogram over `[lo, hi)`; out-of-range samples clamp to the
/// edge bins (telemetry traces occasionally overshoot their design range).
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    count: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins], count: 0 }
    }

    pub fn push(&mut self, x: f64) {
        let n = self.bins.len();
        let idx = ((x - self.lo) / (self.hi - self.lo) * n as f64)
            .floor()
            .clamp(0.0, (n - 1) as f64) as usize;
        self.bins[idx] += 1;
        self.count += 1;
    }

    pub fn bins(&self) -> &[u64] {
        &self.bins
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Fraction of mass in bin `i`.
    pub fn frac(&self, i: usize) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.bins[i] as f64 / self.count as f64
        }
    }

    pub fn bin_center(&self, i: usize) -> f64 {
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        self.lo + w * (i as f64 + 0.5)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 3.5, -1.0, 10.0, 4.25];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var =
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
        assert_eq!(w.min(), -1.0);
        assert_eq!(w.max(), 10.0);
        assert_eq!(w.count(), 6);
    }

    #[test]
    fn welford_empty_state_is_all_nan() {
        let w = Welford::new();
        assert_eq!(w.count(), 0);
        assert!(w.mean().is_nan());
        assert!(w.variance().is_nan());
        assert!(w.std().is_nan());
        assert!(w.min().is_nan(), "empty min must not report +INFINITY");
        assert!(w.max().is_nan(), "empty max must not report -INFINITY");
        // the Default impl is the same empty state
        let d = Welford::default();
        assert!(d.min().is_nan() && d.max().is_nan());
    }

    #[test]
    fn welford_single_sample_pins_min_max() {
        let mut w = Welford::new();
        w.push(3.25);
        assert_eq!(w.min(), 3.25);
        assert_eq!(w.max(), 3.25);
        assert_eq!(w.mean(), 3.25);
        assert_eq!(w.variance(), 0.0);
    }

    #[test]
    fn welford_merge_with_empty_keeps_guards() {
        let mut a = Welford::new();
        let b = Welford::new();
        a.merge(&b);
        assert!(a.min().is_nan() && a.max().is_nan());
        a.push(1.0);
        a.merge(&Welford::new());
        assert_eq!(a.min(), 1.0);
        assert_eq!(a.max(), 1.0);
    }

    #[test]
    fn welford_merge_equals_concat() {
        let a = [1.0, 2.0, 3.0];
        let b = [10.0, 20.0];
        let mut wa = Welford::new();
        let mut wb = Welford::new();
        let mut wall = Welford::new();
        for &x in &a {
            wa.push(x);
            wall.push(x);
        }
        for &x in &b {
            wb.push(x);
            wall.push(x);
        }
        wa.merge(&wb);
        assert!((wa.mean() - wall.mean()).abs() < 1e-12);
        assert!((wa.variance() - wall.variance()).abs() < 1e-12);
    }

    #[test]
    fn median_odd_even_empty() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(median(&[5.0]), 5.0);
        assert!(median(&[]).is_nan());
    }

    #[test]
    fn median_mut_matches_median() {
        for xs in [
            vec![],
            vec![5.0],
            vec![3.0, 1.0, 2.0],
            vec![4.0, 1.0, 2.0, 3.0],
            vec![f64::NAN, 1.0, 2.0],
            vec![f64::INFINITY, f64::NEG_INFINITY, 0.0, 1.0],
        ] {
            let by_ref = median(&xs);
            let mut scratch = xs.clone();
            let in_place = median_mut(&mut scratch);
            assert!(
                by_ref.to_bits() == in_place.to_bits(),
                "{xs:?}: {by_ref} vs {in_place}"
            );
        }
    }

    #[test]
    fn median_is_robust_to_outlier() {
        // the paper's reason for MBBS over mean: a full-frame false
        // positive must not drag the statistic
        let normal = [0.01, 0.012, 0.009, 0.011];
        let with_fp = [0.01, 0.012, 0.009, 0.011, 1.0];
        assert!((median(&with_fp) - median(&normal)).abs() < 0.002);
    }

    #[test]
    fn percentile_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
        assert!(percentile(&[], 50.0).is_nan());
    }

    #[test]
    fn percentile_and_median_single_element() {
        // defined behaviour, not a select_nth precondition accident
        for p in [0.0, 13.7, 50.0, 100.0, -5.0, 250.0] {
            assert_eq!(percentile(&[7.25], p), 7.25);
            assert_eq!(percentile_sorted(&[7.25], p), 7.25);
        }
        assert_eq!(median(&[7.25]), 7.25);
        assert!(median(&[f64::NAN]).is_nan());
    }

    #[test]
    fn percentile_clamps_out_of_range_p_and_rejects_nan_p() {
        let xs = [1.0, 2.0, 3.0];
        assert_eq!(percentile(&xs, -10.0), 1.0);
        assert_eq!(percentile(&xs, 1000.0), 3.0);
        assert!(percentile(&xs, f64::NAN).is_nan());
        assert!(percentile_sorted(&xs, f64::NAN).is_nan());
    }

    #[test]
    fn nan_samples_do_not_panic_order_statistics() {
        // NaN sorts above +inf under total_cmp: the order statistics
        // stay deterministic and the process stays alive
        let xs = [1.0, f64::NAN, 3.0, 2.0];
        let p50 = percentile(&xs, 50.0);
        assert_eq!(p50, 2.5); // sorted: [1, 2, 3, NaN]
        assert_eq!(percentile(&xs, 0.0), 1.0);
        let m = median(&xs); // mid pair (2, 3) -> 2.5
        assert_eq!(m, 2.5);
    }

    #[test]
    fn histogram_bins_and_clamp() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.push(0.5);
        h.push(9.9);
        h.push(-5.0); // clamps to bin 0
        h.push(50.0); // clamps to bin 9
        assert_eq!(h.bins()[0], 2);
        assert_eq!(h.bins()[9], 2);
        assert_eq!(h.count(), 4);
        assert!((h.frac(0) - 0.5).abs() < 1e-12);
        assert!((h.bin_center(0) - 0.5).abs() < 1e-12);
    }
}
