//! Aggregate accelerator-utilisation summary over many stream traces.
//!
//! The multi-stream scheduler produces one [`ScheduleTrace`] per stream,
//! all sharing a single virtual accelerator. This module merges them
//! into one timeline for the tegrastats sampler and reports the figures
//! an operator watches when packing streams onto one edge board: busy
//! seconds (total and per DNN), makespan, utilisation and inference
//! throughput. [`UtilisationSummary::overlap_seconds`] doubles as the
//! correctness probe that the scheduler really serialised the device —
//! it must be ~0 on any valid schedule.

use crate::telemetry::tegrastats::ScheduleTrace;
use crate::DnnKind;

/// Aggregate view of N per-stream schedules sharing one accelerator.
#[derive(Debug, Clone)]
pub struct UtilisationSummary {
    /// Number of stream traces merged.
    pub n_streams: usize,
    /// End of the latest stream (max trace duration), seconds.
    pub makespan: f64,
    /// Total accelerator-busy seconds across all streams.
    pub busy: f64,
    /// Busy seconds split by DNN variant.
    pub busy_per_dnn: [f64; DnnKind::COUNT],
    /// Total inferences across all streams.
    pub inferences: u64,
    /// Busy seconds spent on inferences whose backend *failed* — the
    /// accelerator was held but no fresh detections came back. A subset
    /// of [`busy`](Self::busy); traces alone can't distinguish it, so
    /// drivers fold it in via [`with_failed_busy`](Self::with_failed_busy)
    /// from per-stream [`crate::coordinator::RunResult::failed_busy_s`].
    pub busy_failed: f64,
    /// All busy intervals on one timeline, sorted by start — feed this
    /// to [`crate::telemetry::TegrastatsSim`] for multi-stream power /
    /// GPU figures.
    pub merged: ScheduleTrace,
}

impl UtilisationSummary {
    /// Merge per-stream traces into the aggregate summary.
    pub fn from_traces(traces: &[&ScheduleTrace]) -> Self {
        let mut merged = ScheduleTrace::default();
        let mut busy = 0.0;
        let mut busy_per_dnn = [0.0f64; DnnKind::COUNT];
        let mut inferences = 0u64;
        let mut makespan = 0.0f64;
        for t in traces {
            makespan = makespan.max(t.duration);
            for &(s, e, d) in &t.busy {
                merged.busy.push((s, e, d));
                busy += e - s;
                busy_per_dnn[d.index()] += e - s;
                inferences += 1;
            }
        }
        merged
            .busy
            .sort_by(|a, b| {
                a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1))
            });
        merged.duration = makespan;
        UtilisationSummary {
            n_streams: traces.len(),
            makespan,
            busy,
            busy_per_dnn,
            inferences,
            busy_failed: 0.0,
            merged,
        }
    }

    /// Attribute `seconds` of the busy time to failed inferences.
    pub fn with_failed_busy(mut self, seconds: f64) -> Self {
        self.busy_failed = seconds;
        self
    }

    /// Busy fraction of the accelerator over the makespan.
    pub fn utilisation(&self) -> f64 {
        if self.makespan <= 0.0 {
            0.0
        } else {
            self.busy / self.makespan
        }
    }

    /// Inferences per virtual second (aggregate throughput).
    pub fn throughput_ips(&self) -> f64 {
        if self.makespan <= 0.0 {
            0.0
        } else {
            self.inferences as f64 / self.makespan
        }
    }

    /// Total seconds during which two merged busy intervals overlap.
    /// A scheduler that serialises the shared accelerator yields ~0.0;
    /// anything materially positive means double-booked hardware.
    pub fn overlap_seconds(&self) -> f64 {
        let mut overlap = 0.0;
        let mut busiest_end = f64::NEG_INFINITY;
        for &(s, e, _) in &self.merged.busy {
            if s < busiest_end {
                overlap += busiest_end.min(e) - s;
            }
            busiest_end = busiest_end.max(e);
        }
        overlap
    }

    /// One-paragraph human-readable report.
    pub fn report(&self) -> String {
        let per: Vec<String> = DnnKind::ALL
            .iter()
            .map(|d| {
                format!(
                    "{} {:.1}s",
                    d.short_label(),
                    self.busy_per_dnn[d.index()]
                )
            })
            .collect();
        let failed = if self.busy_failed > 0.0 {
            format!(" | failed busy {:.1}s", self.busy_failed)
        } else {
            String::new()
        };
        format!(
            "{} streams | makespan {:.1}s | busy {:.1}s ({:.1}% util) | \
             {} inferences ({:.1}/s) | per-DNN: {}{}",
            self.n_streams,
            self.makespan,
            self.busy,
            self.utilisation() * 100.0,
            self.inferences,
            self.throughput_ips(),
            per.join(" "),
            failed
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(intervals: &[(f64, f64, DnnKind)], duration: f64) -> ScheduleTrace {
        let mut t = ScheduleTrace::default();
        for &(s, e, d) in intervals {
            t.push(s, e, d);
        }
        t.duration = t.duration.max(duration);
        t
    }

    #[test]
    fn merges_and_sorts_intervals() {
        let a = trace(&[(0.0, 0.1, DnnKind::Y416)], 2.0);
        let b = trace(&[(0.1, 0.15, DnnKind::TinyY288)], 3.0);
        let s = UtilisationSummary::from_traces(&[&a, &b]);
        assert_eq!(s.n_streams, 2);
        assert_eq!(s.inferences, 2);
        assert!((s.makespan - 3.0).abs() < 1e-12);
        assert!((s.busy - 0.15).abs() < 1e-12);
        assert!((s.busy_per_dnn[DnnKind::Y416.index()] - 0.1).abs() < 1e-12);
        assert!(s.merged.busy.windows(2).all(|w| w[0].0 <= w[1].0));
        assert!(s.overlap_seconds() < 1e-12);
        assert!((s.utilisation() - 0.05).abs() < 1e-12);
    }

    #[test]
    fn overlap_detected() {
        let a = trace(&[(0.0, 1.0, DnnKind::Y416)], 1.0);
        let b = trace(&[(0.5, 1.5, DnnKind::Y288)], 1.5);
        let s = UtilisationSummary::from_traces(&[&a, &b]);
        assert!((s.overlap_seconds() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn overlap_handles_contained_intervals() {
        // one long interval fully containing a short one
        let a = trace(&[(0.0, 2.0, DnnKind::Y416)], 2.0);
        let b = trace(&[(0.5, 1.0, DnnKind::Y288)], 2.0);
        let s = UtilisationSummary::from_traces(&[&a, &b]);
        assert!((s.overlap_seconds() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn failed_busy_is_surfaced_only_when_present() {
        let a = trace(&[(0.0, 0.1, DnnKind::Y416)], 2.0);
        let clean = UtilisationSummary::from_traces(&[&a]);
        assert_eq!(clean.busy_failed, 0.0);
        assert!(!clean.report().contains("failed busy"));

        let failing = UtilisationSummary::from_traces(&[&a])
            .with_failed_busy(0.05);
        assert!((failing.busy_failed - 0.05).abs() < 1e-12);
        assert!(failing.report().contains("failed busy 0.1s"));
        // the rest of the line is unchanged
        assert!(failing.report().starts_with(&clean.report()));
    }

    #[test]
    fn empty_traces_are_benign() {
        let s = UtilisationSummary::from_traces(&[]);
        assert_eq!(s.n_streams, 0);
        assert_eq!(s.utilisation(), 0.0);
        assert_eq!(s.throughput_ips(), 0.0);
        assert_eq!(s.overlap_seconds(), 0.0);
        assert!(!s.report().is_empty());
    }
}
