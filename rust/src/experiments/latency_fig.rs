//! Fig. 5: per-DNN inference latency (calibrated model + real PJRT when
//! artifacts are present).

use crate::sim::latency::LatencyModel;
use crate::util::csv::CsvTable;
use crate::util::table::AsciiTable;
use crate::DnnKind;

use super::ExperimentOutput;

pub fn fig5_latency() -> ExperimentOutput {
    let model = LatencyModel::deterministic();
    let mut table = AsciiTable::new(
        "Fig. 5 — Inference Latency (Jetson-Nano-calibrated model)",
        vec!["dnn", "latency_ms", "meets 30fps", "meets 14fps"],
    );
    let mut csv = CsvTable::new(vec![
        "dnn",
        "latency_ms",
        "meets_30fps",
        "meets_14fps",
    ]);
    for k in DnnKind::ALL {
        let ms = model.mean(k) * 1e3;
        let row = vec![
            k.artifact_name().to_string(),
            format!("{ms:.1}"),
            format!("{}", model.meets_realtime(k, 30.0)),
            format!("{}", model.meets_realtime(k, 14.0)),
        ];
        table.push(row.clone());
        csv.push(row);
    }
    let text = format!(
        "{}\n30 FPS budget = 33.3 ms: only yolov4-tiny-288 fits (paper Fig. 5).\n\
         Real CPU-PJRT latencies: run `cargo bench --bench runtime_infer`\n\
         or `tod serve` (requires `make artifacts`).\n",
        table.render()
    );
    ExperimentOutput {
        id: "fig5",
        title: "Fig. 5: inference latency".into(),
        text,
        csv: vec![("fig5_latency.csv".into(), csv)],
    }
}
