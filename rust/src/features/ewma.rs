//! Exponentially-weighted moving average for stream-feature smoothing.
//!
//! Per-frame speed samples are noisy: the oracle's localisation jitter
//! moves detection centroids by a few pixels even in a static scene, and
//! drop-frame schedules space samples unevenly. The selection policy
//! should respond to the *regime* (walking camera vs static camera), not
//! to single-frame noise, so the extractor smooths with an EWMA whose
//! alpha is configurable per deployment.

/// EWMA accumulator: `v <- alpha * x + (1 - alpha) * v`.
///
/// The first observation seeds the average directly (no bias towards an
/// arbitrary zero start), matching the common "EWMA with warm start"
/// formulation.
#[derive(Debug, Clone)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    /// `alpha` in (0, 1]: 1.0 = no smoothing (track the latest sample).
    pub fn new(alpha: f64) -> Self {
        assert!(
            alpha > 0.0 && alpha <= 1.0,
            "EWMA alpha must be in (0, 1], got {alpha}"
        );
        Ewma { alpha, value: None }
    }

    /// Fold one sample in and return the updated average.
    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => self.alpha * x + (1.0 - self.alpha) * v,
        };
        self.value = Some(v);
        v
    }

    /// Current average; 0.0 before the first sample (the same neutral
    /// start as MBBS on an empty frame).
    pub fn value(&self) -> f64 {
        self.value.unwrap_or(0.0)
    }

    /// True once at least one sample has been folded in.
    pub fn is_warm(&self) -> bool {
        self.value.is_some()
    }

    /// Forget all history (stream restart).
    pub fn reset(&mut self) {
        self.value = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_sample_seeds_directly() {
        let mut e = Ewma::new(0.1);
        assert_eq!(e.value(), 0.0);
        assert!(!e.is_warm());
        assert_eq!(e.update(5.0), 5.0);
        assert!(e.is_warm());
    }

    #[test]
    fn converges_to_constant_input() {
        let mut e = Ewma::new(0.3);
        e.update(0.0);
        for _ in 0..100 {
            e.update(8.0);
        }
        assert!((e.value() - 8.0).abs() < 1e-9);
    }

    #[test]
    fn alpha_one_tracks_latest() {
        let mut e = Ewma::new(1.0);
        e.update(3.0);
        assert_eq!(e.update(7.0), 7.0);
    }

    #[test]
    fn smoothing_damps_spikes() {
        let mut e = Ewma::new(0.2);
        e.update(1.0);
        let after_spike = e.update(100.0);
        // one spike moves the average only alpha of the way
        assert!((after_spike - (0.2 * 100.0 + 0.8)).abs() < 1e-12);
    }

    #[test]
    fn reset_forgets() {
        let mut e = Ewma::new(0.5);
        e.update(4.0);
        e.reset();
        assert_eq!(e.value(), 0.0);
        assert_eq!(e.update(2.0), 2.0);
    }

    #[test]
    #[should_panic(expected = "alpha must be in")]
    fn zero_alpha_rejected() {
        Ewma::new(0.0);
    }
}
