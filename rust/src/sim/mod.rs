//! Behavioural models of the paper's testbed: DNN capacity profiles, the
//! oracle detector that stands in for trained COCO weights, and the
//! Jetson-Nano latency model (see DESIGN.md §3).

pub mod latency;
pub mod oracle;
pub mod profiles;

pub use latency::{ContentionModel, LatencyModel};
pub use oracle::OracleDetector;
pub use profiles::DnnProfile;
