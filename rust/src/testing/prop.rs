//! Seeded generator-based property testing: run a property over many
//! random inputs, report the seed of the first failure so it replays.
//!
//! Usage (`no_run`: doctest binaries don't inherit the xla rpath):
//! ```no_run
//! use tod::testing::prop::{Gen, PropConfig};
//! PropConfig::default().run("mbbs in [0,1]", |g| {
//!     let n = g.usize_in(0, 50);
//!     let v: Vec<f64> = (0..n).map(|_| g.f64_in(0.0, 1.0)).collect();
//!     v.iter().all(|x| (0.0..=1.0).contains(x))
//! });
//! ```

use crate::util::rng::Rng;

/// Input generator handed to each property invocation.
pub struct Gen {
    rng: Rng,
    /// Log of drawn values (printed on failure for reproduction).
    log: Vec<String>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed), log: Vec::new() }
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let v = self.rng.uniform(lo, hi);
        self.log.push(format!("f64_in({lo},{hi})={v}"));
        v
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        let v = lo + self.rng.below(hi - lo + 1);
        self.log.push(format!("usize_in({lo},{hi})={v}"));
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.chance(0.5);
        self.log.push(format!("bool={v}"));
        v
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.below(xs.len());
        self.log.push(format!("choice[{i}]"));
        &xs[i]
    }

    /// Normal draw (for noise-like inputs).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let v = self.rng.normal(mean, std);
        self.log.push(format!("normal({mean},{std})={v}"));
        v
    }
}

/// Property-run configuration.
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        // TOD_PROP_SEED replays a failing case; TOD_PROP_CASES scales CI
        let seed = std::env::var("TOD_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xdecaf);
        let cases = std::env::var("TOD_PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(128);
        PropConfig { cases, seed }
    }
}

impl PropConfig {
    pub fn with_cases(cases: usize) -> Self {
        PropConfig { cases, ..Default::default() }
    }

    /// Run `property` over `cases` random inputs; panics (with the seed
    /// and the drawn-value log) on the first failure.
    pub fn run<F: FnMut(&mut Gen) -> bool>(&self, name: &str, mut property: F) {
        for case in 0..self.cases {
            let case_seed = self
                .seed
                .wrapping_add((case as u64).wrapping_mul(0x9e3779b97f4a7c15));
            let mut g = Gen::new(case_seed);
            let ok = property(&mut g);
            if !ok {
                panic!(
                    "property {name:?} failed on case {case} \
                     (TOD_PROP_SEED={case_seed});\n  draws: {}",
                    g.log.join(", ")
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        PropConfig::with_cases(50).run("tautology", |g| {
            n += 1;
            let v = g.f64_in(0.0, 1.0);
            (0.0..1.0).contains(&v)
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property \"falsum\" failed")]
    fn failing_property_panics_with_seed() {
        PropConfig::with_cases(10).run("falsum", |g| g.f64_in(0.0, 1.0) < -1.0);
    }

    #[test]
    fn generator_ranges() {
        let mut g = Gen::new(1);
        for _ in 0..1000 {
            let u = g.usize_in(3, 7);
            assert!((3..=7).contains(&u));
            let f = g.f64_in(-2.0, 2.0);
            assert!((-2.0..2.0).contains(&f));
        }
        let xs = [1, 2, 3];
        for _ in 0..10 {
            assert!(xs.contains(g.choice(&xs)));
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = Gen::new(9);
        let mut b = Gen::new(9);
        for _ in 0..100 {
            assert_eq!(a.f64_in(0.0, 1.0), b.f64_in(0.0, 1.0));
        }
    }
}
