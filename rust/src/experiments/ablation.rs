//! Ablations of TOD's design choices (DESIGN.md §8 calls these out):
//!
//! * **median vs mean** bounding-box statistic — the paper argues the
//!   median resists full-frame false positives (§III.B.3);
//! * **threshold sensitivity** — how mean AP moves as each `h_i` is
//!   perturbed around H_opt (the robustness the grid search relies on);
//! * **proactive vs periodic** — TOD against the Chameleon-lite
//!   re-profiler at several window sizes (§II/§V comparison).

use crate::coordinator::baselines::{run_chameleon_lite, ChameleonConfig};
use crate::coordinator::policy::{
    MbbsPolicy, SelectionPolicy, Thresholds,
};
use crate::coordinator::scheduler::{run_realtime, OracleBackend};
use crate::dataset::catalog::{generate, SequenceId};
use crate::detection::Detection;
use crate::features::FrameFeatures;
use crate::sim::latency::LatencyModel;
use crate::sim::oracle::OracleDetector;
use crate::util::csv::CsvTable;
use crate::util::table::AsciiTable;

use super::ExperimentOutput;

/// A policy variant that drives Algorithm 1 with the *mean* box size —
/// the statistic the paper rejected.
#[derive(Debug, Clone)]
pub struct MeanBbsPolicy(pub MbbsPolicy);

/// Mean box-size fraction (the rejected statistic).
pub fn mean_bbs(dets: &[Detection], fw: f64, fh: f64) -> f64 {
    if dets.is_empty() {
        return 0.0;
    }
    dets.iter().map(|d| d.bbox.area_frac(fw, fh)).sum::<f64>()
        / dets.len() as f64
}

impl SelectionPolicy for MeanBbsPolicy {
    fn select(&mut self, features: &FrameFeatures) -> crate::DnnKind {
        // the ablation loop below builds the feature vector with the
        // *mean* statistic in the size channel instead of the median
        self.0.select_pure(features.mbbs)
    }

    fn label(&self) -> String {
        format!("mean-{}", self.0.label())
    }
}

fn oracle_for(seq: &crate::dataset::synth::Sequence) -> OracleBackend {
    OracleBackend(OracleDetector::new(
        seq.spec.seed,
        seq.spec.width as f64,
        seq.spec.height as f64,
    ))
}

/// Median-vs-mean ablation: rerun the campaign with the mean statistic
/// by injecting synthetic full-frame false positives at a low rate —
/// the scenario the paper cites ("sometimes, entire frames were detected
/// as false positives").
fn median_vs_mean() -> (AsciiTable, CsvTable) {
    use crate::detection::{mbbs, FrameDetections, PERSON_CLASS};
    use crate::eval::ap::{ApMethod, SequenceEval};
    use crate::eval::matching::{match_frame, IOU_THRESHOLD};
    use crate::geometry::BBox;
    use crate::video::dropframe::{DropFrameAccounting, FrameOutcome};

    let mut table = AsciiTable::new(
        "Ablation A1 — median (paper) vs mean box statistic, with \
         full-frame FP bursts",
        vec!["sequence", "AP(median)", "AP(mean)"],
    );
    let mut csv =
        CsvTable::new(vec!["sequence", "ap_median", "ap_mean"]);
    for id in [SequenceId::Mot05, SequenceId::Mot09, SequenceId::Mot11] {
        let seq = generate(id);
        let (fw, fh) = (seq.spec.width as f64, seq.spec.height as f64);
        let mut aps = Vec::new();
        for use_median in [true, false] {
            let mut det = oracle_for(&seq);
            let mut policy = MbbsPolicy::tod_default();
            let mut lat = LatencyModel::deterministic();
            let mut acc = DropFrameAccounting::new(id.eval_fps());
            let mut eval = SequenceEval::new();
            let mut carried: Vec<Detection> = Vec::new();
            let mut rng = crate::util::rng::Rng::new(77);
            for f in 1..=seq.n_frames() {
                let stat = if use_median {
                    mbbs(&carried, fw, fh)
                } else {
                    mean_bbs(&carried, fw, fh)
                };
                let dnn = policy.select(&FrameFeatures::mbbs_only(stat));
                let (outcome, _) = acc.on_frame(f, || lat.sample(dnn));
                if outcome == FrameOutcome::Inferred {
                    use crate::coordinator::scheduler::Detector;
                    // oracle backend never fails; empty on the
                    // (unreachable) error keeps the ablation total
                    let mut raw = det
                        .detect(f, seq.gt(f), dnn)
                        .unwrap_or_default();
                    // ~5% of frames: a full-frame false positive
                    if rng.chance(0.05) {
                        raw.push(Detection::new(
                            BBox::new(0.0, 0.0, fw, fh),
                            0.6,
                            PERSON_CLASS,
                        ));
                    }
                    carried = FrameDetections { frame: f, detections: raw }
                        .filtered()
                        .detections;
                }
                eval.push(&match_frame(&carried, seq.gt(f), IOU_THRESHOLD));
            }
            aps.push(eval.ap(ApMethod::AllPoint));
        }
        table.push(vec![
            id.name().to_string(),
            format!("{:.3}", aps[0]),
            format!("{:.3}", aps[1]),
        ]);
        csv.push(vec![
            id.name().to_string(),
            format!("{:.4}", aps[0]),
            format!("{:.4}", aps[1]),
        ]);
    }
    (table, csv)
}

/// Threshold sensitivity: perturb each h_i by +-50% around H_opt.
fn threshold_sensitivity() -> (AsciiTable, CsvTable) {
    let mut table = AsciiTable::new(
        "Ablation A2 — mean AP vs perturbed thresholds (train sequences)",
        vec!["variant", "h1", "h2", "h3", "mean_AP"],
    );
    let mut csv = CsvTable::new(vec!["variant", "h1", "h2", "h3", "mean_ap"]);
    let base = [0.007, 0.03, 0.04];
    let mut variants: Vec<(String, [f64; 3])> =
        vec![("H_opt".into(), base)];
    for (i, name) in ["h1", "h2", "h3"].iter().enumerate() {
        for (tag, f) in [("-50%", 0.5), ("+50%", 1.5)] {
            let mut h = base;
            h[i] *= f;
            if h[0] < h[1] && h[1] < h[2] {
                variants.push((format!("{name}{tag}"), h));
            }
        }
    }
    let seqs: Vec<_> =
        SequenceId::TRAIN.iter().map(|&id| generate(id)).collect();
    for (name, h) in variants {
        let mut mean = 0.0;
        for seq in &seqs {
            let mut policy = MbbsPolicy::new(
                Thresholds::new(h.to_vec())
                    .expect("perturbed H_opt stays valid"),
            );
            let mut det = oracle_for(seq);
            let mut lat = LatencyModel::deterministic();
            let r = run_realtime(seq, &mut policy, &mut det, &mut lat, 30.0);
            mean += r.ap / seqs.len() as f64;
        }
        table.push(vec![
            name.clone(),
            format!("{}", h[0]),
            format!("{}", h[1]),
            format!("{}", h[2]),
            format!("{mean:.3}"),
        ]);
        csv.push(vec![
            name,
            format!("{}", h[0]),
            format!("{}", h[1]),
            format!("{}", h[2]),
            format!("{mean:.4}"),
        ]);
    }
    (table, csv)
}

/// Proactive TOD vs Chameleon-lite at several re-profiling windows.
fn proactive_vs_periodic() -> (AsciiTable, CsvTable) {
    let mut table = AsciiTable::new(
        "Ablation A3 — proactive TOD vs periodic re-profiling \
         (chameleon-lite), MOT17-05/-09/-11 mean AP",
        vec!["policy", "mean_AP", "mean_drop_rate_%"],
    );
    let mut csv = CsvTable::new(vec!["policy", "mean_ap", "drop_rate"]);
    let ids = [SequenceId::Mot05, SequenceId::Mot09, SequenceId::Mot11];
    let mut rows: Vec<(String, f64, f64)> = Vec::new();
    // TOD
    {
        let mut ap = 0.0;
        let mut dr = 0.0;
        for id in ids {
            let seq = generate(id);
            let mut det = oracle_for(&seq);
            let mut policy = MbbsPolicy::tod_default();
            let mut lat = LatencyModel::deterministic();
            let r = run_realtime(
                &seq, &mut policy, &mut det, &mut lat, id.eval_fps(),
            );
            ap += r.ap / 3.0;
            dr += r.drop_rate() * 100.0 / 3.0;
        }
        rows.push(("TOD (proactive)".into(), ap, dr));
    }
    for window in [60u64, 150, 300] {
        let mut ap = 0.0;
        let mut dr = 0.0;
        for id in ids {
            let seq = generate(id);
            let mut det = oracle_for(&seq);
            let mut lat = LatencyModel::deterministic();
            let r = run_chameleon_lite(
                &seq,
                &mut det,
                &mut lat,
                id.eval_fps(),
                &ChameleonConfig { window, f1_floor: 0.75 },
            );
            ap += r.ap / 3.0;
            dr += r.drop_rate() * 100.0 / 3.0;
        }
        rows.push((format!("chameleon-lite w={window}"), ap, dr));
    }
    for (name, ap, dr) in rows {
        table.push(vec![
            name.clone(),
            format!("{ap:.3}"),
            format!("{dr:.1}"),
        ]);
        csv.push(vec![name, format!("{ap:.4}"), format!("{dr:.2}")]);
    }
    (table, csv)
}

pub fn run_all() -> ExperimentOutput {
    let (t1, c1) = median_vs_mean();
    let (t2, c2) = threshold_sensitivity();
    let (t3, c3) = proactive_vs_periodic();
    let text = format!("{}\n{}\n{}", t1.render(), t2.render(), t3.render());
    ExperimentOutput {
        id: "ablations",
        title: "Ablations A1-A3".into(),
        text,
        csv: vec![
            ("ablation_median_vs_mean.csv".into(), c1),
            ("ablation_threshold_sensitivity.csv".into(), c2),
            ("ablation_proactive_vs_periodic.csv".into(), c3),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::detection::PERSON_CLASS;
    use crate::geometry::BBox;

    #[test]
    fn mean_bbs_dragged_by_full_frame_fp() {
        let mut dets = vec![Detection::new(
            BBox::new(0.0, 0.0, 100.0, 100.0),
            0.9,
            PERSON_CLASS,
        )];
        let base = mean_bbs(&dets, 1000.0, 1000.0);
        dets.push(Detection::new(
            BBox::new(0.0, 0.0, 1000.0, 1000.0),
            0.6,
            PERSON_CLASS,
        ));
        let with_fp = mean_bbs(&dets, 1000.0, 1000.0);
        // mean jumps by ~0.5; the median (see detection tests) barely moves
        assert!(with_fp > base + 0.4);
        assert_eq!(mean_bbs(&[], 10.0, 10.0), 0.0);
    }
}
