//! Vendored shims for the two external crates the seed's PJRT runtime
//! was written against (`anyhow`, `xla`), so the crate keeps its
//! no-external-dependencies invariant (DESIGN.md §2) while the PJRT
//! request path still compiles everywhere.
//!
//! * [`anyhow`] is a minimal API-compatible error type covering the
//!   subset the runtime uses (`Result`, `anyhow!`, `bail!`,
//!   `Context::{context,with_context}`, blanket `From<E: Error>`).
//! * [`xla`] is a **stub**: every entry point that would touch the PJRT
//!   C API returns [`xla::Error`] with an explanatory message, so
//!   `tod serve` degrades to a clean runtime error instead of a build
//!   break on machines without `xla_extension`. Swapping the real
//!   bindings back in is a one-line import change in
//!   `runtime/{engine,pool}.rs` plus a Cargo dependency.

pub mod anyhow;
pub mod xla;
