"""L1 structural perf checks (DESIGN.md §8): interpret mode gives no TPU
wallclock, so the optimization targets are VMEM footprint and MXU
utilisation of the actual layer shapes the detectors lower."""

import pytest

from compile import model
from compile.kernels import mxu_utilisation_estimate, vmem_footprint_bytes
from compile.kernels.fused_matmul import DEFAULT_BK, DEFAULT_BM, DEFAULT_BN

VMEM_BYTES = 16 * 1024 * 1024  # TPU v4-class VMEM


def layer_matmul_shapes(cfg):
    """(M, K, N) of every im2col matmul in a variant's forward pass."""
    shapes = []
    s = cfg.input_size
    params = model.build_params(cfg)
    # walk the conv plan the same way forward() does
    convs = []
    w = cfg.widths
    if cfg.tiny:
        convs = [
            ("stem", 2), ("down2", 2), ("s3", 1), ("s4", 1), ("s5", 1),
            ("neck", 1), ("head32", 1),
        ]
        pools_after = {"s3", "s4", "s5"}
    else:
        convs = [
            ("stem", 2), ("down2", 2), ("s3", 2), ("s3b", 1), ("s4", 2),
            ("s4b", 1), ("s5", 2), ("s5b", 1), ("neck32", 1),
            ("head32", 1),
        ]
        pools_after = set()
    cur = s
    for name, stride in convs:
        kh, kw, cin, cout = params[f"{name}.w"].shape
        cur = cur // stride
        shapes.append((cur * cur, kh * kw * cin, cout))
        if name in pools_after:
            cur //= 2
    return shapes


def test_default_tiles_fit_vmem_with_headroom():
    fp = vmem_footprint_bytes(DEFAULT_BM, DEFAULT_BN, DEFAULT_BK)
    assert fp < VMEM_BYTES // 4, f"{fp} bytes leaves no double-buffer room"


@pytest.mark.parametrize("name", list(model.VARIANTS))
def test_body_conv_mxu_utilisation(name):
    """Full-width body convs (K and N >= 128) must keep >= 50% useful
    MACs under the default tiling. Narrow-channel layers (N = 32) are
    inherently padding-bound at a 128-lane MXU (~14%) — a property of
    compact edge variants, not of the tiling; the K=27 im2col stem
    likewise. Both are documented in EXPERIMENTS.md §Perf."""
    cfg = model.VARIANTS[name]
    saw_wide = False
    for m, k, n in layer_matmul_shapes(cfg):
        u = mxu_utilisation_estimate(m, n, k, DEFAULT_BM, DEFAULT_BN,
                                     DEFAULT_BK)
        assert u > 0.02, f"{name} (M={m},K={k},N={n}): util {u:.2f}"
        if k >= 128 and n >= 128:
            saw_wide = True
            assert u >= 0.50, \
                f"{name} wide layer (M={m},K={k},N={n}): util {u:.2f}"
    assert saw_wide, f"{name} has no full-width layer"


def test_stem_utilisation_documented_bound():
    # the stem's padding-bound utilisation: keep the documented ~21%
    u = mxu_utilisation_estimate(20736, 16, 27, DEFAULT_BM, DEFAULT_BN,
                                 DEFAULT_BK)
    assert 0.01 < u < 0.30


def test_pallas_and_lax_lowerings_differ():
    """The --no-pallas ablation must actually change the lowered HLO
    (guards against the kernel silently not being used)."""
    from compile import aot

    cfg = model.VARIANTS["yolov4-tiny-288"]
    hlo_pallas = aot.lower_variant(cfg, use_pallas=True)
    hlo_lax = aot.lower_variant(cfg, use_pallas=False)
    assert hlo_pallas != hlo_lax
    # the pallas build lowers to explicit loops/dynamic slices; the lax
    # build contains convolution ops instead
    assert "convolution" in hlo_lax
