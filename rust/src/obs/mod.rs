//! Unified deterministic observability: structured events, recorder
//! tiers and the flight recorder (DESIGN.md §14).
//!
//! Every subsystem that makes or prices a scheduling decision — the
//! per-stream session, the multi-stream dispatcher, the scenario
//! harness, the budget governor and the micro-batching simulator —
//! emits the same versioned [`Event`] vocabulary through one
//! [`Recorder`] trait, so "why did stream 3 drop frames 210–260?" is a
//! query over one timeline instead of a join across four siloed
//! summaries ([`crate::coordinator::session::SessionEvent`],
//! [`crate::telemetry::utilisation::UtilisationSummary`],
//! [`crate::power::PowerSummary`],
//! [`crate::runtime::batch::BatchStats`]).
//!
//! Three recorder tiers trade fidelity for overhead:
//!
//! * **null** — no recorder attached (`Option::None` on the emitting
//!   side). The hot path pays one branch; the steady-state zero-alloc
//!   bound of `tests/perf_alloc.rs` is unchanged.
//! * **[`FlightRecorder`]** — a bounded ring buffer pre-allocated at
//!   construction. Recording overwrites the oldest event and never
//!   touches the allocator, so it can stay attached in production and
//!   be dumped post-mortem (the scenario conformance harness dumps it
//!   on golden mismatches).
//! * **[`JsonlSink`]** — the full trace as JSON lines. Timestamps come
//!   from the deterministic virtual clocks, object keys are sorted and
//!   floats print shortest-roundtrip, so the same seed produces a
//!   byte-identical file (`tod run --trace`, pinned in
//!   `rust/tests/obs.rs`).
//!
//! [`metrics`] aggregates the same events (plus the existing summary
//! types) into a registry of monotone counters and fixed-bucket
//! histograms with Prometheus-style exposition; [`replay`] parses
//! traces back and reconstructs drop cause chains
//! (`tod trace explain-drop`).
//!
//! On top of the event spine (DESIGN.md §15): [`span`] adds nested
//! stream ▸ frame ▸ stage spans with per-stream id arenas; [`profile`]
//! folds a span trace into per-stage self-time attribution
//! (`tod trace profile`); [`export`] renders Chrome trace-event JSON
//! and collapsed-stack flamegraphs (`tod trace export --chrome`,
//! `tod trace flame`); [`slo`] evaluates rolling-window health specs
//! over a trace and backs `tod slo check`.

// Observability is on the serving path: failures must surface as
// values, never panics.
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod export;
pub mod metrics;
pub mod profile;
pub mod replay;
pub mod slo;
pub mod span;

use std::cell::RefCell;
use std::rc::Rc;

use crate::util::json::Json;
use crate::DnnKind;

pub use export::{chrome_trace, flamegraph};
pub use metrics::MetricsRegistry;
pub use profile::ProfileReport;
pub use replay::{explain_drops, parse_trace, DropCause, DropExplanation};
pub use slo::{SloReport, SloSignal, SloSpec};
pub use span::{validate_spans, SpanArena, SpanKind};

/// Version of the event schema emitted into trace files. Bump when an
/// event variant or field changes meaning; `tod trace` refuses files
/// from a different major version.
pub const SCHEMA_VERSION: u64 = 1;

/// Schema tag of the trace-file header line.
pub const SCHEMA_TAG: &str = "tod-trace";

/// Compact feasibility mask: bit `i` set means `DnnKind::from_index(i)`
/// is budget-feasible. [`DnnKind::COUNT`] ≤ 8 is asserted at
/// construction sites via [`mask_to_bits`].
pub type MaskBits = u8;

/// Pack a per-DNN feasibility array into [`MaskBits`].
pub fn mask_to_bits(mask: &[bool; DnnKind::COUNT]) -> MaskBits {
    let mut bits = 0u8;
    for (i, &m) in mask.iter().enumerate() {
        if m {
            bits |= 1 << i;
        }
    }
    bits
}

/// Unpack [`MaskBits`] into the per-DNN feasibility array.
pub fn bits_to_mask(bits: MaskBits) -> [bool; DnnKind::COUNT] {
    let mut mask = [false; DnnKind::COUNT];
    for (i, m) in mask.iter_mut().enumerate() {
        *m = bits & (1 << i) != 0;
    }
    mask
}

/// One structured observability event. `Copy` with no heap-reaching
/// fields, so the flight recorder can store events in a pre-allocated
/// ring without ever touching the allocator.
///
/// Timestamps are **virtual stream/board seconds** from the
/// deterministic sim clocks — never wall-clock — which is what makes a
/// trace byte-identical under a fixed seed. Multi-stream emitters add
/// each stream's join epoch so every event of a run shares one board
/// timeline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Event {
    /// A stream was registered (its join epoch, board time).
    StreamJoined { stream: u32, t: f64 },
    /// A stream presented its last frame and closed, with final counts.
    StreamLeft { stream: u32, t: f64, frames: u64, inferred: u64, dropped: u64, failed: u64 },
    /// A frame's capture window opened (the decision clock).
    FramePresented { stream: u32, frame: u64, t: f64 },
    /// The selection policy committed to a DNN for this frame.
    DnnSelected { stream: u32, frame: u64, t: f64, dnn: DnnKind },
    /// A budget governor overrode the inner policy's choice:
    /// `requested` was infeasible under `mask` and `granted` ran
    /// instead. Emitted at selection time (`t` = the frame's capture
    /// start), before the matching [`Event::DnnSelected`].
    BudgetClamp { stream: u32, t: f64, requested: DnnKind, granted: DnnKind, mask: MaskBits },
    /// The DNN ran over `[start, end]` and the backend succeeded.
    FrameInferred { stream: u32, frame: u64, dnn: DnnKind, start: f64, end: f64 },
    /// The DNN ran (accelerator time was spent) but the backend failed;
    /// detections carried forward.
    InferenceFailed { stream: u32, frame: u64, dnn: DnnKind, start: f64, end: f64 },
    /// The frame arrived while the accelerator was busy; `busy_until`
    /// is when the blocking work frees the device (the drop's cause
    /// anchor for `tod trace explain-drop`).
    FrameDropped { stream: u32, frame: u64, t: f64, busy_until: f64 },
    /// A micro-batch run started: this dispatch paid full setup.
    BatchFormed { stream: u32, dnn: DnnKind, t: f64 },
    /// A dispatch continued the current same-DNN run at marginal cost;
    /// `len` is the run length including this item.
    BatchExtended { stream: u32, dnn: DnnKind, len: u32, t: f64 },
    /// A same-DNN run closed (next dispatch broke it, or the schedule
    /// ended) carrying `len` items.
    BatchFlushed { dnn: DnnKind, len: u32, t: f64 },
    /// Admission control rejected the request (queue full, shed mode).
    BatchShed { stream: u32, frame: u64, t: f64 },
    /// A pipeline span opened. `span` ids are dense per stream (see
    /// [`span::SpanArena`]); `parent` is the enclosing open span (0 =
    /// root); `frame` is 0 for spans not tied to a frame (the stream
    /// envelope).
    SpanOpen { stream: u32, frame: u64, span: u32, parent: u32, kind: SpanKind, t: f64 },
    /// The matching close of [`Event::SpanOpen`] (LIFO per stream).
    SpanClose { stream: u32, span: u32, t: f64 },
    /// A rolling-window SLO signal crossed its limit (see [`slo`]).
    SloBreach { stream: u32, t: f64, signal: SloSignal, value: f64, limit: f64 },
    /// A previously breached SLO signal returned inside its limit.
    SloRecovered { stream: u32, t: f64, signal: SloSignal, value: f64, limit: f64 },
}

impl Event {
    /// Stable type tag used in the JSONL encoding and `tod trace grep`.
    pub fn type_tag(&self) -> &'static str {
        match self {
            Event::StreamJoined { .. } => "stream_joined",
            Event::StreamLeft { .. } => "stream_left",
            Event::FramePresented { .. } => "frame_presented",
            Event::DnnSelected { .. } => "dnn_selected",
            Event::BudgetClamp { .. } => "budget_clamp",
            Event::FrameInferred { .. } => "frame_inferred",
            Event::InferenceFailed { .. } => "inference_failed",
            Event::FrameDropped { .. } => "frame_dropped",
            Event::BatchFormed { .. } => "batch_formed",
            Event::BatchExtended { .. } => "batch_extended",
            Event::BatchFlushed { .. } => "batch_flushed",
            Event::BatchShed { .. } => "batch_shed",
            Event::SpanOpen { .. } => "span_open",
            Event::SpanClose { .. } => "span_close",
            Event::SloBreach { .. } => "slo_breach",
            Event::SloRecovered { .. } => "slo_recovered",
        }
    }

    /// Stream the event belongs to, when it has one.
    pub fn stream(&self) -> Option<u32> {
        match *self {
            Event::StreamJoined { stream, .. }
            | Event::StreamLeft { stream, .. }
            | Event::FramePresented { stream, .. }
            | Event::DnnSelected { stream, .. }
            | Event::BudgetClamp { stream, .. }
            | Event::FrameInferred { stream, .. }
            | Event::InferenceFailed { stream, .. }
            | Event::FrameDropped { stream, .. }
            | Event::BatchFormed { stream, .. }
            | Event::BatchExtended { stream, .. }
            | Event::BatchShed { stream, .. }
            | Event::SpanOpen { stream, .. }
            | Event::SpanClose { stream, .. }
            | Event::SloBreach { stream, .. }
            | Event::SloRecovered { stream, .. } => Some(stream),
            Event::BatchFlushed { .. } => None,
        }
    }

    /// Frame the event refers to, when it has one.
    pub fn frame(&self) -> Option<u64> {
        match *self {
            Event::FramePresented { frame, .. }
            | Event::DnnSelected { frame, .. }
            | Event::FrameInferred { frame, .. }
            | Event::InferenceFailed { frame, .. }
            | Event::FrameDropped { frame, .. }
            | Event::BatchShed { frame, .. } => Some(frame),
            // frame 0 marks a span not tied to a frame (stream envelope)
            Event::SpanOpen { frame, .. } if frame != 0 => Some(frame),
            _ => None,
        }
    }

    /// Primary timestamp of the event (interval events use their start).
    pub fn time(&self) -> f64 {
        match *self {
            Event::StreamJoined { t, .. }
            | Event::StreamLeft { t, .. }
            | Event::FramePresented { t, .. }
            | Event::DnnSelected { t, .. }
            | Event::BudgetClamp { t, .. }
            | Event::FrameDropped { t, .. }
            | Event::BatchFormed { t, .. }
            | Event::BatchExtended { t, .. }
            | Event::BatchFlushed { t, .. }
            | Event::BatchShed { t, .. }
            | Event::SpanOpen { t, .. }
            | Event::SpanClose { t, .. }
            | Event::SloBreach { t, .. }
            | Event::SloRecovered { t, .. } => t,
            Event::FrameInferred { start, .. }
            | Event::InferenceFailed { start, .. } => start,
        }
    }

    /// JSON encoding of the event (sorted keys; used for JSONL lines).
    pub fn to_json(&self) -> Json {
        let tag = Json::str(self.type_tag());
        match *self {
            Event::StreamJoined { stream, t } => Json::obj(vec![
                ("type", tag),
                ("stream", Json::num(stream as f64)),
                ("t", Json::num(t)),
            ]),
            Event::StreamLeft { stream, t, frames, inferred, dropped, failed } => {
                Json::obj(vec![
                    ("type", tag),
                    ("stream", Json::num(stream as f64)),
                    ("t", Json::num(t)),
                    ("frames", Json::num(frames as f64)),
                    ("inferred", Json::num(inferred as f64)),
                    ("dropped", Json::num(dropped as f64)),
                    ("failed", Json::num(failed as f64)),
                ])
            }
            Event::FramePresented { stream, frame, t } => Json::obj(vec![
                ("type", tag),
                ("stream", Json::num(stream as f64)),
                ("frame", Json::num(frame as f64)),
                ("t", Json::num(t)),
            ]),
            Event::DnnSelected { stream, frame, t, dnn } => Json::obj(vec![
                ("type", tag),
                ("stream", Json::num(stream as f64)),
                ("frame", Json::num(frame as f64)),
                ("t", Json::num(t)),
                ("dnn", Json::str(dnn.artifact_name())),
            ]),
            Event::BudgetClamp { stream, t, requested, granted, mask } => {
                Json::obj(vec![
                    ("type", tag),
                    ("stream", Json::num(stream as f64)),
                    ("t", Json::num(t)),
                    ("requested", Json::str(requested.artifact_name())),
                    ("granted", Json::str(granted.artifact_name())),
                    ("mask", Json::num(mask as f64)),
                ])
            }
            Event::FrameInferred { stream, frame, dnn, start, end } => {
                Json::obj(vec![
                    ("type", tag),
                    ("stream", Json::num(stream as f64)),
                    ("frame", Json::num(frame as f64)),
                    ("dnn", Json::str(dnn.artifact_name())),
                    ("start", Json::num(start)),
                    ("end", Json::num(end)),
                ])
            }
            Event::InferenceFailed { stream, frame, dnn, start, end } => {
                Json::obj(vec![
                    ("type", tag),
                    ("stream", Json::num(stream as f64)),
                    ("frame", Json::num(frame as f64)),
                    ("dnn", Json::str(dnn.artifact_name())),
                    ("start", Json::num(start)),
                    ("end", Json::num(end)),
                ])
            }
            Event::FrameDropped { stream, frame, t, busy_until } => {
                Json::obj(vec![
                    ("type", tag),
                    ("stream", Json::num(stream as f64)),
                    ("frame", Json::num(frame as f64)),
                    ("t", Json::num(t)),
                    ("busy_until", Json::num(busy_until)),
                ])
            }
            Event::BatchFormed { stream, dnn, t } => Json::obj(vec![
                ("type", tag),
                ("stream", Json::num(stream as f64)),
                ("dnn", Json::str(dnn.artifact_name())),
                ("t", Json::num(t)),
            ]),
            Event::BatchExtended { stream, dnn, len, t } => Json::obj(vec![
                ("type", tag),
                ("stream", Json::num(stream as f64)),
                ("dnn", Json::str(dnn.artifact_name())),
                ("len", Json::num(len as f64)),
                ("t", Json::num(t)),
            ]),
            Event::BatchFlushed { dnn, len, t } => Json::obj(vec![
                ("type", tag),
                ("dnn", Json::str(dnn.artifact_name())),
                ("len", Json::num(len as f64)),
                ("t", Json::num(t)),
            ]),
            Event::BatchShed { stream, frame, t } => Json::obj(vec![
                ("type", tag),
                ("stream", Json::num(stream as f64)),
                ("frame", Json::num(frame as f64)),
                ("t", Json::num(t)),
            ]),
            Event::SpanOpen { stream, frame, span, parent, kind, t } => {
                Json::obj(vec![
                    ("type", tag),
                    ("stream", Json::num(stream as f64)),
                    ("frame", Json::num(frame as f64)),
                    ("span", Json::num(span as f64)),
                    ("parent", Json::num(parent as f64)),
                    ("kind", Json::str(kind.label())),
                    ("t", Json::num(t)),
                ])
            }
            Event::SpanClose { stream, span, t } => Json::obj(vec![
                ("type", tag),
                ("stream", Json::num(stream as f64)),
                ("span", Json::num(span as f64)),
                ("t", Json::num(t)),
            ]),
            Event::SloBreach { stream, t, signal, value, limit }
            | Event::SloRecovered { stream, t, signal, value, limit } => {
                Json::obj(vec![
                    ("type", tag),
                    ("stream", Json::num(stream as f64)),
                    ("t", Json::num(t)),
                    ("signal", Json::str(signal.label())),
                    ("value", Json::num(value)),
                    ("limit", Json::num(limit)),
                ])
            }
        }
    }

    /// Decode one event from its JSON encoding.
    pub fn from_json(v: &Json) -> Result<Event, String> {
        let tag = v
            .get("type")
            .and_then(Json::as_str)
            .ok_or("event has no \"type\" field")?;
        let num = |k: &str| -> Result<f64, String> {
            v.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("{tag}: missing number {k:?}"))
        };
        let uint = |k: &str| -> Result<u64, String> {
            let n = num(k)?;
            if n >= 0.0 && n.fract() == 0.0 {
                Ok(n as u64)
            } else {
                Err(format!("{tag}: {k:?} is not a non-negative integer"))
            }
        };
        let dnn = |k: &str| -> Result<DnnKind, String> {
            v.get(k)
                .and_then(Json::as_str)
                .ok_or_else(|| format!("{tag}: missing dnn {k:?}"))?
                .parse()
        };
        let stream = || uint("stream").map(|s| s as u32);
        Ok(match tag {
            "stream_joined" => {
                Event::StreamJoined { stream: stream()?, t: num("t")? }
            }
            "stream_left" => Event::StreamLeft {
                stream: stream()?,
                t: num("t")?,
                frames: uint("frames")?,
                inferred: uint("inferred")?,
                dropped: uint("dropped")?,
                failed: uint("failed")?,
            },
            "frame_presented" => Event::FramePresented {
                stream: stream()?,
                frame: uint("frame")?,
                t: num("t")?,
            },
            "dnn_selected" => Event::DnnSelected {
                stream: stream()?,
                frame: uint("frame")?,
                t: num("t")?,
                dnn: dnn("dnn")?,
            },
            "budget_clamp" => Event::BudgetClamp {
                stream: stream()?,
                t: num("t")?,
                requested: dnn("requested")?,
                granted: dnn("granted")?,
                mask: uint("mask")? as MaskBits,
            },
            "frame_inferred" => Event::FrameInferred {
                stream: stream()?,
                frame: uint("frame")?,
                dnn: dnn("dnn")?,
                start: num("start")?,
                end: num("end")?,
            },
            "inference_failed" => Event::InferenceFailed {
                stream: stream()?,
                frame: uint("frame")?,
                dnn: dnn("dnn")?,
                start: num("start")?,
                end: num("end")?,
            },
            "frame_dropped" => Event::FrameDropped {
                stream: stream()?,
                frame: uint("frame")?,
                t: num("t")?,
                busy_until: num("busy_until")?,
            },
            "batch_formed" => Event::BatchFormed {
                stream: stream()?,
                dnn: dnn("dnn")?,
                t: num("t")?,
            },
            "batch_extended" => Event::BatchExtended {
                stream: stream()?,
                dnn: dnn("dnn")?,
                len: uint("len")? as u32,
                t: num("t")?,
            },
            "batch_flushed" => Event::BatchFlushed {
                dnn: dnn("dnn")?,
                len: uint("len")? as u32,
                t: num("t")?,
            },
            "batch_shed" => Event::BatchShed {
                stream: stream()?,
                frame: uint("frame")?,
                t: num("t")?,
            },
            "span_open" => Event::SpanOpen {
                stream: stream()?,
                frame: uint("frame")?,
                span: uint("span")? as u32,
                parent: uint("parent")? as u32,
                kind: {
                    let k = v
                        .get("kind")
                        .and_then(Json::as_str)
                        .ok_or_else(|| format!("{tag}: missing \"kind\""))?;
                    SpanKind::from_label(k)
                        .ok_or_else(|| format!("{tag}: unknown kind {k:?}"))?
                },
                t: num("t")?,
            },
            "span_close" => Event::SpanClose {
                stream: stream()?,
                span: uint("span")? as u32,
                t: num("t")?,
            },
            "slo_breach" | "slo_recovered" => {
                let s = v
                    .get("signal")
                    .and_then(Json::as_str)
                    .ok_or_else(|| format!("{tag}: missing \"signal\""))?;
                let signal = SloSignal::from_label(s)
                    .ok_or_else(|| format!("{tag}: unknown signal {s:?}"))?;
                let (stream, t) = (stream()?, num("t")?);
                let (value, limit) = (num("value")?, num("limit")?);
                if tag == "slo_breach" {
                    Event::SloBreach { stream, t, signal, value, limit }
                } else {
                    Event::SloRecovered { stream, t, signal, value, limit }
                }
            }
            other => return Err(format!("unknown event type: {other:?}")),
        })
    }
}

/// Consumer of observability events. `record` must be cheap: the
/// session calls it on every frame of every stream.
pub trait Recorder {
    fn record(&mut self, ev: &Event);
}

/// Shared recorder handle the emitters hold. Single-threaded by design:
/// the deterministic schedulers all run on one thread (the wall-clock
/// server aggregates through [`MetricsRegistry`] snapshots instead).
pub type SharedRecorder = Rc<RefCell<dyn Recorder>>;

/// Wrap a recorder into the [`SharedRecorder`] handle emitters take.
pub fn shared<R: Recorder + 'static>(recorder: R) -> SharedRecorder {
    Rc::new(RefCell::new(recorder))
}

/// The no-op tier: every `record` compiles to nothing. Exists mostly
/// for tests and as the explicit "tracing off" spelling; emitters use
/// `Option::None` on the hot path so not even a dynamic call is paid.
#[derive(Debug, Clone, Copy, Default)]
pub struct NullRecorder;

impl Recorder for NullRecorder {
    #[inline]
    fn record(&mut self, _ev: &Event) {}
}

/// Bounded ring-buffer recorder: keeps the last `capacity` events,
/// allocation-free after construction. The black box you leave attached
/// and dump when something goes wrong.
#[derive(Debug, Clone)]
pub struct FlightRecorder {
    buf: Vec<Event>,
    /// Ring size — `Vec::with_capacity` may over-reserve, so the
    /// requested bound is tracked explicitly.
    cap: usize,
    /// Next write slot once the ring is full.
    head: usize,
    /// Events overwritten after the ring filled.
    overwritten: u64,
}

impl FlightRecorder {
    /// A ring holding the last `capacity` events (>= 1).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "flight recorder capacity must be >= 1");
        FlightRecorder {
            buf: Vec::with_capacity(capacity),
            cap: capacity,
            head: 0,
            overwritten: 0,
        }
    }

    /// Events currently retained.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Events that were overwritten after the ring filled.
    pub fn overwritten(&self) -> u64 {
        self.overwritten
    }

    /// Retained events, oldest first.
    pub fn events(&self) -> impl Iterator<Item = &Event> {
        let (newer, older) = self.buf.split_at(self.head);
        older.iter().chain(newer.iter())
    }

    /// Dump the retained window as trace JSONL (header line first, with
    /// an `overwritten` count so a truncated window is self-describing).
    pub fn to_jsonl(&self, label: &str) -> String {
        let mut out = String::new();
        out.push_str(
            &Json::obj(vec![
                ("schema", Json::str(SCHEMA_TAG)),
                ("version", Json::num(SCHEMA_VERSION as f64)),
                ("label", Json::str(label)),
                ("overwritten", Json::num(self.overwritten as f64)),
            ])
            .to_string(),
        );
        out.push('\n');
        for ev in self.events() {
            out.push_str(&ev.to_json().to_string());
            out.push('\n');
        }
        out
    }
}

impl Recorder for FlightRecorder {
    #[inline]
    fn record(&mut self, ev: &Event) {
        if self.buf.len() < self.cap {
            self.buf.push(*ev);
        } else {
            // cap >= 1 and buf.len() == cap, so head is always in range
            if let Some(slot) = self.buf.get_mut(self.head) {
                *slot = *ev;
            }
            self.head = (self.head + 1) % self.cap;
            self.overwritten += 1;
        }
    }
}

/// Full-fidelity JSON-lines sink. Buffers the trace in memory; the
/// caller writes it out ([`JsonlSink::save`]) after the run. Lines are
/// byte-stable under a fixed seed: sorted keys, shortest-roundtrip
/// floats, virtual-clock timestamps only.
#[derive(Debug, Clone)]
pub struct JsonlSink {
    out: String,
    events: u64,
}

impl JsonlSink {
    /// A sink whose header line carries `label` (e.g. the run's policy
    /// and sequence descriptor).
    pub fn new(label: &str) -> Self {
        let mut out = String::new();
        out.push_str(
            &Json::obj(vec![
                ("schema", Json::str(SCHEMA_TAG)),
                ("version", Json::num(SCHEMA_VERSION as f64)),
                ("label", Json::str(label)),
            ])
            .to_string(),
        );
        out.push('\n');
        JsonlSink { out, events: 0 }
    }

    /// Events recorded so far.
    pub fn events(&self) -> u64 {
        self.events
    }

    /// The trace text (header line + one JSON object per event).
    pub fn contents(&self) -> &str {
        &self.out
    }

    /// Write the trace to `path`.
    pub fn save(&self, path: &std::path::Path) -> Result<(), String> {
        std::fs::write(path, &self.out)
            .map_err(|e| format!("write {}: {e}", path.display()))
    }
}

impl Recorder for JsonlSink {
    fn record(&mut self, ev: &Event) {
        self.out.push_str(&ev.to_json().to_string());
        self.out.push('\n');
        self.events += 1;
    }
}

/// Unbounded in-memory recorder: appends every event to a `Vec`. The
/// offline-analysis tier — SLO evaluation over a whole run, span
/// validation in tests, export rendering — where allocation is fine
/// and nothing may be dropped. Hold an `Rc<RefCell<EventLog>>` and
/// coerce a clone into [`SharedRecorder`] to read the events back
/// after the run.
#[derive(Debug, Clone, Default)]
pub struct EventLog {
    events: Vec<Event>,
}

impl EventLog {
    pub fn new() -> Self {
        EventLog::default()
    }

    /// Recorded events, emission order.
    pub fn events(&self) -> &[Event] {
        &self.events
    }

    /// Consume the log, yielding its events.
    pub fn into_events(self) -> Vec<Event> {
        self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

impl Recorder for EventLog {
    fn record(&mut self, ev: &Event) {
        self.events.push(*ev);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perf::count_allocs;

    fn sample_events(n: u64) -> Vec<Event> {
        (0..n)
            .map(|i| Event::FramePresented {
                stream: (i % 3) as u32,
                frame: i + 1,
                t: i as f64 / 30.0,
            })
            .collect()
    }

    #[test]
    fn mask_bits_roundtrip() {
        for bits in 0..(1u16 << DnnKind::COUNT) {
            let bits = bits as MaskBits;
            assert_eq!(mask_to_bits(&bits_to_mask(bits)), bits);
        }
        assert_eq!(mask_to_bits(&[true; DnnKind::COUNT]), 0b1111);
        assert_eq!(bits_to_mask(0b0101), [true, false, true, false]);
    }

    #[test]
    fn every_event_variant_roundtrips_through_json() {
        let events = [
            Event::StreamJoined { stream: 2, t: 1.5 },
            Event::StreamLeft {
                stream: 2,
                t: 9.0,
                frames: 90,
                inferred: 70,
                dropped: 19,
                failed: 1,
            },
            Event::FramePresented { stream: 0, frame: 7, t: 0.2 },
            Event::DnnSelected {
                stream: 0,
                frame: 7,
                t: 0.2,
                dnn: DnnKind::Y288,
            },
            Event::BudgetClamp {
                stream: 1,
                t: 0.25,
                requested: DnnKind::Y416,
                granted: DnnKind::TinyY416,
                mask: 0b0011,
            },
            Event::FrameInferred {
                stream: 0,
                frame: 7,
                dnn: DnnKind::Y288,
                start: 0.2,
                end: 0.29,
            },
            Event::InferenceFailed {
                stream: 0,
                frame: 8,
                dnn: DnnKind::Y288,
                start: 0.3,
                end: 0.39,
            },
            Event::FrameDropped {
                stream: 0,
                frame: 9,
                t: 0.266,
                busy_until: 0.39,
            },
            Event::BatchFormed { stream: 1, dnn: DnnKind::TinyY288, t: 0.4 },
            Event::BatchExtended {
                stream: 2,
                dnn: DnnKind::TinyY288,
                len: 2,
                t: 0.43,
            },
            Event::BatchFlushed { dnn: DnnKind::TinyY288, len: 2, t: 0.46 },
            Event::BatchShed { stream: 1, frame: 12, t: 0.5 },
            Event::SpanOpen {
                stream: 0,
                frame: 7,
                span: 14,
                parent: 1,
                kind: SpanKind::Inference,
                t: 0.2,
            },
            Event::SpanClose { stream: 0, span: 14, t: 0.29 },
            Event::SloBreach {
                stream: 0,
                t: 4.0,
                signal: SloSignal::Watts,
                value: 7.4,
                limit: 5.8,
            },
            Event::SloRecovered {
                stream: 0,
                t: 9.5,
                signal: SloSignal::Watts,
                value: 5.1,
                limit: 5.8,
            },
        ];
        for ev in events {
            let back = Event::from_json(&ev.to_json()).unwrap();
            assert_eq!(back, ev, "roundtrip of {}", ev.type_tag());
            // the encoding is stable text too
            assert_eq!(back.to_json().to_string(), ev.to_json().to_string());
        }
    }

    #[test]
    fn from_json_rejects_malformed_events() {
        assert!(Event::from_json(&Json::Null).is_err());
        assert!(Event::from_json(&Json::obj(vec![(
            "type",
            Json::str("no_such_event")
        )]))
        .is_err());
        // missing field
        let v = Json::obj(vec![
            ("type", Json::str("frame_presented")),
            ("stream", Json::num(0.0)),
        ]);
        assert!(Event::from_json(&v).is_err());
        // non-integer frame
        let v = Json::obj(vec![
            ("type", Json::str("frame_presented")),
            ("stream", Json::num(0.0)),
            ("frame", Json::num(1.5)),
            ("t", Json::num(0.0)),
        ]);
        assert!(Event::from_json(&v).is_err());
    }

    #[test]
    fn flight_recorder_keeps_the_last_capacity_events() {
        let mut fr = FlightRecorder::new(4);
        for ev in sample_events(10) {
            fr.record(&ev);
        }
        assert_eq!(fr.len(), 4);
        assert_eq!(fr.overwritten(), 6);
        let frames: Vec<u64> =
            fr.events().filter_map(|e| e.frame()).collect();
        // oldest-first window over the 10 recorded frames
        assert_eq!(frames, vec![7, 8, 9, 10]);
    }

    #[test]
    fn flight_recorder_below_capacity_keeps_everything_in_order() {
        let mut fr = FlightRecorder::new(8);
        for ev in sample_events(5) {
            fr.record(&ev);
        }
        assert_eq!(fr.len(), 5);
        assert_eq!(fr.overwritten(), 0);
        let frames: Vec<u64> =
            fr.events().filter_map(|e| e.frame()).collect();
        assert_eq!(frames, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn flight_recorder_wraparound_is_exact_at_multiples() {
        // exactly 2x capacity: the ring must hold the second half
        let mut fr = FlightRecorder::new(5);
        for ev in sample_events(10) {
            fr.record(&ev);
        }
        let frames: Vec<u64> =
            fr.events().filter_map(|e| e.frame()).collect();
        assert_eq!(frames, vec![6, 7, 8, 9, 10]);
        assert_eq!(fr.overwritten(), 5);
    }

    #[test]
    fn flight_recorder_records_without_allocating() {
        let mut fr = FlightRecorder::new(64);
        let events = sample_events(256);
        // warm: nothing to warm, the ring is pre-allocated
        let (delta, ()) = count_allocs(|| {
            for ev in &events {
                fr.record(ev);
            }
        });
        assert_eq!(
            delta.allocs, 0,
            "flight recording allocated {} times",
            delta.allocs
        );
        assert_eq!(fr.len(), 64);
    }

    #[test]
    fn flight_recorder_dump_has_header_and_events() {
        let mut fr = FlightRecorder::new(4);
        for ev in sample_events(6) {
            fr.record(&ev);
        }
        let dump = fr.to_jsonl("unit");
        let lines: Vec<&str> = dump.lines().collect();
        assert_eq!(lines.len(), 5);
        let header = Json::parse(lines[0]).unwrap();
        assert_eq!(header.get("schema").unwrap().as_str(), Some(SCHEMA_TAG));
        assert_eq!(header.get("overwritten").unwrap().as_f64(), Some(2.0));
        for line in &lines[1..] {
            let ev = Event::from_json(&Json::parse(line).unwrap()).unwrap();
            assert_eq!(ev.type_tag(), "frame_presented");
        }
    }

    #[test]
    fn jsonl_sink_is_deterministic_text() {
        let mut a = JsonlSink::new("run");
        let mut b = JsonlSink::new("run");
        for ev in sample_events(20) {
            a.record(&ev);
            b.record(&ev);
        }
        assert_eq!(a.contents(), b.contents());
        assert_eq!(a.events(), 20);
        assert!(a.contents().starts_with('{'));
        assert_eq!(a.contents().lines().count(), 21);
    }

    #[test]
    fn null_recorder_is_a_no_op() {
        let mut n = NullRecorder;
        for ev in sample_events(3) {
            n.record(&ev);
        }
        // and through the shared handle
        let rec = shared(NullRecorder);
        rec.borrow_mut().record(&sample_events(1)[0]);
    }

    #[test]
    fn event_accessors_are_consistent() {
        let ev = Event::FrameInferred {
            stream: 3,
            frame: 9,
            dnn: DnnKind::Y416,
            start: 1.0,
            end: 1.2,
        };
        assert_eq!(ev.stream(), Some(3));
        assert_eq!(ev.frame(), Some(9));
        assert_eq!(ev.time(), 1.0);
        let flush = Event::BatchFlushed { dnn: DnnKind::Y288, len: 3, t: 2.0 };
        assert_eq!(flush.stream(), None);
        assert_eq!(flush.frame(), None);
        // frame 0 on a span marks "no frame" (the stream envelope)
        let root = Event::SpanOpen {
            stream: 2,
            frame: 0,
            span: 1,
            parent: 0,
            kind: SpanKind::Stream,
            t: 0.0,
        };
        assert_eq!(root.frame(), None);
        assert_eq!(root.stream(), Some(2));
        let frame_span = Event::SpanOpen {
            stream: 2,
            frame: 4,
            span: 2,
            parent: 1,
            kind: SpanKind::Frame,
            t: 0.1,
        };
        assert_eq!(frame_span.frame(), Some(4));
    }

    #[test]
    fn event_log_retains_everything_in_order() {
        let log = Rc::new(RefCell::new(EventLog::new()));
        let rec: SharedRecorder = log.clone();
        for ev in sample_events(6) {
            rec.borrow_mut().record(&ev);
        }
        let inner = log.borrow();
        assert_eq!(inner.len(), 6);
        assert!(!inner.is_empty());
        let frames: Vec<u64> =
            inner.events().iter().filter_map(|e| e.frame()).collect();
        assert_eq!(frames, vec![1, 2, 3, 4, 5, 6]);
    }
}
