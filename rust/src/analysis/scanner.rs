//! Token/AST-lite scanner over the crate's own Rust sources.
//!
//! The lint pass (DESIGN.md §16) needs four facts about every source
//! line, none of which require a full parse:
//!
//! 1. the line's **code text with comments and string literals blanked
//!    out** (so `"panic!"` inside a usage string never matches a rule
//!    needle),
//! 2. whether the line sits inside a `#[cfg(test)]` region (tests may
//!    unwrap the happy path — `clippy.toml` already says so),
//! 3. the stack of **enclosing function names**, qualified by their
//!    `impl` type (`StreamSession::step_with`), so hot-path rules can
//!    scope to the functions the policy enumerates, and
//! 4. any inline **waiver comment** (`// tod-lint: allow(<rule>)
//!    reason="..."`) attached to the line.
//!
//! The scanner is two passes over the raw text: a character-level
//! *masker* that blanks comments/strings while preserving the byte
//! layout (so `file:line` findings point at real source), then a
//! token walk over the masked text that tracks brace depth,
//! `#[cfg(test)]` regions, `impl` blocks and `fn` bodies. It is
//! deliberately not a parser — no `syn`, no new dependencies — and it
//! errs on the side of *seeing* code: a construct the walker cannot
//! classify stays visible to the rules rather than vanishing.

/// A waiver comment parsed from the source (see
/// [`crate::analysis::waivers`] for matching semantics).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WaiverComment {
    /// 1-based line the comment sits on.
    pub line: usize,
    /// `true` when the comment shares its line with code (trailing
    /// waiver — applies to that line); `false` for a standalone
    /// comment line (applies to the next code line).
    pub trailing: bool,
    /// Raw comment text after `//`, untrimmed.
    pub text: String,
}

/// Per-line scan output.
#[derive(Debug, Clone)]
pub struct LineInfo {
    /// Code text with comments and string/char literals blanked.
    pub masked: String,
    /// Line sits inside a `#[cfg(test)]` item or module.
    pub in_test: bool,
    /// Qualified names of enclosing functions, outermost first
    /// (e.g. `["StreamSession::step_with"]`; nested fns append).
    pub functions: Vec<String>,
}

/// A fully scanned source file.
#[derive(Debug, Clone)]
pub struct ScannedFile {
    /// Path relative to the scan root, with `/` separators.
    pub rel_path: String,
    /// One entry per source line, 0-based index = line - 1.
    pub lines: Vec<LineInfo>,
    /// Waiver comments in file order.
    pub waivers: Vec<WaiverComment>,
}

/// Scan one file's source text.
pub fn scan_source(rel_path: &str, source: &str) -> ScannedFile {
    let (masked, waivers) = mask(source);
    let lines = annotate(&masked);
    ScannedFile { rel_path: rel_path.to_string(), lines, waivers }
}

// ---------------------------------------------------------------------
// pass 1: masking
// ---------------------------------------------------------------------

/// Blank comments and string/char literals with spaces, preserving the
/// exact line structure, and collect `//` comment texts that carry
/// `tod-lint:` waivers.
fn mask(source: &str) -> (String, Vec<WaiverComment>) {
    let b = source.as_bytes();
    let mut out = Vec::with_capacity(b.len());
    let mut waivers = Vec::new();
    let mut line = 1usize;
    // whether any code byte has been emitted on the current line
    // (decides trailing vs standalone for waiver comments)
    let mut code_on_line = false;
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        if c == b'\n' {
            out.push(b'\n');
            line += 1;
            code_on_line = false;
            i += 1;
            continue;
        }
        // line comment — capture text, blank it
        if c == b'/' && b.get(i + 1) == Some(&b'/') {
            let start = i;
            while i < b.len() && b[i] != b'\n' {
                i += 1;
            }
            let text = String::from_utf8_lossy(&b[start + 2..i]).into_owned();
            // a waiver is a plain `//` comment whose body *starts* with
            // the marker — doc comments (`///`, `//!`) and prose that
            // merely mentions the syntax never parse as waivers
            let is_doc = text.starts_with('/') || text.starts_with('!');
            if !is_doc && text.trim_start().starts_with("tod-lint:") {
                waivers.push(WaiverComment {
                    line,
                    trailing: code_on_line,
                    text,
                });
            }
            for _ in start..i {
                out.push(b' ');
            }
            continue;
        }
        // block comment (nested, possibly multi-line)
        if c == b'/' && b.get(i + 1) == Some(&b'*') {
            let mut depth = 1usize;
            out.push(b' ');
            out.push(b' ');
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                    depth += 1;
                    out.push(b' ');
                    out.push(b' ');
                    i += 2;
                } else if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                    depth -= 1;
                    out.push(b' ');
                    out.push(b' ');
                    i += 2;
                } else if b[i] == b'\n' {
                    out.push(b'\n');
                    line += 1;
                    code_on_line = false;
                    i += 1;
                } else {
                    out.push(b' ');
                    i += 1;
                }
            }
            continue;
        }
        // raw string r"..." / r#"..."# (and br variants)
        if (c == b'r' || c == b'b') && !prev_is_ident(&out) {
            if let Some(consumed) = raw_string_len(&b[i..]) {
                for k in 0..consumed {
                    if b[i + k] == b'\n' {
                        out.push(b'\n');
                        line += 1;
                        code_on_line = false;
                    } else {
                        out.push(b' ');
                    }
                }
                i += consumed;
                continue;
            }
        }
        // ordinary string (or byte string — the b was emitted as code)
        if c == b'"' {
            out.push(b' ');
            i += 1;
            while i < b.len() {
                match b[i] {
                    b'\\' => {
                        out.push(b' ');
                        out.push(b' ');
                        i += 2;
                    }
                    b'"' => {
                        out.push(b' ');
                        i += 1;
                        break;
                    }
                    b'\n' => {
                        out.push(b'\n');
                        line += 1;
                        code_on_line = false;
                        i += 1;
                    }
                    _ => {
                        out.push(b' ');
                        i += 1;
                    }
                }
            }
            continue;
        }
        // char literal vs lifetime: 'a' is a literal, 'a (no closing
        // quote right after) is a lifetime and stays visible
        if c == b'\'' {
            if let Some(consumed) = char_literal_len(&b[i..]) {
                for _ in 0..consumed {
                    out.push(b' ');
                }
                i += consumed;
                code_on_line = true;
                continue;
            }
        }
        if !c.is_ascii_whitespace() {
            code_on_line = true;
        }
        out.push(c);
        i += 1;
    }
    // the masker only ever replaces bytes with spaces/newlines, so the
    // output is valid UTF-8 wherever the input was
    (String::from_utf8_lossy(&out).into_owned(), waivers)
}

/// Last emitted byte is an identifier character (so `r` in `for` or
/// `br` in `abr` is not a raw-string prefix).
fn prev_is_ident(out: &[u8]) -> bool {
    matches!(out.last(), Some(c) if c.is_ascii_alphanumeric() || *c == b'_')
}

/// Length of a raw (byte) string literal starting at `b[0]`, or None.
fn raw_string_len(b: &[u8]) -> Option<usize> {
    let mut i = 0;
    if b.get(i) == Some(&b'b') {
        i += 1;
    }
    if b.get(i) != Some(&b'r') {
        return None;
    }
    i += 1;
    let mut hashes = 0usize;
    while b.get(i) == Some(&b'#') {
        hashes += 1;
        i += 1;
    }
    if b.get(i) != Some(&b'"') {
        return None;
    }
    i += 1;
    loop {
        match b.get(i) {
            None => return Some(i), // unterminated: consume to EOF
            Some(b'"') => {
                let mut k = 0;
                while k < hashes && b.get(i + 1 + k) == Some(&b'#') {
                    k += 1;
                }
                if k == hashes {
                    return Some(i + 1 + hashes);
                }
                i += 1;
            }
            Some(_) => i += 1,
        }
    }
}

/// Length of a char/byte-char literal starting at the `'`, or None
/// when the quote is a lifetime.
fn char_literal_len(b: &[u8]) -> Option<usize> {
    debug_assert_eq!(b.first(), Some(&b'\''));
    match b.get(1) {
        Some(b'\\') => {
            // escape: consume to the closing quote
            let mut i = 2;
            while i < b.len() && b[i] != b'\'' {
                i += 1;
            }
            Some((i + 1).min(b.len()))
        }
        Some(c) if *c != b'\'' => {
            // 'x' is a char literal only when the closing quote follows
            // the (possibly multi-byte) scalar immediately; otherwise
            // it's a lifetime and the tick stays in the code stream
            let mut i = 2;
            while i < b.len() && i < 6 && (b[i] & 0xC0) == 0x80 {
                i += 1; // UTF-8 continuation bytes of one scalar
            }
            if b.get(i) == Some(&b'\'') {
                Some(i + 1)
            } else {
                None
            }
        }
        _ => None,
    }
}

// ---------------------------------------------------------------------
// pass 2: structural annotation
// ---------------------------------------------------------------------

fn is_ident_char(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Walk the masked text and annotate each line with its `#[cfg(test)]`
/// / enclosing-function context.
fn annotate(masked: &str) -> Vec<LineInfo> {
    let mut out: Vec<LineInfo> = Vec::new();
    let mut depth = 0usize;
    // depths at which a #[cfg(test)] region opened
    let mut test_depths: Vec<usize> = Vec::new();
    let mut pending_test = false;
    // (type name, depth at the impl's opening brace)
    let mut impl_stack: Vec<(String, usize)> = Vec::new();
    // pending impl header: Some(accumulating type name) until `{`
    let mut pending_impl: Option<ImplHeader> = None;
    // (qualified fn name, depth at the body's opening brace)
    let mut fn_stack: Vec<(String, usize)> = Vec::new();
    // parsed fn name waiting for its body brace
    let mut pending_fn: Option<String> = None;
    let mut expect_fn_name = false;

    for raw_line in masked.split('\n') {
        let in_test_at_start =
            !test_depths.is_empty() || pending_test;
        let functions: Vec<String> =
            fn_stack.iter().map(|(n, _)| n.clone()).collect();
        let line_has_cfg_test = raw_line.contains("#[cfg(test)]");
        if line_has_cfg_test {
            pending_test = true;
        }

        let b = raw_line.as_bytes();
        let mut i = 0;
        while i < b.len() {
            let c = b[i];
            if is_ident_char(c) {
                let start = i;
                while i < b.len() && is_ident_char(b[i]) {
                    i += 1;
                }
                let word = &raw_line[start..i];
                if expect_fn_name {
                    // `fn` followed by an identifier: a definition
                    // (a fn-pointer type has `(` here instead)
                    let qualified = match impl_stack.last() {
                        Some((ty, _)) => format!("{ty}::{word}"),
                        None => word.to_string(),
                    };
                    pending_fn = Some(qualified);
                    expect_fn_name = false;
                    continue;
                }
                match word {
                    "fn" => expect_fn_name = true,
                    "impl" => {
                        pending_impl = Some(ImplHeader::default());
                        pending_fn = None;
                    }
                    _ => {
                        if let Some(h) = pending_impl.as_mut() {
                            h.push_ident(word);
                        }
                    }
                }
                continue;
            }
            match c {
                b'<' => {
                    if let Some(h) = pending_impl.as_mut() {
                        h.angle += 1;
                    }
                }
                b'>' => {
                    if let Some(h) = pending_impl.as_mut() {
                        h.angle = h.angle.saturating_sub(1);
                    }
                }
                b'{' => {
                    if pending_test {
                        test_depths.push(depth);
                        pending_test = false;
                    }
                    if let Some(h) = pending_impl.take() {
                        impl_stack.push((h.name, depth));
                    } else if let Some(name) = pending_fn.take() {
                        fn_stack.push((name, depth));
                    }
                    expect_fn_name = false;
                    depth += 1;
                }
                b'}' => {
                    depth = depth.saturating_sub(1);
                    while matches!(fn_stack.last(), Some((_, d)) if *d >= depth)
                    {
                        fn_stack.pop();
                    }
                    while matches!(impl_stack.last(), Some((_, d)) if *d >= depth)
                    {
                        impl_stack.pop();
                    }
                    while matches!(test_depths.last(), Some(d) if *d >= depth)
                    {
                        test_depths.pop();
                    }
                }
                b';' => {
                    // `#[cfg(test)] use ...;` / trait method decls:
                    // nothing braced follows, clear pending state
                    if pending_impl.is_none() {
                        pending_fn = None;
                        pending_test = false;
                    }
                    expect_fn_name = false;
                }
                _ => {
                    // `fn` not followed by an identifier is a
                    // fn-pointer type (`fn(i32) -> i32`), not a
                    // definition: only whitespace may separate the
                    // keyword from the name
                    if !c.is_ascii_whitespace() {
                        expect_fn_name = false;
                    }
                }
            }
            i += 1;
        }

        out.push(LineInfo {
            masked: raw_line.to_string(),
            in_test: in_test_at_start
                || !test_depths.is_empty()
                || pending_test,
            functions,
        });
    }
    out
}

/// Accumulates the self-type name of an `impl` header: the last
/// identifier seen at angle-bracket depth 0, with `for` resetting the
/// capture (so `impl Trait for Type` yields `Type`), `where` ending it
/// (clause bounds must not overwrite the name), and path/marker
/// keywords skipped.
#[derive(Default)]
struct ImplHeader {
    name: String,
    angle: usize,
    done: bool,
}

impl ImplHeader {
    fn push_ident(&mut self, word: &str) {
        if self.angle > 0 || self.done {
            return;
        }
        match word {
            "for" => self.name.clear(),
            "where" => self.done = true,
            "dyn" | "crate" | "super" | "self" => {}
            w => {
                self.name.clear();
                self.name.push_str(w);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan(src: &str) -> ScannedFile {
        scan_source("t.rs", src)
    }

    #[test]
    fn masks_comments_and_strings() {
        let f = scan(concat!(
            "let x = \"panic!()\"; // Instant::now in a comment\n",
            "/* HashMap in\n   a block */ let y = 2;\n",
        ));
        assert!(!f.lines[0].masked.contains("panic"));
        assert!(!f.lines[0].masked.contains("Instant"));
        assert!(f.lines[0].masked.contains("let x ="));
        assert!(!f.lines[1].masked.contains("HashMap"));
        assert!(f.lines[2].masked.contains("let y = 2;"));
    }

    #[test]
    fn masks_raw_strings_and_chars() {
        let f = scan(concat!(
            "let r = r#\"unwrap() \"quoted\" \"#;\n",
            "let c = '\\''; let l: &'static str = s;\n",
        ));
        assert!(!f.lines[0].masked.contains("unwrap"));
        assert!(f.lines[1].masked.contains("static")); // lifetime kept
        assert!(!f.lines[1].masked.contains("\\'"));
    }

    #[test]
    fn cfg_test_regions_cover_mod_and_fn() {
        let f = scan(concat!(
            "fn live() { x.unwrap(); }\n",
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    fn t() { y.unwrap(); }\n",
            "}\n",
            "fn live2() {}\n",
        ));
        assert!(!f.lines[0].in_test);
        assert!(f.lines[2].in_test);
        assert!(f.lines[3].in_test);
        assert!(f.lines[4].in_test);
        assert!(!f.lines[5].in_test);
    }

    #[test]
    fn cfg_not_test_is_not_a_test_region() {
        let f = scan("#[cfg(not(test))]\nfn live() { x.unwrap(); }\n");
        assert!(!f.lines[1].in_test);
    }

    #[test]
    fn cfg_test_on_use_item_does_not_leak() {
        let f = scan(concat!(
            "#[cfg(test)]\n",
            "use std::collections::HashMap;\n",
            "fn live() {}\n",
        ));
        assert!(f.lines[1].in_test);
        assert!(!f.lines[2].in_test);
    }

    #[test]
    fn enclosing_functions_are_qualified_by_impl() {
        let f = scan(concat!(
            "impl<T: Clone> Foo<T> {\n",
            "    pub fn bar(&self) -> usize {\n",
            "        self.x\n",
            "    }\n",
            "}\n",
            "fn free() {\n",
            "    1\n",
            "}\n",
        ));
        assert!(f.lines[2].functions.contains(&"Foo::bar".to_string()));
        assert!(f.lines[6].functions.contains(&"free".to_string()));
        assert!(f.lines[4].functions.is_empty());
    }

    #[test]
    fn trait_impl_uses_self_type() {
        let f = scan(concat!(
            "impl Display for Wide<'_> {\n",
            "    fn fmt(&self) -> usize {\n",
            "        0\n",
            "    }\n",
            "}\n",
        ));
        assert!(f.lines[2].functions.contains(&"Wide::fmt".to_string()));
    }

    #[test]
    fn trait_method_decl_without_body_is_skipped() {
        let f = scan(concat!(
            "trait T {\n",
            "    fn decl(&self) -> usize;\n",
            "    fn with_default(&self) -> usize {\n",
            "        2\n",
            "    }\n",
            "}\n",
        ));
        assert!(f.lines[1].functions.is_empty());
        assert!(f.lines[3]
            .functions
            .contains(&"with_default".to_string()));
    }

    #[test]
    fn waiver_comments_are_collected() {
        let f = scan(concat!(
            "// tod-lint: allow(srv-unwrap) reason=\"test\"\n",
            "x.unwrap(); // tod-lint: allow(srv-unwrap) reason=\"y\"\n",
            "// an ordinary comment\n",
        ));
        assert_eq!(f.waivers.len(), 2);
        assert_eq!(f.waivers[0].line, 1);
        assert!(!f.waivers[0].trailing);
        assert_eq!(f.waivers[1].line, 2);
        assert!(f.waivers[1].trailing);
    }

    #[test]
    fn doc_comments_and_prose_mentions_are_not_waivers() {
        let f = scan(concat!(
            "//! syntax is `// tod-lint: allow(<rule>) reason=\"..\"`\n",
            "/// see the tod-lint: allow protocol\n",
            "// the tod-lint: marker must start the comment\n",
            "//tod-lint: allow(srv-unwrap) reason=\"no space, ok\"\n",
        ));
        assert_eq!(f.waivers.len(), 1);
        assert_eq!(f.waivers[0].line, 4);
    }

    #[test]
    fn nested_fn_stacks() {
        let f = scan(concat!(
            "fn outer() {\n",
            "    fn inner() {\n",
            "        1\n",
            "    }\n",
            "    2\n",
            "}\n",
        ));
        assert_eq!(f.lines[2].functions, vec!["outer", "inner"]);
        assert_eq!(f.lines[4].functions, vec!["outer"]);
    }
}
