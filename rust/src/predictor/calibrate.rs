//! Offline calibration campaign: fills a [`CalibrationTable`] by
//! measuring each DNN's *real-time* AP on synthetic sequences pinned to
//! each (object size × apparent speed) operating point.
//!
//! The oracle detector is the ground-truth-conditioned stand-in for the
//! trained networks (DESIGN.md §3), and every cell runs under the
//! Algorithm 2 drop-frame accounting at the target FPS — so a cell's AP
//! prices in both the DNN's detection capacity *and* its computational
//! demand (frame drops + carried-box staleness). This is the ROMA-style
//! evolution of the paper's hand-tuned threshold ladder: the table *is*
//! the learned mapping from stream characteristics to the
//! best-performing network.

use crate::coordinator::policy::FixedPolicy;
use crate::coordinator::scheduler::{run_realtime, OracleBackend};
use crate::dataset::synth::{CameraMotion, Sequence, SequenceSpec};
use crate::sim::latency::LatencyModel;
use crate::sim::oracle::OracleDetector;
use crate::DnnKind;

use super::model::CalibrationTable;

/// Frame geometry every calibration sequence uses. Sizes and speeds are
/// expressed as frame *fractions*, so the calibrated table transfers to
/// streams at any resolution.
pub const CAL_WIDTH: u32 = 960;
pub const CAL_HEIGHT: u32 = 540;

/// Configuration of one calibration campaign.
#[derive(Debug, Clone)]
pub struct CalibrationConfig {
    /// Evaluation FPS the cells are scheduled under.
    pub fps: f64,
    /// Frames per calibration sequence (per cell, per DNN).
    pub frames: u64,
    /// Target MBBS cell centers (ascending, fraction of frame area).
    pub size_targets: Vec<f64>,
    /// Target apparent-speed cell centers (ascending, frame diagonals
    /// per frame — the [`crate::features`] unit).
    pub speed_targets: Vec<f64>,
    /// Base seed for the synthetic worlds (cells derive their own).
    pub seed: u64,
}

impl CalibrationConfig {
    /// The default campaign: a 5×5 grid spanning the MOT17 regimes, from
    /// sub-h1 boxes on a static camera to MOT17-05-sized boxes under a
    /// fast pan.
    pub fn default_for_fps(fps: f64) -> Self {
        CalibrationConfig {
            fps,
            frames: 180,
            size_targets: vec![0.002, 0.005, 0.012, 0.03, 0.07],
            speed_targets: vec![0.0, 0.002, 0.006, 0.012, 0.024],
            seed: 0xca11b,
        }
    }

    /// A tiny 2×2 grid for smoke tests and CI round-trips.
    pub fn quick(fps: f64) -> Self {
        CalibrationConfig {
            fps,
            frames: 45,
            size_targets: vec![0.004, 0.04],
            speed_targets: vec![0.0, 0.015],
            seed: 0xca11b,
        }
    }
}

/// The synthetic world for one (size, speed) cell.
///
/// Geometry inverts [`SequenceSpec::nominal_area_frac`] at the mid
/// depth: a pedestrian at depth `d` gets
/// `ref_height = d * sqrt(size * W * H / 0.41)`. The speed coordinate
/// is the *coherent camera flow* seen at mid depth (`flow / d_mid`,
/// converted to frame-diagonal fractions) — exactly the statistic the
/// runtime extractor's median signed displacement reports, which is
/// what keeps table lookups consistent between calibration and runtime.
/// Pedestrian gait stays at its small natural value in every cell: it
/// cancels in the extractor's median and contributes the same constant
/// staleness everywhere.
pub fn cell_spec(
    size_frac: f64,
    speed_frac: f64,
    frames: u64,
    seed: u64,
) -> SequenceSpec {
    let (w, h) = (CAL_WIDTH as f64, CAL_HEIGHT as f64);
    let diag = (w * w + h * h).sqrt();
    let depth_range = (1.0, 2.0);
    let d_mid = (depth_range.0 + depth_range.1) / 2.0;
    let ref_height = d_mid * (size_frac * w * h / 0.41).sqrt();
    let walk_speed = 1.2;
    // target = coherent flow at mid depth = flow_speed / d_mid
    let flow = speed_frac * diag * d_mid;
    let camera = if flow > 0.05 {
        CameraMotion::Vehicle { flow_speed: flow }
    } else {
        CameraMotion::Static
    };
    SequenceSpec {
        name: format!("CAL-s{size_frac:.4}-v{speed_frac:.4}"),
        width: CAL_WIDTH,
        height: CAL_HEIGHT,
        fps: 30.0,
        frames,
        density: 8,
        ref_height,
        depth_range,
        walk_speed,
        camera,
        seed,
    }
}

/// Run the calibration campaign and return the fitted table.
/// Deterministic in the config (oracle detectors and the latency model
/// are seeded; the latency model runs jitter-free).
pub fn calibrate(cfg: &CalibrationConfig) -> CalibrationTable {
    let n_s = cfg.size_targets.len();
    let n_v = cfg.speed_targets.len();
    let mut ap =
        vec![vec![vec![0.0; n_v]; n_s]; DnnKind::ALL.len()];
    for (si, &size) in cfg.size_targets.iter().enumerate() {
        for (vi, &speed) in cfg.speed_targets.iter().enumerate() {
            let seed = cfg
                .seed
                .wrapping_mul(0x9e3779b97f4a7c15)
                .wrapping_add((si * 101 + vi) as u64);
            let seq =
                Sequence::generate(cell_spec(size, speed, cfg.frames, seed));
            for dnn in DnnKind::ALL {
                let mut det = OracleBackend(OracleDetector::new(
                    seq.spec.seed,
                    seq.spec.width as f64,
                    seq.spec.height as f64,
                ));
                let mut pol = FixedPolicy(dnn);
                let mut lat = LatencyModel::deterministic();
                let r = run_realtime(&seq, &mut pol, &mut det, &mut lat, cfg.fps);
                ap[dnn.index()][si][vi] = r.ap;
            }
        }
    }
    CalibrationTable::new(
        cfg.fps,
        cfg.size_targets.clone(),
        cfg.speed_targets.clone(),
        ap,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_spec_hits_its_targets() {
        let spec = cell_spec(0.012, 0.012, 120, 7);
        let diag = ((CAL_WIDTH * CAL_WIDTH + CAL_HEIGHT * CAL_HEIGHT) as f64)
            .sqrt();
        assert!((spec.nominal_area_frac() - 0.012).abs() < 1e-9);
        // the speed coordinate is the mid-depth coherent flow — the
        // statistic the runtime extractor reports (gait cancels there)
        let d_mid = (spec.depth_range.0 + spec.depth_range.1) / 2.0;
        match spec.camera {
            CameraMotion::Vehicle { flow_speed } => {
                assert!((flow_speed / d_mid / diag - 0.012).abs() < 1e-9);
            }
            other => panic!("expected vehicle flow, got {other:?}"),
        }
    }

    #[test]
    fn zero_speed_cells_use_a_static_camera() {
        let spec = cell_spec(0.01, 0.0, 120, 7);
        assert!(matches!(spec.camera, CameraMotion::Static));
        let fast = cell_spec(0.01, 0.02, 120, 7);
        assert!(matches!(fast.camera, CameraMotion::Vehicle { .. }));
    }

    #[test]
    fn quick_calibration_is_deterministic_and_sane() {
        let cfg = CalibrationConfig::quick(30.0);
        let a = calibrate(&cfg);
        let b = calibrate(&cfg);
        assert_eq!(a, b);
        assert_eq!(a.size_axis, cfg.size_targets);
        assert_eq!(a.speed_axis, cfg.speed_targets);
        assert_eq!(a.n_cells(), 4 * 2 * 2);
        assert!(a.validate().is_ok());
    }

    #[test]
    fn heavy_wins_small_slow_light_wins_large_fast() {
        // the two regimes the paper's Algorithm 1 is built on, measured
        // end to end through the calibration pipeline at 30 FPS
        let cfg = CalibrationConfig {
            fps: 30.0,
            frames: 150,
            size_targets: vec![0.002, 0.07],
            speed_targets: vec![0.0, 0.02],
            seed: 0xca11b,
        };
        let t = calibrate(&cfg);
        // small + slow: Y-416's capacity dominates despite the drops
        assert!(
            t.project(DnnKind::Y416, 0.002, 0.0)
                > t.project(DnnKind::TinyY288, 0.002, 0.0) + 0.05
        );
        // large + fast: the no-drop tiny net dominates the stale heavy
        assert!(
            t.project(DnnKind::TinyY288, 0.07, 0.02)
                > t.project(DnnKind::Y416, 0.07, 0.02) + 0.05
        );
    }
}
