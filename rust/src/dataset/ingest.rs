//! Real-MOT17Det ingestion: load a downloaded MOTChallenge directory
//! (`<root>/<SEQ>/seqinfo.ini` + `<root>/<SEQ>/gt/gt.txt`) into the same
//! [`Sequence`] type the synthetic generator produces, so the entire
//! pipeline — scheduler, evaluator, figures — runs unchanged on the real
//! dataset when it is available.

use std::collections::BTreeMap;
use std::path::Path;

use crate::dataset::mot;
use crate::dataset::synth::{CameraMotion, Sequence, SequenceSpec};

/// Parsed `seqinfo.ini` (the MOTChallenge per-sequence metadata file).
#[derive(Debug, Clone, PartialEq)]
pub struct SeqInfo {
    pub name: String,
    pub frame_rate: f64,
    pub seq_length: u64,
    pub im_width: u32,
    pub im_height: u32,
}

/// Parse the INI subset MOTChallenge uses: `[Sequence]` section with
/// `key=value` lines; comments (`;`/`#`) and blank lines ignored.
pub fn parse_seqinfo(text: &str) -> Result<SeqInfo, String> {
    let mut kv: BTreeMap<String, String> = BTreeMap::new();
    let mut in_sequence = false;
    for raw in text.lines() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with(';') || line.starts_with('#') {
            continue;
        }
        if line.starts_with('[') {
            in_sequence = line.eq_ignore_ascii_case("[sequence]");
            continue;
        }
        if !in_sequence {
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            return Err(format!("bad ini line: {line:?}"));
        };
        kv.insert(k.trim().to_lowercase(), v.trim().to_string());
    }
    let get = |k: &str| -> Result<&String, String> {
        kv.get(k).ok_or_else(|| format!("seqinfo missing {k}"))
    };
    let num = |k: &str| -> Result<f64, String> {
        get(k)?.parse().map_err(|e| format!("seqinfo {k}: {e}"))
    };
    Ok(SeqInfo {
        name: get("name")?.clone(),
        frame_rate: num("framerate")?,
        seq_length: num("seqlength")? as u64,
        im_width: num("imwidth")? as u32,
        im_height: num("imheight")? as u32,
    })
}

/// Load one real sequence directory (`<dir>/seqinfo.ini`,
/// `<dir>/gt/gt.txt`). Ground truth is pre-processed with the paper's
/// flag rules (non-person classes zeroed).
pub fn load_sequence(dir: &Path) -> Result<Sequence, String> {
    let ini_text = std::fs::read_to_string(dir.join("seqinfo.ini"))
        .map_err(|e| format!("{}: {e}", dir.join("seqinfo.ini").display()))?;
    let info = parse_seqinfo(&ini_text)?;
    let entries = mot::read_file(&dir.join("gt").join("gt.txt"))?;
    let entries: Vec<_> = entries
        .into_iter()
        .map(|e| e.preprocess_for_eval())
        .collect();
    let frames = mot::group_by_frame(&entries, info.seq_length);
    Ok(Sequence {
        spec: SequenceSpec {
            name: info.name,
            width: info.im_width,
            height: info.im_height,
            fps: info.frame_rate,
            frames: info.seq_length,
            // world-model parameters are not applicable to real footage;
            // they are recorded as zeros and unused by the schedulers
            density: 0,
            ref_height: 0.0,
            depth_range: (1.0, 1.0),
            walk_speed: 0.0,
            camera: CameraMotion::Static,
            seed: 0,
        },
        frames,
    })
}

/// Load every `MOT*` subdirectory under a MOTChallenge train root.
pub fn load_root(root: &Path) -> Result<Vec<Sequence>, String> {
    let mut out = Vec::new();
    let rd = std::fs::read_dir(root)
        .map_err(|e| format!("{}: {e}", root.display()))?;
    let mut dirs: Vec<_> = rd
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.is_dir()
                && p.file_name()
                    .and_then(|n| n.to_str())
                    .map(|n| n.starts_with("MOT"))
                    .unwrap_or(false)
        })
        .collect();
    dirs.sort();
    for d in dirs {
        out.push(load_sequence(&d)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    const INI: &str = "[Sequence]\nname=MOT17-04\nimDir=img1\n\
                       frameRate=30\nseqLength=1050\nimWidth=1920\n\
                       imHeight=1080\nimExt=.jpg\n";

    #[test]
    fn parses_motchallenge_seqinfo() {
        let info = parse_seqinfo(INI).unwrap();
        assert_eq!(info.name, "MOT17-04");
        assert_eq!(info.frame_rate, 30.0);
        assert_eq!(info.seq_length, 1050);
        assert_eq!(info.im_width, 1920);
        assert_eq!(info.im_height, 1080);
    }

    #[test]
    fn ignores_comments_and_other_sections() {
        let text = format!("; comment\n[Other]\nname=X\n{INI}# trailing\n");
        let info = parse_seqinfo(&text).unwrap();
        assert_eq!(info.name, "MOT17-04");
    }

    #[test]
    fn missing_fields_rejected() {
        assert!(parse_seqinfo("[Sequence]\nname=X\n").is_err());
        assert!(parse_seqinfo("[Sequence]\nbadline\n").is_err());
    }

    #[test]
    fn roundtrip_with_exported_synthetic_sequence() {
        // export a synthetic sequence in MOT layout, load it back via
        // the real-data path, verify identical evaluation inputs
        let seq = crate::dataset::catalog::generate(
            crate::dataset::catalog::SequenceId::Mot09,
        );
        let dir = std::env::temp_dir().join("tod_ingest_rt");
        std::fs::create_dir_all(dir.join("gt")).unwrap();
        std::fs::write(
            dir.join("seqinfo.ini"),
            format!(
                "[Sequence]\nname={}\nframeRate={}\nseqLength={}\n\
                 imWidth={}\nimHeight={}\n",
                seq.spec.name,
                seq.spec.fps,
                seq.n_frames(),
                seq.spec.width,
                seq.spec.height
            ),
        )
        .unwrap();
        mot::write_file(&dir.join("gt").join("gt.txt"), &seq.all_entries())
            .unwrap();
        let loaded = load_sequence(&dir).unwrap();
        assert_eq!(loaded.spec.name, seq.spec.name);
        assert_eq!(loaded.n_frames(), seq.n_frames());
        for f in 1..=seq.n_frames() {
            assert_eq!(loaded.gt(f).len(), seq.gt(f).len(), "frame {f}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
