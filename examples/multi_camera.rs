//! Multi-camera serving: N mall cameras sharing one edge accelerator.
//!
//! The paper serves one camera per Jetson; a deployed system packs many
//! onto one board. This example builds four camera feeds with different
//! scene statistics (so TOD picks different DNN ladders per stream),
//! schedules them over a single virtual accelerator with the
//! contention-aware latency model, and compares round-robin against
//! earliest-deadline-first dispatch.
//!
//! ```bash
//! cargo run --release --example multi_camera
//! ```

use tod::coordinator::multistream::{DispatchPolicy, MultiStreamScheduler};
use tod::coordinator::policy::MbbsPolicy;
use tod::coordinator::scheduler::OracleBackend;
use tod::coordinator::session::StreamSession;
use tod::dataset::synth::{CameraMotion, Sequence, SequenceSpec};
use tod::sim::latency::{ContentionModel, LatencyModel};
use tod::sim::oracle::OracleDetector;
use tod::telemetry::tegrastats::TegrastatsSim;

fn camera(
    name: &str,
    seed: u64,
    ref_height: f64,
    camera: CameraMotion,
) -> Sequence {
    Sequence::generate(SequenceSpec {
        name: name.into(),
        width: 1280,
        height: 720,
        fps: 30.0,
        frames: 450,
        density: 10,
        ref_height,
        depth_range: (1.1, 2.6),
        walk_speed: 1.6,
        camera,
        seed,
    })
}

fn main() {
    // four feeds: entrance (small, far), atrium (mid), food court
    // (close-up, large boxes), parking shuttle (vehicle-mounted)
    let cams = vec![
        camera("ENTRANCE", 21, 140.0, CameraMotion::Static),
        camera("ATRIUM", 22, 260.0, CameraMotion::Static),
        camera("FOODCOURT", 23, 520.0, CameraMotion::Walking {
            pan_speed: 12.0,
        }),
        camera("SHUTTLE", 24, 200.0, CameraMotion::Vehicle {
            flow_speed: 14.0,
        }),
    ];

    for dispatch in [
        DispatchPolicy::RoundRobin,
        DispatchPolicy::EarliestDeadlineFirst,
    ] {
        let mut sched = MultiStreamScheduler::new(
            dispatch,
            ContentionModel::jetson_nano(),
            LatencyModel::deterministic(),
        );
        for cam in &cams {
            let det = OracleBackend(OracleDetector::new(
                cam.spec.seed,
                cam.spec.width as f64,
                cam.spec.height as f64,
            ));
            sched.add_stream(
                StreamSession::new(cam, MbbsPolicy::tod_default(), 30.0),
                Box::new(det),
            );
        }
        let result = sched.run();

        println!("== {dispatch} dispatch ==");
        for r in &result.per_stream {
            let freq = r.deploy_freq();
            println!(
                "  {:<10} AP {:.3} | drop {:>5.1}% | tiny-DNN share {:>5.1}%",
                r.sequence,
                r.ap,
                r.drop_rate() * 100.0,
                (freq[0] + freq[1]) * 100.0
            );
        }
        println!("  {}", result.utilisation.report());
        let sim = TegrastatsSim::default();
        println!(
            "  board: mean power {:.1} W, mean GPU {:.1}%\n",
            sim.mean_power(&result.utilisation.merged),
            sim.mean_gpu(&result.utilisation.merged)
        );
    }
}
