//! Trace rendering for external viewers (DESIGN.md §15).
//!
//! [`chrome_trace`] converts a recorded event stream into Chrome
//! trace-event JSON (`chrome://tracing` / Perfetto): each matched
//! span open/close pair becomes a complete (`"X"`) slice on its
//! stream's track, and the point events that explain behaviour —
//! drops, sheds, budget clamps, SLO transitions — become thread-scoped
//! instants (`"i"`). [`flamegraph`] renders the same spans as
//! collapsed stacks (`stream_0;frame;inference 25000`) for standard
//! flamegraph tooling, weighted by self-time microseconds.
//!
//! Both renderings are pure functions of the event stream with
//! deterministic iteration order, so a fixed seed produces
//! byte-identical output (`tod trace export --chrome`,
//! `tod trace flame`) — pinned by tests.

use std::collections::BTreeMap;

use crate::obs::span::SpanKind;
use crate::obs::Event;
use crate::util::json::Json;

/// Virtual seconds → Chrome trace microseconds.
fn us(t: f64) -> f64 {
    t * 1e6
}

/// Render events as a Chrome trace-event JSON object
/// (`{"traceEvents": [...]}`). Spans become `"X"` complete slices in
/// close order; explanatory point events become `"i"` instants in
/// emission order. `pid` is always 0; `tid` is the stream id.
pub fn chrome_trace(events: &[Event]) -> Json {
    // (stream, span id) -> (open time, kind, frame)
    let mut open: BTreeMap<(u32, u32), (f64, SpanKind, u64)> =
        BTreeMap::new();
    let mut slices: Vec<Json> = Vec::new();
    for ev in events {
        match *ev {
            Event::SpanOpen { stream, frame, span, kind, t, .. } => {
                open.insert((stream, span), (t, kind, frame));
            }
            Event::SpanClose { stream, span, t } => {
                let Some((t0, kind, frame)) = open.remove(&(stream, span))
                else {
                    continue;
                };
                slices.push(Json::obj(vec![
                    ("ph", Json::str("X")),
                    ("name", Json::str(kind.label())),
                    ("pid", Json::num(0.0)),
                    ("tid", Json::num(stream as f64)),
                    ("ts", Json::num(us(t0))),
                    ("dur", Json::num(us((t - t0).max(0.0)))),
                    (
                        "args",
                        Json::obj(vec![
                            ("frame", Json::num(frame as f64)),
                            ("span", Json::num(span as f64)),
                        ]),
                    ),
                ]));
            }
            Event::FrameDropped { stream, frame, t, busy_until } => {
                slices.push(instant(
                    "frame_dropped",
                    stream,
                    t,
                    vec![
                        ("busy_until", Json::num(us(busy_until))),
                        ("frame", Json::num(frame as f64)),
                    ],
                ));
            }
            Event::BatchShed { stream, frame, t } => {
                slices.push(instant(
                    "batch_shed",
                    stream,
                    t,
                    vec![("frame", Json::num(frame as f64))],
                ));
            }
            Event::BudgetClamp { stream, t, requested, granted, .. } => {
                slices.push(instant(
                    "budget_clamp",
                    stream,
                    t,
                    vec![
                        ("granted", Json::str(granted.artifact_name())),
                        ("requested", Json::str(requested.artifact_name())),
                    ],
                ));
            }
            Event::SloBreach { stream, t, signal, value, limit }
            | Event::SloRecovered { stream, t, signal, value, limit } => {
                slices.push(instant(
                    ev.type_tag(),
                    stream,
                    t,
                    vec![
                        ("limit", Json::num(limit)),
                        ("signal", Json::str(signal.label())),
                        ("value", Json::num(value)),
                    ],
                ));
            }
            _ => {}
        }
    }
    Json::obj(vec![("traceEvents", Json::arr(slices))])
}

fn instant(name: &str, stream: u32, t: f64, args: Vec<(&str, Json)>) -> Json {
    Json::obj(vec![
        ("ph", Json::str("i")),
        ("name", Json::str(name)),
        ("pid", Json::num(0.0)),
        ("tid", Json::num(stream as f64)),
        ("ts", Json::num(us(t))),
        ("s", Json::str("t")),
        ("args", Json::obj(args)),
    ])
}

/// Render spans as collapsed flamegraph stacks: one line per unique
/// stack path, `stream_<id>;<kind>;...;<kind> <self µs>`, sorted by
/// path. Weights are self-time microseconds (children subtracted),
/// rounded to whole µs; zero-weight paths are kept so zero-width
/// instants (the selector stages) still show up in the graph.
pub fn flamegraph(events: &[Event]) -> String {
    // per stream: stack of (span id, kind, open t, child seconds)
    let mut stacks: BTreeMap<u32, Vec<(u32, SpanKind, f64, f64)>> =
        BTreeMap::new();
    let mut folded: BTreeMap<String, f64> = BTreeMap::new();
    for ev in events {
        match *ev {
            Event::SpanOpen { stream, span, kind, t, .. } => {
                stacks.entry(stream).or_default().push((span, kind, t, 0.0));
            }
            Event::SpanClose { stream, span, t } => {
                let Some(stack) = stacks.get_mut(&stream) else {
                    continue;
                };
                // mismatched closes are a validate_spans error; the
                // export just skips them
                if stack.last().map(|&(id, ..)| id) != Some(span) {
                    continue;
                }
                let Some((_, kind, t0, child_s)) = stack.pop() else {
                    continue;
                };
                let total = (t - t0).max(0.0);
                if let Some(parent) = stack.last_mut() {
                    parent.3 += total;
                }
                let mut path = format!("stream_{stream}");
                for &(_, k, ..) in stack.iter() {
                    path.push(';');
                    path.push_str(k.label());
                }
                path.push(';');
                path.push_str(kind.label());
                *folded.entry(path).or_insert(0.0) +=
                    (total - child_s).max(0.0);
            }
            _ => {}
        }
    }
    let mut out = String::new();
    for (path, self_s) in &folded {
        out.push_str(path);
        out.push(' ');
        out.push_str(&format!("{}", (self_s * 1e6).round() as u64));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn open(
        stream: u32,
        frame: u64,
        span: u32,
        parent: u32,
        kind: SpanKind,
        t: f64,
    ) -> Event {
        Event::SpanOpen { stream, frame, span, parent, kind, t }
    }

    fn close(stream: u32, span: u32, t: f64) -> Event {
        Event::SpanClose { stream, span, t }
    }

    fn sample_trace() -> Vec<Event> {
        vec![
            open(0, 0, 1, 0, SpanKind::Stream, 0.0),
            open(0, 3, 2, 1, SpanKind::Frame, 0.1),
            open(0, 3, 3, 2, SpanKind::Inference, 0.1),
            close(0, 3, 0.35),
            close(0, 2, 0.35),
            Event::FrameDropped {
                stream: 0,
                frame: 4,
                t: 0.4,
                busy_until: 0.5,
            },
            close(0, 1, 1.0),
        ]
    }

    #[test]
    fn chrome_trace_emits_slices_and_instants() {
        let v = chrome_trace(&sample_trace());
        let evs = v.get("traceEvents").and_then(Json::as_arr).unwrap();
        // 3 spans + 1 drop instant
        assert_eq!(evs.len(), 4);
        // slices appear in close order: inference first
        let first = &evs[0];
        assert_eq!(first.get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(
            first.get("name").and_then(Json::as_str),
            Some("inference")
        );
        assert_eq!(first.get("ts").and_then(Json::as_f64), Some(100000.0));
        assert_eq!(first.get("dur").and_then(Json::as_f64), Some(250000.0));
        assert_eq!(first.get("tid").and_then(Json::as_f64), Some(0.0));
        assert_eq!(
            first.at(&["args", "frame"]).and_then(Json::as_f64),
            Some(3.0)
        );
        let drop = &evs[2];
        assert_eq!(drop.get("ph").and_then(Json::as_str), Some("i"));
        assert_eq!(
            drop.get("name").and_then(Json::as_str),
            Some("frame_dropped")
        );
        // stream envelope closes last
        let last = &evs[3];
        assert_eq!(last.get("name").and_then(Json::as_str), Some("stream"));
        assert_eq!(last.get("dur").and_then(Json::as_f64), Some(1e6));
    }

    #[test]
    fn chrome_trace_is_byte_identical_for_the_same_events() {
        let a = chrome_trace(&sample_trace()).to_string();
        let b = chrome_trace(&sample_trace()).to_string();
        assert_eq!(a, b);
        assert!(a.starts_with("{\"traceEvents\":["));
    }

    #[test]
    fn flamegraph_folds_self_time_by_stack_path() {
        let out = flamegraph(&sample_trace());
        let lines: Vec<&str> = out.lines().collect();
        // sorted by path: stream < stream;frame < stream;frame;inference
        assert_eq!(
            lines,
            vec![
                // stream self = 1.0 - 0.25 frame
                "stream_0;stream 750000",
                // frame self = 0.25 - 0.25 inference
                "stream_0;stream;frame 0",
                "stream_0;stream;frame;inference 250000",
            ]
        );
    }

    #[test]
    fn exports_skip_unmatched_closes_and_non_span_events() {
        let evs = vec![
            close(0, 9, 0.5),
            Event::FramePresented { stream: 0, frame: 1, t: 0.0 },
        ];
        let v = chrome_trace(&evs);
        assert_eq!(
            v.get("traceEvents").and_then(Json::as_arr).map(<[Json]>::len),
            Some(0)
        );
        assert_eq!(flamegraph(&evs), "");
    }
}
