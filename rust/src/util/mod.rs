//! Small self-contained utilities the rest of the system builds on.
//!
//! This crate builds fully offline against the vendored `xla` dependency
//! closure, so the usual ecosystem crates (rand, serde, serde_json, csv,
//! prettytable) are reimplemented here at the scale this project needs —
//! see DESIGN.md §3 "Offline-environment substitutions".

pub mod csv;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
